"""L2/AOT correctness: graph shapes, HLO-text emission, manifest schema.

Verifies exactly what the rust runtime depends on: every artifact lowers to
parseable HLO text with the declared entry shapes, f32 everywhere, and the
manifest enumerates it faithfully.
"""

import json
import os
import re
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_dist_graph_shapes():
    q = jnp.zeros((32, 24), jnp.float32)
    c = jnp.zeros((256, 24), jnp.float32)
    (out,) = model.dist_graph(q, c)
    assert out.shape == (32, 256) and out.dtype == jnp.float32


def test_topk_graph_shapes():
    fn = model.make_dist_topk_graph(64)
    q = jnp.zeros((128, 32), jnp.float32)
    c = jnp.ones((512, 32), jnp.float32)
    v, i = fn(q, c)
    assert v.shape == (128, 64) and i.shape == (128, 64)
    assert v.dtype == jnp.float32 and i.dtype == jnp.int32


def test_hist_graph_shapes():
    q = jnp.zeros((128, 96), jnp.float32)
    c = jnp.ones((1024, 96), jnp.float32)
    edges2 = jnp.linspace(1.0, 10.0, 64)
    counts, dsum, npair = model.hist_graph(q, c, edges2)
    assert counts.shape == (64,) and dsum.shape == (1,) and npair.shape == (1,)


def test_to_hlo_text_structure():
    """The emitted text must be an HLO module with an ENTRY computation and
    a tuple root - the exact contract HloModuleProto::from_text expects."""
    lowered = jax.jit(model.dist_graph).lower(
        aot.f32(32, 24), aot.f32(256, 24)
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True => root is a tuple of one f32[32,256]
    assert re.search(r"\(f32\[32,256\]", text) or "tuple(" in text


def test_build_artifacts_enumeration():
    arts = list(aot.build_artifacts())
    names = [a[0] for a in arts]
    assert len(names) == len(set(names)), "artifact names unique"
    # every family present for every dim
    for d in aot.DIMS:
        assert f"dist_q128_c512_d{d}" in names
        assert f"dist_q32_c256_d{d}" in names
        assert f"disttopk_q128_c512_d{d}_k{aot.TOPK_K}" in names
        assert f"hist_s{aot.HIST_S}_c{aot.HIST_CT}_d{d}_b{aot.HIST_BINS}" in names


def test_manifest_matches_tree():
    """If artifacts/ has been built (make artifacts), the manifest must list
    exactly the .hlo.txt files present."""
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art_dir, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built")
    with open(manifest_path) as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text"
    listed = {a["file"] for a in manifest["artifacts"]}
    on_disk = {p for p in os.listdir(art_dir) if p.endswith(".hlo.txt")}
    assert listed == on_disk
    for a in manifest["artifacts"]:
        assert a["kind"] in ("dist", "disttopk", "hist")
        text = open(os.path.join(art_dir, a["file"])).read(64)
        assert text.startswith("HloModule")
