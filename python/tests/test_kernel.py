"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes/value scales; every property asserts
allclose against ref.py - the CORE correctness signal for the kernels the
rust runtime will execute as AOT HLO.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.dist_tile import PAD_SENTINEL, dist_tile
from compile.kernels.hist_tile import hist_tile
from compile.kernels.ref import ref_dist, ref_hist, ref_topk
from compile.model import dist_graph, hist_graph, make_dist_topk_graph

# interpret-mode pallas on CPU: generous-but-tight f32 tolerances for the
# matmul (vs subtract-square) distance formulation.
RTOL, ATOL = 3e-4, 5e-4


def rnd(rng, *shape, scale=1.0, dtype=np.float32):
    return (rng.standard_normal(shape) * scale).astype(dtype)


@st.composite
def tile_shapes(draw):
    qt = draw(st.sampled_from([1, 3, 8, 32, 128]))
    ct = draw(st.sampled_from([1, 2, 16, 64, 256, 512]))
    d = draw(st.sampled_from([1, 2, 8, 24, 96]))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = draw(st.sampled_from([1e-2, 1.0, 1e2]))
    return qt, ct, d, seed, scale


@settings(max_examples=40, deadline=None)
@given(tile_shapes())
def test_dist_tile_matches_ref(shape):
    qt, ct, d, seed, scale = shape
    rng = np.random.default_rng(seed)
    q = rnd(rng, qt, d, scale=scale)
    c = rnd(rng, ct, d, scale=scale)
    got = np.asarray(dist_tile(jnp.asarray(q), jnp.asarray(c)))
    want = np.asarray(ref_dist(jnp.asarray(q), jnp.asarray(c)))
    # scale-aware tolerance: dist2 ~ scale^2
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL * scale * scale)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([np.float64, np.float32]))
def test_dist_tile_dtype_coercion(seed, dtype):
    """Inputs in other dtypes are coerced to the f32 artifact contract."""
    rng = np.random.default_rng(seed)
    q = rnd(rng, 4, 8, dtype=dtype)
    c = rnd(rng, 8, 8, dtype=dtype)
    got = np.asarray(dist_tile(jnp.asarray(q), jnp.asarray(c)))
    assert got.dtype == np.float32
    want = np.asarray(ref_dist(jnp.asarray(q), jnp.asarray(c)))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_dist_tile_zero_distance_diagonal():
    rng = np.random.default_rng(7)
    q = rnd(rng, 16, 24)
    got = np.asarray(dist_tile(jnp.asarray(q), jnp.asarray(q)))
    # matmul formulation: diagonal is ~0 (not exactly 0); symmetric.
    assert np.all(np.abs(np.diag(got)) < 1e-3)
    np.testing.assert_allclose(got, got.T, rtol=1e-5, atol=1e-3)


def test_dist_tile_pad_sentinel_dominates():
    """Padded candidates (sentinel coords) must sort after all real ones."""
    rng = np.random.default_rng(8)
    q = rnd(rng, 8, 24, scale=10.0)
    c = rnd(rng, 12, 24, scale=10.0)
    pad = np.full((4, 24), PAD_SENTINEL, dtype=np.float32)
    cp = np.concatenate([c, pad])
    got = np.asarray(dist_tile(jnp.asarray(q), jnp.asarray(cp)))
    assert np.all(got[:, 12:] > 1e20)
    assert np.all(np.isfinite(got[:, :12]))


@settings(max_examples=25, deadline=None)
@given(
    st.sampled_from([1, 4, 8]),
    st.sampled_from([16, 64, 256]),
    st.sampled_from([2, 24]),
    st.sampled_from([1, 5, 16]),
    st.integers(0, 2**31 - 1),
)
def test_topk_graph_matches_ref(qt, ct, d, k, seed):
    rng = np.random.default_rng(seed)
    q = rnd(rng, qt, d)
    c = rnd(rng, ct, d)
    fn = make_dist_topk_graph(k)
    v, i = fn(jnp.asarray(q), jnp.asarray(c))
    rv, ri = ref_topk(jnp.asarray(q), jnp.asarray(c), k)
    v, i, rv = np.asarray(v), np.asarray(i), np.asarray(rv)
    np.testing.assert_allclose(v, rv, rtol=RTOL, atol=ATOL)
    assert i.dtype == np.int32
    # values ascending per row
    assert np.all(np.diff(v, axis=1) >= -ATOL)
    # indices consistent with values they claim
    d2 = np.asarray(ref_dist(jnp.asarray(q), jnp.asarray(c)))
    np.testing.assert_allclose(
        np.take_along_axis(d2, i.astype(np.int64), axis=1), v, rtol=RTOL, atol=ATOL
    )


@settings(max_examples=25, deadline=None)
@given(
    st.sampled_from([2, 8, 32]),
    st.sampled_from([16, 64, 512]),
    st.sampled_from([2, 24, 96]),
    st.sampled_from([4, 16, 64]),
    st.integers(0, 2**31 - 1),
)
def test_hist_tile_matches_ref(s, ct, d, nbins, seed):
    rng = np.random.default_rng(seed)
    q = rnd(rng, s, d)
    c = rnd(rng, ct, d)
    hi = float(np.quantile(np.asarray(ref_dist(jnp.asarray(q), jnp.asarray(c))), 0.9))
    edges2 = np.linspace(hi / nbins, hi, nbins).astype(np.float32)
    got = hist_tile(jnp.asarray(q), jnp.asarray(c), jnp.asarray(edges2))
    want = ref_hist(jnp.asarray(q), jnp.asarray(c), jnp.asarray(edges2))
    for g, w in zip(got, want):
        g, w = np.asarray(g), np.asarray(w)
        # counts near bin edges may differ by a few pairs due to the matmul
        # rounding of dist2; allow a sliver of slack, exact otherwise.
        np.testing.assert_allclose(g, w, rtol=1e-3, atol=max(2.0, 1e-3 * s * ct))


def test_hist_counts_monotone_nondecreasing():
    rng = np.random.default_rng(11)
    q = rnd(rng, 16, 24)
    c = rnd(rng, 128, 24)
    edges2 = np.linspace(0.5, 80.0, 32).astype(np.float32)
    counts, dsum, npair = hist_tile(jnp.asarray(q), jnp.asarray(c), jnp.asarray(edges2))
    counts = np.asarray(counts)
    assert np.all(np.diff(counts) >= 0), "cumulative histogram must be monotone"
    assert float(np.asarray(npair)[0]) == 16 * 128
    assert float(np.asarray(dsum)[0]) > 0


def test_hist_duplicate_points_tolerated():
    """Self-pairs (exact duplicates) are excluded only approximately under
    the matmul formulation - the estimator tolerates O(#dups) slack."""
    rng = np.random.default_rng(12)
    q = rnd(rng, 8, 24)
    c = np.concatenate([q[:4], rnd(rng, 28, 24)])
    edges2 = np.linspace(0.5, 80.0, 16).astype(np.float32)
    got = np.asarray(hist_tile(jnp.asarray(q), jnp.asarray(c), jnp.asarray(edges2))[0])
    want = np.asarray(ref_hist(jnp.asarray(q), jnp.asarray(c), jnp.asarray(edges2))[0])
    assert np.all(np.abs(got - want) <= 4), "slack bounded by duplicate count"
