"""L1 Pallas kernel: cumulative distance histogram for epsilon selection.

Implements the sampling kernel of paper Sec. V-C2: given a sample of query
points and a chunk of the dataset, count how many pairwise distances fall at
or below each bin edge (the paper's cumulative counts B^c_d). The rust
coordinator sums tile results over dataset chunks and derives
eps_default / eps_beta from the cumulative curve.

Grid = candidate blocks; the (NBINS,) output is accumulated across grid
steps (initialised at step 0), the standard Pallas reduction pattern.
Distances are compared *squared* against squared edges - no sqrt on the
device, monotonicity preserves bin assignment.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dist_tile import _pick_block


def _hist_block_kernel(q_ref, c_ref, edges2_ref, cnt_ref, sum_ref, npair_ref):
    """Accumulate cumulative-histogram counts for one candidate block.

    q_ref:      (S, D) sample queries, resident.
    c_ref:      (CT_BLK, D) candidate block.
    edges2_ref: (NBINS,) squared bin edges (ascending).
    cnt_ref:    (NBINS,) f32 accumulator - #pairs with dist2 <= edge2[b].
    sum_ref:    (1,) f32 accumulator - sum of sqrt(dist2) of pairs below the
                last edge (used for eps_mean refinement / diagnostics).
    npair_ref:  (1,) f32 accumulator - #non-self pairs considered.
    """
    q = q_ref[...]
    c = c_ref[...]
    qn = jnp.sum(q * q, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1, keepdims=True)
    d2 = qn + cn.T - 2.0 * jnp.dot(q, c.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(d2, 0.0)
    # Exclude self-pairs (exact zero distance) like the paper's estimator,
    # which samples "distances between points" (a point is not its own
    # neighbor in the KNN semantics of Sec. III).
    valid = d2 > 0.0
    edges2 = edges2_ref[...]
    # (S, CT_BLK, NBINS) one-shot comparison: small enough per block.
    below = (d2[:, :, None] <= edges2[None, None, :]) & valid[:, :, None]
    counts = jnp.sum(below.astype(jnp.float32), axis=(0, 1))
    in_range = valid & (d2 <= edges2[-1])
    dsum = jnp.sum(jnp.where(in_range, jnp.sqrt(d2), 0.0))
    npair = jnp.sum(valid.astype(jnp.float32))

    @pl.when(pl.program_id(0) == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)
        npair_ref[...] = jnp.zeros_like(npair_ref)

    cnt_ref[...] += counts
    sum_ref[...] += dsum[None]
    npair_ref[...] += npair[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def hist_tile(
    q: jax.Array, c: jax.Array, edges2: jax.Array, *, interpret: bool = True
):
    """Cumulative histogram of pair distances (squared-edge comparison).

    q: (S, D) sample queries; c: (CT, D) dataset chunk; edges2: (NBINS,)
    ascending squared bin edges. Returns (counts (NBINS,), dist_sum (1,),
    n_pairs (1,)) - all f32 (counts are exact integers in f32 range).
    """
    s, d = q.shape
    ct, d2_ = c.shape
    assert d == d2_, f"dim mismatch {d} vs {d2_}"
    (nbins,) = edges2.shape
    blk = _pick_block(ct)
    grid = (ct // blk,)
    return pl.pallas_call(
        _hist_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s, d), lambda i: (0, 0)),
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((nbins,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((nbins,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbins,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q.astype(jnp.float32), c.astype(jnp.float32), edges2.astype(jnp.float32))
