"""Pure-jnp oracles for the Pallas kernels (pytest compares against these).

No pallas, no tiling - the straightforward O(S*CT) formulations used as the
numerical ground truth for dist_tile / hist_tile and the L2 graphs.
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_dist(q, c):
    """(QT, D) x (CT, D) -> (QT, CT) squared Euclidean distances."""
    q = q.astype(jnp.float32)
    c = c.astype(jnp.float32)
    diff = q[:, None, :] - c[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def ref_topk(q, c, k):
    """k smallest squared distances per query: (vals asc, idx), int32 idx."""
    d2 = ref_dist(q, c)
    order = jnp.argsort(d2, axis=1)[:, :k]
    vals = jnp.take_along_axis(d2, order, axis=1)
    return vals, order.astype(jnp.int32)


def ref_hist(q, c, edges2):
    """Cumulative counts of non-self pairs with dist2 <= edge, plus the sum
    of in-range true distances and the number of non-self pairs."""
    d2 = jnp.maximum(ref_dist(q, c), 0.0)
    valid = d2 > 0.0
    below = (d2[:, :, None] <= edges2[None, None, :]) & valid[:, :, None]
    counts = jnp.sum(below.astype(jnp.float32), axis=(0, 1))
    in_range = valid & (d2 <= edges2[-1])
    dsum = jnp.sum(jnp.where(in_range, jnp.sqrt(d2), 0.0))
    npair = jnp.sum(valid.astype(jnp.float32))
    return counts, dsum[None], npair[None]
