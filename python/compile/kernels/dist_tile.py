"""L1 Pallas kernel: tiled pairwise squared-Euclidean distances.

The hot spot of GPU-JOIN / GPU-JOINLINEAR (paper Alg. 1, line 26,
``calcDistancePts``) recast for the TPU: instead of one CUDA thread per
(query, candidate-chunk) we tile the computation for VMEM and express the
inner product as a matmul so it lands on the MXU systolic array:

    dist2[i, j] = ||q_i||^2 + ||c_j||^2 - 2 * <q_i, c_j>

The candidate axis is the Pallas grid: each program instance streams one
(CT_BLK, D) candidate block HBM->VMEM while the (QT, D) query tile stays
resident, which is the BlockSpec analogue of the paper's
"many threads per query point" granularity scheme (Sec. V-G).

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO that the rust
runtime executes. Real-TPU perf is estimated in DESIGN.md Sec. 7.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sentinel coordinate used by the rust coordinator to pad candidate tiles.
# Finite (not +inf) so norms stay finite in f32: 520 dims * (1e15)^2 =
# 5.2e32 < f32 max. Any padded pair distance ~1e30 fails every eps test.
PAD_SENTINEL = 1.0e15


def _dist_block_kernel(q_ref, c_ref, o_ref):
    """One grid step: distances from the resident query tile to one
    candidate block.

    q_ref: (QT, D) f32 in VMEM (same block every step)
    c_ref: (CT_BLK, D) f32 in VMEM (block `pl.program_id(0)`)
    o_ref: (QT, CT_BLK) f32 in VMEM
    """
    q = q_ref[...]
    c = c_ref[...]
    qn = jnp.sum(q * q, axis=1, keepdims=True)  # (QT, 1)
    cn = jnp.sum(c * c, axis=1, keepdims=True)  # (CT_BLK, 1)
    # MXU-formulated cross term; preferred_element_type keeps f32 accumulate.
    cross = jnp.dot(q, c.T, preferred_element_type=jnp.float32)
    o_ref[...] = qn + cn.T - 2.0 * cross


def _pick_block(ct: int) -> int:
    """Largest candidate block <= 256 dividing ct (VMEM-friendly)."""
    for blk in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if ct % blk == 0:
            return blk
    return ct


@functools.partial(jax.jit, static_argnames=("interpret",))
def dist_tile(q: jax.Array, c: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Squared distances between every row of ``q`` (QT, D) and ``c`` (CT, D).

    Returns (QT, CT) f32. Grid iterates candidate blocks; the query tile is
    re-used every step (index_map pins block 0), i.e. it stays in VMEM.
    """
    qt, d = q.shape
    ct, d2 = c.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    blk = _pick_block(ct)
    grid = (ct // blk,)
    return pl.pallas_call(
        _dist_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((qt, d), lambda i: (0, 0)),
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((qt, blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((qt, ct), jnp.float32),
        interpret=interpret,
    )(q.astype(jnp.float32), c.astype(jnp.float32))
