"""AOT: lower the L2 graphs to HLO *text* artifacts + a manifest.

HLO text (NOT lowered.compiler_ir("hlo") protos / .serialize()) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the rust side's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Artifact families (all f32, shape-static):
  dist_q{QT}_c{CT}_d{D}            -> (dist2 [QT,CT],)
  disttopk_q{QT}_c{CT}_d{D}_k{K}   -> (dist2 [QT,K] asc, idx i32 [QT,K])
  hist_s{S}_c{CT}_d{D}_b{B}        -> (counts [B], dsum [1], npairs [1])

manifest.json records every artifact's name, file, kind, and shapes so the
rust runtime can pick tiles without hard-coding. `make artifacts` is a no-op
when inputs are older than the manifest (handled in the Makefile).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Tile configurations. Dims cover the four surrogate datasets after padding
# to a multiple of 8 (18->24, 32->32, 90->96, 518->520) plus the generic
# low-dim examples (d<=24 pads into 24).
DIMS = (24, 32, 96, 520)
DIST_TILES = ((128, 512), (32, 256))  # (QT, CT)
TOPK_TILES = ((128, 512),)
TOPK_K = 64
HIST_S = 64
HIST_CT = 512
HIST_BINS = 64


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe round trip)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_artifacts():
    """Yield (name, kind, arg_shapes, out_shapes, lowered)."""
    for d in DIMS:
        for qt, ct in DIST_TILES:
            name = f"dist_q{qt}_c{ct}_d{d}"
            lowered = jax.jit(model.dist_graph).lower(f32(qt, d), f32(ct, d))
            yield (
                name,
                "dist",
                {"qt": qt, "ct": ct, "d": d},
                [[qt, ct]],
                lowered,
            )
        for qt, ct in TOPK_TILES:
            k = TOPK_K
            name = f"disttopk_q{qt}_c{ct}_d{d}_k{k}"
            fn = model.make_dist_topk_graph(k)
            lowered = jax.jit(fn).lower(f32(qt, d), f32(ct, d))
            yield (
                name,
                "disttopk",
                {"qt": qt, "ct": ct, "d": d, "k": k},
                [[qt, k], [qt, k]],
                lowered,
            )
        name = f"hist_s{HIST_S}_c{HIST_CT}_d{d}_b{HIST_BINS}"
        lowered = jax.jit(model.hist_graph).lower(
            f32(HIST_S, d), f32(HIST_CT, d), f32(HIST_BINS)
        )
        yield (
            name,
            "hist",
            {"s": HIST_S, "ct": HIST_CT, "d": d, "bins": HIST_BINS},
            [[HIST_BINS], [1], [1]],
            lowered,
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "dtype": "f32", "artifacts": []}
    for name, kind, params, out_shapes, lowered in build_artifacts():
        path = f"{name}.hlo.txt"
        text = to_hlo_text(lowered)
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": path,
                "kind": kind,
                "params": params,
                "out_shapes": out_shapes,
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
