"""L2: the JAX compute graphs AOT-exported for the rust coordinator.

Each graph composes the L1 Pallas kernels (kernels/) with the surrounding
jnp glue the paper's GPU component needs:

  dist      - raw (QT, CT) squared-distance tile (GPU-JOIN filter path and
              GPU-JOINLINEAR brute-force lower bound).
  dist_topk - distance tile + on-device k-smallest selection (lax.top_k on
              negated distances). The perf-optimised GPU-JOIN path: the host
              merges (QT, KMAX) instead of scanning (QT, CT).
  hist      - cumulative distance histogram + mean-distance accumulators for
              the empirical epsilon selection of Sec. V-C2.

Everything is shape-static (PJRT AOT requirement); the rust runtime pads
queries/candidates to the artifact tile shape using dist_tile.PAD_SENTINEL
coordinates and post-filters.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.dist_tile import dist_tile
from .kernels.hist_tile import hist_tile

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see kernels/.


def dist_graph(q, c):
    """(QT, D), (CT, D) -> 1-tuple of (QT, CT) squared distances."""
    return (dist_tile(q, c, interpret=INTERPRET),)


def make_dist_topk_graph(k: int):
    """Distance tile + k-smallest selection (ascending).

    NOTE: formulated as lax.sort + slice rather than lax.top_k - jax lowers
    top_k to the `topk(..., largest=true)` HLO instruction, which the rust
    side's xla_extension 0.5.1 text parser rejects; `sort` round-trips.
    """

    def dist_topk(q, c):
        d2 = dist_tile(q, c, interpret=INTERPRET)
        ct = d2.shape[1]
        idx = jnp.broadcast_to(jnp.arange(ct, dtype=jnp.int32), d2.shape)
        sv, si = jax.lax.sort((d2, idx), dimension=1, num_keys=1)
        return (sv[:, :k], si[:, :k])

    return dist_topk


def hist_graph(q, c, edges2):
    """Cumulative histogram for epsilon selection; see hist_tile."""
    counts, dsum, npair = hist_tile(q, c, edges2, interpret=INTERPRET)
    return (counts, dsum, npair)
