//! Fig. 10: rho^Model vs K for all datasets.
use hybrid_knn_join::bench::{experiments, workloads};
use hybrid_knn_join::runtime::Engine;

fn main() {
    let engine = Engine::load_default().expect("make artifacts");
    let t = experiments::fig10(
        &engine,
        &workloads(),
        &[1, 2, 4, 8, 16, 25, 32, 48, 64],
        0.2,
    )
    .unwrap();
    println!("{}", t.render());
}
