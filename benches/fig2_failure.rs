//! Fig. 2: analytic KNN-failure fraction under a fixed result budget.
use hybrid_knn_join::bench::experiments;

fn main() {
    println!("{}", experiments::fig2(5).render());
}
