//! Online-service bench: resident-engine streaming micro-batches vs the
//! one-shot batch flush, with per-request latency percentiles
//! (DESIGN.md §11).
//!
//! One resident [`KnnEngine`] serves every scenario, so arenas, the
//! brute-tier tile cache, and the PJRT executable cache stay warm - the
//! production shape:
//!
//! * `batch` - the whole query pool in a single flush (the amortization
//!   ceiling every streaming case is measured against);
//! * `clients_{1,2,4}` - closed-loop streaming: each client submits its
//!   next batch the moment the previous reply lands, so the ingress
//!   coalesces under maximum pressure;
//! * `open_loop` - clients submit on a timer at ~60% of the measured
//!   closed-loop throughput: the controlled-load tail-latency view.
//!
//! Tracked columns are same-run ratios (machine-portable):
//! `stream_vs_batch` = streaming throughput / batch-flush throughput
//! (floors how much the ingress+flush cycle may cost over one giant
//! batch) and `p99_fairness` = wall / (requests-per-client x p99)
//! (floors the tail: ~1.0 when request latencies are uniform, collapsing
//! toward 1/requests when one straggler dominates the run). Before any
//! JSON is written, a deterministic-mode spot check asserts streamed
//! results are bit-identical to the one-shot batch flush on the same
//! queries. Emits `BENCH_service.json`, regression-gated against
//! `benches/baselines/BENCH_service.json` in CI.
//!
//!   cargo bench --bench service
//!   HKNN_RANKS=8 cargo bench --bench service

use hybrid_knn_join::prelude::*;
use hybrid_knn_join::util::json::Json;

const REQUESTS: usize = 6;
const BATCH: usize = 64;

/// Closed-loop (interval = 0) or open-loop streaming of contiguous
/// request slices of `pool` through the resident session.
fn run_case(
    session: &mut KnnEngine,
    pool: &Dataset,
    clients: usize,
    interval: f64,
) -> ServiceReport {
    let ingress = Ingress::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let client = ingress.client();
                s.spawn(move || {
                    for r in 0..REQUESTS {
                        if interval > 0.0 {
                            std::thread::sleep(
                                std::time::Duration::from_secs_f64(interval),
                            );
                        }
                        let start = (c * REQUESTS + r) * BATCH;
                        let rows: Vec<usize> =
                            (start..start + BATCH).collect();
                        if client.query(&pool.gather(&rows)).is_err() {
                            break;
                        }
                    }
                })
            })
            .collect();
        let rep = session.serve(&ingress).expect("serve loop");
        for h in handles {
            h.join().expect("client thread panicked");
        }
        rep
    })
}

/// Deterministic-replay spot check: two streamed chunks must be
/// bit-identical to the one-shot flush of the same queries.
fn verify_stream_equals_batch(engine: &Engine, corpus: &Dataset, pool: &Dataset) {
    let mut p = HybridParams::new(6);
    p.cpu_ranks = 0;
    let sub = pool.gather(&(0..128).collect::<Vec<_>>());
    let mut one_shot = KnnEngine::build(engine, corpus, p.clone()).unwrap();
    let (want, _) = one_shot.flush(&sub).unwrap();
    let mut streamed = KnnEngine::build(engine, corpus, p).unwrap();
    let ingress = Ingress::new();
    let replies = std::thread::scope(|s| {
        let client = ingress.client();
        let sub = &sub;
        let h = s.spawn(move || {
            let a = client
                .query(&sub.gather(&(0..50).collect::<Vec<_>>()))
                .unwrap();
            let b = client
                .query(&sub.gather(&(50..128).collect::<Vec<_>>()))
                .unwrap();
            (a, b)
        });
        streamed.serve(&ingress).expect("serve loop");
        h.join().expect("client thread panicked")
    });
    let got: Vec<QueryResult> = replies
        .0
        .results
        .into_iter()
        .chain(replies.1.results)
        .collect();
    assert_eq!(got.len(), sub.len());
    for (q, g) in got.iter().enumerate() {
        let w = want.get(q);
        assert_eq!(g.ids.as_slice(), w.ids(), "q={q}: id lane");
        assert_eq!(g.dist2.as_slice(), w.dist2s(), "q={q}: dist² lane");
    }
    println!("verified: streamed == one-shot batch, bit for bit (128 queries)");
}

fn main() {
    let ranks: usize = std::env::var("HKNN_RANKS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let engine = Engine::load_default().expect("run `make artifacts` first");
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    let corpus = susy_like(2500).generate(0xFA);
    let pool = susy_like(2048).generate(0x5EED);
    let k = 6;

    verify_stream_equals_batch(&engine, &corpus, &pool);

    let mut p = HybridParams::new(k);
    p.cpu_ranks = ranks;
    let mut session =
        KnnEngine::build(&engine, &corpus, p).expect("resident engine");
    // warm: compiles executables, allocates the first drain arenas
    let warm = pool.gather(&(0..64).collect::<Vec<_>>());
    let _ = session.flush(&warm).expect("warmup flush");

    // amortization ceiling: the whole pool as one flush
    let (batch_res, batch_rep) = session.flush(&pool).expect("batch flush");
    assert_eq!(batch_res.solved_count(k), pool.len(), "batch flush complete");
    let batch_qps = pool.len() as f64 / batch_rep.secs.max(1e-12);
    println!(
        "batch flush: {} queries in {:.4}s = {:.1} q/s (ranks={ranks}, hw={hw})",
        pool.len(),
        batch_rep.secs,
        batch_qps
    );

    let mut rows = vec![Json::obj(vec![
        ("case", Json::Str("batch".into())),
        ("queries", Json::Num(pool.len() as f64)),
        ("secs", Json::Num(batch_rep.secs)),
        ("throughput_qps", Json::Num(batch_qps)),
    ])];
    println!(
        "{:>10} {:>8} {:>9} {:>9} {:>9} {:>9} {:>8} {:>8}",
        "case", "queries", "qps", "p50 ms", "p99 ms", "flushes", "str r",
        "p99 f"
    );
    let mut closed4_qps = batch_qps;
    let cases: [(&str, usize, f64); 3] = [
        ("clients_1", 1, 0.0),
        ("clients_2", 2, 0.0),
        ("clients_4", 4, 0.0),
    ];
    let mut emit = |name: &str,
                    clients: usize,
                    rep: &ServiceReport,
                    rows: &mut Vec<Json>| {
        let stream_vs_batch = rep.throughput_qps / batch_qps.max(1e-12);
        let p99_fairness = rep.wall_secs
            / (REQUESTS as f64 * rep.latency_p99.max(1e-12));
        println!(
            "{:>10} {:>8} {:>9.1} {:>9.2} {:>9.2} {:>9} {:>7.2}x {:>7.2}x",
            name,
            rep.queries,
            rep.throughput_qps,
            rep.latency_p50 * 1e3,
            rep.latency_p99 * 1e3,
            rep.flushes,
            stream_vs_batch,
            p99_fairness
        );
        rows.push(Json::obj(vec![
            ("case", Json::Str(name.into())),
            ("clients", Json::Num(clients as f64)),
            ("queries", Json::Num(rep.queries as f64)),
            ("requests", Json::Num(rep.requests as f64)),
            ("flushes", Json::Num(rep.flushes as f64)),
            ("mean_flush_queries", Json::Num(rep.mean_flush_queries)),
            ("wall_secs", Json::Num(rep.wall_secs)),
            ("throughput_qps", Json::Num(rep.throughput_qps)),
            ("p50_ms", Json::Num(rep.latency_p50 * 1e3)),
            ("p99_ms", Json::Num(rep.latency_p99 * 1e3)),
            ("mean_ms", Json::Num(rep.latency_mean * 1e3)),
            ("q_fail", Json::Num(rep.q_fail as f64)),
            ("stream_vs_batch", Json::Num(stream_vs_batch)),
            ("p99_fairness", Json::Num(p99_fairness)),
        ]));
    };
    for (name, clients, interval) in cases {
        let rep = run_case(&mut session, &pool, clients, interval);
        assert_eq!(
            rep.queries,
            clients * REQUESTS * BATCH,
            "{name}: every submitted query served"
        );
        assert_eq!(rep.q_gpu + rep.q_cpu, rep.queries, "{name}: exactly-once");
        if clients == 4 {
            closed4_qps = rep.throughput_qps;
        }
        emit(name, clients, &rep, &mut rows);
    }

    // open loop at ~60% of the measured closed-loop saturation rate
    let open_clients = 4usize;
    let rate = (0.6 * closed4_qps).max(1.0);
    let interval = open_clients as f64 * BATCH as f64 / rate;
    let rep = run_case(&mut session, &pool, open_clients, interval);
    assert_eq!(rep.queries, open_clients * REQUESTS * BATCH);
    emit("open_loop", open_clients, &rep, &mut rows);

    let doc = Json::obj(vec![
        ("bench", Json::Str("service".into())),
        (
            "baseline",
            Json::Str(
                "one-shot batch flush of the whole query pool through the \
                 same resident engine"
                    .into(),
            ),
        ),
        (
            "contender",
            Json::Str(
                "concurrent clients streaming query micro-batches through \
                 the ingress coalescer (closed loop at 1/2/4 clients, open \
                 loop at ~60% of closed-loop throughput), per-request \
                 p50/p99 latency"
                    .into(),
            ),
        ),
        ("ranks", Json::Num(ranks as f64)),
        ("hw_threads", Json::Num(hw as f64)),
        ("requests_per_client", Json::Num(REQUESTS as f64)),
        ("batch_per_request", Json::Num(BATCH as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_service.json", doc.to_string() + "\n")
        .expect("write BENCH_service.json");
    println!("wrote BENCH_service.json");
}
