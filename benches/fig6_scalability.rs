//! Fig. 6: REFIMPL speedup vs rank count (SuSy* and FMA*, K=5).
use hybrid_knn_join::bench::{experiments, workloads};

fn main() {
    let ws = workloads();
    let t = experiments::fig6(&[ws[0].clone(), ws[3].clone()], 5);
    println!("{}", t.render());
}
