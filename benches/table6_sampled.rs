//! Table VI: parameter recovery from a fraction f of the queries.
use hybrid_knn_join::bench::{experiments, workloads};
use hybrid_knn_join::runtime::Engine;

fn main() {
    let engine = Engine::load_default().expect("make artifacts");
    let t = experiments::table6(&engine, &workloads(), &[0.05, 0.1, 0.05, 0.1]).unwrap();
    println!("{}", t.render());
}
