//! Work-division shootout: the density-ordered dynamic work queue vs the
//! paper's one-shot static split, end to end through the hybrid join -
//! with a sync / two-stage / three-stage drain matrix isolating what
//! each pipeline stage buys (exec/filter overlap, then the dedicated
//! device-to-host transfer stage).
//!
//! Covers self-join and bipartite workloads at several skew levels, with
//! a deliberately mispredicted γ in the sweep - the regime where the
//! static split strands one architecture while the other finishes its
//! fixed share. Emits `BENCH_scheduler.json` (uploaded as a CI artifact
//! alongside `BENCH_cpu_engine.json`, and regression-gated against
//! `benches/baselines/`) so later PRs can track the scheduling
//! trajectory. Overlap is observable per row: `gpu_exec_time +
//! gpu_transfer_time + gpu_filter_time > gpu wall` exactly when a
//! pipeline overlapped its stages, and `gpu_transfer_overlap` isolates
//! the share the transfer stage hid.
//!
//!   cargo bench --bench scheduler
//!   HKNN_RANKS=8 cargo bench --bench scheduler

use hybrid_knn_join::prelude::*;
use hybrid_knn_join::util::json::Json;

struct Case {
    name: &'static str,
    /// (R, S): S = None means self-join
    r: Dataset,
    s: Option<Dataset>,
    k: usize,
    gamma: f64,
    rho: f64,
}

fn run_one(
    engine: &Engine,
    case: &Case,
    scheduler: Scheduler,
    ranks: usize,
    drain: DrainMode,
) -> HybridReport {
    let mut p = HybridParams::new(case.k);
    p.cpu_ranks = ranks;
    p.gamma = case.gamma;
    p.rho = case.rho;
    p.scheduler = scheduler;
    p.gpu_drain = drain;
    match &case.s {
        None => HybridKnnJoin::run(engine, &case.r, &p).expect(case.name),
        Some(s) => HybridKnnJoin::run_rs(engine, &case.r, s, &p).expect(case.name),
    }
}

fn main() {
    let ranks: usize = std::env::var("HKNN_RANKS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let engine = Engine::load_default().expect("run `make artifacts` first");
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    // warm the executable cache so no contender pays compilation
    {
        let warm = susy_like(400).generate(1);
        let mut p = HybridParams::new(3);
        p.cpu_ranks = ranks;
        let _ = HybridKnnJoin::run(&engine, &warm, &p).expect("warmup");
    }

    let cases = vec![
        Case {
            name: "susy_selfjoin_gamma_low",
            r: susy_like(3000).generate(0xA1),
            s: None,
            k: 8,
            gamma: 0.1,
            rho: 0.0,
        },
        Case {
            name: "chist_skewed_gamma_mid",
            r: chist_like(2000).generate(0xA2),
            s: None,
            k: 5,
            gamma: 0.4,
            rho: 0.1,
        },
        Case {
            // the misprediction regime: a high γ starves the static GPU
            // side on clustered data; the queue discovers the real split
            name: "chist_skewed_gamma_mispredicted",
            r: chist_like(2000).generate(0xA2),
            s: None,
            k: 5,
            gamma: 0.9,
            rho: 0.0,
        },
        Case {
            name: "susy_bipartite",
            r: susy_like(1200).generate(0xA3),
            s: Some(susy_like(2400).generate(0xA4)),
            k: 4,
            gamma: 0.2,
            rho: 0.1,
        },
    ];

    let mut rows = Vec::new();
    println!(
        "scheduler shootout: static split vs dynamic queue, sync vs two-stage \
         vs three-stage GPU drain (ranks={ranks}, hw={hw})"
    );
    println!(
        "{:>34} {:>9} {:>9} {:>9} {:>9} {:>8} {:>7} {:>9} {:>8}",
        "case", "static s", "dyn-sync", "dyn-2st", "dyn-3st", "speedup",
        "pipe x", "xfer ovl", "q_fail"
    );
    for case in &cases {
        let stat =
            run_one(&engine, case, Scheduler::StaticSplit, ranks, DrainMode::Sync);
        let dyn_sync =
            run_one(&engine, case, Scheduler::DynamicQueue, ranks, DrainMode::Sync);
        let dyn_two = run_one(
            &engine, case, Scheduler::DynamicQueue, ranks, DrainMode::TwoStage,
        );
        let dyn_ = run_one(
            &engine, case, Scheduler::DynamicQueue, ranks, DrainMode::ThreeStage,
        );
        let gpu_claims = dyn_
            .claims
            .iter()
            .filter(|c| matches!(c.arch, Arch::Gpu))
            .count();
        let cpu_claims = dyn_.claims.len() - gpu_claims;
        let speedup = stat.response_time / dyn_.response_time.max(1e-12);
        let pipeline_speedup =
            dyn_sync.response_time / dyn_.response_time.max(1e-12);
        let three_stage_gain =
            dyn_two.response_time / dyn_.response_time.max(1e-12);
        println!(
            "{:>34} {:>9.4} {:>9.4} {:>9.4} {:>9.4} {:>7.2}x {:>6.2}x {:>9.4} {:>8}",
            case.name,
            stat.response_time,
            dyn_sync.response_time,
            dyn_two.response_time,
            dyn_.response_time,
            speedup,
            pipeline_speedup,
            dyn_.gpu_transfer_overlap,
            dyn_.q_fail
        );
        // all four runs must have produced complete, identical-
        // cardinality results - a scheduler can move work, never drop it
        let solved_k = case.k.min(case.r.len().saturating_sub(1));
        for (rep, tag) in [
            (&stat, "static"),
            (&dyn_sync, "dyn-sync"),
            (&dyn_two, "dyn-two-stage"),
            (&dyn_, "dyn-three-stage"),
        ] {
            assert_eq!(
                rep.result.solved_count(solved_k),
                case.r.len(),
                "{} [{}]",
                case.name,
                tag
            );
        }
        rows.push(Json::obj(vec![
            ("case", Json::Str(case.name.into())),
            ("n", Json::Num(case.r.len() as f64)),
            ("bipartite", Json::Bool(case.s.is_some())),
            ("k", Json::Num(case.k as f64)),
            ("gamma", Json::Num(case.gamma)),
            ("rho", Json::Num(case.rho)),
            ("static_secs", Json::Num(stat.response_time)),
            ("dynamic_sync_secs", Json::Num(dyn_sync.response_time)),
            ("dynamic_two_stage_secs", Json::Num(dyn_two.response_time)),
            ("dynamic_secs", Json::Num(dyn_.response_time)),
            ("speedup", Json::Num(speedup)),
            ("pipeline_speedup", Json::Num(pipeline_speedup)),
            ("three_stage_gain", Json::Num(three_stage_gain)),
            ("gpu_exec_time", Json::Num(dyn_.gpu_exec_time)),
            ("gpu_transfer_time", Json::Num(dyn_.gpu_transfer_time)),
            ("gpu_filter_time", Json::Num(dyn_.gpu_filter_time)),
            ("gpu_filter_overlap", Json::Num(dyn_.gpu_filter_overlap)),
            ("gpu_transfer_overlap", Json::Num(dyn_.gpu_transfer_overlap)),
            ("static_q_gpu", Json::Num(stat.q_gpu as f64)),
            ("static_q_cpu", Json::Num(stat.q_cpu as f64)),
            ("dynamic_q_gpu", Json::Num(dyn_.q_gpu as f64)),
            ("dynamic_q_cpu", Json::Num(dyn_.q_cpu as f64)),
            ("gpu_claims", Json::Num(gpu_claims as f64)),
            ("cpu_claims", Json::Num(cpu_claims as f64)),
            ("q_fail_recirculated", Json::Num(dyn_.q_fail as f64)),
            ("rho_model_dynamic", Json::Num(dyn_.rho_model)),
        ]));
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("scheduler".into())),
        (
            "baseline",
            Json::Str("one-shot static split (γ threshold + ρ floor) + serial Q^Fail".into()),
        ),
        (
            "contender",
            Json::Str(
                "density-ordered shared work queue, two-ended dynamic claims, \
                 live Q^Fail recirculation, three-stage pipelined GPU master \
                 (exec claim i+1 / transfer claim i / filter claim i-1 via \
                 per-claim round lanes; dynamic_sync_secs and \
                 dynamic_two_stage_secs = same queue with the sync and \
                 two-stage ablation drains)"
                    .into(),
            ),
        ),
        ("ranks", Json::Num(ranks as f64)),
        ("hw_threads", Json::Num(hw as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_scheduler.json", doc.to_string() + "\n")
        .expect("write BENCH_scheduler.json");
    println!("wrote BENCH_scheduler.json");
}
