#!/usr/bin/env python3
"""Regression gate for tracked bench columns vs committed baselines.

Usage: check_regression.py [--allow-missing] FRESH BASELINE
       check_regression.py --update-baselines FRESH BASELINE

The baseline JSON mirrors the bench output schema plus three gate fields:

  "tracked":   row columns to gate - ratio columns (speedups), which are
               same-run relative and therefore comparable across machines,
               unlike absolute seconds;
  "tolerance": fractional drop allowed vs the baseline value (default
               0.15, the >15% regression gate of ROADMAP (g));
  "key":       row field(s) identifying a row across runs.

A fresh row regresses when fresh[col] < baseline[col] * (1 - tolerance).
Baseline rows missing from the fresh run fail (coverage loss); fresh rows
absent from the baseline pass with a notice (new cases stay untracked
until the baseline is refreshed). --allow-missing turns a missing FRESH
file into a skip - for benches that cannot run on stock runners (the
scheduler bench needs the AOT artifacts + xla native lib).

--update-baselines rewrites BASELINE in place from FRESH: every fresh
row's tracked columns replace (or add) the matching baseline row, keeping
the gate fields and note. Run the bench on the reference machine (ideally
taking the median of several runs), then:

    cargo bench --bench scheduler
    python3 benches/check_regression.py --update-baselines \
        BENCH_scheduler.json benches/baselines/BENCH_scheduler.json

and commit the result - this is how the conservative bootstrap floors
are replaced with measured medians (ROADMAP (g)).
"""

import json
import sys


def key_of(row, keys):
    return tuple(row.get(k) for k in keys)


def update_baselines(fresh_path, base_path):
    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(base_path) as f:
        base = json.load(f)
    tracked = base.get("tracked", [])
    keys = base.get("key", ["case"])
    base_rows = {key_of(r, keys): r for r in base.get("rows", [])}
    updated, added = 0, 0
    for frow in fresh.get("rows", []):
        k = key_of(frow, keys)
        vals = {c: frow[c] for c in tracked if c in frow}
        if k in base_rows:
            base_rows[k].update(vals)
            updated += 1
        else:
            row = {kf: kv for kf, kv in zip(keys, k)}
            row.update(vals)
            base["rows"].append(row)
            base_rows[k] = row
            added += 1
        print(f"  set {k}: " + ", ".join(f"{c}={v:.3f}" for c, v in vals.items()))
    with open(base_path, "w") as f:
        json.dump(base, f, indent=2)
        f.write("\n")
    print(
        f"[check_regression] refreshed {base_path} from {fresh_path} "
        f"({updated} updated, {added} added); review + commit it"
    )
    return 0


def main(argv):
    allow_missing = "--allow-missing" in argv
    update = "--update-baselines" in argv
    paths = [a for a in argv if not a.startswith("--")]
    if len(paths) != 2:
        print(__doc__)
        return 2
    fresh_path, base_path = paths
    if update:
        return update_baselines(fresh_path, base_path)
    try:
        with open(fresh_path) as f:
            fresh = json.load(f)
    except FileNotFoundError:
        msg = f"[check_regression] fresh results {fresh_path} missing"
        if allow_missing:
            print(msg + " - skipping (bench did not run on this runner)")
            return 0
        print(msg)
        return 1
    with open(base_path) as f:
        base = json.load(f)

    tracked = base.get("tracked", [])
    tol = float(base.get("tolerance", 0.15))
    keys = base.get("key", ["case"])
    fresh_rows = {key_of(r, keys): r for r in fresh.get("rows", [])}
    base_keys = {key_of(r, keys) for r in base.get("rows", [])}
    failures = []

    print(
        f"[check_regression] {fresh_path} vs {base_path} "
        f"(tracked={tracked}, tolerance={tol:.0%})"
    )
    for brow in base.get("rows", []):
        k = key_of(brow, keys)
        frow = fresh_rows.get(k)
        if frow is None:
            failures.append(f"row {k}: in baseline but missing from fresh run")
            continue
        for col in tracked:
            bv = brow.get(col)
            if bv is None:
                continue  # column not gated for this row
            fv = frow.get(col)
            if fv is None:
                failures.append(f"row {k}: column {col} missing from fresh run")
                continue
            floor = bv * (1.0 - tol)
            ok = fv >= floor
            print(
                f"  {'OK  ' if ok else 'FAIL'} {k} {col}: "
                f"fresh {fv:.3f} vs floor {floor:.3f} (baseline {bv:.3f})"
            )
            if not ok:
                failures.append(
                    f"row {k}: {col} regressed to {fv:.3f} < floor {floor:.3f}"
                )
    for k in fresh_rows:
        if k not in base_keys:
            print(f"  note: new row {k} untracked until the baseline is refreshed")

    if failures:
        print("[check_regression] REGRESSIONS (>{:.0%} vs baseline):".format(tol))
        for f in failures:
            print("  - " + f)
        return 1
    print("[check_regression] all tracked columns within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
