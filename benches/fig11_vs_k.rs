//! Fig. 11: response time vs K - HYBRIDKNN-JOIN vs REFIMPL vs
//! GPU-JOINLINEAR (the paper's headline comparison).
use hybrid_knn_join::bench::{experiments, workloads};
use hybrid_knn_join::runtime::Engine;

fn main() {
    let engine = Engine::load_default().expect("make artifacts");
    let t = experiments::fig11(&engine, &workloads(), &[1, 4, 16, 64]).unwrap();
    println!("{}", t.render());
}
