//! Fault-tolerance overhead bench: what the always-on injection hooks and
//! the claim-scoped recovery machinery cost when nothing fails, and what
//! recovery itself costs when something does.
//!
//! Three scenarios per drain mode, all through the full hybrid join:
//!
//! * `nofault_secs` - `FaultPlan::none()`: the production hot path, hooks
//!   compiled in and reduced to an is-empty branch per flush round;
//! * `transient_secs` - one injected exec fault on (claim 0, round 0),
//!   recovered by a synchronous in-place retry (backoff zeroed);
//! * `degraded_secs` - a persistent exec fault from claim 0: the master
//!   reclaims, demotes itself, and the CPU ranks finish the run;
//! * `cpu_only_secs` - ρ = 1.0: the planned pure-CPU schedule the
//!   degraded run is measured against.
//!
//! The tracked columns are same-run ratios (machine-portable, like the
//! scheduler bench): `retry_recovery_ratio = nofault / transient` gates
//! the cost of one recovery cycle, `degrade_recovery_ratio = cpu_only /
//! degraded` gates graceful degradation against the planned CPU-only
//! run. Emits `BENCH_fault.json`, regression-gated against
//! `benches/baselines/BENCH_fault.json` in CI.
//!
//!   cargo bench --bench fault
//!   HKNN_RANKS=8 cargo bench --bench fault

use hybrid_knn_join::prelude::*;
use hybrid_knn_join::util::json::Json;

fn base_params(k: usize, ranks: usize, drain: DrainMode) -> HybridParams {
    let mut p = HybridParams::new(k);
    p.cpu_ranks = ranks;
    p.gamma = 0.1;
    p.gpu_drain = drain;
    p
}

fn main() {
    let ranks: usize = std::env::var("HKNN_RANKS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let engine = Engine::load_default().expect("run `make artifacts` first");
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    // warm the executable cache so no scenario pays compilation
    {
        let warm = susy_like(400).generate(1);
        let mut p = HybridParams::new(3);
        p.cpu_ranks = ranks;
        let _ = HybridKnnJoin::run(&engine, &warm, &p).expect("warmup");
    }

    let data = susy_like(2500).generate(0xFA);
    let k = 6;
    let drains = [
        ("sync", DrainMode::Sync),
        ("two_stage", DrainMode::TwoStage),
        ("three_stage", DrainMode::ThreeStage),
    ];

    let mut rows = Vec::new();
    println!(
        "fault-tolerance overhead: no-fault hot path vs transient retry vs \
         persistent-fault degradation (ranks={ranks}, hw={hw})"
    );
    println!(
        "{:>14} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "drain", "nofault", "transient", "degraded", "cpu-only", "retry r",
        "degr r"
    );
    for (name, drain) in drains {
        // production hot path: empty plan, machinery armed but silent
        let p0 = base_params(k, ranks, drain);
        let nofault = HybridKnnJoin::run(&engine, &data, &p0).expect(name);
        assert_eq!(nofault.gpu_faults, 0, "{name}: empty plan must be silent");
        assert!(!nofault.degraded, "{name}");

        // one transient exec fault, retried in place
        let mut p1 = base_params(k, ranks, drain);
        p1.fault =
            FaultPlan::one(FaultSpec::transient(FaultKind::ExecError, 0, 0));
        p1.recovery.backoff_base_secs = 0.0;
        let transient = HybridKnnJoin::run(&engine, &data, &p1).expect(name);
        assert!(!transient.degraded, "{name}: one transient must not demote");

        // dead device from claim 0: reclaim, demote, finish CPU-only
        let mut p2 = base_params(k, ranks, drain);
        p2.fault =
            FaultPlan::one(FaultSpec::persistent(FaultKind::ExecError, 0));
        p2.recovery.retry_limit = 0;
        p2.recovery.demote_after = 1;
        p2.recovery.backoff_base_secs = 0.0;
        let degraded = HybridKnnJoin::run(&engine, &data, &p2).expect(name);
        assert!(degraded.degraded, "{name}: persistent fault must demote");
        assert_eq!(degraded.solved_on_gpu, 0, "{name}");

        // the planned pure-CPU schedule the degraded run chases
        let mut p3 = base_params(k, ranks, drain);
        p3.rho = 1.0;
        let cpu_only = HybridKnnJoin::run(&engine, &data, &p3).expect(name);

        // a fault plan can move work, never drop it
        for (rep, tag) in [
            (&nofault, "nofault"),
            (&transient, "transient"),
            (&degraded, "degraded"),
            (&cpu_only, "cpu-only"),
        ] {
            assert_eq!(
                rep.result.solved_count(k),
                data.len(),
                "{name} [{tag}]"
            );
        }

        let retry_ratio =
            nofault.response_time / transient.response_time.max(1e-12);
        let degrade_ratio =
            cpu_only.response_time / degraded.response_time.max(1e-12);
        println!(
            "{:>14} {:>9.4} {:>9.4} {:>9.4} {:>9.4} {:>8.2}x {:>8.2}x",
            name,
            nofault.response_time,
            transient.response_time,
            degraded.response_time,
            cpu_only.response_time,
            retry_ratio,
            degrade_ratio
        );
        rows.push(Json::obj(vec![
            ("case", Json::Str(name.into())),
            ("n", Json::Num(data.len() as f64)),
            ("k", Json::Num(k as f64)),
            ("nofault_secs", Json::Num(nofault.response_time)),
            ("transient_secs", Json::Num(transient.response_time)),
            ("degraded_secs", Json::Num(degraded.response_time)),
            ("cpu_only_secs", Json::Num(cpu_only.response_time)),
            ("retry_recovery_ratio", Json::Num(retry_ratio)),
            ("degrade_recovery_ratio", Json::Num(degrade_ratio)),
            ("transient_retries", Json::Num(transient.gpu_retries as f64)),
            (
                "degraded_reclaimed_cells",
                Json::Num(degraded.reclaimed_cells as f64),
            ),
            ("degraded_q_fail", Json::Num(degraded.q_fail as f64)),
            (
                "degraded_fault_events",
                Json::Num(degraded.fault_log.events.len() as f64),
            ),
        ]));
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("fault".into())),
        (
            "baseline",
            Json::Str("fault-free hybrid join (hooks armed, plan empty)".into()),
        ),
        (
            "contender",
            Json::Str(
                "same join under injected exec faults: transient = one \
                 in-place synchronous retry; degraded = persistent fault, \
                 claim reclaimed through Q^Fail and the master demoted \
                 (run completes CPU-only)"
                    .into(),
            ),
        ),
        ("ranks", Json::Num(ranks as f64)),
        ("hw_threads", Json::Num(hw as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_fault.json", doc.to_string() + "\n")
        .expect("write BENCH_fault.json");
    println!("wrote BENCH_fault.json");
}
