//! Table III: TSTATIC / TDYNAMIC kernel granularity (device model over the
//! real grid workload; beta=gamma=rho=0).
use hybrid_knn_join::bench::{experiments, workloads};
use hybrid_knn_join::runtime::Engine;

fn main() {
    let engine = Engine::load_default().expect("make artifacts");
    let t = experiments::table3(&engine, &workloads()).unwrap();
    println!("{}", t.render());
}
