//! Table IV: the beta x gamma parameter grid at rho=0.5.
use hybrid_knn_join::bench::{experiments, workloads};
use hybrid_knn_join::runtime::Engine;

fn main() {
    let engine = Engine::load_default().expect("make artifacts");
    let t = experiments::table4(&engine, &workloads()).unwrap();
    println!("{}", t.render());
}
