//! Fig. 7: GPU-JOINLINEAR response time vs eps (expected flat).
use hybrid_knn_join::bench::{experiments, workloads};
use hybrid_knn_join::runtime::Engine;

fn main() {
    let engine = Engine::load_default().expect("make artifacts");
    let ws = workloads();
    let t = experiments::fig7(&engine, &ws[1..]).unwrap();
    println!("{}", t.render());
}
