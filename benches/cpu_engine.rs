//! CPU query-engine throughput: REFIMPL queries/sec for the refactored
//! zero-allocation engine (SoA result + scratch reuse + dynamic chunked
//! scheduling) vs an in-tree reimplementation of the pre-refactor
//! baseline (static round-robin, per-query heap/Vec allocation, per-rank
//! result buffers copied into the final container).
//!
//! Emits `BENCH_cpu_engine.json` (queries/sec per n, k on susy_like) so
//! later PRs can track the perf trajectory of the hot path.
//!
//!   cargo bench --bench cpu_engine            # full sweep (n up to 50k)
//!   HKNN_RANKS=8 cargo bench --bench cpu_engine

use std::time::Instant;

use hybrid_knn_join::core::Neighbor;
use hybrid_knn_join::prelude::*;
use hybrid_knn_join::util::{json::Json, pool};

/// The seed engine, reconstructed: static round-robin rank assignment and
/// the allocating per-query path (`KdTree::knn`: fresh scratch + sorted
/// `Vec<Neighbor>` per call), with per-rank `(query, neighbors)` buffers
/// copied into the result container afterwards. Kept here (not in the
/// library) purely as the measurement baseline.
fn legacy_ref_impl(data: &Dataset, tree: &KdTree, k: usize, ranks: usize) -> KnnResult {
    let queries: Vec<u32> = (0..data.len() as u32).collect();
    let rank_results: Vec<Vec<(u32, Vec<Neighbor>)>> = pool::run_ranks(ranks, |r| {
        let mut out = Vec::new();
        let mut i = r;
        while i < queries.len() {
            let q = queries[i];
            out.push((q, tree.knn(data, data.point(q as usize), k, q)));
            i += ranks;
        }
        out
    });
    let mut result = KnnResult::new(data.len(), k);
    for items in rank_results {
        for (q, ns) in items {
            result.set(q as usize, &ns);
        }
    }
    result
}

fn qps(queries: usize, secs: f64) -> f64 {
    queries as f64 / secs.max(1e-12)
}

fn main() {
    let ranks: usize = std::env::var("HKNN_RANKS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let cases: &[(usize, usize)] = &[(10_000, 4), (25_000, 16), (50_000, 16)];

    let mut rows = Vec::new();
    println!("CPU engine throughput, susy_like, ranks={ranks}");
    println!(
        "{:>8} {:>4} {:>14} {:>14} {:>8}",
        "n", "k", "refimpl q/s", "baseline q/s", "speedup"
    );
    for &(n, k) in cases {
        let data = susy_like(n).generate(0xBE_5C);
        let tree = KdTree::build(&data);

        // warm-up touch so first-measurement page faults do not skew n=10k
        let _ = ref_impl(&data, &tree, k, ranks);

        let t0 = Instant::now();
        let new_out = ref_impl(&data, &tree, k, ranks);
        let t_new = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let old_res = legacy_ref_impl(&data, &tree, k, ranks);
        let t_old = t1.elapsed().as_secs_f64();

        // both engines must produce identical distance sets
        for q in (0..data.len()).step_by(997) {
            let (a, b) = (new_out.result.get(q), old_res.get(q));
            assert_eq!(a.len(), b.len(), "q={q}");
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.dist2, y.dist2, "q={q}");
            }
        }

        let (new_qps, old_qps) = (qps(n, t_new), qps(n, t_old));
        println!(
            "{:>8} {:>4} {:>14.0} {:>14.0} {:>7.2}x",
            n,
            k,
            new_qps,
            old_qps,
            new_qps / old_qps.max(1e-12)
        );
        rows.push(Json::obj(vec![
            ("n", Json::Num(n as f64)),
            ("k", Json::Num(k as f64)),
            ("refimpl_qps", Json::Num(new_qps)),
            ("baseline_qps", Json::Num(old_qps)),
            ("refimpl_secs", Json::Num(t_new)),
            ("baseline_secs", Json::Num(t_old)),
            ("speedup", Json::Num(new_qps / old_qps.max(1e-12))),
        ]));
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("cpu_engine".into())),
        ("dataset", Json::Str("susy_like".into())),
        ("engine", Json::Str("REFIMPL (EXACT-ANN over all of D)".into())),
        ("ranks", Json::Num(ranks as f64)),
        (
            "baseline",
            Json::Str(
                "pre-refactor: round-robin ranks, per-query heap/Vec alloc, \
                 copy-merge result"
                    .into(),
            ),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_cpu_engine.json", doc.to_string() + "\n")
        .expect("write BENCH_cpu_engine.json");
    println!("wrote BENCH_cpu_engine.json");
}
