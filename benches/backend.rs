//! Backend-crossover bench: the grid-hybrid GPU tier vs the tiled
//! brute-force tier vs the Auto router, swept over the indexed
//! dimensionality m at fixed |D| and K (the router's other two inputs).
//!
//! The grid tier's cost grows with m - 3^m adjacent-cell walks, more and
//! smaller cells, more per-tile fixed cost - while the brute tier's
//! corpus scan is m-independent, so past some m the brute tier wins and
//! the per-claim router is supposed to find that crossover on its own.
//! All three runs drive the same queue through `gpu_join_drain` (GPU
//! only: no CPU ranks, so the tiers are timed in isolation) over the
//! same grid and tile plans; only `params.backend` differs.
//!
//! Result verification is baked in and gated (`verified` column):
//!
//! * within a workload, the forced-Brute table is checksum-identical
//!   across every m (the corpus scan never consults the grid, so m may
//!   only reorder claims, never change a slot);
//! * grid-solved queries match the brute table bit for bit (both tiers
//!   compute the same f32 device distances), grid-failed slots are
//!   empty, and the Auto run satisfies the same split per query.
//!
//! The tracked ratio column `auto_vs_best = min(grid, brute) / auto` is
//! same-run relative (machine-portable): ~1.0 when the router matches
//! the better forced backend on both sides of the crossover. Emits
//! `BENCH_backend.json`, regression-gated against
//! `benches/baselines/BENCH_backend.json` in CI.
//!
//!   cargo bench --bench backend

use std::time::Instant;

use hybrid_knn_join::gpu::join::gpu_join_drain;
use hybrid_knn_join::prelude::*;
use hybrid_knn_join::util::json::Json;

const K: usize = 8;

/// One timed GPU-only drain of the given queue with a forced backend.
fn run_drain(
    engine: &Engine,
    data: &Dataset,
    grid: &GridIndex,
    queue: &WorkQueue,
    eps: f64,
    backend: BackendMode,
) -> (KnnResult, hybrid_knn_join::gpu::GpuJoinStats, f64) {
    let mut params = GpuJoinParams::new(K, eps);
    params.backend = backend;
    // several claims per run, so the router decides more than once
    params.buffer_pairs = 100_000;
    let mut result = KnnResult::new(data.len(), K);
    let slots = result.slots();
    let t = Instant::now();
    let stats = gpu_join_drain(
        engine, data, data, grid, queue, &params, &slots,
        queue.len(),
    )
    .expect("drain");
    let secs = t.elapsed().as_secs_f64();
    drop(slots);
    assert_eq!(
        stats.solved + stats.failed.len(),
        data.len(),
        "{backend:?}: exactly-once accounting"
    );
    (result, stats, secs)
}

/// Grid-solved slots must equal the brute table bit for bit; failed
/// slots must be untouched (the brute tier has no ε gate, so its table
/// is the full-K reference for every query).
fn verify_against_brute(
    res: &KnnResult,
    failed: &[u32],
    brute: &KnnResult,
    ctx: &str,
) {
    let failed: std::collections::HashSet<u32> = failed.iter().copied().collect();
    for q in 0..res.len() {
        let (a, b) = (res.get(q), brute.get(q));
        if failed.contains(&(q as u32)) {
            assert_eq!(a.len(), 0, "{ctx}: q={q} failed slot written");
        } else {
            assert_eq!(a.ids(), b.ids(), "{ctx}: q={q} id lane");
            assert_eq!(a.dist2s(), b.dist2s(), "{ctx}: q={q} dist2 lane");
        }
    }
}

fn main() {
    let engine = Engine::load_default().expect("run `make artifacts` first");

    // fixed |D| and K; only m (the third router input) sweeps
    let susy = susy_like(2_400).generate(0xBE01);
    let chist = chist_like(2_000).generate(0xBE02);
    let susy_eps = EpsilonSelector::default().select_host(&susy, K, 0.0).eps;
    let chist_eps = EpsilonSelector::default().select_host(&chist, K, 0.2).eps;
    let workloads: Vec<(&str, &Dataset, f64)> = vec![
        ("susy_uniform", &susy, susy_eps),
        ("chist_skewed", &chist, chist_eps),
    ];
    let ms = [2usize, 4, 6, 8];

    // warm the executable cache so no timed run pays compilation
    {
        let warm = susy_like(300).generate(1);
        let grid = GridIndex::build(&warm, 2, susy_eps);
        let queries: Vec<u32> = (0..warm.len() as u32).collect();
        let queue = build_queue(&warm, &grid, &queries, K, 0.0, 0.0, true);
        for backend in [BackendMode::Grid, BackendMode::Brute] {
            let _ = run_drain(&engine, &warm, &grid, &queue, susy_eps, backend);
        }
    }

    let mut rows = Vec::new();
    println!(
        "backend crossover: grid tier vs brute tier vs Auto router, m sweep \
         at fixed |D| and K={K}"
    );
    println!(
        "{:>14} {:>3} {:>9} {:>9} {:>9} {:>8} {:>9} {:>11}",
        "workload", "m", "grid s", "brute s", "auto s", "brute x",
        "auto/best", "auto g/b"
    );
    for &(name, data, eps) in &workloads {
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let mut brute_sum: Option<u64> = None;
        let mut crossover_m: Option<usize> = None;
        for &m in &ms {
            let ctx = format!("{name} m={m}");
            let grid = GridIndex::build(data, m, eps);
            let queue = build_queue(data, &grid, &queries, K, 0.0, 0.0, true);

            let (grid_res, grid_stats, grid_secs) =
                run_drain(&engine, data, &grid, &queue, eps, BackendMode::Grid);
            let (brute_res, brute_stats, brute_secs) =
                run_drain(&engine, data, &grid, &queue, eps, BackendMode::Brute);
            let (auto_res, auto_stats, auto_secs) =
                run_drain(&engine, data, &grid, &queue, eps, BackendMode::Auto);

            // -- verification (the `verified` column is gated in CI) --
            assert_eq!(grid_stats.brute_claims, 0, "{ctx}");
            assert_eq!(brute_stats.grid_claims, 0, "{ctx}");
            assert!(brute_stats.failed.is_empty(), "{ctx}: no ε gate");
            assert_eq!(brute_res.solved_count(K), data.len(), "{ctx}");
            let sum = brute_res.checksum();
            match brute_sum {
                None => brute_sum = Some(sum),
                Some(s) => assert_eq!(
                    s, sum,
                    "{ctx}: brute table must not depend on m"
                ),
            }
            verify_against_brute(&grid_res, &grid_stats.failed, &brute_res, &ctx);
            verify_against_brute(&auto_res, &auto_stats.failed, &brute_res, &ctx);

            let brute_speedup = grid_secs / brute_secs.max(1e-12);
            if crossover_m.is_none() && brute_speedup > 1.0 {
                crossover_m = Some(m);
            }
            let best = grid_secs.min(brute_secs);
            let auto_vs_best = best / auto_secs.max(1e-12);
            println!(
                "{:>14} {:>3} {:>9.4} {:>9.4} {:>9.4} {:>7.2}x {:>9.2} {:>5}/{:<5}",
                name, m, grid_secs, brute_secs, auto_secs, brute_speedup,
                auto_vs_best, auto_stats.grid_claims, auto_stats.brute_claims
            );
            rows.push(Json::obj(vec![
                ("workload", Json::Str(name.into())),
                ("m", Json::Num(m as f64)),
                ("n", Json::Num(data.len() as f64)),
                ("k", Json::Num(K as f64)),
                ("eps", Json::Num(eps)),
                ("grid_secs", Json::Num(grid_secs)),
                ("brute_secs", Json::Num(brute_secs)),
                ("auto_secs", Json::Num(auto_secs)),
                // >1.0: the brute tier beat the grid tier at this m
                ("brute_speedup", Json::Num(brute_speedup)),
                // tracked: ~1.0 when Auto matches the better backend
                ("auto_vs_best", Json::Num(auto_vs_best)),
                // 1.0 iff every in-memory cross-check above passed (the
                // asserts abort the bench otherwise, so a row that
                // reaches the JSON is verified by construction)
                ("verified", Json::Num(1.0)),
                ("grid_q_fail", Json::Num(grid_stats.failed.len() as f64)),
                ("brute_tiles", Json::Num(brute_stats.brute_tiles as f64)),
                ("auto_grid_claims", Json::Num(auto_stats.grid_claims as f64)),
                ("auto_brute_claims", Json::Num(auto_stats.brute_claims as f64)),
                (
                    "brute_checksum",
                    Json::Str(format!("{:016x}", sum)),
                ),
            ]));
        }
        match crossover_m {
            Some(m) => println!("  {name}: brute tier wins from m={m}"),
            None => println!("  {name}: grid tier won at every swept m"),
        }
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("backend".into())),
        (
            "baseline",
            Json::Str(
                "forced single-tier drains (backend=grid / backend=brute) \
                 over the identical queue, grid and tile plans"
                    .into(),
            ),
        ),
        (
            "contender",
            Json::Str(
                "backend=auto: the per-claim router picking a tier from \
                 (m, K, claimed candidate density) at claim time"
                    .into(),
            ),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_backend.json", doc.to_string() + "\n")
        .expect("write BENCH_backend.json");
    println!("wrote BENCH_backend.json");
}
