//! Fig. 9: response time vs beta for a range of rho (gamma=0.6;
//! SuSy* and Songs*, the two opposite-trend datasets of the paper).
use hybrid_knn_join::bench::{experiments, workloads};
use hybrid_knn_join::runtime::Engine;

fn main() {
    let engine = Engine::load_default().expect("make artifacts");
    let ws = workloads();
    let t = experiments::fig9(
        &engine,
        &[ws[0].clone(), ws[2].clone()],
        &[0.0, 0.5, 1.0],
        &[0.0, 0.2, 0.4, 0.6, 0.8, 1.0],
    )
    .unwrap();
    println!("{}", t.render());
}
