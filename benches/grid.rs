//! Grid-engine microbench: the CSR cell-adjacency engine (O(1) point→cell
//! rank map + precomputed neighbor rows + memoized adjacent populations)
//! vs an in-tree reconstruction of the pre-refactor walk (per-query
//! coordinate recompute with a fresh `Vec<u64>`, a binary search per
//! adjacent cell, per-cell `Vec` allocations).
//!
//! Two workloads, mirroring the two hot consumers:
//!
//! * **pricing** - what `sched::build_queue` pays per query: cell
//!   population + adjacent-block population. Legacy: recompute + 3^m walk
//!   per query; CSR: two O(1) array reads.
//! * **tile_build** - what `gpu::join`'s tile builders pay per cell:
//!   materialise the cell's candidate list once. Legacy: walk with growth
//!   reallocations; CSR: exact-capacity reserve + flat slice copies.
//!
//! Emits `BENCH_grid.json` (tracked `speedup` column per case x dataset),
//! gated against `benches/baselines/BENCH_grid.json` in CI.
//!
//!   cargo bench --bench grid

use std::time::Instant;

use hybrid_knn_join::prelude::*;
use hybrid_knn_join::util::json::Json;

/// The seed grid engine, reconstructed: B/G/A arrays only, coordinates
/// recomputed per call into a fresh `Vec`, every adjacent cell binary-
/// searched. Kept here (not in the library) purely as the measurement
/// baseline.
struct LegacyGrid {
    eps: f64,
    m: usize,
    mins: Vec<f64>,
    widths: Vec<u64>,
    cell_ids: Vec<u64>,
    ranges: Vec<(u32, u32)>,
    point_ids: Vec<u32>,
}

impl LegacyGrid {
    fn build(d: &Dataset, m: usize, eps: f64) -> LegacyGrid {
        let m = m.clamp(1, d.dims());
        let n = d.len();
        let mut mins = vec![f64::INFINITY; m];
        let mut maxs = vec![f64::NEG_INFINITY; m];
        for i in 0..n {
            let p = d.point(i);
            for j in 0..m {
                mins[j] = mins[j].min(p[j] as f64);
                maxs[j] = maxs[j].max(p[j] as f64);
            }
        }
        let widths: Vec<u64> = (0..m)
            .map(|j| (((maxs[j] - mins[j]) / eps).floor() as u64 + 1).max(1))
            .collect();
        let mut pairs: Vec<(u64, u32)> = (0..n)
            .map(|i| {
                let coords = Self::cell_coords_of(d.point(i), &mins, eps, m);
                (Self::linearise(&coords, &widths), i as u32)
            })
            .collect();
        pairs.sort_unstable();
        let mut cell_ids = Vec::new();
        let mut ranges: Vec<(u32, u32)> = Vec::new();
        let mut point_ids = Vec::with_capacity(n);
        for (cell, pid) in pairs {
            if cell_ids.last() != Some(&cell) {
                cell_ids.push(cell);
                let s = point_ids.len() as u32;
                ranges.push((s, s));
            }
            point_ids.push(pid);
            ranges.last_mut().unwrap().1 += 1;
        }
        LegacyGrid { eps, m, mins, widths, cell_ids, ranges, point_ids }
    }

    fn cell_coords_of(p: &[f32], mins: &[f64], eps: f64, m: usize) -> Vec<u64> {
        (0..m)
            .map(|j| (((p[j] as f64 - mins[j]) / eps).floor().max(0.0)) as u64)
            .collect()
    }

    fn linearise(coords: &[u64], widths: &[u64]) -> u64 {
        let mut id = 0u64;
        for (c, w) in coords.iter().zip(widths) {
            id = id.wrapping_mul(*w).wrapping_add(*c);
        }
        id
    }

    fn cell_population(&self, p: &[f32]) -> usize {
        let coords = Self::cell_coords_of(p, &self.mins, self.eps, self.m);
        match self.cell_ids.binary_search(&Self::linearise(&coords, &self.widths)) {
            Ok(pos) => {
                let (s, e) = self.ranges[pos];
                (e - s) as usize
            }
            Err(_) => 0,
        }
    }

    fn visit_adjacent(&self, p: &[f32], mut visit: impl FnMut(&[u32])) {
        let base = Self::cell_coords_of(p, &self.mins, self.eps, self.m);
        let m = self.m;
        let mut offs = vec![-1i64; m];
        'outer: loop {
            let mut coords = Vec::with_capacity(m);
            let mut ok = true;
            for j in 0..m {
                let c = base[j] as i64 + offs[j];
                if c < 0 || c >= self.widths[j] as i64 {
                    ok = false;
                    break;
                }
                coords.push(c as u64);
            }
            if ok {
                let id = Self::linearise(&coords, &self.widths);
                if let Ok(pos) = self.cell_ids.binary_search(&id) {
                    let (s, e) = self.ranges[pos];
                    visit(&self.point_ids[s as usize..e as usize]);
                }
            }
            for j in (0..m).rev() {
                if offs[j] < 1 {
                    offs[j] += 1;
                    continue 'outer;
                }
                offs[j] = -1;
            }
            break;
        }
    }

    fn candidates_of(&self, p: &[f32]) -> Vec<u32> {
        let mut out = Vec::new();
        self.visit_adjacent(p, |ids| out.extend_from_slice(ids));
        out
    }

    fn adjacent_population(&self, p: &[f32]) -> usize {
        let mut n = 0usize;
        self.visit_adjacent(p, |ids| n += ids.len());
        n
    }
}

fn qps(items: usize, secs: f64) -> f64 {
    items as f64 / secs.max(1e-12)
}

fn main() {
    let susy = susy_like(12_000).generate(0x6B1D);
    let chist = chist_like(8_000).generate(0x6B1E);
    let chist_eps = EpsilonSelector::default().select_host(&chist, 5, 0.2).eps;
    let cases: Vec<(&str, &Dataset, f64)> = vec![
        ("susy_like", &susy, 2.0),
        ("chist_skewed", &chist, chist_eps),
    ];

    let mut rows = Vec::new();
    println!("grid engine: CSR cell-adjacency vs reconstructed legacy walk (m=6)");
    println!(
        "{:>12} {:>14} {:>14} {:>14} {:>8}",
        "case", "dataset", "csr q/s", "legacy q/s", "speedup"
    );
    for &(name, data, eps) in &cases {
        let t0 = Instant::now();
        let grid = GridIndex::build(data, 6, eps);
        let csr_build = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let legacy = LegacyGrid::build(data, 6, eps);
        let legacy_build = t1.elapsed().as_secs_f64();

        let queries: Vec<u32> = (0..data.len() as u32).collect();

        // ---- pricing: per-query cell pop + adjacent-block pop ----
        // warm-up touch so page faults do not skew the first measurement
        let mut warm = 0u64;
        for &q in queries.iter().step_by(97) {
            warm += grid.adjacent_population_of_id(q) as u64;
        }
        let t = Instant::now();
        let mut csr_acc = 0u64;
        for &q in &queries {
            csr_acc += grid.cell_population_of_id(q) as u64
                + grid.adjacent_population_of_id(q) as u64;
        }
        let csr_pricing = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let mut legacy_acc = 0u64;
        for &q in &queries {
            let p = data.point(q as usize);
            legacy_acc += legacy.cell_population(p) as u64
                + legacy.adjacent_population(p) as u64;
        }
        let legacy_pricing = t.elapsed().as_secs_f64();
        assert_eq!(csr_acc, legacy_acc, "pricing engines disagree ({name})");
        assert!(warm <= csr_acc);

        // ---- tile build: materialise each cell's candidate list ----
        let n_cells = grid.non_empty_cells();
        let reps: Vec<u32> = (0..n_cells)
            .map(|rank| grid.rank_points(rank)[0])
            .collect();
        let mut buf: Vec<u32> = Vec::new();
        let t = Instant::now();
        let mut csr_sum = 0u64;
        for rank in 0..n_cells {
            grid.candidates_into_rank(rank, &mut buf);
            csr_sum += buf.iter().map(|&x| x as u64).sum::<u64>();
        }
        let csr_tiles = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let mut legacy_sum = 0u64;
        for &rep in &reps {
            let c = legacy.candidates_of(data.point(rep as usize));
            legacy_sum += c.iter().map(|&x| x as u64).sum::<u64>();
        }
        let legacy_tiles = t.elapsed().as_secs_f64();
        assert_eq!(csr_sum, legacy_sum, "tile builders disagree ({name})");

        for (case, items, csr_secs, legacy_secs) in [
            ("pricing", queries.len(), csr_pricing, legacy_pricing),
            ("tile_build", n_cells, csr_tiles, legacy_tiles),
        ] {
            let (csr_qps, legacy_qps) = (qps(items, csr_secs), qps(items, legacy_secs));
            let speedup = csr_qps / legacy_qps.max(1e-12);
            println!(
                "{:>12} {:>14} {:>14.0} {:>14.0} {:>7.2}x",
                case, name, csr_qps, legacy_qps, speedup
            );
            rows.push(Json::obj(vec![
                ("case", Json::Str(case.into())),
                ("dataset", Json::Str(name.into())),
                ("items", Json::Num(items as f64)),
                ("csr_qps", Json::Num(csr_qps)),
                ("legacy_qps", Json::Num(legacy_qps)),
                ("csr_secs", Json::Num(csr_secs)),
                ("legacy_secs", Json::Num(legacy_secs)),
                ("speedup", Json::Num(speedup)),
                // build-time context (untracked): the CSR precomputation
                // is paid once at build, amortised by every consumer
                ("csr_build_secs", Json::Num(csr_build)),
                ("legacy_build_secs", Json::Num(legacy_build)),
            ]));
        }
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("grid".into())),
        (
            "engine",
            Json::Str(
                "CSR cell-adjacency grid (O(1) rank map + precomputed \
                 neighbor rows + memoized adjacent populations)"
                    .into(),
            ),
        ),
        (
            "baseline",
            Json::Str(
                "pre-refactor walk: per-query coordinate recompute, binary \
                 search per adjacent cell, per-cell Vec allocations"
                    .into(),
            ),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_grid.json", doc.to_string() + "\n")
        .expect("write BENCH_grid.json");
    println!("wrote BENCH_grid.json");
}
