//! Churn microbench: incremental index maintenance (CSR row patches on
//! the grid + the kd-tree's buffered delta set, with threshold re-sorts)
//! vs the eager policy that rebuilds both indexes from scratch after
//! every mutation batch.
//!
//! One seeded schedule of interleaved inserts (fresh rows) and removes
//! (random live ids) is replayed twice over the same corpus, in batches
//! of 64:
//!
//! * **patch** - `GridIndex::{insert,remove}` / `KdTree::{insert,remove}`
//!   per op, then `maybe_rebuild` / `maybe_merge` per batch (the
//!   dirty-fraction-threshold amortisation the resident engine uses);
//! * **rebuild** - the same splices followed by an unconditional
//!   `rebuilt()` of both indexes per batch (the splice cost is shared by
//!   both sides, so the delta is the rebuild work itself).
//!
//! Both sides must converge to the *same* canonical index - asserted via
//! `assert_same_layout` + live-id equality before anything is written -
//! so the tracked ratio compares two implementations of one result.
//!
//! Emits `BENCH_churn.json` (tracked `patch_vs_rebuild` column per
//! churn-fraction case), gated against `benches/baselines/BENCH_churn.json`
//! in CI.
//!
//!   cargo bench --bench churn

use std::time::Instant;

use hybrid_knn_join::prelude::*;
use hybrid_knn_join::util::json::Json;
use hybrid_knn_join::util::rng::Rng;

/// One step of the pre-simulated mutation schedule: `Insert` consumes
/// the next spare row (ids are append-only, so both replays assign the
/// same id), `Remove` names a corpus id that is live at that point.
#[derive(Clone, Copy)]
enum Op {
    Insert,
    Remove(u32),
}

fn schedule(n: usize, muts: usize, seed: u64) -> Vec<Op> {
    let mut rng = Rng::new(seed);
    let mut live: Vec<u32> = (0..n as u32).collect();
    let mut next_id = n as u32;
    let mut ops = Vec::with_capacity(muts);
    for i in 0..muts {
        if i % 2 == 0 {
            live.push(next_id);
            next_id += 1;
            ops.push(Op::Insert);
        } else {
            let victim = live.swap_remove(rng.below(live.len()));
            ops.push(Op::Remove(victim));
        }
    }
    ops
}

fn main() {
    const N: usize = 20_000;
    const BATCH: usize = 64;
    const M: usize = 6;
    const EPS: f64 = 2.0;
    let base = susy_like(N).generate(0xBE4C);
    let spare = susy_like(N / 2 + 1).generate(0xF00D);

    let cases = [
        ("churn_1pct", 0.01f64),
        ("churn_10pct", 0.10),
        ("churn_50pct", 0.50),
    ];

    let mut rows = Vec::new();
    println!("index churn: threshold-patched maintenance vs per-batch rebuild");
    println!(
        "{:>12} {:>9} {:>12} {:>12} {:>8} {:>16}",
        "case", "muts", "patch ops/s", "rebuild ops/s", "resorts", "patch_vs_rebuild"
    );
    for &(case, frac) in &cases {
        let muts = ((N as f64) * frac) as usize;
        let ops = schedule(N, muts, 0x5C4E ^ muts as u64);

        // ---- patch side: splices + threshold re-sorts ----
        let mut d = base.clone();
        let mut g = GridIndex::build(&d, M, EPS);
        let mut t = KdTree::build(&d);
        let mut next = 0usize;
        let mut resorts = 0usize;
        let t0 = Instant::now();
        for batch in ops.chunks(BATCH) {
            for op in batch {
                match *op {
                    Op::Insert => {
                        let id = d.push_row(spare.point(next));
                        next += 1;
                        g.insert(&d, id);
                        t.insert(&d, id);
                    }
                    Op::Remove(id) => {
                        assert!(g.remove(id) && t.remove(id));
                    }
                }
            }
            resorts += usize::from(g.maybe_rebuild(&d));
            t.maybe_merge(&d);
        }
        let patch_secs = t0.elapsed().as_secs_f64();
        let (g_patch, t_patch) = (g, t);

        // ---- rebuild side: same splices, unconditional per-batch rebuild ----
        let mut d = base.clone();
        let mut g = GridIndex::build(&d, M, EPS);
        let mut t = KdTree::build(&d);
        let mut next = 0usize;
        let t0 = Instant::now();
        for batch in ops.chunks(BATCH) {
            for op in batch {
                match *op {
                    Op::Insert => {
                        let id = d.push_row(spare.point(next));
                        next += 1;
                        g.insert(&d, id);
                        t.insert(&d, id);
                    }
                    Op::Remove(id) => {
                        assert!(g.remove(id) && t.remove(id));
                    }
                }
            }
            g = g.rebuilt(&d);
            t = t.rebuilt(&d);
        }
        let rebuild_secs = t0.elapsed().as_secs_f64();

        // both policies are implementations of the same canonical index
        g_patch.assert_same_layout(&g);
        let (mut a, mut b) = (t_patch.live_ids(), t.live_ids());
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "{case}: kd-tree live sets diverged");

        let patch_ops_s = muts as f64 / patch_secs.max(1e-12);
        let rebuild_ops_s = muts as f64 / rebuild_secs.max(1e-12);
        let ratio = rebuild_secs / patch_secs.max(1e-12);
        println!(
            "{:>12} {:>9} {:>12.0} {:>12.0} {:>8} {:>15.2}x",
            case, muts, patch_ops_s, rebuild_ops_s, resorts, ratio
        );
        rows.push(Json::obj(vec![
            ("case", Json::Str(case.into())),
            ("dataset", Json::Str("susy_like".into())),
            ("corpus", Json::Num(N as f64)),
            ("mutations", Json::Num(muts as f64)),
            ("batch", Json::Num(BATCH as f64)),
            ("patch_secs", Json::Num(patch_secs)),
            ("rebuild_secs", Json::Num(rebuild_secs)),
            ("patch_ops_s", Json::Num(patch_ops_s)),
            ("rebuild_ops_s", Json::Num(rebuild_ops_s)),
            ("patch_resorts", Json::Num(resorts as f64)),
            ("patch_vs_rebuild", Json::Num(ratio)),
        ]));
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("churn".into())),
        (
            "engine",
            Json::Str(
                "threshold-patched maintenance: canonical CSR row splices \
                 + kd-tree delta buffer, dirty-fraction re-sorts"
                    .into(),
            ),
        ),
        (
            "baseline",
            Json::Str(
                "eager policy: identical splices + unconditional per-batch \
                 index rebuild (grid assemble + kd-tree rebuild)"
                    .into(),
            ),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_churn.json", doc.to_string() + "\n")
        .expect("write BENCH_churn.json");
    println!("wrote BENCH_churn.json");
}
