//! Overload bench: goodput under admission-controlled overload vs the
//! unbounded closed-loop saturation throughput (DESIGN.md §13).
//!
//! One resident [`KnnEngine`] serves every scenario (warm arenas, warm
//! executable cache). The schedule:
//!
//! * `saturation` - 4 closed-loop clients through a *permissive*
//!   ingress: the service's measured capacity, the denominator every
//!   overload case is judged against;
//! * `overload_newest` - 8 closed-loop clients (offered load roughly
//!   2x the saturating client count) through a pending bound of
//!   4 x BATCH rows, shedding newest-first;
//! * `overload_deadline` - the same offered load with a generous
//!   default deadline and [`ShedPolicy::ByDeadline`] victim selection.
//!
//! Tracked columns are same-run ratios (machine-portable):
//! `goodput_at_saturation` = overload-case served throughput /
//! saturation throughput - the ISSUE 10 acceptance bar says shedding
//! overhead may cost at most 15% of saturation goodput; and
//! `shed_precision` = typed rejections / rejected requests - every
//! request the service does not answer must carry a typed
//! [`Rejected`] in its error chain (the bench also asserts this
//! exactly, in-run, before any JSON is written). The admission ledger
//! (admitted == served + shed) is asserted per case. Emits
//! `BENCH_overload.json`, regression-gated against
//! `benches/baselines/BENCH_overload.json` in CI.
//!
//!   cargo bench --bench overload
//!   HKNN_RANKS=8 cargo bench --bench overload

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use hybrid_knn_join::prelude::*;
use hybrid_knn_join::util::json::Json;

const BATCH: usize = 64;
const SAT_CLIENTS: usize = 4;
const SAT_REQUESTS: usize = 6;
const OVER_CLIENTS: usize = 8;
const OVER_REQUESTS: usize = 8;

/// Closed-loop streaming of `requests` BATCH-row query slices per
/// client through `policy`. Rejected requests are counted (total and
/// typed) and not retried - the client spins straight on to its next
/// request, which is what keeps the offered load above the bound.
/// Returns (report, offered rows, rejected requests, typed rejections).
fn run_closed_loop(
    session: &mut KnnEngine,
    pool: &Dataset,
    clients: usize,
    requests: usize,
    policy: AdmissionPolicy,
) -> (ServiceReport, usize, usize, usize) {
    let ingress = Ingress::with_policy(policy);
    let errs = AtomicUsize::new(0);
    let typed = AtomicUsize::new(0);
    let rep = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let client = ingress.client();
                let (errs, typed) = (&errs, &typed);
                s.spawn(move || {
                    for r in 0..requests {
                        let start = ((c * requests + r) * BATCH)
                            % (pool.len() - BATCH);
                        let rows: Vec<usize> =
                            (start..start + BATCH).collect();
                        match client.query(&pool.gather(&rows)) {
                            Ok(_) => {}
                            Err(e) => {
                                errs.fetch_add(1, Ordering::Relaxed);
                                if e.downcast_ref::<Rejected>().is_some() {
                                    typed.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        let rep = session.serve(&ingress).expect("serve loop");
        for h in handles {
            h.join().expect("client thread panicked");
        }
        rep
    });
    (
        rep,
        clients * requests * BATCH,
        errs.load(Ordering::Relaxed),
        typed.load(Ordering::Relaxed),
    )
}

fn main() {
    let ranks: usize = std::env::var("HKNN_RANKS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let engine = Engine::load_default().expect("run `make artifacts` first");
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    let corpus = susy_like(2500).generate(0xFB);
    let pool = susy_like(2048).generate(0x5EEE);
    let k = 6;

    let mut p = HybridParams::new(k);
    p.cpu_ranks = ranks;
    let mut session =
        KnnEngine::build(&engine, &corpus, p).expect("resident engine");
    let warm = pool.gather(&(0..64).collect::<Vec<_>>());
    let _ = session.flush(&warm).expect("warmup flush");

    // the denominator: unbounded closed-loop saturation
    let (sat, sat_offered, sat_errs, _) = run_closed_loop(
        &mut session,
        &pool,
        SAT_CLIENTS,
        SAT_REQUESTS,
        AdmissionPolicy::default(),
    );
    assert_eq!(sat_errs, 0, "the permissive policy never rejects");
    assert_eq!(sat.queries, sat_offered, "saturation serves everything");
    let sat_qps = sat.throughput_qps.max(1e-12);
    println!(
        "saturation: {} queries in {:.4}s = {:.1} q/s \
         ({SAT_CLIENTS} clients, ranks={ranks}, hw={hw})",
        sat.queries, sat.wall_secs, sat_qps
    );

    let mut rows = vec![Json::obj(vec![
        ("case", Json::Str("saturation".into())),
        ("clients", Json::Num(SAT_CLIENTS as f64)),
        ("queries", Json::Num(sat.queries as f64)),
        ("throughput_qps", Json::Num(sat.throughput_qps)),
        ("p99_ms", Json::Num(sat.latency_p99 * 1e3)),
    ])];
    println!(
        "{:>17} {:>8} {:>8} {:>9} {:>9} {:>9} {:>9} {:>8} {:>9}",
        "case", "offered", "served", "shed", "qps", "p50 ms", "p99 ms",
        "goodput", "precision"
    );

    let bound = 4 * BATCH;
    let cases: [(&str, AdmissionPolicy); 2] = [
        (
            "overload_newest",
            AdmissionPolicy {
                max_pending_queries: bound,
                shed_policy: ShedPolicy::NewestFirst,
                ..AdmissionPolicy::default()
            },
        ),
        (
            "overload_deadline",
            AdmissionPolicy {
                max_pending_queries: bound,
                default_deadline: Some(Duration::from_secs(5)),
                shed_policy: ShedPolicy::ByDeadline,
                ..AdmissionPolicy::default()
            },
        ),
    ];
    for (name, policy) in cases {
        let (rep, offered, errs, typed) = run_closed_loop(
            &mut session,
            &pool,
            OVER_CLIENTS,
            OVER_REQUESTS,
            policy,
        );
        // exactly-once, client side: every request was answered or
        // rejected, and every rejection carried the typed error
        assert_eq!(
            offered,
            rep.queries + errs * BATCH,
            "{name}: offered rows = served + rejected"
        );
        assert_eq!(errs, typed, "{name}: an untyped rejection escaped");
        // the admission ledger, service side (no degradation in this
        // bench, so queue-side overload sheds cannot occur)
        assert_eq!(
            rep.admitted,
            rep.queries + rep.shed_deadline,
            "{name}: admitted rows are served or shed, exactly once"
        );
        assert_eq!(rep.rejected_requests, errs, "{name}: rejection count");
        let goodput = rep.throughput_qps / sat_qps;
        let precision = if errs == 0 {
            1.0
        } else {
            typed as f64 / errs as f64
        };
        let shed_rows = offered - rep.queries;
        println!(
            "{:>17} {:>8} {:>8} {:>9} {:>9.1} {:>9.2} {:>9.2} {:>7.2}x {:>9.2}",
            name,
            offered,
            rep.queries,
            shed_rows,
            rep.throughput_qps,
            rep.latency_p50 * 1e3,
            rep.latency_p99 * 1e3,
            goodput,
            precision
        );
        rows.push(Json::obj(vec![
            ("case", Json::Str(name.into())),
            ("clients", Json::Num(OVER_CLIENTS as f64)),
            ("offered", Json::Num(offered as f64)),
            ("queries", Json::Num(rep.queries as f64)),
            ("shed_rows", Json::Num(shed_rows as f64)),
            ("shed_overload", Json::Num(rep.shed_overload as f64)),
            ("shed_deadline", Json::Num(rep.shed_deadline as f64)),
            ("rejected_requests", Json::Num(rep.rejected_requests as f64)),
            ("flushes", Json::Num(rep.flushes as f64)),
            ("wall_secs", Json::Num(rep.wall_secs)),
            ("throughput_qps", Json::Num(rep.throughput_qps)),
            ("p50_ms", Json::Num(rep.latency_p50 * 1e3)),
            ("p99_ms", Json::Num(rep.latency_p99 * 1e3)),
            ("goodput_at_saturation", Json::Num(goodput)),
            ("shed_precision", Json::Num(precision)),
        ]));
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("overload".into())),
        (
            "baseline",
            Json::Str(
                "unbounded closed-loop saturation throughput (4 clients) \
                 on the same warm resident engine"
                    .into(),
            ),
        ),
        (
            "contender",
            Json::Str(
                "8 closed-loop clients (offered >= 2x the saturating \
                 client count) through a bounded ingress (4 x BATCH \
                 pending rows), shedding newest-first resp. by-deadline; \
                 rejected requests are not retried"
                    .into(),
            ),
        ),
        ("ranks", Json::Num(ranks as f64)),
        ("hw_threads", Json::Num(hw as f64)),
        ("batch_per_request", Json::Num(BATCH as f64)),
        ("max_pending_queries", Json::Num(bound as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_overload.json", doc.to_string() + "\n")
        .expect("write BENCH_overload.json");
    println!("wrote BENCH_overload.json");
}
