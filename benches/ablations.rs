//! Ablations over the design choices DESIGN.md calls out:
//!   REORDER on/off, indexed dims m, dist-vs-topk device path,
//!   and the adaptive tile class.
use hybrid_knn_join::bench::{secs, workloads, Table};
use hybrid_knn_join::prelude::*;
use hybrid_knn_join::runtime::Engine;

fn main() {
    let engine = Engine::load_default().expect("make artifacts");
    let ws = workloads();

    let mut t = Table::new(
        "Ablation - REORDER (variance dim reordering)",
        &["dataset", "K", "reorder", "time (s)", "|Q_gpu|", "|Q_fail|"],
    );
    for w in &ws {
        for reorder in [true, false] {
            let mut p = HybridParams::new(w.table_k);
            p.cpu_ranks = 3;
            p.reorder = reorder;
            let rep = HybridKnnJoin::run(&engine, &w.dataset(), &p).unwrap();
            t.row(vec![
                w.name.into(),
                w.table_k.to_string(),
                reorder.to_string(),
                secs(rep.response_time),
                rep.q_gpu.to_string(),
                rep.q_fail.to_string(),
            ]);
        }
    }
    println!("{}", t.render());

    let mut t = Table::new(
        "Ablation - indexed dims m (index dimensionality reduction)",
        &["dataset", "K", "m", "time (s)", "|Q_gpu|", "|Q_fail|"],
    );
    for w in &ws {
        for m in [2usize, 4, 6, 8] {
            let mut p = HybridParams::new(w.table_k);
            p.cpu_ranks = 3;
            p.m = m;
            let rep = HybridKnnJoin::run(&engine, &w.dataset(), &p).unwrap();
            t.row(vec![
                w.name.into(),
                w.table_k.to_string(),
                m.to_string(),
                secs(rep.response_time),
                rep.q_gpu.to_string(),
                rep.q_fail.to_string(),
            ]);
        }
    }
    println!("{}", t.render());

    let mut t = Table::new(
        "Ablation - device path (dist+host filter vs on-device top-k)",
        &["dataset", "K", "path", "time (s)", "gpu kernel (s)"],
    );
    for w in &ws {
        for topk in [false, true] {
            let mut p = HybridParams::new(w.table_k);
            p.cpu_ranks = 3;
            p.use_topk = topk;
            let rep = HybridKnnJoin::run(&engine, &w.dataset(), &p).unwrap();
            t.row(vec![
                w.name.into(),
                w.table_k.to_string(),
                if topk { "topk".into() } else { "dist".to_string() },
                secs(rep.response_time),
                secs(rep.gpu_kernel_time),
            ]);
        }
    }
    println!("{}", t.render());
}
