//! Fig. 8: response time vs beta for a range of gamma (rho=0).
use hybrid_knn_join::bench::{experiments, workloads};
use hybrid_knn_join::runtime::Engine;

fn main() {
    let engine = Engine::load_default().expect("make artifacts");
    let t = experiments::fig8(
        &engine,
        &workloads(),
        &[0.0, 0.25, 0.5, 0.75, 1.0],
        &[0.0, 0.6, 0.8, 1.0],
    )
    .unwrap();
    println!("{}", t.render());
}
