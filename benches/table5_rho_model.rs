//! Table V: rho^Model (Eq. 6) load balancing - speedup over rho=0.5.
use hybrid_knn_join::bench::{experiments, workloads};
use hybrid_knn_join::runtime::Engine;

fn main() {
    let engine = Engine::load_default().expect("make artifacts");
    let t = experiments::table5(&engine, &workloads()).unwrap();
    println!("{}", t.render());
}
