//! Core types: the point database, distance kernels (incl. SHORTC), and
//! KNN result containers (paper Sec. III problem statement).

/// The flat SoA KNN result table and its disjoint slot writers.
pub mod result;

pub use result::{
    BoundedHeap, KnnResult, Neighbor, Neighbors, NeighborsIter, SlotMut, SoaSlots,
};

/// An in-memory database of n-dimensional points, stored row-major f32
/// (flat, cache-friendly; the same layout the runtime uploads to PJRT).
#[derive(Debug, Clone)]
pub struct Dataset {
    data: Vec<f32>,
    dims: usize,
}

impl Dataset {
    /// Wrap a flat row-major buffer (length must divide by `dims`).
    pub fn new(data: Vec<f32>, dims: usize) -> Dataset {
        assert!(dims > 0, "dims must be positive");
        assert!(
            data.len() % dims == 0,
            "data length {} not divisible by dims {dims}",
            data.len()
        );
        Dataset { data, dims }
    }

    /// Build from per-point rows (all rows must share one length).
    pub fn from_rows(rows: &[Vec<f32>]) -> Dataset {
        assert!(!rows.is_empty());
        let dims = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * dims);
        for r in rows {
            assert_eq!(r.len(), dims, "ragged rows");
            data.extend_from_slice(r);
        }
        Dataset::new(data, dims)
    }

    /// Number of points.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len() / self.dims
    }

    /// True when the dataset holds no points.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Dimensionality n.
    #[inline]
    pub fn dims(&self) -> usize {
        self.dims
    }

    /// Point i's coordinates.
    #[inline]
    pub fn point(&self, i: usize) -> &[f32] {
        &self.data[i * self.dims..(i + 1) * self.dims]
    }

    /// The flat row-major buffer.
    #[inline]
    pub fn raw(&self) -> &[f32] {
        &self.data
    }

    /// Coordinate j of point i.
    #[inline]
    pub fn coord(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.dims + j]
    }

    /// Apply a dimension permutation (used by REORDER): new dim j comes
    /// from old dim perm[j].
    pub fn permute_dims(&self, perm: &[usize]) -> Dataset {
        assert_eq!(perm.len(), self.dims);
        let n = self.len();
        let mut out = vec![0f32; self.data.len()];
        for i in 0..n {
            let src = self.point(i);
            let dst = &mut out[i * self.dims..(i + 1) * self.dims];
            for (j, &pj) in perm.iter().enumerate() {
                dst[j] = src[pj];
            }
        }
        Dataset::new(out, self.dims)
    }

    /// Gather a subset of points (by id) into a new dataset.
    pub fn gather(&self, ids: &[usize]) -> Dataset {
        let mut data = Vec::with_capacity(ids.len() * self.dims);
        for &i in ids {
            data.extend_from_slice(self.point(i));
        }
        Dataset::new(data, self.dims)
    }

    /// Append one point, returning its id (= the previous length). The
    /// churn path grows the resident corpus in place: ids are append-only,
    /// so every existing id, index entry and result row stays valid.
    pub fn push_row(&mut self, row: &[f32]) -> u32 {
        assert_eq!(row.len(), self.dims, "row dimensionality mismatch");
        let id = self.len() as u32;
        self.data.extend_from_slice(row);
        id
    }
}

/// Full squared Euclidean distance. The `chunks_exact(8)` body computes
/// each 8-wide block's lanes independently and pairwise-reduces them
/// (bounds-check free, autovectorizable without reassociating a serial
/// accumulator - strict FP semantics forbid that rewrite on the naive
/// loop).
///
/// The accumulation order is *bit-identical* to
/// [`sqdist_short_circuit`]'s (same per-block pairwise reduction into one
/// serial accumulator, same serial remainder): for any pair, whichever
/// kernel a caller happens to use - the choice depends on transient heap
/// state in `KdTree::knn_into` - the returned f64 has the same bits. The
/// churn rebuild-equivalence harness (rust/tests/churn.rs) relies on
/// this.
#[inline]
pub fn sqdist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    let mut acc = 0f64;
    for (xa, xb) in ca.zip(cb) {
        let mut lanes = [0f64; 8];
        for j in 0..8 {
            let d = (xa[j] - xb[j]) as f64;
            lanes[j] = d * d;
        }
        acc += ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
    }
    for (&x, &y) in ra.iter().zip(rb) {
        let d = (x - y) as f64;
        acc += d * d;
    }
    acc
}

/// SHORTC (paper Sec. IV-E): abort the accumulation as soon as the running
/// total exceeds `cut` (squared distance threshold). Returns None when the
/// true distance is certainly > cut.
///
/// The cut check runs once per 8-dim block: it amortises the branch like
/// the paper's unrolled CUDA loop while keeping early exit effective in
/// high dimensions, and the fixed-width `chunks_exact` block (bounds-check
/// free, pairwise-reduced) autovectorizes.
#[inline]
pub fn sqdist_short_circuit(a: &[f32], b: &[f32], cut: f64) -> Option<f64> {
    debug_assert_eq!(a.len(), b.len());
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    let mut acc = 0f64;
    for (xa, xb) in ca.zip(cb) {
        let mut lanes = [0f64; 8];
        for j in 0..8 {
            let d = (xa[j] - xb[j]) as f64;
            lanes[j] = d * d;
        }
        acc += ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
        if acc > cut {
            return None;
        }
    }
    for (&x, &y) in ra.iter().zip(rb) {
        let d = (x - y) as f64;
        acc += d * d;
    }
    if acc > cut {
        None
    } else {
        Some(acc)
    }
}

/// Squared distance over only the first `m` dims (index projection).
#[inline]
pub fn sqdist_prefix(a: &[f32], b: &[f32], m: usize) -> f64 {
    let mut acc = 0f64;
    for i in 0..m {
        let d = (a[i] - b[i]) as f64;
        acc += d * d;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn dataset_accessors() {
        let d = Dataset::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        assert_eq!(d.len(), 3);
        assert_eq!(d.dims(), 2);
        assert_eq!(d.point(1), &[3.0, 4.0]);
        assert_eq!(d.coord(2, 1), 6.0);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn dataset_rejects_ragged() {
        Dataset::new(vec![1.0, 2.0, 3.0], 2);
    }

    #[test]
    fn permute_dims_roundtrip() {
        let d = Dataset::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let p = d.permute_dims(&[2, 0, 1]);
        assert_eq!(p.point(0), &[3.0, 1.0, 2.0]);
        // inverse permutation restores
        let back = p.permute_dims(&[1, 2, 0]);
        assert_eq!(back.point(0), d.point(0));
        assert_eq!(back.point(1), d.point(1));
    }

    #[test]
    fn gather_subset() {
        let d = Dataset::from_rows(&[vec![0.0], vec![1.0], vec![2.0], vec![3.0]]);
        let g = d.gather(&[3, 1]);
        assert_eq!(g.len(), 2);
        assert_eq!(g.point(0), &[3.0]);
        assert_eq!(g.point(1), &[1.0]);
    }

    #[test]
    fn sqdist_known() {
        assert_eq!(sqdist(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(sqdist(&[1.0], &[1.0]), 0.0);
    }

    fn check_short_circuit_case(a: &[f32], b: &[f32], cut: f64) {
        let full = sqdist(a, b);
        match sqdist_short_circuit(a, b, cut) {
            Some(d) => {
                assert!((d - full).abs() < 1e-9);
                assert!(full <= cut + 1e-12);
            }
            None => assert!(full > cut - 1e-9),
        }
    }

    #[test]
    fn short_circuit_agrees_with_full() {
        prop::cases(200, 0xC0FE, |rng| {
            let n = 1 + rng.below(40);
            let a: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect();
            let cut = rng.range(0.0, 4.0 * n as f64);
            check_short_circuit_case(&a, &b, cut);
        });
    }

    #[test]
    fn short_circuit_remainder_lanes() {
        // lengths 1..=9 cover every remainder width plus the first full
        // 8-wide block with a 1-long tail
        prop::cases(100, 0xC0DE, |rng| {
            for n in 1..=9usize {
                let a: Vec<f32> =
                    (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect();
                let b: Vec<f32> =
                    (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect();
                let full = sqdist(&a, &b);
                // a generous cut must return the full distance...
                check_short_circuit_case(&a, &b, full + 1.0);
                assert!(sqdist_short_circuit(&a, &b, full + 1.0).is_some());
                // ...a cut strictly below it must reject (remainder path
                // must enforce the cut, not just the 8-wide blocks)
                if full > 1e-9 {
                    assert!(sqdist_short_circuit(&a, &b, full * 0.5 - 1e-12).is_none());
                }
                check_short_circuit_case(&a, &b, rng.range(0.0, 4.0 * n as f64));
            }
        });
    }

    #[test]
    fn short_circuit_bit_identical_to_full() {
        // The churn harness's foundation: when the short-circuit kernel
        // returns a distance at all, its bits equal the full kernel's -
        // the two share one accumulation order, so which kernel ran
        // (a transient-heap-state decision) can never show in results.
        prop::cases(300, 0xB17E, |rng| {
            let n = 1 + rng.below(40);
            let a: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect();
            let full = sqdist(&a, &b);
            if let Some(d) = sqdist_short_circuit(&a, &b, full) {
                assert_eq!(d.to_bits(), full.to_bits());
            } else {
                panic!("cut == full distance must not short-circuit");
            }
        });
    }

    #[test]
    fn push_row_appends() {
        let mut d = Dataset::from_rows(&[vec![1.0, 2.0]]);
        let id = d.push_row(&[3.0, 4.0]);
        assert_eq!(id, 1);
        assert_eq!(d.len(), 2);
        assert_eq!(d.point(1), &[3.0, 4.0]);
    }

    #[test]
    fn prefix_distance_partial() {
        let a = [1.0f32, 2.0, 10.0];
        let b = [1.0f32, 4.0, -10.0];
        assert_eq!(sqdist_prefix(&a, &b, 2), 4.0);
        assert!(sqdist_prefix(&a, &b, 2) <= sqdist(&a, &b));
    }
}
