//! KNN result containers: per-query bounded neighbor heaps and the final
//! join result (the paper's key/value result set, Sec. V-H, after
//! `filterKeys`).

use std::cmp::Ordering;

/// One neighbor: point id + squared distance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    pub id: u32,
    pub dist2: f64,
}

impl Eq for Neighbor {}

impl PartialOrd for Neighbor {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Neighbor {
    fn cmp(&self, other: &Self) -> Ordering {
        // total order: by distance then id (NaN-free by construction)
        self.dist2
            .partial_cmp(&other.dist2)
            .unwrap_or(Ordering::Equal)
            .then(self.id.cmp(&other.id))
    }
}

/// Bounded max-heap of the K best (smallest-distance) neighbors seen so
/// far. `push` is O(log K); the hot path of every engine in this repo.
#[derive(Debug, Clone)]
pub struct BoundedHeap {
    k: usize,
    heap: Vec<Neighbor>, // max-heap by dist2
}

impl BoundedHeap {
    pub fn new(k: usize) -> Self {
        assert!(k > 0);
        BoundedHeap { k, heap: Vec::with_capacity(k) }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    #[inline]
    pub fn is_full(&self) -> bool {
        self.heap.len() == self.k
    }

    /// Current worst (largest) distance kept, or +inf if not yet full.
    /// Search pruning bound: anything farther cannot enter the result.
    #[inline]
    pub fn bound(&self) -> f64 {
        if self.is_full() {
            self.heap[0].dist2
        } else {
            f64::INFINITY
        }
    }

    /// Offer a neighbor; keeps only the K nearest.
    #[inline]
    pub fn push(&mut self, n: Neighbor) {
        if self.heap.len() < self.k {
            self.heap.push(n);
            // sift up
            let mut i = self.heap.len() - 1;
            while i > 0 {
                let parent = (i - 1) / 2;
                if self.heap[parent] < self.heap[i] {
                    self.heap.swap(parent, i);
                    i = parent;
                } else {
                    break;
                }
            }
        } else if n.dist2 < self.heap[0].dist2 {
            self.heap[0] = n;
            // sift down
            let mut i = 0;
            loop {
                let l = 2 * i + 1;
                let r = 2 * i + 2;
                let mut big = i;
                if l < self.heap.len() && self.heap[big] < self.heap[l] {
                    big = l;
                }
                if r < self.heap.len() && self.heap[big] < self.heap[r] {
                    big = r;
                }
                if big == i {
                    break;
                }
                self.heap.swap(i, big);
                i = big;
            }
        }
    }

    /// Extract neighbors sorted ascending by distance.
    pub fn into_sorted(mut self) -> Vec<Neighbor> {
        self.heap.sort();
        self.heap
    }

    pub fn as_slice(&self) -> &[Neighbor] {
        &self.heap
    }
}

/// The KNN self-join result: for each query id, its (up to) K nearest
/// neighbors sorted ascending by distance.
#[derive(Debug, Clone, Default)]
pub struct KnnResult {
    /// neighbors[i] are the neighbors of query point i (empty = unsolved).
    neighbors: Vec<Vec<Neighbor>>,
}

impl KnnResult {
    pub fn with_capacity(n: usize) -> Self {
        KnnResult { neighbors: vec![Vec::new(); n] }
    }

    pub fn len(&self) -> usize {
        self.neighbors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.neighbors.is_empty()
    }

    pub fn set(&mut self, query: usize, mut ns: Vec<Neighbor>) {
        ns.sort();
        self.neighbors[query] = ns;
    }

    pub fn get(&self, query: usize) -> &[Neighbor] {
        &self.neighbors[query]
    }

    /// Queries that found at least k neighbors.
    pub fn solved_count(&self, k: usize) -> usize {
        self.neighbors.iter().filter(|ns| ns.len() >= k).count()
    }

    /// Merge another result into this one (other wins where it is solved).
    pub fn merge_from(&mut self, other: KnnResult) {
        assert_eq!(self.len(), other.len());
        for (mine, theirs) in self.neighbors.iter_mut().zip(other.neighbors) {
            if !theirs.is_empty() {
                *mine = theirs;
            }
        }
    }

    /// Total number of stored neighbor entries (result set size |R|).
    pub fn total_neighbors(&self) -> usize {
        self.neighbors.iter().map(|n| n.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn nb(id: u32, d: f64) -> Neighbor {
        Neighbor { id, dist2: d }
    }

    #[test]
    fn heap_keeps_k_smallest() {
        let mut h = BoundedHeap::new(3);
        for (id, d) in [(0, 5.0), (1, 1.0), (2, 4.0), (3, 2.0), (4, 3.0)] {
            h.push(nb(id, d));
        }
        let out = h.into_sorted();
        assert_eq!(
            out.iter().map(|n| n.id).collect::<Vec<_>>(),
            vec![1, 3, 4]
        );
        assert_eq!(out[0].dist2, 1.0);
    }

    #[test]
    fn heap_bound_tracks_worst() {
        let mut h = BoundedHeap::new(2);
        assert_eq!(h.bound(), f64::INFINITY);
        h.push(nb(0, 9.0));
        assert_eq!(h.bound(), f64::INFINITY);
        h.push(nb(1, 4.0));
        assert_eq!(h.bound(), 9.0);
        h.push(nb(2, 1.0));
        assert_eq!(h.bound(), 4.0);
    }

    #[test]
    fn heap_property_matches_sort() {
        prop::cases(100, 0xBEEF, |rng| {
            let n = 1 + rng.below(64);
            let k = 1 + rng.below(12);
            let items: Vec<Neighbor> = (0..n)
                .map(|i| nb(i as u32, rng.range(0.0, 100.0)))
                .collect();
            let mut h = BoundedHeap::new(k);
            for &it in &items {
                h.push(it);
            }
            let got = h.into_sorted();
            let mut want = items.clone();
            want.sort();
            want.truncate(k);
            assert_eq!(got, want);
        });
    }

    #[test]
    fn result_merge_and_counts() {
        let mut a = KnnResult::with_capacity(3);
        a.set(0, vec![nb(1, 1.0)]);
        let mut b = KnnResult::with_capacity(3);
        b.set(1, vec![nb(2, 2.0), nb(0, 0.5)]);
        a.merge_from(b);
        assert_eq!(a.get(0).len(), 1);
        assert_eq!(a.get(1)[0].id, 0, "sorted ascending");
        assert_eq!(a.solved_count(1), 2);
        assert_eq!(a.solved_count(2), 1);
        assert_eq!(a.total_neighbors(), 3);
    }
}
