//! KNN result containers: per-query bounded neighbor heaps and the final
//! join result (the paper's key/value result set, Sec. V-H, after
//! `filterKeys`).
//!
//! `KnnResult` is a flat fixed-stride structure-of-arrays: one `u32` id
//! lane and one `f64` dist² lane, `k` entries per query, plus a per-query
//! count. Every engine (CPU ranks, the GPU merge path, the Q^Fail pass)
//! writes its queries *in place* through disjoint `SoaSlots` writers, so
//! the hybrid join performs no post-pass merge copies and the steady-state
//! CPU query loop performs zero heap allocations (see DESIGN.md §3).

use std::cmp::Ordering;
use std::marker::PhantomData;

/// One neighbor: point id + squared distance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    /// neighbor point id
    pub id: u32,
    /// squared distance to the query
    pub dist2: f64,
}

impl Eq for Neighbor {}

impl PartialOrd for Neighbor {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Neighbor {
    fn cmp(&self, other: &Self) -> Ordering {
        // total order: by distance then id (NaN-free by construction)
        self.dist2
            .partial_cmp(&other.dist2)
            .unwrap_or(Ordering::Equal)
            .then(self.id.cmp(&other.id))
    }
}

/// Bounded max-heap of the K best (smallest-distance) neighbors seen so
/// far. `push` is O(log K); the hot path of every engine in this repo.
/// Reusable: `reset` rebounds K without dropping the allocation, and
/// `drain_sorted_into` empties the heap in place, keeping its capacity.
#[derive(Debug, Clone)]
pub struct BoundedHeap {
    k: usize,
    heap: Vec<Neighbor>, // max-heap by dist2
}

impl BoundedHeap {
    /// New empty heap bounded at `k` entries.
    pub fn new(k: usize) -> Self {
        assert!(k > 0);
        BoundedHeap { k, heap: Vec::with_capacity(k) }
    }

    /// Re-arm for a new query with bound `k`, reusing the allocation.
    /// Zero-alloc once the largest `k` seen has been reserved.
    #[inline]
    pub fn reset(&mut self, k: usize) {
        assert!(k > 0);
        self.k = k;
        self.heap.clear();
        self.heap.reserve(k);
    }

    /// Neighbors currently held (≤ K).
    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no neighbor has been kept yet.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// True when the heap holds K neighbors (bound is live).
    #[inline]
    pub fn is_full(&self) -> bool {
        self.heap.len() == self.k
    }

    /// Current worst (largest) distance kept, or +inf if not yet full.
    /// Search pruning bound: anything farther cannot enter the result.
    #[inline]
    pub fn bound(&self) -> f64 {
        if self.is_full() {
            self.heap[0].dist2
        } else {
            f64::INFINITY
        }
    }

    /// Offer a neighbor; keeps only the K nearest *under the total
    /// `(dist², id)` order* - on an exact distance tie with the current
    /// worst, the smaller id wins. That makes the kept k-set canonical
    /// (the k smallest pairs of everything offered), independent of the
    /// order candidates arrive in - the property the churn harness's
    /// delta-vs-rebuild bit-equivalence rests on, since a buffered delta
    /// scan visits candidates in a different order than a rebuilt tree.
    #[inline]
    pub fn push(&mut self, n: Neighbor) {
        if self.heap.len() < self.k {
            self.heap.push(n);
            // sift up
            let mut i = self.heap.len() - 1;
            while i > 0 {
                let parent = (i - 1) / 2;
                if self.heap[parent] < self.heap[i] {
                    self.heap.swap(parent, i);
                    i = parent;
                } else {
                    break;
                }
            }
        } else if n < self.heap[0] {
            self.heap[0] = n;
            // sift down
            let mut i = 0;
            loop {
                let l = 2 * i + 1;
                let r = 2 * i + 2;
                let mut big = i;
                if l < self.heap.len() && self.heap[big] < self.heap[l] {
                    big = l;
                }
                if r < self.heap.len() && self.heap[big] < self.heap[r] {
                    big = r;
                }
                if big == i {
                    break;
                }
                self.heap.swap(i, big);
                i = big;
            }
        }
    }

    /// Extract neighbors sorted ascending by distance.
    pub fn into_sorted(mut self) -> Vec<Neighbor> {
        self.heap.sort_unstable();
        self.heap
    }

    /// Drain sorted ascending into a fresh `Vec` (the heap's buffer moves
    /// out, so the next `reset` re-allocates - convenience path only; the
    /// zero-alloc emit path is `drain_sorted_into`).
    pub fn drain_sorted(&mut self) -> Vec<Neighbor> {
        self.heap.sort_unstable();
        std::mem::take(&mut self.heap)
    }

    /// Drain sorted ascending into parallel SoA lanes; returns the number
    /// of entries written. The allocation-free emit path of the engines.
    pub fn drain_sorted_into(&mut self, ids: &mut [u32], dist2: &mut [f64]) -> usize {
        self.heap.sort_unstable();
        let n = self.heap.len();
        assert!(
            n <= ids.len() && n <= dist2.len(),
            "result slot narrower than heap: {n} > {}",
            ids.len().min(dist2.len())
        );
        for (i, nb) in self.heap.iter().enumerate() {
            ids[i] = nb.id;
            dist2[i] = nb.dist2;
        }
        self.heap.clear();
        n
    }

    /// The kept neighbors in heap order (unsorted).
    pub fn as_slice(&self) -> &[Neighbor] {
        &self.heap
    }
}

/// The KNN join result in flat SoA form: for each query id, up to K
/// nearest neighbors sorted ascending by distance, stored at stride K in
/// `ids`/`dist2` with the valid prefix length in `counts` (0 = unsolved).
#[derive(Debug, Clone)]
pub struct KnnResult {
    k: usize,
    counts: Vec<u32>,
    ids: Vec<u32>,
    dist2: Vec<f64>,
}

impl KnnResult {
    /// Result table for `n` queries, `k` neighbor slots per query.
    pub fn new(n: usize, k: usize) -> Self {
        assert!(k > 0, "k must be positive");
        KnnResult {
            k,
            counts: vec![0; n],
            ids: vec![0; n * k],
            dist2: vec![0.0; n * k],
        }
    }

    /// Number of query slots.
    pub fn len(&self) -> usize {
        self.counts.len()
    }

    /// True when the table has no query slots.
    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    /// The per-query stride (neighbor capacity).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of neighbors stored for `query`.
    #[inline]
    pub fn count(&self, query: usize) -> usize {
        self.counts[query] as usize
    }

    /// The neighbors of `query`, ascending by distance.
    #[inline]
    pub fn get(&self, query: usize) -> Neighbors<'_> {
        let c = self.counts[query] as usize;
        let base = query * self.k;
        Neighbors {
            ids: &self.ids[base..base + c],
            dist2: &self.dist2[base..base + c],
        }
    }

    /// Drain `heap` (sorted) into the slot of `query`. Allocation-free.
    pub fn write_heap(&mut self, query: usize, heap: &mut BoundedHeap) {
        let base = query * self.k;
        let n = heap.drain_sorted_into(
            &mut self.ids[base..base + self.k],
            &mut self.dist2[base..base + self.k],
        );
        self.counts[query] = n as u32;
    }

    /// Store up to k neighbors for `query` (sorted on the way in).
    /// Convenience for tests and small consumers - allocates a scratch
    /// copy for the sort; engines use `write_heap`/`SoaSlots` instead.
    pub fn set(&mut self, query: usize, ns: &[Neighbor]) {
        assert!(ns.len() <= self.k, "{} neighbors > stride {}", ns.len(), self.k);
        let mut sorted = ns.to_vec();
        sorted.sort_unstable();
        let base = query * self.k;
        for (i, nb) in sorted.iter().enumerate() {
            self.ids[base + i] = nb.id;
            self.dist2[base + i] = nb.dist2;
        }
        self.counts[query] = sorted.len() as u32;
    }

    /// Queries that found at least k neighbors.
    pub fn solved_count(&self, k: usize) -> usize {
        self.counts.iter().filter(|&&c| c as usize >= k).count()
    }

    /// Total number of stored neighbor entries (result set size |R|).
    pub fn total_neighbors(&self) -> usize {
        self.counts.iter().map(|&c| c as usize).sum()
    }

    /// Order-sensitive FNV-1a checksum over the whole table: per-query
    /// counts, id lanes and dist² bit patterns, in query order. Two
    /// tables compare equal iff their checksums do (up to hash
    /// collisions), which lets the benches assert result equivalence
    /// across runs without shipping full tables into the JSON. Note
    /// dist² enters as raw bits: results that agree only up to
    /// float-rounding (e.g. CPU f64 vs GPU f32 solves of the same
    /// query) hash differently by design.
    pub fn checksum(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        fn eat(mut h: u64, word: u64) -> u64 {
            for i in 0..8 {
                h ^= (word >> (8 * i)) & 0xff;
                h = h.wrapping_mul(PRIME);
            }
            h
        }
        let mut h = eat(eat(OFFSET, self.counts.len() as u64), self.k as u64);
        for (q, &c) in self.counts.iter().enumerate() {
            h = eat(h, c as u64);
            let base = q * self.k;
            for i in base..base + c as usize {
                h = eat(h, self.ids[i] as u64);
                h = eat(h, self.dist2[i].to_bits());
            }
        }
        h
    }

    /// Disjoint-slot writer factory for concurrent in-place result
    /// emission. Holds the table mutably borrowed until dropped.
    pub fn slots(&mut self) -> SoaSlots<'_> {
        SoaSlots {
            counts: self.counts.as_mut_ptr(),
            ids: self.ids.as_mut_ptr(),
            dist2: self.dist2.as_mut_ptr(),
            n: self.counts.len(),
            k: self.k,
            _borrow: PhantomData,
        }
    }
}

/// Borrowed view of one query's neighbors (SoA lanes zipped on demand).
#[derive(Debug, Clone, Copy)]
pub struct Neighbors<'a> {
    ids: &'a [u32],
    dist2: &'a [f64],
}

impl<'a> Neighbors<'a> {
    /// Number of neighbors in the view.
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    /// True when the query has no stored neighbors.
    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// The i-th nearest neighbor, if present.
    pub fn get(&self, i: usize) -> Option<Neighbor> {
        if i < self.ids.len() {
            Some(Neighbor { id: self.ids[i], dist2: self.dist2[i] })
        } else {
            None
        }
    }

    /// The i-th nearest neighbor; panics when out of range.
    pub fn at(&self, i: usize) -> Neighbor {
        self.get(i).expect("neighbor index out of range")
    }

    /// The nearest neighbor, if any.
    pub fn first(&self) -> Option<Neighbor> {
        self.get(0)
    }

    /// Iterate neighbors ascending by distance.
    pub fn iter(&self) -> NeighborsIter<'a> {
        NeighborsIter { ids: self.ids.iter(), dist2: self.dist2.iter() }
    }

    /// The raw id lane.
    pub fn ids(&self) -> &'a [u32] {
        self.ids
    }

    /// The raw dist² lane.
    pub fn dist2s(&self) -> &'a [f64] {
        self.dist2
    }

    /// Collect the view into owned `Neighbor`s (tests/consumers).
    pub fn to_vec(&self) -> Vec<Neighbor> {
        self.iter().collect()
    }
}

impl<'a> IntoIterator for Neighbors<'a> {
    type Item = Neighbor;
    type IntoIter = NeighborsIter<'a>;

    fn into_iter(self) -> NeighborsIter<'a> {
        NeighborsIter { ids: self.ids.iter(), dist2: self.dist2.iter() }
    }
}

/// Iterator over a `Neighbors` view, yielding `Neighbor` by value.
#[derive(Debug, Clone)]
pub struct NeighborsIter<'a> {
    ids: std::slice::Iter<'a, u32>,
    dist2: std::slice::Iter<'a, f64>,
}

impl<'a> Iterator for NeighborsIter<'a> {
    type Item = Neighbor;

    fn next(&mut self) -> Option<Neighbor> {
        match (self.ids.next(), self.dist2.next()) {
            (Some(&id), Some(&d)) => Some(Neighbor { id, dist2: d }),
            _ => None,
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        self.ids.size_hint()
    }
}

impl ExactSizeIterator for NeighborsIter<'_> {}

/// Hands out mutable SoA slot views for *disjoint* query ids so multiple
/// engines / ranks write one result table concurrently with no locks and
/// no merge pass. The only unsafe surface of the result layer; the
/// soundness contract is concentrated in [`SoaSlots::slot`].
pub struct SoaSlots<'a> {
    counts: *mut u32,
    ids: *mut u32,
    dist2: *mut f64,
    n: usize,
    k: usize,
    _borrow: PhantomData<&'a mut KnnResult>,
}

// SAFETY: the pointers stay valid for 'a (the table is mutably borrowed
// for that long), and disjointness of concurrent `slot` calls is the
// caller contract documented on `slot`.
unsafe impl Send for SoaSlots<'_> {}
unsafe impl Sync for SoaSlots<'_> {}

impl SoaSlots<'_> {
    /// Number of query slots in the underlying table.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when the underlying table has no query slots.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// The per-query stride (neighbor capacity).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Mutable view of one query's slot.
    ///
    /// # Safety
    /// No two threads may hold a slot for the same `query` at the same
    /// time. Callers satisfy this by construction: query lists are
    /// duplicate-free and each query id is claimed by exactly one worker
    /// (e.g. `util::pool::parallel_chunks*` hands each index range to one
    /// thread), and sequential passes (GPU resolve, Q^Fail) only touch
    /// queries no concurrent writer owns.
    pub unsafe fn slot(&self, query: usize) -> SlotMut<'_> {
        assert!(query < self.n, "slot {query} out of range {}", self.n);
        let base = query * self.k;
        SlotMut {
            count: &mut *self.counts.add(query),
            ids: std::slice::from_raw_parts_mut(self.ids.add(base), self.k),
            dist2: std::slice::from_raw_parts_mut(self.dist2.add(base), self.k),
        }
    }
}

/// Exclusive writer for one query's SoA slot.
pub struct SlotMut<'a> {
    count: &'a mut u32,
    ids: &'a mut [u32],
    dist2: &'a mut [f64],
}

impl SlotMut<'_> {
    /// Drain `heap` (sorted ascending) into this slot. Allocation-free.
    pub fn write_heap(&mut self, heap: &mut BoundedHeap) {
        *self.count = heap.drain_sorted_into(self.ids, self.dist2) as u32;
    }

    /// Store pre-sorted neighbors verbatim.
    pub fn write_sorted(&mut self, ns: &[Neighbor]) {
        assert!(ns.len() <= self.ids.len());
        for (i, nb) in ns.iter().enumerate() {
            self.ids[i] = nb.id;
            self.dist2[i] = nb.dist2;
        }
        *self.count = ns.len() as u32;
    }

    /// Mark the query unsolved (count 0; lanes left as-is).
    pub fn clear(&mut self) {
        *self.count = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{pool, prop};

    fn nb(id: u32, d: f64) -> Neighbor {
        Neighbor { id, dist2: d }
    }

    #[test]
    fn heap_keeps_k_smallest() {
        let mut h = BoundedHeap::new(3);
        for (id, d) in [(0, 5.0), (1, 1.0), (2, 4.0), (3, 2.0), (4, 3.0)] {
            h.push(nb(id, d));
        }
        let out = h.into_sorted();
        assert_eq!(
            out.iter().map(|n| n.id).collect::<Vec<_>>(),
            vec![1, 3, 4]
        );
        assert_eq!(out[0].dist2, 1.0);
    }

    #[test]
    fn heap_bound_tracks_worst() {
        let mut h = BoundedHeap::new(2);
        assert_eq!(h.bound(), f64::INFINITY);
        h.push(nb(0, 9.0));
        assert_eq!(h.bound(), f64::INFINITY);
        h.push(nb(1, 4.0));
        assert_eq!(h.bound(), 9.0);
        h.push(nb(2, 1.0));
        assert_eq!(h.bound(), 4.0);
    }

    #[test]
    fn heap_property_matches_sort() {
        prop::cases(100, 0xBEEF, |rng| {
            let n = 1 + rng.below(64);
            let k = 1 + rng.below(12);
            let items: Vec<Neighbor> = (0..n)
                .map(|i| nb(i as u32, rng.range(0.0, 100.0)))
                .collect();
            let mut h = BoundedHeap::new(k);
            for &it in &items {
                h.push(it);
            }
            let got = h.into_sorted();
            let mut want = items.clone();
            want.sort();
            want.truncate(k);
            assert_eq!(got, want);
        });
    }

    #[test]
    fn heap_tie_break_is_order_independent() {
        // Exact distance ties resolve by id no matter the arrival order:
        // the kept set is the k smallest (dist², id) pairs, full stop.
        prop::cases(100, 0x71E5, |rng| {
            let k = 1 + rng.below(6);
            let n = k + rng.below(24);
            // few distinct distances -> ties are common
            let mut items: Vec<Neighbor> = (0..n)
                .map(|i| nb(i as u32, rng.below(4) as f64))
                .collect();
            let mut want = items.clone();
            want.sort();
            want.truncate(k);
            // forward order
            let mut h = BoundedHeap::new(k);
            for &it in &items {
                h.push(it);
            }
            assert_eq!(h.into_sorted(), want);
            // reversed order must keep the identical set
            items.reverse();
            let mut h = BoundedHeap::new(k);
            for &it in &items {
                h.push(it);
            }
            assert_eq!(h.into_sorted(), want);
        });
    }

    #[test]
    fn heap_reset_reuses_and_rebounds() {
        let mut h = BoundedHeap::new(2);
        h.push(nb(0, 1.0));
        h.push(nb(1, 2.0));
        h.reset(4);
        assert!(h.is_empty());
        assert_eq!(h.bound(), f64::INFINITY);
        for i in 0..6 {
            h.push(nb(i, i as f64));
        }
        assert_eq!(h.len(), 4);
        assert_eq!(h.bound(), 3.0);
    }

    #[test]
    fn heap_drain_into_lanes_sorted() {
        let mut h = BoundedHeap::new(3);
        for (id, d) in [(5, 3.0), (6, 1.0), (7, 2.0)] {
            h.push(nb(id, d));
        }
        let mut ids = [0u32; 3];
        let mut d2 = [0f64; 3];
        let n = h.drain_sorted_into(&mut ids, &mut d2);
        assert_eq!(n, 3);
        assert_eq!(ids, [6, 7, 5]);
        assert_eq!(d2, [1.0, 2.0, 3.0]);
        assert!(h.is_empty(), "drained heap is reusable");
    }

    #[test]
    fn result_soa_set_get_counts() {
        let mut r = KnnResult::new(3, 2);
        assert_eq!(r.len(), 3);
        assert_eq!(r.k(), 2);
        r.set(0, &[nb(1, 1.0)]);
        r.set(1, &[nb(2, 2.0), nb(0, 0.5)]); // unsorted in, sorted out
        assert_eq!(r.get(0).len(), 1);
        assert_eq!(r.get(1).at(0).id, 0, "sorted ascending");
        assert_eq!(r.get(1).at(1).id, 2);
        assert!(r.get(2).is_empty());
        assert_eq!(r.count(1), 2);
        assert_eq!(r.solved_count(1), 2);
        assert_eq!(r.solved_count(2), 1);
        assert_eq!(r.total_neighbors(), 3);
        // overwrite in place (the Q^Fail reassignment pattern)
        r.set(0, &[nb(9, 0.25), nb(8, 0.75)]);
        assert_eq!(r.get(0).ids(), &[9, 8]);
        assert_eq!(r.get(0).dist2s(), &[0.25, 0.75]);
    }

    #[test]
    fn result_view_iteration() {
        let mut r = KnnResult::new(1, 3);
        r.set(0, &[nb(3, 3.0), nb(1, 1.0), nb(2, 2.0)]);
        let v = r.get(0);
        let ids: Vec<u32> = v.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
        assert_eq!(v.first().unwrap().dist2, 1.0);
        assert_eq!(v.get(5), None);
        let mut by_for = Vec::new();
        for n in v {
            by_for.push(n.dist2);
        }
        assert_eq!(by_for, vec![1.0, 2.0, 3.0]);
        assert_eq!(v.to_vec().len(), 3);
    }

    #[test]
    fn checksum_distinguishes_and_matches() {
        let mut a = KnnResult::new(3, 2);
        let mut b = KnnResult::new(3, 2);
        for r in [&mut a, &mut b] {
            r.set(0, &[nb(1, 1.0)]);
            r.set(2, &[nb(5, 0.5), nb(6, 2.5)]);
        }
        assert_eq!(a.checksum(), b.checksum(), "equal tables, equal sums");
        b.set(1, &[nb(9, 9.0)]);
        assert_ne!(a.checksum(), b.checksum(), "extra solve changes the sum");
        b.set(1, &[]);
        // count-0 lanes are excluded, so clearing restores equality even
        // though the id/dist lanes still hold the stale entries
        assert_eq!(a.checksum(), b.checksum());
        b.set(2, &[nb(5, 0.5), nb(6, 2.5 + 1e-12)]);
        assert_ne!(a.checksum(), b.checksum(), "dist bits are significant");
    }

    #[test]
    fn result_write_heap_in_place() {
        let mut r = KnnResult::new(2, 4);
        let mut h = BoundedHeap::new(4);
        for (id, d) in [(3, 0.3), (1, 0.1), (2, 0.2)] {
            h.push(nb(id, d));
        }
        r.write_heap(1, &mut h);
        assert_eq!(r.get(1).ids(), &[1, 2, 3]);
        assert!(r.get(0).is_empty());
        assert!(h.is_empty());
    }

    #[test]
    fn slots_parallel_disjoint_writes() {
        // the concurrency pattern of the hybrid join: many workers pull
        // disjoint query chunks off an atomic cursor and write in place
        let (n, k) = (1000, 4);
        let mut r = KnnResult::new(n, k);
        let slots = r.slots();
        pool::parallel_chunks(n, 4, 37, |range| {
            let mut h = BoundedHeap::new(k);
            for q in range {
                for j in 0..k {
                    h.push(nb((q * 10 + j) as u32, j as f64));
                }
                // SAFETY: the cursor hands each q to exactly one worker
                unsafe { slots.slot(q) }.write_heap(&mut h);
            }
        });
        drop(slots);
        for q in 0..n {
            let v = r.get(q);
            assert_eq!(v.len(), k);
            assert_eq!(v.at(0).id, (q * 10) as u32);
            for w in v.dist2s().windows(2) {
                assert!(w[0] <= w[1]);
            }
        }
        assert_eq!(r.total_neighbors(), n * k);
    }

    #[test]
    fn slot_write_sorted_and_clear() {
        let mut r = KnnResult::new(2, 2);
        {
            let slots = r.slots();
            // SAFETY: single-threaded use
            let mut s = unsafe { slots.slot(0) };
            s.write_sorted(&[nb(4, 0.5)]);
        }
        assert_eq!(r.get(0).at(0).id, 4);
        {
            let slots = r.slots();
            let mut s = unsafe { slots.slot(0) };
            s.clear();
        }
        assert!(r.get(0).is_empty());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn slot_index_checked() {
        let mut r = KnnResult::new(2, 2);
        let slots = r.slots();
        let _ = unsafe { slots.slot(2) };
    }
}
