//! DBSCAN over the GPU-JOIN ε-grid (paper [29]; the grid/batching lineage
//! of this paper comes from the author's GPU-DBSCAN work [28]).
//!
//! Range queries run against the same non-hierarchical grid index the
//! join uses, so the clustering exercises an independent consumer of the
//! index substrate. Classic label semantics: core points (>= min_pts
//! in-ε neighbors incl. self) expand clusters; border points adopt the
//! first core cluster that reaches them; everything else is NOISE.

use crate::core::{sqdist, Dataset};
use crate::index::GridIndex;

/// Label for unclustered points.
pub const NOISE: i32 = -1;

/// DBSCAN parameters.
#[derive(Debug, Clone)]
pub struct DbscanParams {
    /// neighborhood radius
    pub eps: f64,
    /// core-point density threshold
    pub min_pts: usize,
    /// indexed dims of the grid (m <= n, as in the join)
    pub m: usize,
}

/// DBSCAN clustering outcome.
#[derive(Debug)]
pub struct DbscanResult {
    /// cluster id per point, or NOISE
    pub labels: Vec<i32>,
    /// clusters found
    pub clusters: usize,
    /// points labeled NOISE
    pub noise: usize,
}

/// Run DBSCAN. Builds an ε-grid over the first `m` dims and expands
/// clusters by BFS over in-ε neighborhoods. Every range query is the
/// grid's id-keyed CSR walk into one reusable candidate buffer, so the
/// BFS allocates nothing per point.
pub fn dbscan(data: &Dataset, params: &DbscanParams) -> DbscanResult {
    let n = data.len();
    let grid = GridIndex::build(data, params.m, params.eps);
    let eps2 = params.eps * params.eps;

    // in-ε neighborhood of point i into `out` (cleared first); includes
    // i itself (dist 0), matching the min_pts convention
    let neighbors = |i: usize, out: &mut Vec<u32>| {
        out.clear();
        grid.visit_adjacent_of_id(i as u32, |ids| {
            for &j in ids {
                if sqdist(data.point(i), data.point(j as usize)) <= eps2 {
                    out.push(j);
                }
            }
        });
    };

    let mut labels = vec![NOISE; n];
    let mut visited = vec![false; n];
    let mut cluster = 0i32;
    let mut queue: std::collections::VecDeque<u32> = Default::default();
    // candidate scratch, reused across all range queries: the BFS
    // consumes it (into `queue`) before the next query refills it
    let mut nbuf: Vec<u32> = Vec::new();

    for p in 0..n {
        if visited[p] {
            continue;
        }
        visited[p] = true;
        neighbors(p, &mut nbuf);
        if nbuf.len() < params.min_pts {
            continue; // noise (may later become a border point)
        }
        // new cluster seeded at core point p
        labels[p] = cluster;
        queue.clear();
        queue.extend(nbuf.iter().copied());
        while let Some(q) = queue.pop_front() {
            let q = q as usize;
            if labels[q] == NOISE {
                labels[q] = cluster; // border or core adoption
            }
            if visited[q] {
                continue;
            }
            visited[q] = true;
            neighbors(q, &mut nbuf);
            if nbuf.len() >= params.min_pts {
                queue.extend(nbuf.iter().copied()); // q is core: expand
            }
        }
        cluster += 1;
    }

    let noise = labels.iter().filter(|&&l| l == NOISE).count();
    DbscanResult { labels, clusters: cluster as usize, noise }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn blobs(rng: &mut Rng, centers: &[(f64, f64)], per: usize, sd: f64) -> Dataset {
        let mut rows = Vec::new();
        for &(cx, cy) in centers {
            for _ in 0..per {
                rows.push(vec![
                    rng.normal(cx, sd) as f32,
                    rng.normal(cy, sd) as f32,
                ]);
            }
        }
        Dataset::from_rows(&rows)
    }

    #[test]
    fn two_blobs_two_clusters() {
        let mut rng = Rng::new(1);
        let d = blobs(&mut rng, &[(0.0, 0.0), (20.0, 20.0)], 100, 0.5);
        let r = dbscan(&d, &DbscanParams { eps: 2.0, min_pts: 5, m: 2 });
        assert_eq!(r.clusters, 2);
        assert_eq!(r.noise, 0);
        // all of blob 0 shares a label; different from blob 1
        assert!(r.labels[..100].iter().all(|&l| l == r.labels[0]));
        assert!(r.labels[100..].iter().all(|&l| l == r.labels[100]));
        assert_ne!(r.labels[0], r.labels[100]);
    }

    #[test]
    fn isolated_points_are_noise() {
        let mut rng = Rng::new(2);
        let mut d = blobs(&mut rng, &[(0.0, 0.0)], 80, 0.4);
        // append far-away isolated points
        let mut rows: Vec<Vec<f32>> = (0..d.len()).map(|i| d.point(i).to_vec()).collect();
        rows.push(vec![100.0, 100.0]);
        rows.push(vec![-100.0, 50.0]);
        d = Dataset::from_rows(&rows);
        let r = dbscan(&d, &DbscanParams { eps: 2.0, min_pts: 5, m: 2 });
        assert_eq!(r.clusters, 1);
        assert_eq!(r.noise, 2);
        assert_eq!(r.labels[80], NOISE);
        assert_eq!(r.labels[81], NOISE);
    }

    #[test]
    fn min_pts_gate() {
        // 3 points close together but min_pts=5 -> all noise
        let d = Dataset::from_rows(&[
            vec![0.0, 0.0],
            vec![0.1, 0.0],
            vec![0.0, 0.1],
        ]);
        let r = dbscan(&d, &DbscanParams { eps: 1.0, min_pts: 5, m: 2 });
        assert_eq!(r.clusters, 0);
        assert_eq!(r.noise, 3);
    }

    #[test]
    fn labels_partition_consistently() {
        // every non-noise label < clusters; every cluster non-empty
        let mut rng = Rng::new(3);
        let d = blobs(&mut rng, &[(0.0, 0.0), (8.0, 0.0), (0.0, 8.0)], 60, 0.6);
        let r = dbscan(&d, &DbscanParams { eps: 1.5, min_pts: 4, m: 2 });
        assert!(r.clusters >= 2);
        let mut seen = vec![false; r.clusters];
        for &l in &r.labels {
            if l != NOISE {
                assert!((l as usize) < r.clusters);
                seen[l as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn projected_grid_still_exact() {
        // m < n: grid over 2 of 4 dims; correctness must not change
        let mut rng = Rng::new(4);
        let rows: Vec<Vec<f32>> = (0..150)
            .map(|i| {
                let c = if i < 75 { 0.0 } else { 30.0 };
                vec![
                    rng.normal(c, 0.5) as f32,
                    rng.normal(c, 0.5) as f32,
                    rng.normal(0.0, 0.1) as f32,
                    rng.normal(0.0, 0.1) as f32,
                ]
            })
            .collect();
        let d = Dataset::from_rows(&rows);
        let full = dbscan(&d, &DbscanParams { eps: 2.0, min_pts: 4, m: 4 });
        let proj = dbscan(&d, &DbscanParams { eps: 2.0, min_pts: 4, m: 2 });
        assert_eq!(full.clusters, proj.clusters);
        assert_eq!(full.noise, proj.noise);
    }
}
