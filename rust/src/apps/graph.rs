//! kNN graphs from a join result: directed kNN edges, the mutual-kNN
//! graph (the symmetrised variant graph-clustering algorithms use), and
//! union-find connected components.

use crate::core::KnnResult;

/// Adjacency-list graph over point ids.
#[derive(Debug, Clone)]
pub struct KnnGraph {
    /// out-neighbors per point id
    pub adj: Vec<Vec<u32>>,
}

impl KnnGraph {
    /// Number of vertices.
    pub fn n(&self) -> usize {
        self.adj.len()
    }

    /// Total directed edges.
    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum()
    }
}

/// Directed kNN graph: edge q -> n for each of q's (up to k) neighbors.
pub fn knn_graph(result: &KnnResult, k: usize) -> KnnGraph {
    let adj = (0..result.len())
        .map(|q| result.get(q).iter().take(k).map(|n| n.id).collect())
        .collect();
    KnnGraph { adj }
}

/// Mutual-kNN graph: undirected edge {a, b} iff a lists b AND b lists a.
pub fn mutual_knn_graph(result: &KnnResult, k: usize) -> KnnGraph {
    let directed = knn_graph(result, k);
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); directed.n()];
    for (a, ns) in directed.adj.iter().enumerate() {
        for &b in ns {
            if directed.adj[b as usize].contains(&(a as u32)) {
                adj[a].push(b);
            }
        }
    }
    KnnGraph { adj }
}

/// Connected components via union-find (path halving + union by size).
/// Returns (component id per node, number of components).
pub fn connected_components(g: &KnnGraph) -> (Vec<u32>, usize) {
    let n = g.n();
    let mut parent: Vec<u32> = (0..n as u32).collect();
    let mut size = vec![1u32; n];

    fn find(parent: &mut [u32], mut x: u32) -> u32 {
        while parent[x as usize] != x {
            parent[x as usize] = parent[parent[x as usize] as usize];
            x = parent[x as usize];
        }
        x
    }

    for (a, ns) in g.adj.iter().enumerate() {
        for &b in ns {
            let (ra, rb) = (find(&mut parent, a as u32), find(&mut parent, b));
            if ra != rb {
                let (big, small) = if size[ra as usize] >= size[rb as usize] {
                    (ra, rb)
                } else {
                    (rb, ra)
                };
                parent[small as usize] = big;
                size[big as usize] += size[small as usize];
            }
        }
    }
    // relabel roots densely
    let mut label = vec![u32::MAX; n];
    let mut next = 0u32;
    let mut out = vec![0u32; n];
    for i in 0..n {
        let r = find(&mut parent, i as u32) as usize;
        if label[r] == u32::MAX {
            label[r] = next;
            next += 1;
        }
        out[i] = label[r];
    }
    (out, next as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{KnnResult, Neighbor};

    fn nb(id: u32) -> Neighbor {
        Neighbor { id, dist2: 1.0 }
    }

    fn two_cliques() -> KnnResult {
        // nodes 0-2 point at each other; 3-5 point at each other
        let mut r = KnnResult::new(6, 2);
        r.set(0, &[nb(1), nb(2)]);
        r.set(1, &[nb(0), nb(2)]);
        r.set(2, &[nb(0), nb(1)]);
        r.set(3, &[nb(4), nb(5)]);
        r.set(4, &[nb(3), nb(5)]);
        r.set(5, &[nb(3), nb(4)]);
        r
    }

    #[test]
    fn knn_graph_respects_k() {
        let r = two_cliques();
        assert_eq!(knn_graph(&r, 2).edge_count(), 12);
        assert_eq!(knn_graph(&r, 1).edge_count(), 6);
    }

    #[test]
    fn components_of_two_cliques() {
        let g = knn_graph(&two_cliques(), 2);
        let (labels, n) = connected_components(&g);
        assert_eq!(n, 2);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[0], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_ne!(labels[0], labels[3]);
    }

    #[test]
    fn mutual_graph_drops_one_way_edges() {
        let mut r = KnnResult::new(3, 1);
        r.set(0, &[nb(1)]);
        r.set(1, &[nb(2)]); // 1 does NOT list 0
        r.set(2, &[nb(1)]);
        let m = mutual_knn_graph(&r, 1);
        assert!(m.adj[0].is_empty(), "0->1 is one-way");
        assert_eq!(m.adj[1], vec![2]);
        assert_eq!(m.adj[2], vec![1]);
    }

    #[test]
    fn singleton_nodes_are_own_components() {
        let r = KnnResult::new(4, 3); // no edges at all
        let g = knn_graph(&r, 3);
        let (_, n) = connected_components(&g);
        assert_eq!(n, 4);
    }
}
