//! K-distance diagram (Ester et al. [29], referenced by the paper's
//! ε-selection): the sorted distance-to-k-th-neighbor curve, whose knee is
//! the classic choice of DBSCAN's ε.

use crate::core::KnnResult;

/// Sorted (descending, as conventionally plotted) k-th neighbor distance
/// for every solved query. Queries with < k neighbors are skipped.
pub fn k_distance_curve(result: &KnnResult, k: usize) -> Vec<f64> {
    assert!(k >= 1);
    let mut curve: Vec<f64> = (0..result.len())
        .filter_map(|q| result.get(q).get(k - 1).map(|n| n.dist2.sqrt()))
        .collect();
    curve.sort_by(|a, b| b.partial_cmp(a).unwrap());
    curve
}

/// Knee heuristic: the point of maximum discrete curvature (second
/// difference) on the descending k-distance curve, returned as an ε
/// suggestion for DBSCAN. Falls back to the median for tiny curves.
pub fn suggest_dbscan_eps(curve: &[f64]) -> f64 {
    if curve.len() < 5 {
        return curve.get(curve.len() / 2).copied().unwrap_or(0.0);
    }
    let mut best = (0usize, f64::NEG_INFINITY);
    for i in 1..curve.len() - 1 {
        let curvature = curve[i - 1] - 2.0 * curve[i] + curve[i + 1];
        if curvature > best.1 {
            best = (i, curvature);
        }
    }
    curve[best.0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{KnnResult, Neighbor};

    fn result_with_kth(dists: &[f64], k: usize) -> KnnResult {
        let mut r = KnnResult::new(dists.len(), k);
        for (q, &d) in dists.iter().enumerate() {
            let ns: Vec<Neighbor> = (0..k)
                .map(|j| Neighbor {
                    id: j as u32,
                    dist2: (d * (j + 1) as f64 / k as f64).powi(2),
                })
                .collect();
            r.set(q, &ns);
        }
        r
    }

    #[test]
    fn curve_is_descending_and_complete() {
        let r = result_with_kth(&[3.0, 1.0, 2.0, 5.0], 2);
        let c = k_distance_curve(&r, 2);
        assert_eq!(c.len(), 4);
        for w in c.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!((c[0] - 5.0).abs() < 1e-9);
    }

    #[test]
    fn skips_underfilled_queries() {
        let mut r = result_with_kth(&[3.0, 1.0], 2);
        r.set(1, &[Neighbor { id: 0, dist2: 1.0 }]); // only 1 neighbor
        assert_eq!(k_distance_curve(&r, 2).len(), 1);
    }

    #[test]
    fn knee_found_on_elbow_curve() {
        // flat tail at 1.0 with a sharp elbow from 10.0
        let mut curve = vec![10.0, 9.0, 8.0, 1.2, 1.1, 1.05, 1.0, 1.0, 1.0];
        curve.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let eps = suggest_dbscan_eps(&curve);
        assert!(eps <= 1.5, "knee should sit at the flat tail start: {eps}");
    }

    #[test]
    fn tiny_curve_fallback() {
        assert_eq!(suggest_dbscan_eps(&[2.0, 4.0]), 4.0);
        assert_eq!(suggest_dbscan_eps(&[]), 0.0);
    }
}
