//! Applications built on the KNN join - the workloads the paper's
//! introduction motivates: kNN-graph construction for graph clustering
//! (Chameleon [5], k-means seeding [4]), the k-distance diagram used to
//! pick DBSCAN's ε (the paper's own ε-selection is "similar to the
//! procedure used to create a K-distance diagram", Sec. V-C2), and a
//! DBSCAN implementation running its range queries over the same ε-grid
//! index as GPU-JOIN.

/// DBSCAN over the ε-grid (a KNN-join consumer).
pub mod dbscan;
/// kNN / mutual-kNN graphs and connected components.
pub mod graph;
/// k-dist curves (the DBSCAN ε-selection heuristic).
pub mod kdist;

pub use dbscan::{dbscan, DbscanParams, DbscanResult, NOISE};
pub use graph::{connected_components, knn_graph, mutual_knn_graph, KnnGraph};
pub use kdist::{k_distance_curve, suggest_dbscan_eps};
