//! Datasets: synthetic surrogates for the paper's UCI workloads, CSV/binary
//! IO, and the REORDER (variance) preprocessing step.

pub mod io;
pub mod synthetic;
pub mod variance;

pub use synthetic::{chist_like, fma_like, songs_like, susy_like, DatasetSpec};
pub use variance::{reorder_by_variance, variance_per_dim};
