//! Datasets: synthetic surrogates for the paper's UCI workloads, CSV/binary
//! IO, and the REORDER (variance) preprocessing step.

/// Dataset loading/saving (CSV-ish flat files).
pub mod io;
/// Synthetic surrogate dataset generators (DESIGN.md §2).
pub mod synthetic;
/// Variance-descending dimension reorder (Sec. IV-D).
pub mod variance;

pub use synthetic::{chist_like, fma_like, songs_like, susy_like, DatasetSpec};
pub use variance::{reorder_by_variance, variance_per_dim};
