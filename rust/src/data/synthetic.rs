//! Synthetic surrogate datasets (DESIGN.md §2 "Dataset substitution").
//!
//! The UCI datasets of Table I are unavailable offline; these generators
//! are matched on the properties the paper identifies as controlling the
//! KNN workload - |D|, dimensionality n, and distribution: clustered dense
//! regions (GPU-friendly) embedded in sparse background (CPU-friendly),
//! with deliberately imbalanced per-dimension variances so REORDER and the
//! m < n index projection have the same effect they have on the real data.

use crate::core::Dataset;
use crate::util::rng::Rng;

/// Shape of a Gaussian-mixture surrogate.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    /// generator name (which paper dataset it surrogates)
    pub name: &'static str,
    /// |D| - points to generate
    pub n_points: usize,
    /// dimensionality n
    pub dims: usize,
    /// Gaussian mixture components
    pub clusters: usize,
    /// fraction of points drawn from the uniform background (sparse region)
    pub background: f64,
    /// cluster stddev range (sampled per cluster, log-uniform-ish)
    pub sigma: (f64, f64),
    /// exponent of the per-dimension variance decay: dimension j gets
    /// global scale (j+1)^-decay, producing the variance imbalance REORDER
    /// exploits. 0.0 = isotropic.
    pub variance_decay: f64,
    /// intrinsic dimensionality: cluster offsets live in a rank-r subspace
    /// (r = intrinsic.min(dims)); mimics feature datasets (FMA) whose 518
    /// dims have low intrinsic rank.
    pub intrinsic: usize,
}

impl DatasetSpec {
    /// Generate the dataset (deterministic in `seed`).
    pub fn generate(&self, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed ^ 0xDA7A_5E_ED);
        let d = self.dims;
        let r = self.intrinsic.min(d).max(1);

        // Per-dimension global scale: imbalanced variance profile.
        let dim_scale: Vec<f64> = (0..d)
            .map(|j| ((j + 1) as f64).powf(-self.variance_decay))
            .collect();

        // Random rank-r loading matrix (r x d): cluster centers =
        // z (r-dim) * loadings, so data concentrates near a subspace.
        let mut loadings = vec![0f64; r * d];
        {
            let mut lr = rng.fork(17);
            for row in 0..r {
                for col in 0..d {
                    loadings[row * d + col] =
                        lr.normal(0.0, 1.0) * dim_scale[col] / (r as f64).sqrt();
                }
            }
        }

        // Cluster centers + sizes (sizes long-tailed: Zipf-ish weights).
        let mut centers = Vec::with_capacity(self.clusters);
        let mut sigmas = Vec::with_capacity(self.clusters);
        let mut weights = Vec::with_capacity(self.clusters);
        for cidx in 0..self.clusters {
            let mut z = vec![0f64; r];
            for zj in z.iter_mut() {
                *zj = rng.normal(0.0, 8.0);
            }
            let mut c = vec![0f64; d];
            for (col, cc) in c.iter_mut().enumerate() {
                let mut acc = 0.0;
                for row in 0..r {
                    acc += z[row] * loadings[row * d + col];
                }
                *cc = acc;
            }
            centers.push(c);
            let (lo, hi) = self.sigma;
            sigmas.push(lo * (hi / lo).powf(rng.f64()));
            weights.push(1.0 / (cidx + 1) as f64);
        }
        let wsum: f64 = weights.iter().sum();
        let cum: Vec<f64> = weights
            .iter()
            .scan(0.0, |acc, w| {
                *acc += w / wsum;
                Some(*acc)
            })
            .collect();

        let mut data = Vec::with_capacity(self.n_points * d);
        for _ in 0..self.n_points {
            if rng.f64() < self.background {
                // sparse uniform background over the bounding region
                for j in 0..d {
                    data.push((rng.range(-30.0, 30.0) * dim_scale[j]) as f32);
                }
            } else {
                let u = rng.f64();
                let c = cum.iter().position(|&x| u <= x).unwrap_or(0);
                let s = sigmas[c];
                for j in 0..d {
                    data.push(
                        (centers[c][j] + rng.normal(0.0, s) * dim_scale[j]) as f32,
                    );
                }
            }
        }
        Dataset::new(data, d)
    }
}

/// SuSy surrogate: 18-D, strongly clustered physics-like features.
/// Paper: 5e6 x 18; default bench size scaled (DESIGN.md §2).
pub fn susy_like(n_points: usize) -> DatasetSpec {
    DatasetSpec {
        name: "susy",
        n_points,
        dims: 18,
        clusters: 40,
        background: 0.15,
        sigma: (0.5, 2.0),
        variance_decay: 0.35,
        intrinsic: 12,
    }
}

/// Color-Histogram surrogate: 32-D image features, heavy variance
/// imbalance (histogram bins sparsely populated). Paper: 68 040 x 32.
pub fn chist_like(n_points: usize) -> DatasetSpec {
    DatasetSpec {
        name: "chist",
        n_points,
        dims: 32,
        clusters: 60,
        background: 0.10,
        sigma: (0.3, 1.5),
        variance_decay: 0.8,
        intrinsic: 10,
    }
}

/// Million-Song surrogate: 90-D audio features, long-tail cluster scales.
/// Paper: 515 345 x 90.
pub fn songs_like(n_points: usize) -> DatasetSpec {
    DatasetSpec {
        name: "songs",
        n_points,
        dims: 90,
        clusters: 30,
        background: 0.25,
        sigma: (1.0, 6.0),
        variance_decay: 0.5,
        intrinsic: 20,
    }
}

/// FMA surrogate: 518-D audio features with low intrinsic rank.
/// Paper: 106 574 x 518.
pub fn fma_like(n_points: usize) -> DatasetSpec {
    DatasetSpec {
        name: "fma",
        n_points,
        dims: 518,
        clusters: 25,
        background: 0.12,
        sigma: (0.5, 3.0),
        variance_decay: 0.6,
        intrinsic: 40,
    }
}

/// Lookup by name (CLI / bench harness).
pub fn by_name(name: &str, n_points: usize) -> Option<DatasetSpec> {
    match name {
        "susy" => Some(susy_like(n_points)),
        "chist" => Some(chist_like(n_points)),
        "songs" => Some(songs_like(n_points)),
        "fma" => Some(fma_like(n_points)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::variance;

    #[test]
    fn shapes_match_spec() {
        for spec in [
            susy_like(500),
            chist_like(300),
            songs_like(200),
            fma_like(100),
        ] {
            let d = spec.generate(1);
            assert_eq!(d.len(), spec.n_points);
            assert_eq!(d.dims(), spec.dims);
            assert!(d.raw().iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = susy_like(200).generate(7);
        let b = susy_like(200).generate(7);
        let c = susy_like(200).generate(8);
        assert_eq!(a.raw(), b.raw());
        assert_ne!(a.raw(), c.raw());
    }

    #[test]
    fn variance_imbalance_present() {
        // chist surrogate has strong decay: first dims much wider than last
        let d = chist_like(4000).generate(3);
        let per_dim: Vec<f64> = (0..d.dims())
            .map(|j| {
                let col: Vec<f64> = (0..d.len()).map(|i| d.coord(i, j) as f64).collect();
                variance(&col)
            })
            .collect();
        let head: f64 = per_dim[..4].iter().sum();
        let tail: f64 = per_dim[d.dims() - 4..].iter().sum();
        assert!(
            head > 5.0 * tail,
            "variance decay missing: head={head} tail={tail}"
        );
    }

    #[test]
    fn clustered_denser_than_uniform() {
        // nearest-neighbor distances in the mixture should be far smaller
        // than for a uniform scatter of the same bounding box.
        let spec = susy_like(800);
        let d = spec.generate(9);
        let mut rng = crate::util::rng::Rng::new(5);
        let sample = rng.sample_indices(d.len(), 60);
        let mut nn_dists = Vec::new();
        for &i in &sample {
            let mut best = f64::INFINITY;
            for j in 0..d.len() {
                if j == i {
                    continue;
                }
                let dd = crate::core::sqdist(d.point(i), d.point(j));
                if dd < best {
                    best = dd;
                }
            }
            nn_dists.push(best.sqrt());
        }
        let mean_nn = crate::util::math::mean(&nn_dists);
        // bounding scale is ~60 per dim; clustered NN distance must be tiny
        // relative to it
        assert!(mean_nn < 10.0, "mean NN distance {mean_nn} too large");
    }
}
