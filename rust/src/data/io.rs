//! Dataset persistence: CSV (interoperable) and a raw little-endian binary
//! format (fast reload of generated surrogates between bench runs).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::core::Dataset;

/// Write CSV (no header): one point per row.
pub fn write_csv(d: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for i in 0..d.len() {
        let row: Vec<String> = d.point(i).iter().map(|x| format!("{x}")).collect();
        writeln!(w, "{}", row.join(","))?;
    }
    Ok(())
}

/// Read CSV of floats; all rows must have equal arity.
pub fn read_csv(path: &Path) -> Result<Dataset> {
    let r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut data = Vec::new();
    let mut dims = None;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        let row: Vec<f32> = t
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<f32>()
                    .with_context(|| format!("line {}: bad float {s:?}", lineno + 1))
            })
            .collect::<Result<_>>()?;
        match dims {
            None => dims = Some(row.len()),
            Some(d) if d != row.len() => {
                bail!("line {}: expected {d} columns, got {}", lineno + 1, row.len())
            }
            _ => {}
        }
        data.extend(row);
    }
    let dims = dims.context("empty csv")?;
    Ok(Dataset::new(data, dims))
}

const MAGIC: &[u8; 8] = b"HKNNDS01";

/// Write the raw binary format: magic, u64 n, u64 dims, then f32 LE data.
pub fn write_bin(d: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(d.len() as u64).to_le_bytes())?;
    w.write_all(&(d.dims() as u64).to_le_bytes())?;
    for &x in d.raw() {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

/// Read the raw binary format.
pub fn read_bin(path: &Path) -> Result<Dataset> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad magic: not a HKNNDS01 file");
    }
    let mut buf8 = [0u8; 8];
    r.read_exact(&mut buf8)?;
    let n = u64::from_le_bytes(buf8) as usize;
    r.read_exact(&mut buf8)?;
    let dims = u64::from_le_bytes(buf8) as usize;
    let mut bytes = Vec::new();
    r.read_to_end(&mut bytes)?;
    if bytes.len() != n * dims * 4 {
        bail!("truncated data: want {} bytes, got {}", n * dims * 4, bytes.len());
    }
    let data: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Dataset::new(data, dims))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::chist_like;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("hknn_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn csv_roundtrip() {
        let d = chist_like(50).generate(1);
        let p = tmp("a.csv");
        write_csv(&d, &p).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back.len(), d.len());
        assert_eq!(back.dims(), d.dims());
        for (a, b) in d.raw().iter().zip(back.raw()) {
            assert!((a - b).abs() <= f32::EPSILON * a.abs().max(1.0) * 10.0);
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn bin_roundtrip_exact() {
        let d = chist_like(64).generate(2);
        let p = tmp("b.bin");
        write_bin(&d, &p).unwrap();
        let back = read_bin(&p).unwrap();
        assert_eq!(back.raw(), d.raw());
        assert_eq!(back.dims(), d.dims());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmp("c.csv");
        std::fs::write(&p, "1,2,3\n4,5\n").unwrap();
        assert!(read_csv(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn bin_rejects_bad_magic() {
        let p = tmp("d.bin");
        std::fs::write(&p, b"NOTMAGIC________").unwrap();
        assert!(read_bin(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
