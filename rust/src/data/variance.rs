//! REORDER (paper Sec. IV-D): reorder dimensions by descending variance so
//! the first m indexed dimensions carry the most discriminatory power.

use crate::core::Dataset;

/// Per-dimension variance (population), computed in one pass per dim.
pub fn variance_per_dim(d: &Dataset) -> Vec<f64> {
    let n = d.len();
    let dims = d.dims();
    if n == 0 {
        return vec![0.0; dims];
    }
    let mut sums = vec![0f64; dims];
    let mut sqs = vec![0f64; dims];
    for i in 0..n {
        let p = d.point(i);
        for j in 0..dims {
            let x = p[j] as f64;
            sums[j] += x;
            sqs[j] += x * x;
        }
    }
    (0..dims)
        .map(|j| {
            let m = sums[j] / n as f64;
            (sqs[j] / n as f64 - m * m).max(0.0)
        })
        .collect()
}

/// The REORDER transform: returns the permuted dataset plus the applied
/// permutation (new dim j = old dim perm[j], variances descending).
pub fn reorder_by_variance(d: &Dataset) -> (Dataset, Vec<usize>) {
    let vars = variance_per_dim(d);
    let mut perm: Vec<usize> = (0..d.dims()).collect();
    perm.sort_by(|&a, &b| vars[b].partial_cmp(&vars[a]).unwrap());
    (d.permute_dims(&perm), perm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::sqdist;
    use crate::util::{prop, rng::Rng};

    fn gen(rng: &mut Rng, n: usize, dims: usize) -> Dataset {
        let mut scale = vec![0.0; dims];
        for s in scale.iter_mut() {
            *s = rng.range(0.01, 10.0);
        }
        let data: Vec<f32> = (0..n * dims)
            .map(|i| (rng.normal(0.0, scale[i % dims])) as f32)
            .collect();
        Dataset::new(data, dims)
    }

    #[test]
    fn variances_descending_after_reorder() {
        prop::cases(30, 0x11AA, |rng| {
            let n = 64 + rng.below(128);
            let dims = 2 + rng.below(12);
            let d = gen(rng, n, dims);
            let (r, perm) = reorder_by_variance(&d);
            let v = variance_per_dim(&r);
            for w in v.windows(2) {
                assert!(w[0] >= w[1] - 1e-9, "not descending: {v:?}");
            }
            // perm is a permutation
            let mut sorted = perm.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..d.dims()).collect::<Vec<_>>());
        });
    }

    #[test]
    fn distances_preserved() {
        // reordering dims never changes pairwise distances
        prop::cases(20, 0x22BB, |rng| {
            let dims = 3 + rng.below(8);
            let d = gen(rng, 32, dims);
            let (r, _) = reorder_by_variance(&d);
            for _ in 0..10 {
                let i = rng.below(d.len());
                let j = rng.below(d.len());
                let orig = sqdist(d.point(i), d.point(j));
                let new = sqdist(r.point(i), r.point(j));
                assert!((orig - new).abs() < 1e-6 * (1.0 + orig));
            }
        });
    }

    #[test]
    fn known_variance_order() {
        // dims with variances [small, big, medium] -> perm [1, 2, 0]
        let mut rng = Rng::new(3);
        let data: Vec<f32> = (0..3000)
            .flat_map(|_| {
                [
                    rng.normal(0.0, 0.1) as f32,
                    rng.normal(5.0, 10.0) as f32,
                    rng.normal(-2.0, 1.0) as f32,
                ]
            })
            .collect();
        let d = Dataset::new(data, 3);
        let (_, perm) = reorder_by_variance(&d);
        assert_eq!(perm, vec![1, 2, 0]);
    }
}
