//! EXACT-ANN and REFIMPL (paper Sec. V-B, VI-C): rank-parallel exact KNN
//! over the kd-tree.
//!
//! The paper parallelises the ANN library with shared-nothing MPI ranks,
//! each holding its own copy of the index and taking queries round-robin.
//! Here a rank is an OS thread; the kd-tree is shared *read-only* (same
//! shared-nothing semantics - no rank mutates the index - without paying
//! |p| duplicate builds). REFIMPL is EXACT-ANN run over all of D with one
//! extra rank (the paper frees the GPU-master rank).

use std::time::Instant;

use crate::core::{Dataset, KnnResult};
use crate::index::KdTree;
use crate::util::pool;

/// Outcome of a CPU-side KNN pass.
#[derive(Debug)]
pub struct CpuKnnOutcome {
    pub result: KnnResult,
    /// wall time of each rank (seconds)
    pub per_rank_time: Vec<f64>,
    /// wall time of the whole pass
    pub total_time: f64,
    pub queries: usize,
}

/// EXACT-ANN: find the KNN of `queries` using `ranks` parallel ranks with
/// round-robin assignment (query i -> rank i mod |p|). Self-join form.
pub fn exact_ann(
    data: &Dataset,
    tree: &KdTree,
    queries: &[u32],
    k: usize,
    ranks: usize,
) -> CpuKnnOutcome {
    exact_ann_rs(data, tree, data, queries, k, ranks, true)
}

/// Bipartite EXACT-ANN: `queries` index `r_data` (outer relation); the
/// kd-tree indexes `data` = S. `exclude_self` only makes sense when
/// r_data and data are the same relation.
pub fn exact_ann_rs(
    data: &Dataset,
    tree: &KdTree,
    r_data: &Dataset,
    queries: &[u32],
    k: usize,
    ranks: usize,
    exclude_self: bool,
) -> CpuKnnOutcome {
    let t0 = Instant::now();
    let ranks = ranks.max(1);
    let rank_results: Vec<(f64, Vec<(u32, Vec<crate::core::Neighbor>)>)> =
        pool::run_ranks(ranks, |r| {
            let t = Instant::now();
            let mut out = Vec::new();
            let mut i = r;
            while i < queries.len() {
                let q = queries[i];
                let excl = if exclude_self { q } else { u32::MAX };
                out.push((q, tree.knn(data, r_data.point(q as usize), k, excl)));
                i += ranks;
            }
            (t.elapsed().as_secs_f64(), out)
        });

    let mut result = KnnResult::with_capacity(r_data.len());
    let mut per_rank_time = Vec::with_capacity(ranks);
    for (secs, items) in rank_results {
        per_rank_time.push(secs);
        for (q, ns) in items {
            result.set(q as usize, ns);
        }
    }
    CpuKnnOutcome {
        result,
        per_rank_time,
        total_time: t0.elapsed().as_secs_f64(),
        queries: queries.len(),
    }
}

/// REFIMPL: the CPU-only parallel reference - EXACT-ANN over all of D.
pub fn ref_impl(data: &Dataset, tree: &KdTree, k: usize, ranks: usize) -> CpuKnnOutcome {
    let queries: Vec<u32> = (0..data.len() as u32).collect();
    exact_ann(data, tree, &queries, k, ranks)
}

/// Per-rank *work* times measured serially (one thread executes each
/// rank's share in turn). On a single-core testbed this is the honest way
/// to study the round-robin load balance of Fig. 6: the speedup-vs-ranks
/// curve is total_work / max_rank_work, i.e. ideal scheduling without
/// memory-bus contention (see DESIGN.md hardware-adaptation notes).
pub fn rank_work_times(
    data: &Dataset,
    tree: &KdTree,
    queries: &[u32],
    k: usize,
    ranks: usize,
) -> Vec<f64> {
    let ranks = ranks.max(1);
    (0..ranks)
        .map(|r| {
            let t = Instant::now();
            let mut i = r;
            while i < queries.len() {
                let q = queries[i];
                std::hint::black_box(tree.knn(data, data.point(q as usize), k, q));
                i += ranks;
            }
            t.elapsed().as_secs_f64()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::susy_like;

    #[test]
    fn exact_ann_covers_all_queries_exactly() {
        let data = susy_like(500).generate(41);
        let tree = KdTree::build(&data);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let out = exact_ann(&data, &tree, &queries, 5, 4);
        assert_eq!(out.result.solved_count(5), data.len());
        assert_eq!(out.per_rank_time.len(), 4);
        // results equal single-rank run
        let single = exact_ann(&data, &tree, &queries, 5, 1);
        for q in (0..data.len()).step_by(43) {
            let (a, b) = (out.result.get(q), single.result.get(q));
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.dist2, y.dist2);
            }
        }
    }

    #[test]
    fn subset_and_empty_queries() {
        let data = susy_like(200).generate(42);
        let tree = KdTree::build(&data);
        let out = exact_ann(&data, &tree, &[5, 50, 150], 3, 2);
        assert_eq!(out.queries, 3);
        assert_eq!(out.result.solved_count(3), 3);
        assert!(out.result.get(0).is_empty());
        let empty = exact_ann(&data, &tree, &[], 3, 2);
        assert_eq!(empty.result.solved_count(1), 0);
    }

    #[test]
    fn ref_impl_is_full_dataset() {
        let data = susy_like(300).generate(43);
        let tree = KdTree::build(&data);
        let out = ref_impl(&data, &tree, 2, 3);
        assert_eq!(out.queries, data.len());
        assert_eq!(out.result.solved_count(2), data.len());
    }

    #[test]
    fn rank_work_roughly_balanced_by_round_robin() {
        let data = susy_like(2000).generate(44);
        let tree = KdTree::build(&data);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let times = rank_work_times(&data, &tree, &queries, 5, 8);
        assert_eq!(times.len(), 8);
        let total: f64 = times.iter().sum();
        let max = times.iter().cloned().fold(0.0, f64::max);
        let speedup = total / max;
        // near-ideal load balancing (paper: round-robin yields near-ideal)
        assert!(speedup > 5.5, "poor balance: speedup {speedup} of 8");
    }
}
