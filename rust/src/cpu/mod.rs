//! EXACT-ANN and REFIMPL (paper Sec. V-B, VI-C): rank-parallel exact KNN
//! over the kd-tree.
//!
//! The paper parallelises the ANN library with shared-nothing MPI ranks,
//! each holding its own copy of the index. Here a rank is an OS thread;
//! the kd-tree is shared *read-only* (same shared-nothing semantics - no
//! rank mutates the index - without paying |p| duplicate builds), and
//! queries are claimed in fixed-size chunks off a shared atomic cursor
//! (dynamic scheduling; see DESIGN.md §3). Relative to the paper's static
//! round-robin this directly attacks optimisation (iii) - load imbalance -
//! when per-query cost varies with local density. Each rank carries a
//! reusable `KnnScratch` and writes finished queries straight into the
//! shared SoA `KnnResult` through disjoint slot writers: the steady-state
//! query loop performs zero heap allocations and no merge pass exists.
//! REFIMPL is EXACT-ANN run over all of D with one extra rank (the paper
//! frees the GPU-master rank).

use std::time::Instant;

use crate::core::{Dataset, KnnResult, SoaSlots};
use crate::index::{KdTree, KnnScratch};
use crate::sched::{Arch, ClaimRecord, WorkQueue};
use crate::util::pool;

/// Outcome of a CPU-side KNN pass that owns its result table.
#[derive(Debug)]
pub struct CpuKnnOutcome {
    /// the KNN table (every requested query filled)
    pub result: KnnResult,
    /// wall time of each rank (seconds)
    pub per_rank_time: Vec<f64>,
    /// wall time of the whole pass
    pub total_time: f64,
    /// queries processed
    pub queries: usize,
}

/// Timing/accounting of an in-place CPU pass (`exact_ann_rs_into`); the
/// results live in the caller's `KnnResult`.
#[derive(Debug)]
pub struct CpuKnnStats {
    /// wall time of each rank (seconds)
    pub per_rank_time: Vec<f64>,
    /// wall time of the whole pass
    pub total_time: f64,
    /// queries processed
    pub queries: usize,
    /// dynamic-scheduling grain used (diagnostics)
    pub chunk: usize,
}

/// Dynamic-scheduling grain: small enough that density skew cannot strand
/// one rank with a disproportionate tail (~16 chunks per rank minimum),
/// large enough that the atomic cursor and result-lane cache-line handoff
/// stay negligible against thousands of distance evaluations per chunk.
fn chunk_for(n: usize, ranks: usize) -> usize {
    (n / (ranks * 16)).clamp(8, 512).min(n.max(1))
}

/// EXACT-ANN: find the KNN of `queries` using `ranks` dynamically
/// scheduled parallel ranks. Self-join form.
pub fn exact_ann(
    data: &Dataset,
    tree: &KdTree,
    queries: &[u32],
    k: usize,
    ranks: usize,
) -> CpuKnnOutcome {
    exact_ann_rs(data, tree, data, queries, k, ranks, true)
}

/// Bipartite EXACT-ANN: `queries` index `r_data` (outer relation); the
/// kd-tree indexes `data` = S. `exclude_self` only makes sense when
/// r_data and data are the same relation.
pub fn exact_ann_rs(
    data: &Dataset,
    tree: &KdTree,
    r_data: &Dataset,
    queries: &[u32],
    k: usize,
    ranks: usize,
    exclude_self: bool,
) -> CpuKnnOutcome {
    let mut result = KnnResult::new(r_data.len(), k);
    let slots = result.slots();
    let stats =
        exact_ann_rs_into(data, tree, r_data, queries, k, ranks, exclude_self, &slots);
    drop(slots);
    CpuKnnOutcome {
        result,
        per_rank_time: stats.per_rank_time,
        total_time: stats.total_time,
        queries: stats.queries,
    }
}

/// EXACT-ANN writing results *in place* through `slots` - the form the
/// hybrid join uses so CPU ranks, the GPU path, and the Q^Fail pass share
/// one result table with no merge copies.
///
/// `queries` must be duplicate-free, and the caller must not concurrently
/// write any of these query slots elsewhere (see `SoaSlots::slot`).
#[allow(clippy::too_many_arguments)]
pub fn exact_ann_rs_into(
    data: &Dataset,
    tree: &KdTree,
    r_data: &Dataset,
    queries: &[u32],
    k: usize,
    ranks: usize,
    exclude_self: bool,
    slots: &SoaSlots<'_>,
) -> CpuKnnStats {
    let t0 = Instant::now();
    let ranks = ranks.max(1);
    assert!(k <= slots.k(), "result stride {} < k {}", slots.k(), k);

    // Leaf-order blocking (cache locality): for the self-join, sorting the
    // query list by the tree's leaf-major order makes consecutive queries
    // spatial neighbors, so a chunk's traversals walk near-identical node
    // paths and re-touch the same candidate cache lines. Results are keyed
    // by query id, so the visit order is invisible to callers.
    let ordered: Vec<u32>;
    let qs: &[u32] = if std::ptr::eq(data, r_data) && queries.len() > 1 {
        let mut v = queries.to_vec();
        v.sort_unstable_by_key(|&q| tree.leaf_order_key(q));
        ordered = v;
        &ordered
    } else {
        queries
    };

    let chunk = chunk_for(qs.len(), ranks);
    let per_rank_time = pool::parallel_chunks_stateful(
        qs.len(),
        ranks,
        chunk,
        |_rank| (Instant::now(), KnnScratch::new()),
        |state, range| {
            let scratch = &mut state.1;
            for i in range {
                let q = qs[i];
                let excl = if exclude_self { q } else { u32::MAX };
                tree.knn_into(data, r_data.point(q as usize), k, excl, scratch);
                // SAFETY: `queries` is duplicate-free and the atomic
                // cursor hands each index to exactly one rank, so no two
                // threads ever write the same slot (caller keeps other
                // writers off these ids).
                unsafe { slots.slot(q as usize) }.write_heap(scratch.heap_mut());
            }
        },
        |(t, _)| t.elapsed().as_secs_f64(),
    );

    CpuKnnStats {
        per_rank_time,
        total_time: t0.elapsed().as_secs_f64(),
        queries: queries.len(),
        chunk,
    }
}

/// Accounting of a queue-draining CPU pass (`exact_ann_drain`).
#[derive(Debug)]
pub struct CpuDrainStats {
    /// wall time of each rank, including idle waits on the GPU (seconds)
    pub per_rank_time: Vec<f64>,
    /// wall time of the whole pass
    pub total_time: f64,
    /// queries claimed off the queue tail
    pub queries: usize,
    /// recirculated Q^Fail queries absorbed while the join ran
    pub recirc_queries: usize,
    /// per-claim telemetry, all ranks merged
    pub claims: Vec<ClaimRecord>,
    /// dynamic-scheduling grain used (diagnostics)
    pub chunk: usize,
}

/// EXACT-ANN as a *queue consumer*: `ranks` workers claim small chunks
/// off the sparse tail of the shared work queue and absorb recirculated
/// Q^Fail queries, until the queue is drained and the GPU master has
/// signalled completion. Results land in `slots` exactly as in
/// `exact_ann_rs_into`; every claim is logged for the running ρ^Model.
///
/// Slot safety: the two-ended cursor hands each tail position to exactly
/// one rank, the GPU master never writes the slots of queries it failed,
/// and each recirculated id is claimed by exactly one rank - so every
/// query id still has a single writer.
#[allow(clippy::too_many_arguments)]
pub fn exact_ann_drain(
    data: &Dataset,
    tree: &KdTree,
    r_data: &Dataset,
    queue: &WorkQueue,
    k: usize,
    ranks: usize,
    exclude_self: bool,
    slots: &SoaSlots<'_>,
) -> CpuDrainStats {
    let t0 = Instant::now();
    let ranks = ranks.max(1);
    assert!(k <= slots.k(), "result stride {} < k {}", slots.k(), k);
    let chunk = chunk_for(queue.len(), ranks);

    let solve_one = |scratch: &mut KnnScratch, q: u32| {
        let excl = if exclude_self { q } else { u32::MAX };
        tree.knn_into(data, r_data.point(q as usize), k, excl, scratch);
        // SAFETY: single writer per query id (see function docs).
        unsafe { slots.slot(q as usize) }.write_heap(scratch.heap_mut());
    };

    let rank_outs: Vec<(f64, Vec<ClaimRecord>, usize, usize)> =
        pool::run_ranks(ranks, |_rank| {
            let mut scratch = KnnScratch::new();
            let mut records: Vec<ClaimRecord> = Vec::new();
            let (mut tail_q, mut rec_q) = (0usize, 0usize);
            let t_rank = Instant::now();
            loop {
                // Read the done flag BEFORE the claim attempts: any failure
                // the GPU published before setting the flag (Release) is
                // visible to the Acquire claim below, so a true reading
                // plus two empty claims means nothing more can arrive.
                let done = queue.gpu_done();
                // sparse tail first: that is this architecture's territory
                if let Some(r) = queue.claim_tail(chunk) {
                    let t = Instant::now();
                    let work = queue.range_work(r.clone());
                    let qs = queue.query_slice(r);
                    for &q in qs {
                        solve_one(&mut scratch, q);
                    }
                    let secs = t.elapsed().as_secs_f64();
                    queue.note_cpu(qs.len(), work, secs);
                    records.push(ClaimRecord {
                        arch: Arch::Cpu,
                        queries: qs.len(),
                        est_work: work,
                        secs,
                        exec_secs: 0.0,
                        transfer_secs: 0.0,
                        filter_secs: 0.0,
                        from_recirc: false,
                        brute: false,
                        failed: false,
                    });
                    tail_q += qs.len();
                    continue;
                }
                // then failures the GPU recirculated, credited at the mean
                // per-query price (their true tail position is gone) so the
                // live CPU rate feeding the GPU's batch sizing stays honest
                if let Some(ids) = queue.claim_recirc(chunk) {
                    let t = Instant::now();
                    for &q in &ids {
                        solve_one(&mut scratch, q);
                    }
                    let secs = t.elapsed().as_secs_f64();
                    let work = queue.mean_query_work() * ids.len() as u64;
                    queue.note_cpu(ids.len(), work, secs);
                    records.push(ClaimRecord {
                        arch: Arch::Cpu,
                        queries: ids.len(),
                        est_work: work,
                        secs,
                        exec_secs: 0.0,
                        transfer_secs: 0.0,
                        filter_secs: 0.0,
                        from_recirc: true,
                        brute: false,
                        failed: false,
                    });
                    rec_q += ids.len();
                    continue;
                }
                if done {
                    break;
                }
                // queue momentarily dry while the GPU computes: back off
                // briefly instead of spinning hot
                std::thread::sleep(std::time::Duration::from_micros(50));
            }
            (t_rank.elapsed().as_secs_f64(), records, tail_q, rec_q)
        });

    let mut per_rank_time = Vec::with_capacity(rank_outs.len());
    let mut claims = Vec::new();
    let (mut queries, mut recirc_queries) = (0usize, 0usize);
    for (secs, records, tq, rq) in rank_outs {
        per_rank_time.push(secs);
        claims.extend(records);
        queries += tq;
        recirc_queries += rq;
    }
    CpuDrainStats {
        per_rank_time,
        total_time: t0.elapsed().as_secs_f64(),
        queries,
        recirc_queries,
        claims,
        chunk,
    }
}

/// REFIMPL: the CPU-only parallel reference - EXACT-ANN over all of D.
pub fn ref_impl(data: &Dataset, tree: &KdTree, k: usize, ranks: usize) -> CpuKnnOutcome {
    let queries: Vec<u32> = (0..data.len() as u32).collect();
    exact_ann(data, tree, &queries, k, ranks)
}

/// Per-rank *work* times measured serially (one thread executes each
/// rank's share in turn), with the paper's static round-robin assignment.
/// On a single-core testbed this is the honest way to study the
/// round-robin load balance of Fig. 6: the speedup-vs-ranks curve is
/// total_work / max_rank_work, i.e. ideal scheduling without memory-bus
/// contention (see DESIGN.md hardware-adaptation notes). The production
/// engine above replaces round-robin with dynamic chunking; this probe
/// keeps the paper's assignment as the object of study.
pub fn rank_work_times(
    data: &Dataset,
    tree: &KdTree,
    queries: &[u32],
    k: usize,
    ranks: usize,
) -> Vec<f64> {
    let ranks = ranks.max(1);
    let mut scratch = KnnScratch::new();
    (0..ranks)
        .map(|r| {
            let t = Instant::now();
            let mut i = r;
            while i < queries.len() {
                let q = queries[i];
                tree.knn_into(data, data.point(q as usize), k, q, &mut scratch);
                std::hint::black_box(scratch.heap_mut().len());
                i += ranks;
            }
            t.elapsed().as_secs_f64()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::susy_like;

    #[test]
    fn exact_ann_covers_all_queries_exactly() {
        let data = susy_like(500).generate(41);
        let tree = KdTree::build(&data);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let out = exact_ann(&data, &tree, &queries, 5, 4);
        assert_eq!(out.result.solved_count(5), data.len());
        assert_eq!(out.per_rank_time.len(), 4);
        // results equal single-rank run
        let single = exact_ann(&data, &tree, &queries, 5, 1);
        for q in (0..data.len()).step_by(43) {
            let (a, b) = (out.result.get(q), single.result.get(q));
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.dist2, y.dist2);
            }
        }
    }

    #[test]
    fn subset_and_empty_queries() {
        let data = susy_like(200).generate(42);
        let tree = KdTree::build(&data);
        let out = exact_ann(&data, &tree, &[5, 50, 150], 3, 2);
        assert_eq!(out.queries, 3);
        assert_eq!(out.result.solved_count(3), 3);
        assert!(out.result.get(0).is_empty());
        let empty = exact_ann(&data, &tree, &[], 3, 2);
        assert_eq!(empty.result.solved_count(1), 0);
    }

    #[test]
    fn ref_impl_is_full_dataset() {
        let data = susy_like(300).generate(43);
        let tree = KdTree::build(&data);
        let out = ref_impl(&data, &tree, 2, 3);
        assert_eq!(out.queries, data.len());
        assert_eq!(out.result.solved_count(2), data.len());
    }

    #[test]
    fn into_variant_respects_existing_slots() {
        // the hybrid pattern: disjoint query sets written by separate
        // passes into one table, no merge
        let data = susy_like(400).generate(45);
        let tree = KdTree::build(&data);
        let mut result = KnnResult::new(data.len(), 4);
        let evens: Vec<u32> = (0..data.len() as u32).step_by(2).collect();
        let odds: Vec<u32> = (1..data.len() as u32).step_by(2).collect();
        let slots = result.slots();
        let s1 = exact_ann_rs_into(&data, &tree, &data, &evens, 4, 3, true, &slots);
        let s2 = exact_ann_rs_into(&data, &tree, &data, &odds, 4, 2, true, &slots);
        drop(slots);
        assert_eq!(s1.queries + s2.queries, data.len());
        assert_eq!(s1.per_rank_time.len(), 3);
        assert_eq!(s2.per_rank_time.len(), 2);
        assert!(s1.chunk >= 1);
        assert_eq!(result.solved_count(4), data.len());
        let single = exact_ann(&data, &tree, &evens, 4, 1);
        for q in (0..data.len()).step_by(20) {
            assert_eq!(result.get(q).len(), single.result.get(q).len());
            for (x, y) in result.get(q).iter().zip(single.result.get(q)) {
                assert_eq!(x.dist2, y.dist2);
            }
        }
    }

    #[test]
    fn bipartite_skips_leaf_reorder_and_stays_exact() {
        let s = susy_like(300).generate(46);
        let r = susy_like(80).generate(47);
        let tree = KdTree::build(&s);
        let queries: Vec<u32> = (0..r.len() as u32).collect();
        let out = exact_ann_rs(&s, &tree, &r, &queries, 3, 2, false);
        assert_eq!(out.result.solved_count(3), r.len());
        for q in (0..r.len()).step_by(7) {
            let want = tree.knn(&s, r.point(q), 3, u32::MAX);
            for (g, w) in out.result.get(q).iter().zip(&want) {
                assert_eq!(g.dist2, w.dist2);
            }
        }
    }

    #[test]
    fn drain_consumes_tail_and_recirc_exactly() {
        use crate::index::GridIndex;
        use crate::sched::build_queue;

        let data = susy_like(600).generate(48);
        let tree = KdTree::build(&data);
        let k = 4;
        let grid = GridIndex::build(&data, 6, 2.0);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let queue = build_queue(&data, &grid, &queries, k, 0.0, 0.0, true);

        // play the GPU master: claim a dense head batch, "solve" half of
        // it, recirculate the other half as Q^Fail
        let head = queue
            .claim_head_work(queue.total_work() / 4, queue.len())
            .unwrap();
        let head_ids: Vec<u32> = queue.query_slice(head.clone()).to_vec();
        let mid = head_ids.len() / 2;
        let (gpu_solved, failed) = head_ids.split_at(mid);
        queue.push_failed(failed);
        queue.set_gpu_done();

        let mut result = KnnResult::new(data.len(), k);
        let slots = result.slots();
        let stats = exact_ann_drain(&data, &tree, &data, &queue, k, 3, true, &slots);
        // complete the table for the queries our fake GPU kept
        let _ = exact_ann_rs_into(&data, &tree, &data, gpu_solved, k, 2, true, &slots);
        drop(slots);

        assert_eq!(stats.queries, data.len() - head_ids.len());
        assert_eq!(stats.recirc_queries, failed.len());
        assert_eq!(stats.per_rank_time.len(), 3);
        assert!(stats.claims.iter().all(|c| matches!(c.arch, crate::sched::Arch::Cpu)));
        assert!(stats.claims.iter().any(|c| c.from_recirc));
        assert_eq!(result.solved_count(k), data.len());
        // drained results are exact
        for q in (0..data.len()).step_by(53) {
            let want = tree.knn(&data, data.point(q), k, q as u32);
            for (g, w) in result.get(q).iter().zip(&want) {
                assert_eq!(g.dist2, w.dist2, "q={q}");
            }
        }
    }

    #[test]
    fn rank_work_roughly_balanced_by_round_robin() {
        let data = susy_like(2000).generate(44);
        let tree = KdTree::build(&data);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let times = rank_work_times(&data, &tree, &queries, 5, 8);
        assert_eq!(times.len(), 8);
        let total: f64 = times.iter().sum();
        let max = times.iter().cloned().fold(0.0, f64::max);
        let speedup = total / max;
        // near-ideal load balancing (paper: round-robin yields near-ideal)
        assert!(speedup > 5.5, "poor balance: speedup {speedup} of 8");
    }
}
