//! Dynamic CPU/GPU work scheduling: the density-ordered shared work
//! queue that replaces the one-shot static split of paper Sec. V-D/F.
//!
//! `build_queue` re-expresses the splitter as *queue construction*: grid
//! cells are priced with the Sec. V-B work estimator (adjacent-block
//! population × queries) and sorted densest-first into a flat SoA arena.
//! The γ threshold no longer partitions the work - it *seeds* the GPU's
//! first batch size (`first_batch_work`) and, on single-core hosts, caps
//! the GPU's total share at what the static split would have given it.
//! The ρ floor degenerates to a reservation on the sparse tail that only
//! CPU ranks may claim. From then on the split is discovered by the
//! two-ended draining in [`queue::WorkQueue`], with `next_batch_work`
//! turning Eq. 6's ρ^Model into run-time feedback: each GPU batch is
//! sized from the live CPU/GPU work rates so the two fronts meet in the
//! middle with neither architecture idling on a misprediction.

/// The shared work queue itself (claims, recirculation, telemetry).
pub mod queue;

use std::collections::HashMap;

use crate::core::Dataset;
use crate::index::{GridIndex, QueryKey};
use crate::split;

pub use queue::{Arch, ClaimRecord, QueueCell, WorkQueue};

/// Which GPU execution tier the engine uses for dense claims.
///
/// The grid-hybrid tier prunes candidates through the ε-grid's 3^m
/// adjacent-block walk - unbeatable while candidate sets are small
/// fractions of |D|, but the walk degenerates as m (and with it cell
/// adjacency fan-out and per-cell population) grows: candidate sets
/// approach |D| while still paying grouping, packing and gating
/// overhead per cell. The brute tier skips pruning entirely and streams
/// dense claims through tiled all-corpus distance kernels with an exact
/// host top-k - the Garcia et al. (arxiv 0804.1448) regime. `Auto`
/// routes per *claim* with [`route_brute`]; the forced modes pin every
/// GPU claim to one tier (ablation and the crossover bench).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendMode {
    /// Route each GPU claim by the [`route_brute`] heuristic.
    Auto,
    /// Every GPU claim takes the grid-hybrid candidate path.
    Grid,
    /// Every GPU claim takes the tiled brute-force path.
    Brute,
}

/// Candidate-population fraction of |D| beyond which a claim routes to
/// the brute tier, as a function of m and k.
///
/// Shape: brute pays O(|D|) distance work per query regardless of
/// density, so it wins exactly when the grid's candidate walk would
/// scan a comparable fraction of |D| anyway *after* paying its own
/// per-cell overheads (grouping, packing, ε-gating). Those overheads
/// grow with m (3^m adjacency fan-out → more, smaller packed cells)
/// and with k (deeper heaps make the ε-gate less selective), so the
/// break-even fraction *falls* as either grows. 0.9 at the origin
/// (grid must be nearly pruning-free before brute wins at low m/k),
/// decaying with m/8 and k/128 — at (m=8, k=32) a claim scanning ~40%
/// of |D| already routes brute. Clamped to [0.05, 0.95] so neither
/// tier is ever unreachable by heuristic alone.
pub fn brute_crossover_frac(m: usize, k: usize) -> f64 {
    (0.9 / (1.0 + m as f64 / 8.0 + k as f64 / 128.0)).clamp(0.05, 0.95)
}

/// The per-claim routing predicate: route to the brute tier when the
/// mean per-query candidate population of the claim *strictly* exceeds
/// the crossover fraction of |D|. Ties (and everything below) route to
/// the grid tier - the pruning path keeps the benefit of the doubt at
/// the boundary, where its candidate work equals brute's but its
/// transfer volume is lower.
pub fn route_brute(mean_candidates: f64, n_data: usize, m: usize, k: usize) -> bool {
    mean_candidates > brute_crossover_frac(m, k) * n_data as f64
}

/// Build the density-ordered work queue for `queries` (ids into
/// `r_data`), with densities and candidate work taken from the S-side
/// `grid`. γ seeds the dense prefix via n^thresh (Sec. V-D); ρ reserves
/// the sparse tail for the CPU (Sec. V-F).
///
/// `key` selects the per-query lookup path (see [`QueryKey`]):
/// `Native` marks the self-join case where `queries` index the very
/// dataset the grid was built over - grouping and pricing then run on
/// the grid's O(1) point→cell-rank map (two array reads per query, no
/// coordinate recompute, no searches). `Cached` gives the bipartite R
/// side the same O(1) complexity off a precomputed
/// [`crate::index::QueryRankCache`]. With `Coords` each query pays one
/// coordinate linearisation and each *cell* one binary search. Either
/// way the pricing itself is O(1) per cell off the grid's memoized CSR
/// adjacent-population table - the former per-cell 3^m recompute walk
/// (O(3^m log|B|) with per-cell allocations) is gone, so queue
/// construction costs O(|Q|) + O(cells), not O(|Q| x 3^m log|B|).
pub fn build_queue_keyed(
    r_data: &Dataset,
    grid: &GridIndex,
    queries: &[u32],
    k: usize,
    gamma: f64,
    rho: f64,
    key: QueryKey,
) -> WorkQueue {
    // group queries by their grid cell
    let mut by_cell: HashMap<u64, Vec<u32>> = HashMap::new();
    for &q in queries {
        by_cell
            .entry(grid.query_cell_id_keyed(key, r_data, q))
            .or_default()
            .push(q);
    }

    // price each cell: population decides the order (densest first), the
    // memoized adjacent-block population is the per-query work estimate.
    // A rank-less cell (bipartite query in an empty S cell) has density 0
    // and keeps the recompute-walk estimate as its work price.
    struct CellRec {
        pop: usize,
        cell: QueueCell,
    }
    let mut cells: Vec<CellRec> = by_cell
        .into_iter()
        .map(|(id, qs)| {
            // rank resolved once per cell: O(1) for native or cached
            // keys, one binary search for the coordinate path
            let rank = grid.query_rank_keyed(key, r_data, qs[0]);
            let (pop, per_q) = match rank {
                Some(r) => (
                    grid.rank_population(r),
                    grid.adjacent_population_of_rank(r) as u64,
                ),
                None => {
                    let p0 = r_data.point(qs[0] as usize);
                    (0, grid.adjacent_population(p0) as u64)
                }
            };
            CellRec {
                pop,
                cell: QueueCell { cell_id: id, per_query_work: per_q.max(1), queries: qs },
            }
        })
        .collect();
    // densest first; ties broken by cell id so the order is deterministic
    cells.sort_unstable_by(|a, b| {
        b.pop.cmp(&a.pop).then(a.cell.cell_id.cmp(&b.cell.cell_id))
    });

    // γ seed: the leading queries the static split would call Q^GPU
    let thresh = split::n_thresh(k, grid.m, gamma);
    let dense_prefix: usize = cells
        .iter()
        .take_while(|c| c.pop as f64 >= thresh)
        .map(|c| c.cell.queries.len())
        .sum();

    // ρ floor: tail reservation
    let reserve = (rho * queries.len() as f64).ceil() as usize;

    WorkQueue::from_cells(
        cells.into_iter().map(|c| c.cell).collect(),
        dense_prefix,
        reserve,
        thresh,
    )
    .with_generation(grid.epoch())
}

/// Bool-keyed wrapper over [`build_queue_keyed`] for call sites that
/// only distinguish self-join (`native_ids`) from coordinate recompute.
pub fn build_queue(
    r_data: &Dataset,
    grid: &GridIndex,
    queries: &[u32],
    k: usize,
    gamma: f64,
    rho: f64,
    native_ids: bool,
) -> WorkQueue {
    let key = if native_ids {
        QueryKey::Native
    } else {
        QueryKey::Coords
    };
    build_queue_keyed(r_data, grid, queries, k, gamma, rho, key)
}

/// Size of the GPU's *first* head claim, in estimated work: a third of
/// the γ-seeded dense prefix (so the feedback loop gets at least a few
/// batches over the region the static split would have committed in one
/// shot), floored at a 1/64 slice of the total so a γ that predicts an
/// empty GPU side still yields a probe batch.
pub fn first_batch_work(total_work: u64, dense_work: u64) -> u64 {
    (dense_work / 3).max(total_work / 64).max(1)
}

/// Size of each subsequent head claim: Eq. 6 as feedback. `gpu_rate` and
/// `cpu_rate` are live throughputs in estimated-work units per second;
/// the GPU's fair share of the remaining head work is halved so the two
/// fronts converge geometrically (late batches shrink, bounding the
/// worst-case idle tail by one small claim), floored at a 1/64 slice so
/// progress never stalls on noisy rates.
pub fn next_batch_work(remaining_work: u64, gpu_rate: f64, cpu_rate: f64) -> u64 {
    let share = if gpu_rate > 0.0 && cpu_rate > 0.0 {
        gpu_rate / (gpu_rate + cpu_rate)
    } else {
        // one side unmeasured: split the difference until evidence lands
        0.5
    };
    (((remaining_work as f64) * share / 2.0) as u64)
        .max(remaining_work / 64)
        .max(1)
}

/// Watchdog deadline for one GPU claim, in seconds: how long the master
/// waits for a claim of `est_work` before declaring the device hung.
/// The deadline is the live ρ^Model expectation (`est_work / rate`)
/// inflated by `slack`, floored at `floor_secs` so cold-start noise and
/// tiny claims never trip it. The rate is the GPU's own measured
/// throughput when available, falling back to the CPU's (a device slower
/// than the kd-tree ranks is as good as hung); with *no* rate evidence at
/// all the deadline is infinite - the first claim can never time out on a
/// misprediction, it has nothing to be mispredicted against.
pub fn claim_deadline_secs(
    est_work: u64,
    gpu_rate: f64,
    cpu_rate: f64,
    slack: f64,
    floor_secs: f64,
) -> f64 {
    let rate = if gpu_rate > 0.0 { gpu_rate } else { cpu_rate };
    if rate <= 0.0 {
        return f64::INFINITY;
    }
    (slack * est_work as f64 / rate).max(floor_secs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{chist_like, susy_like};

    #[test]
    fn queue_covers_all_queries_densest_first() {
        let d = susy_like(2000).generate(7);
        let grid = GridIndex::build(&d, 6, 2.0);
        let queries: Vec<u32> = (0..d.len() as u32).collect();
        let q = build_queue(&d, &grid, &queries, 5, 0.3, 0.0, true);
        assert_eq!(q.len(), d.len());
        let mut all: Vec<u32> = q.query_slice(0..q.len()).to_vec();
        all.sort_unstable();
        assert_eq!(all, queries);
        // populations are non-increasing along the queue
        let mut last = usize::MAX;
        for r in q.cell_ranges(0..q.len()) {
            let pop = grid.cell_population(d.point(q.query_slice(r)[0] as usize));
            assert!(pop <= last, "queue must be densest-first");
            last = pop;
        }
    }

    #[test]
    fn dense_prefix_matches_static_split() {
        let d = susy_like(1500).generate(8);
        let grid = GridIndex::build(&d, 6, 2.5);
        let queries: Vec<u32> = (0..d.len() as u32).collect();
        for gamma in [0.0, 0.4, 0.9] {
            let q = build_queue(&d, &grid, &queries, 5, gamma, 0.0, true);
            let s = split::split_work(&d, &grid, 5, gamma, 0.0, true);
            assert_eq!(
                q.dense_prefix(),
                s.q_gpu.len(),
                "γ seed equals the static Q^GPU (γ={gamma})"
            );
            // and the prefix really is the dense head of the queue
            let head: std::collections::HashSet<u32> =
                q.query_slice(0..q.dense_prefix()).iter().copied().collect();
            let want: std::collections::HashSet<u32> =
                s.q_gpu.iter().copied().collect();
            assert_eq!(head, want);
        }
    }

    #[test]
    fn queue_respects_query_subset_and_rho() {
        let d = chist_like(900).generate(9);
        let grid = GridIndex::build(&d, 6, 1.5);
        let queries: Vec<u32> = (0..d.len() as u32).step_by(3).collect();
        let q = build_queue(&d, &grid, &queries, 4, 0.2, 0.5, true);
        assert_eq!(q.len(), queries.len());
        assert_eq!(q.reserve(), (queries.len() + 1) / 2);
        let mut all: Vec<u32> = q.query_slice(0..q.len()).to_vec();
        all.sort_unstable();
        assert_eq!(all, queries);
    }

    #[test]
    fn native_and_coordinate_keyed_queues_are_identical() {
        // self-join: the O(1) id-keyed grouping/pricing path must build
        // exactly the queue the coordinate-keyed path builds
        let d = chist_like(1200).generate(13);
        let grid = GridIndex::build(&d, 6, 1.8);
        let queries: Vec<u32> = (0..d.len() as u32).collect();
        for (gamma, rho) in [(0.0, 0.0), (0.4, 0.2), (0.9, 0.5)] {
            let a = build_queue(&d, &grid, &queries, 5, gamma, rho, true);
            let b = build_queue(&d, &grid, &queries, 5, gamma, rho, false);
            assert_eq!(a.len(), b.len());
            assert_eq!(a.dense_prefix(), b.dense_prefix());
            assert_eq!(a.reserve(), b.reserve());
            assert_eq!(a.total_work(), b.total_work());
            assert_eq!(
                a.query_slice(0..a.len()),
                b.query_slice(0..b.len()),
                "queue order must not depend on the lookup path"
            );
        }
    }

    #[test]
    fn cached_key_queue_identical_to_coordinate_queue() {
        // carried item (n): the R-side rank cache must build exactly the
        // queue the coordinate path builds, including for R points whose
        // clamped cell is empty (they price via the recompute walk)
        use crate::index::QueryKey;
        let s = chist_like(1000).generate(21);
        let r = susy_like(700).generate(22);
        let grid = GridIndex::build(&s, 6, 1.8);
        let cache = grid.build_query_ranks(&r);
        let queries: Vec<u32> = (0..r.len() as u32).collect();
        for (gamma, rho) in [(0.0, 0.0), (0.5, 0.25)] {
            let a = build_queue_keyed(&r, &grid, &queries, 5, gamma, rho, QueryKey::Coords);
            let b =
                build_queue_keyed(&r, &grid, &queries, 5, gamma, rho, QueryKey::Cached(&cache));
            assert_eq!(a.len(), b.len());
            assert_eq!(a.dense_prefix(), b.dense_prefix());
            assert_eq!(a.reserve(), b.reserve());
            assert_eq!(a.total_work(), b.total_work());
            assert_eq!(
                a.query_slice(0..a.len()),
                b.query_slice(0..b.len()),
                "queue order must not depend on the lookup path"
            );
        }
    }

    #[test]
    fn routing_boundary_ties_go_to_grid() {
        // the forced-routing unit test for the heuristic boundary: a mean
        // candidate population exactly AT the crossover fraction stays on
        // the grid tier (strict inequality); one unit above routes brute
        let (m, k, n) = (4, 8, 10_000usize);
        let frac = brute_crossover_frac(m, k);
        let boundary = frac * n as f64;
        assert!(!route_brute(boundary, n, m, k), "tie must route to grid");
        assert!(route_brute(boundary + 1.0, n, m, k));
        assert!(!route_brute(boundary - 1.0, n, m, k));
        // the crossover falls as m and k grow (brute wins earlier in
        // exactly the regimes where the 3^m walk degenerates) ...
        assert!(brute_crossover_frac(2, 4) > brute_crossover_frac(8, 4));
        assert!(brute_crossover_frac(4, 4) > brute_crossover_frac(4, 64));
        // ... and stays clamped so neither tier is unreachable
        assert!(brute_crossover_frac(1, 1) <= 0.95);
        assert!(brute_crossover_frac(1 << 20, 1 << 20) >= 0.05);
    }

    #[test]
    fn batch_policy_seeds_and_converges() {
        // γ seed: a third of the dense prefix, probe floor otherwise
        assert_eq!(first_batch_work(6400, 3000), 1000);
        assert_eq!(first_batch_work(6400, 0), 100);
        assert_eq!(first_batch_work(0, 0), 1);
        // feedback: faster GPU -> bigger share
        let fast = next_batch_work(10_000, 900.0, 100.0);
        let slow = next_batch_work(10_000, 100.0, 900.0);
        assert!(fast > slow);
        assert_eq!(fast, 4500); // (10000 * 0.9) / 2
        // no evidence yet: split the difference
        assert_eq!(next_batch_work(8000, 0.0, 100.0), 2000);
        // floors: a vanishing share still claims the 1/64 slice (here 1)
        assert_eq!(next_batch_work(64, 1.0, 1e9), 1);
        assert_eq!(next_batch_work(0, 1.0, 1.0), 1);
    }

    #[test]
    fn watchdog_deadline_scales_floors_and_defers() {
        // live GPU rate: slack * est_work / rate
        assert_eq!(claim_deadline_secs(1000, 100.0, 50.0, 8.0, 0.01), 80.0);
        // no GPU evidence yet: fall back to the CPU rate
        assert_eq!(claim_deadline_secs(1000, 0.0, 50.0, 8.0, 0.01), 160.0);
        // no evidence at all: never trip on the very first claim
        assert_eq!(
            claim_deadline_secs(1000, 0.0, 0.0, 8.0, 0.01),
            f64::INFINITY
        );
        // the floor absorbs tiny claims and cold-start noise
        assert_eq!(claim_deadline_secs(1, 1e9, 0.0, 8.0, 5.0), 5.0);
    }
}
