//! The density-ordered shared work queue (the paper's Sec. V work queue,
//! realised): grid cells sorted densest-first into a flat SoA arena, with
//! a lock-free two-ended cursor over the flattened query list.
//!
//! * the **GPU master** claims large batches of aggregate estimated work
//!   from the dense *head* (`claim_head_work`) - high-density cells are
//!   where device throughput per kernel launch is maximised (Sec. V-A);
//! * **CPU ranks** claim small chunks from the sparse *tail*
//!   (`claim_tail`) - low-density cells are where the kd-tree wins;
//! * the two fronts meet in the middle, so the CPU/GPU split is
//!   *discovered* at run time instead of predicted by γ/ρ up front;
//! * queries the GPU fails (< K in-ε neighbors) recirculate through a
//!   single-producer/multi-consumer buffer (`push_failed` /
//!   `claim_recirc`) and are absorbed by the CPU ranks while the join is
//!   still running - the serial Q^Fail post-pass of Algorithm 1
//!   disappears.
//!
//! Claim disjointness is inherited from [`TwoEndedCursor`]: a single CAS
//! decides every claim, so each query position is handed out exactly
//! once; the recirculation buffer is written only by the GPU master and
//! drained through a CAS'd read cursor, so each failed query is re-solved
//! exactly once. Per-claim telemetry feeds a *running* ρ^Model (Eq. 6 as
//! feedback): the GPU sizes its next batch from the live CPU/GPU work
//! rates instead of diagnosing the balance after the fact.

use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

use crate::util::pool::TwoEndedCursor;

/// Which architecture serviced a claim.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    /// an EXACT-ANN CPU rank
    Cpu,
    /// the GPU master
    Gpu,
}

/// One claim serviced by one architecture - the unit of the scheduling
/// telemetry that replaces the single-shot T1/T2 accounting.
#[derive(Debug, Clone)]
pub struct ClaimRecord {
    /// which architecture serviced the claim
    pub arch: Arch,
    /// queries solved under this claim
    pub queries: usize,
    /// estimated work (candidate scans) of the claim
    pub est_work: u64,
    /// seconds spent servicing the claim. For pipelined GPU claims this
    /// is `exec_secs + transfer_secs + filter_secs` (resource time - the
    /// components overlap in wall time); everywhere else it is plain
    /// wall time.
    pub secs: f64,
    /// GPU claims: master-thread seconds materialising, packing and
    /// executing the claim's tiles on the device - the kernel-side time
    /// the claim-ahead sizing feeds on. Excludes the device-to-host copy
    /// (`transfer_secs`). 0 for CPU claims.
    pub exec_secs: f64,
    /// GPU claims: seconds converting the claim's device output literals
    /// into flat host buffers (the host half of the device-to-host path;
    /// the buffer-to-literal step inside `exec_lits` stays in
    /// `exec_secs` - see `GpuJoinStats::transfer_time`). Under the
    /// three-stage drain this runs on the dedicated transfer stage and
    /// overlaps later claims' `exec_secs`; under the sync/two-stage
    /// drains it runs on the master thread. 0 for CPU claims.
    pub transfer_secs: f64,
    /// GPU claims: filter-stage wall seconds over the claim's flush
    /// rounds. Under the pipelined drains this overlaps later claims'
    /// `exec_secs`, which is what makes Σexec + Σtransfer + Σfilter
    /// exceed the GPU phase wall time when the pipeline is working. 0
    /// for CPU claims.
    pub filter_secs: f64,
    /// true when the claim drained recirculated Q^Fail queries
    pub from_recirc: bool,
    /// true when the claim ran on the GPU's tiled brute-force tier (the
    /// `sched::route_brute` decision, or a forced `BackendMode`); false
    /// for grid-tier GPU claims and always false for CPU claims
    pub brute: bool,
    /// true when the claim failed on the GPU and its queries were pushed
    /// back through Q^Fail (claim-scoped recovery): `queries` then counts
    /// the *reclaimed* queries, which some CPU rank (or a later GPU
    /// recirc claim) re-solves under its own record. Always false for
    /// CPU claims.
    pub failed: bool,
}

/// One grid cell's entry into the queue, pre-sorted by the builder
/// (`sched::build_queue`) densest first.
#[derive(Debug, Clone)]
pub struct QueueCell {
    /// linearised grid cell id (diagnostics)
    pub cell_id: u64,
    /// estimated work per query of this cell (adjacent-block population)
    pub per_query_work: u64,
    /// query ids (into R) whose point falls in this cell; non-empty
    pub queries: Vec<u32>,
}

/// The shared work queue. Built once before the join, then drained
/// concurrently from both ends; all claim paths are lock-free.
#[derive(Debug)]
pub struct WorkQueue {
    /// query ids, grouped by cell, densest cell first
    queries: Vec<u32>,
    /// cell boundaries into `queries`, with a final sentinel == len
    cell_starts: Vec<u32>,
    /// linearised grid id per cell (diagnostics, aligned with boundaries)
    cell_ids: Vec<u64>,
    /// prefix_work[i] = estimated work of queries[0..i]; len == n + 1
    prefix_work: Vec<u64>,
    cursor: TwoEndedCursor,
    /// queries in cells meeting the γ threshold (the static split's Q^GPU
    /// - kept as a *seed hint* for the first GPU batch and as the GPU cap
    /// on single-core hosts)
    dense_prefix: usize,
    /// ρ floor: tail positions claimable only by the CPU
    reserve: usize,
    /// the n^thresh used (diagnostics)
    threshold: f64,
    /// index epoch the queue was built against (0 when not stamped):
    /// consumers holding cross-flush caches (the GPU brute tile cache)
    /// compare stamps and invalidate on change
    generation: u64,

    // ---- Q^Fail recirculation (single producer: the GPU master) ----
    recirc: Vec<AtomicU32>,
    recirc_published: AtomicUsize,
    recirc_taken: AtomicUsize,
    gpu_done: AtomicBool,

    // ---- live telemetry for the running ρ^Model ----
    t0: Instant,
    cpu_busy_nanos: AtomicU64,
    cpu_work: AtomicU64,
    cpu_queries: AtomicUsize,
}

impl WorkQueue {
    /// Assemble the queue from cells already sorted densest-first.
    /// `dense_prefix` is the number of *leading* queries whose cells meet
    /// the γ threshold; `reserve` is the ρ floor in queries.
    pub fn from_cells(
        cells: Vec<QueueCell>,
        dense_prefix: usize,
        reserve: usize,
        threshold: f64,
    ) -> Self {
        let n: usize = cells.iter().map(|c| c.queries.len()).sum();
        let mut queries = Vec::with_capacity(n);
        let mut cell_starts = Vec::with_capacity(cells.len() + 1);
        let mut cell_ids = Vec::with_capacity(cells.len());
        let mut prefix_work = Vec::with_capacity(n + 1);
        let mut acc = 0u64;
        prefix_work.push(acc);
        for c in &cells {
            debug_assert!(!c.queries.is_empty(), "empty cell in queue build");
            cell_starts.push(queries.len() as u32);
            cell_ids.push(c.cell_id);
            let w = c.per_query_work.max(1);
            for &q in &c.queries {
                queries.push(q);
                acc += w;
                prefix_work.push(acc);
            }
        }
        cell_starts.push(n as u32);
        let reserve = reserve.min(n);
        WorkQueue {
            cursor: TwoEndedCursor::new(n, reserve),
            queries,
            cell_starts,
            cell_ids,
            prefix_work,
            dense_prefix: dense_prefix.min(n),
            reserve,
            threshold,
            generation: 0,
            recirc: (0..n).map(|_| AtomicU32::new(0)).collect(),
            recirc_published: AtomicUsize::new(0),
            recirc_taken: AtomicUsize::new(0),
            gpu_done: AtomicBool::new(false),
            t0: Instant::now(),
            cpu_busy_nanos: AtomicU64::new(0),
            cpu_work: AtomicU64::new(0),
            cpu_queries: AtomicUsize::new(0),
        }
    }

    /// Total queries in the queue.
    pub fn len(&self) -> usize {
        self.queries.len()
    }

    /// True when the queue was built over zero queries.
    pub fn is_empty(&self) -> bool {
        self.queries.is_empty()
    }

    /// Number of cells.
    pub fn cells(&self) -> usize {
        self.cell_ids.len()
    }

    /// The query ids of a claimed position range.
    pub fn query_slice(&self, r: Range<usize>) -> &[u32] {
        &self.queries[r]
    }

    /// Estimated work of a position range.
    pub fn range_work(&self, r: Range<usize>) -> u64 {
        self.prefix_work[r.end] - self.prefix_work[r.start]
    }

    /// Total estimated work of the queue.
    pub fn total_work(&self) -> u64 {
        *self.prefix_work.last().unwrap()
    }

    /// Queries in cells meeting the γ threshold (static split's Q^GPU).
    pub fn dense_prefix(&self) -> usize {
        self.dense_prefix
    }

    /// Estimated work of the dense prefix (the γ seed).
    pub fn dense_work(&self) -> u64 {
        self.prefix_work[self.dense_prefix]
    }

    /// ρ floor actually applied, in queries.
    pub fn reserve(&self) -> usize {
        self.reserve
    }

    /// Mean estimated work per query. Recirculated Q^Fail queries are
    /// re-credited at this price (their tail position is gone), so the
    /// live CPU work rate - the GPU's batch-sizing feedback - does not
    /// decay toward zero on recirculation-heavy runs.
    pub fn mean_query_work(&self) -> u64 {
        if self.queries.is_empty() {
            1
        } else {
            (self.total_work() / self.len() as u64).max(1)
        }
    }

    /// The n^thresh the γ seeding used (diagnostics).
    pub fn threshold(&self) -> f64 {
        self.threshold
    }

    /// Stamp the queue with the index epoch it was built against
    /// (builder form: `from_cells(..).with_generation(g)`). The churn
    /// path stamps every queue with [`crate::index::GridIndex::epoch`]
    /// so in-flight drains read a consistent snapshot.
    pub fn with_generation(mut self, generation: u64) -> Self {
        self.generation = generation;
        self
    }

    /// Index epoch this queue was built against (0 when unstamped).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Split a claimed position range at cell boundaries. Each returned
    /// sub-range lies within one cell, so its queries share one candidate
    /// list. (A range may *start* mid-cell when a previous front claim was
    /// clipped by the advancing back - the partial remainder still groups
    /// correctly.)
    pub fn cell_ranges(&self, r: Range<usize>) -> Vec<Range<usize>> {
        let mut out = Vec::new();
        let mut s = r.start;
        let mut bi = self.cell_starts.partition_point(|&b| (b as usize) <= s);
        while s < r.end {
            let e = self
                .cell_starts
                .get(bi)
                .map(|&b| b as usize)
                .unwrap_or(self.queries.len())
                .min(r.end);
            out.push(s..e);
            s = e;
            bi += 1;
        }
        out
    }

    // ---- claims ----

    /// GPU-master claim: take whole cells off the dense head until their
    /// aggregate estimated work reaches `target` (at least one cell; the
    /// final claim may be clipped by the advancing CPU back or by
    /// `pos_cap`). Returns the claimed position range.
    pub fn claim_head_work(&self, target: u64, pos_cap: usize) -> Option<Range<usize>> {
        self.cursor.claim_front_with(pos_cap, |head, avail| {
            let limit = head + avail;
            let base = self.prefix_work[head];
            // first cell boundary past head whose cumulated work meets the
            // target; fall back to everything available
            let bi = self.cell_starts.partition_point(|&b| (b as usize) <= head);
            let mut end = limit;
            for &b in &self.cell_starts[bi..] {
                let b = b as usize;
                if b >= limit {
                    break;
                }
                if self.prefix_work[b] - base >= target {
                    end = b;
                    break;
                }
            }
            end - head
        })
    }

    /// CPU-rank claim: up to `chunk` queries off the sparse tail.
    pub fn claim_tail(&self, chunk: usize) -> Option<Range<usize>> {
        self.cursor.claim_back(chunk)
    }

    /// Can the head still yield work under `pos_cap`?
    pub fn head_open(&self, pos_cap: usize) -> bool {
        let head = self.cursor.claimed_front();
        let back = self.cursor.claimed_back();
        head < self.cursor.front_limit().min(pos_cap).min(self.len() - back)
    }

    /// Estimated work still claimable from the head (heuristic snapshot;
    /// the live cursors move underneath it).
    pub fn head_work_remaining(&self, pos_cap: usize) -> u64 {
        let head = self.cursor.claimed_front();
        let back = self.cursor.claimed_back();
        let limit = self.cursor.front_limit().min(pos_cap).min(self.len() - back);
        if head >= limit {
            0
        } else {
            self.prefix_work[limit] - self.prefix_work[head]
        }
    }

    /// Queries claimed by the GPU so far.
    pub fn claimed_head(&self) -> usize {
        self.cursor.claimed_front()
    }

    /// Queries claimed by CPU ranks (tail claims) so far.
    pub fn claimed_tail(&self) -> usize {
        self.cursor.claimed_back()
    }

    // ---- Q^Fail recirculation ----

    /// Recirculate failed queries into the live queue. **Single producer**:
    /// only the GPU master may call this (it is the only source of
    /// failures); the Release publish makes the ids visible to any
    /// consumer that observes the new count.
    ///
    /// Publication may lag claiming: under the pipelined GPU drains a
    /// claim's failures land here only when the claim is *resolved* - up
    /// to two (two-stage) or three (three-stage) claims after it was
    /// taken off the head. The exactly-once contract is unaffected (it
    /// depends only on the published-count CAS), but consumers must not
    /// assume a failure is visible before later head claims are; the
    /// CPU exit protocol (done flag read before the final empty claims)
    /// already tolerates this, and `rust/tests/failure_injection.rs`
    /// race-tests exactly this deferred ordering at both pipeline depths.
    pub fn push_failed(&self, ids: &[u32]) {
        if ids.is_empty() {
            return;
        }
        let start = self.recirc_published.load(Ordering::Relaxed);
        assert!(
            start + ids.len() <= self.recirc.len(),
            "recirculation overflow: {} + {} > {}",
            start,
            ids.len(),
            self.recirc.len()
        );
        for (i, &q) in ids.iter().enumerate() {
            self.recirc[start + i].store(q, Ordering::Relaxed);
        }
        self.recirc_published.store(start + ids.len(), Ordering::Release);
    }

    /// Claim up to `max` recirculated queries (multi-consumer; each id is
    /// handed out exactly once).
    pub fn claim_recirc(&self, max: usize) -> Option<Vec<u32>> {
        let max = max.max(1);
        loop {
            let published = self.recirc_published.load(Ordering::Acquire);
            let taken = self.recirc_taken.load(Ordering::Acquire);
            if taken >= published {
                return None;
            }
            let take = max.min(published - taken);
            if self
                .recirc_taken
                .compare_exchange(taken, taken + take, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                continue;
            }
            return Some(
                (taken..taken + take)
                    .map(|i| self.recirc[i].load(Ordering::Relaxed))
                    .collect(),
            );
        }
    }

    /// Failures recirculated so far (diagnostics).
    pub fn recirc_pushed(&self) -> usize {
        self.recirc_published.load(Ordering::Acquire)
    }

    /// The GPU master is done claiming and has published its last
    /// failures; CPU ranks may exit once the tail and the recirculation
    /// buffer are both drained.
    pub fn set_gpu_done(&self) {
        self.gpu_done.store(true, Ordering::Release);
    }

    /// Has the GPU master finished claiming and publishing failures?
    pub fn gpu_done(&self) -> bool {
        self.gpu_done.load(Ordering::Acquire)
    }

    // ---- live telemetry (running ρ^Model feedback) ----

    /// CPU ranks report a serviced claim.
    pub fn note_cpu(&self, queries: usize, work: u64, secs: f64) {
        self.cpu_busy_nanos
            .fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
        self.cpu_work.fetch_add(work, Ordering::Relaxed);
        self.cpu_queries.fetch_add(queries, Ordering::Relaxed);
    }

    /// Collective CPU throughput in estimated-work units per second since
    /// queue construction (0.0 until the first CPU claim lands). The GPU
    /// master divides its own rate by this to size the next batch.
    pub fn cpu_work_rate(&self) -> f64 {
        let w = self.cpu_work.load(Ordering::Relaxed) as f64;
        let secs = self.t0.elapsed().as_secs_f64();
        if w <= 0.0 || secs <= 0.0 {
            0.0
        } else {
            w / secs
        }
    }

    /// Total CPU busy seconds reported so far.
    pub fn cpu_busy_secs(&self) -> f64 {
        self.cpu_busy_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Queries the CPU has solved so far (tail + recirculated).
    pub fn cpu_queries_done(&self) -> usize {
        self.cpu_queries.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Random queue: `n_cells` cells with random sizes/works; query ids
    /// are 0..n in flat order so position == id (easiest to audit).
    fn random_queue(rng: &mut Rng) -> WorkQueue {
        let n_cells = 1 + rng.below(40);
        let mut next_id = 0u32;
        let cells: Vec<QueueCell> = (0..n_cells)
            .map(|c| {
                let sz = 1 + rng.below(30);
                let queries: Vec<u32> = (next_id..next_id + sz as u32).collect();
                next_id += sz as u32;
                QueueCell {
                    cell_id: c as u64,
                    per_query_work: 1 + rng.below(50) as u64,
                    queries,
                }
            })
            .collect();
        let n = next_id as usize;
        let dense = rng.below(n + 1);
        let reserve = rng.below(n + 1);
        WorkQueue::from_cells(cells, dense, reserve, 0.0)
    }

    #[test]
    fn head_claims_align_to_cell_boundaries() {
        let cells = vec![
            QueueCell { cell_id: 0, per_query_work: 10, queries: vec![0, 1, 2] },
            QueueCell { cell_id: 1, per_query_work: 5, queries: vec![3, 4] },
            QueueCell { cell_id: 2, per_query_work: 1, queries: vec![5] },
        ];
        let q = WorkQueue::from_cells(cells, 3, 0, 0.0);
        assert_eq!(q.len(), 6);
        assert_eq!(q.total_work(), 3 * 10 + 2 * 5 + 1);
        assert_eq!(q.dense_work(), 30);
        // a tiny target still claims the whole first cell
        let r = q.claim_head_work(1, q.len()).unwrap();
        assert_eq!(r, 0..3);
        // a target spanning cell 1 claims exactly cell 1
        let r = q.claim_head_work(10, q.len()).unwrap();
        assert_eq!(r, 3..5);
        // remainder
        let r = q.claim_head_work(u64::MAX, q.len()).unwrap();
        assert_eq!(r, 5..6);
        assert!(q.claim_head_work(1, q.len()).is_none());
    }

    #[test]
    fn cell_ranges_split_claims_per_cell() {
        let cells = vec![
            QueueCell { cell_id: 7, per_query_work: 2, queries: vec![10, 11] },
            QueueCell { cell_id: 8, per_query_work: 2, queries: vec![12, 13, 14] },
            QueueCell { cell_id: 9, per_query_work: 2, queries: vec![15] },
        ];
        let q = WorkQueue::from_cells(cells, 0, 0, 0.0);
        assert_eq!(q.cells(), 3);
        let rs = q.cell_ranges(0..6);
        assert_eq!(rs, vec![0..2, 2..5, 5..6]);
        // mid-cell start and end
        let rs = q.cell_ranges(1..4);
        assert_eq!(rs, vec![1..2, 2..4]);
        assert_eq!(q.query_slice(2..5), &[12, 13, 14]);
        assert!(q.cell_ranges(3..3).is_empty());
    }

    #[test]
    fn rho_reserve_caps_the_head() {
        let cells = vec![QueueCell {
            cell_id: 0,
            per_query_work: 1,
            queries: (0..10).collect(),
        }];
        let q = WorkQueue::from_cells(cells, 10, 4, 0.0);
        assert_eq!(q.reserve(), 4);
        let r = q.claim_head_work(u64::MAX, q.len()).unwrap();
        assert_eq!(r, 0..6, "head clipped by the ρ reserve");
        assert!(!q.head_open(q.len()));
        assert_eq!(q.head_work_remaining(q.len()), 0);
        let mut tail = 0;
        while let Some(r) = q.claim_tail(3) {
            tail += r.len();
        }
        assert_eq!(tail, 4);
    }

    #[test]
    fn recirc_single_producer_multi_consumer_exact_once() {
        let cells = vec![QueueCell {
            cell_id: 0,
            per_query_work: 1,
            queries: (0..2000).collect(),
        }];
        let q = WorkQueue::from_cells(cells, 0, 0, 0.0);
        let hits: Vec<AtomicUsize> = (0..2000).map(|_| AtomicUsize::new(0)).collect();
        std::thread::scope(|scope| {
            scope.spawn(|| {
                // GPU-master pattern: publish failures in bursts, then done
                for burst in 0..40u32 {
                    let ids: Vec<u32> = (burst * 50..(burst + 1) * 50).collect();
                    q.push_failed(&ids);
                }
                q.set_gpu_done();
            });
            for _ in 0..3 {
                let (q, hits) = (&q, &hits);
                scope.spawn(move || loop {
                    if let Some(ids) = q.claim_recirc(7) {
                        for id in ids {
                            hits[id as usize].fetch_add(1, Ordering::Relaxed);
                        }
                        continue;
                    }
                    if q.gpu_done() {
                        if let Some(ids) = q.claim_recirc(7) {
                            for id in ids {
                                hits[id as usize].fetch_add(1, Ordering::Relaxed);
                            }
                            continue;
                        }
                        break;
                    }
                    std::thread::yield_now();
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(q.recirc_pushed(), 2000);
    }

    #[test]
    fn concurrent_two_ended_drain_partitions_exactly_once() {
        // The satellite property: under concurrent two-ended draining with
        // any rank count, batch sizing, and reserve, every query position
        // is claimed exactly once and the reserve never leaks to the head.
        prop::cases(12, 0x52ED, |rng| {
            let q = random_queue(rng);
            let n = q.len();
            let ranks = 1 + rng.below(4);
            let chunk = 1 + rng.below(9);
            let target0 = 1 + rng.below(200) as u64;
            let reserve = q.reserve();
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            std::thread::scope(|scope| {
                {
                    let (q, hits) = (&q, &hits);
                    scope.spawn(move || {
                        let mut target = target0;
                        while let Some(r) = q.claim_head_work(target, n) {
                            for i in r {
                                hits[i].fetch_add(1, Ordering::Relaxed);
                            }
                            target = (target * 2).max(1) % 500 + 1;
                        }
                        q.set_gpu_done();
                    });
                }
                for _ in 0..ranks {
                    let (q, hits) = (&q, &hits);
                    scope.spawn(move || loop {
                        if let Some(r) = q.claim_tail(chunk) {
                            for i in r {
                                hits[i].fetch_add(1, Ordering::Relaxed);
                            }
                            continue;
                        }
                        if q.gpu_done() {
                            break;
                        }
                        std::thread::yield_now();
                    });
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "every position claimed exactly once"
            );
            assert_eq!(q.claimed_head() + q.claimed_tail(), n);
            assert!(q.claimed_tail() >= reserve, "ρ reserve honoured");
        });
    }

    #[test]
    fn degenerate_queues() {
        let q = WorkQueue::from_cells(Vec::new(), 0, 0, 0.0);
        assert!(q.is_empty());
        assert!(q.claim_head_work(100, 10).is_none());
        assert!(q.claim_tail(4).is_none());
        assert!(q.claim_recirc(4).is_none());
        assert_eq!(q.total_work(), 0);
        assert!(!q.head_open(usize::MAX));

        // single query, full reserve
        let q = WorkQueue::from_cells(
            vec![QueueCell { cell_id: 1, per_query_work: 3, queries: vec![9] }],
            1,
            1,
            0.0,
        );
        assert!(q.claim_head_work(1, q.len()).is_none());
        assert_eq!(q.claim_tail(8).unwrap(), 0..1);
        assert_eq!(q.query_slice(0..1), &[9]);
    }

    #[test]
    fn telemetry_accumulates() {
        let q = WorkQueue::from_cells(
            vec![QueueCell { cell_id: 0, per_query_work: 2, queries: vec![0, 1] }],
            0,
            0,
            0.0,
        );
        assert_eq!(q.cpu_work_rate(), 0.0);
        q.note_cpu(2, 40, 0.5);
        q.note_cpu(1, 10, 0.25);
        assert_eq!(q.cpu_queries_done(), 3);
        assert!((q.cpu_busy_secs() - 0.75).abs() < 1e-9);
        assert!(q.cpu_work_rate() > 0.0);
    }
}
