//! Deterministic fault injection and the GPU master's recovery policy.
//!
//! The hybrid drain treats a device fault as costing *one claim*, not the
//! run: the shared queue's Q^Fail recirculation buffer is already the
//! natural substrate for handing a failed claim's queries back to the CPU
//! ranks (or to a retried GPU claim) with the exactly-once contract
//! intact. This module holds the pieces that make that testable and
//! tunable:
//!
//! * [`FaultPlan`] - a seeded, deterministic schedule of injected faults
//!   (exec error, transfer error, stall, filter panic), threaded as
//!   always-on hooks into the GPU drain's three stages. The hooks are
//!   branch-on-empty no-ops under [`FaultPlan::none()`], so production
//!   runs pay one `is_empty` check per round - there is no `cfg(test)`
//!   fork between the tested and the shipped drain.
//! * [`RecoveryPolicy`] - bounded exponential backoff for transient
//!   faults, a consecutive-failure demotion threshold, and the watchdog
//!   slack applied to the live ρ^Model rate (see
//!   [`crate::sched::claim_deadline_secs`]).
//! * [`FaultLog`] / [`FaultEvent`] - the per-event telemetry surfaced
//!   through `GpuJoinStats` and `HybridReport`.
//! * [`InjectedFault`] / [`WatchdogTimeout`] - typed, downcastable error
//!   values so tests can distinguish an injected fault from a real one.

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};

use crate::util::rng::Rng;

/// The failure mode a [`FaultSpec`] injects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The exec stage returns an error mid-claim (device kernel fault).
    ExecError,
    /// The device-to-host transfer stage fails for one round.
    TransferError,
    /// The exec stage hangs for `stall_secs`; detected by the per-claim
    /// watchdog deadline, not by the injection itself (the hook sleeps
    /// and then *succeeds* - only the deadline turns it into a fault).
    StallTimeout,
    /// A filter-stage worker panics while folding a round's tiles.
    FilterPanic,
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            FaultKind::ExecError => "exec-error",
            FaultKind::TransferError => "transfer-error",
            FaultKind::StallTimeout => "stall-timeout",
            FaultKind::FilterPanic => "filter-panic",
        };
        f.write_str(s)
    }
}

/// One scheduled fault. Transient specs fire exactly once, at the first
/// attempt that reaches (`claim`, `round`); persistent specs fire on
/// *every* attempt of every claim `>= claim` (broken-device semantics:
/// retries of the faulted claim and all later claims fail too, which is
/// what drives the master through its demotion path).
#[derive(Debug)]
pub struct FaultSpec {
    /// failure mode to inject
    pub kind: FaultKind,
    /// claim index (in claim order off the queue head) that triggers it
    pub claim: usize,
    /// flush-round index within the claim that triggers it
    pub round: usize,
    /// false: fire once and disarm; true: fire on every claim `>= claim`
    pub persistent: bool,
    /// [`FaultKind::StallTimeout`] only: seconds the exec hook sleeps
    pub stall_secs: f64,
    fired: AtomicBool,
}

impl FaultSpec {
    /// A transient fault: fires once at exactly (`claim`, `round`).
    pub fn transient(kind: FaultKind, claim: usize, round: usize) -> Self {
        FaultSpec {
            kind,
            claim,
            round,
            persistent: false,
            stall_secs: 0.0,
            fired: AtomicBool::new(false),
        }
    }

    /// A persistent fault: fires on every attempt of every claim
    /// `>= claim` (the device is broken from that point on).
    pub fn persistent(kind: FaultKind, claim: usize) -> Self {
        FaultSpec {
            kind,
            claim,
            round: 0,
            persistent: true,
            stall_secs: 0.0,
            fired: AtomicBool::new(false),
        }
    }

    /// Whether this spec triggers for the given (claim, round) attempt.
    /// Transient specs disarm themselves on their first match (atomic
    /// swap - at most one trigger even when stages race).
    fn triggers(&self, claim: usize, round: usize) -> bool {
        if self.persistent {
            return claim >= self.claim;
        }
        claim == self.claim
            && round == self.round
            && !self.fired.swap(true, Ordering::Relaxed)
    }
}

impl Clone for FaultSpec {
    fn clone(&self) -> Self {
        FaultSpec {
            kind: self.kind,
            claim: self.claim,
            round: self.round,
            persistent: self.persistent,
            stall_secs: self.stall_secs,
            fired: AtomicBool::new(self.fired.load(Ordering::Relaxed)),
        }
    }
}

/// A deterministic schedule of injected faults, shared by the drain's
/// exec, transfer and filter stages. Empty (the default) in production.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// the scheduled faults; checked in order, first trigger wins
    pub specs: Vec<FaultSpec>,
}

impl FaultPlan {
    /// The production plan: no faults, hooks reduce to an `is_empty`
    /// branch.
    pub fn none() -> Self {
        FaultPlan { specs: Vec::new() }
    }

    /// Plan with a single spec.
    pub fn one(spec: FaultSpec) -> Self {
        FaultPlan { specs: vec![spec] }
    }

    /// True when no spec can ever fire.
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// A seeded random plan for property tests: 1-3 *transient* faults
    /// over the first few claims/rounds, mixing all four kinds. Stalls
    /// sleep a few milliseconds - long enough to trip a test-tuned
    /// watchdog, short enough for the default (5 s floor) to ignore.
    pub fn random(rng: &mut Rng) -> Self {
        let kinds = [
            FaultKind::ExecError,
            FaultKind::TransferError,
            FaultKind::StallTimeout,
            FaultKind::FilterPanic,
        ];
        let n = 1 + rng.below(3);
        let specs = (0..n)
            .map(|_| {
                let kind = kinds[rng.below(4)];
                let mut s = FaultSpec::transient(kind, rng.below(3), rng.below(2));
                if kind == FaultKind::StallTimeout {
                    s.stall_secs = 0.001 + rng.f64() * 0.003;
                }
                s
            })
            .collect();
        FaultPlan { specs }
    }

    /// Exec-stage hook, called once per flush round on the master
    /// thread. Sleeps through any matching stall spec (the watchdog, not
    /// the hook, decides whether that was a fault), then errors on any
    /// matching exec spec.
    pub fn exec_round(&self, claim: usize, round: usize) -> anyhow::Result<()> {
        if self.specs.is_empty() {
            return Ok(());
        }
        for s in &self.specs {
            if s.kind == FaultKind::StallTimeout && s.triggers(claim, round) {
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    s.stall_secs.max(0.0),
                ));
            }
        }
        for s in &self.specs {
            if s.kind == FaultKind::ExecError && s.triggers(claim, round) {
                return Err(InjectedFault::new(s.kind, claim, round).into());
            }
        }
        Ok(())
    }

    /// Transfer-stage hook, called once per round on the transfer worker
    /// (three-stage drain) or the master (sync/two-stage).
    pub fn transfer_fault(&self, claim: usize, round: usize) -> Option<anyhow::Error> {
        if self.specs.is_empty() {
            return None;
        }
        for s in &self.specs {
            if s.kind == FaultKind::TransferError && s.triggers(claim, round) {
                return Some(InjectedFault::new(s.kind, claim, round).into());
            }
        }
        None
    }

    /// Filter-stage hook, called once per round on a filter worker; a
    /// `true` return makes the worker panic (which the recoverable pool
    /// catches and surfaces as that lane's claim failure).
    pub fn filter_panic(&self, claim: usize, round: usize) -> bool {
        if self.specs.is_empty() {
            return false;
        }
        self.specs
            .iter()
            .any(|s| s.kind == FaultKind::FilterPanic && s.triggers(claim, round))
    }
}

/// The typed error an injected exec/transfer fault surfaces as, so tests
/// can `downcast_ref` it out of the `anyhow` chain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedFault {
    /// failure mode that was injected
    pub kind: FaultKind,
    /// claim index the fault fired on
    pub claim: usize,
    /// flush round the fault fired on
    pub round: usize,
}

impl InjectedFault {
    fn new(kind: FaultKind, claim: usize, round: usize) -> Self {
        InjectedFault { kind, claim, round }
    }
}

impl fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "injected {} fault (claim {}, round {})",
            self.kind, self.claim, self.round
        )
    }
}

impl std::error::Error for InjectedFault {}

/// The typed error a tripped per-claim watchdog deadline surfaces as.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WatchdogTimeout {
    /// claim index that overran its deadline
    pub claim: usize,
    /// seconds the claim had been running when the trip was detected
    pub elapsed: f64,
    /// the deadline it overran (see [`crate::sched::claim_deadline_secs`])
    pub deadline: f64,
}

impl fmt::Display for WatchdogTimeout {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "claim {} watchdog: {:.3}s elapsed > {:.3}s deadline",
            self.claim, self.elapsed, self.deadline
        )
    }
}

impl std::error::Error for WatchdogTimeout {}

/// How the GPU master reacts to claim failures: retry budget and backoff
/// for transients, the demotion threshold, and the watchdog envelope.
#[derive(Debug, Clone, Copy)]
pub struct RecoveryPolicy {
    /// synchronous retries per failed claim before it is reclaimed
    pub retry_limit: usize,
    /// backoff before retry `a` is `min(cap, base * 2^a)` seconds
    pub backoff_base_secs: f64,
    /// cap on the exponential backoff
    pub backoff_cap_secs: f64,
    /// consecutive claim *reclaims* (retries exhausted) after which the
    /// master demotes itself and the run completes CPU-only
    pub demote_after: usize,
    /// watchdog deadline = `slack * est_work / live_rate` (see
    /// [`crate::sched::claim_deadline_secs`])
    pub watchdog_slack: f64,
    /// floor on the watchdog deadline, so cold-start noise and tiny
    /// claims never trip it
    pub watchdog_min_secs: f64,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy {
            retry_limit: 2,
            backoff_base_secs: 0.05,
            backoff_cap_secs: 1.0,
            demote_after: 3,
            watchdog_slack: 8.0,
            watchdog_min_secs: 5.0,
        }
    }
}

impl RecoveryPolicy {
    /// Seconds to sleep before retry attempt `attempt` (0-based):
    /// bounded exponential backoff, `min(cap, base * 2^attempt)`.
    pub fn backoff_secs(&self, attempt: usize) -> f64 {
        let exp = self.backoff_base_secs * (1u64 << attempt.min(32)) as f64;
        exp.min(self.backoff_cap_secs)
    }
}

/// What the master did about a fault event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// transient: the claim was retried synchronously after backoff
    Retried,
    /// retries exhausted: the claim's queries went back through Q^Fail
    Reclaimed,
    /// too many consecutive reclaims: the GPU master shut itself down
    Demoted,
}

impl fmt::Display for FaultAction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            FaultAction::Retried => "retried",
            FaultAction::Reclaimed => "reclaimed",
            FaultAction::Demoted => "demoted",
        };
        f.write_str(s)
    }
}

/// One fault the master observed, with what it did about it.
#[derive(Debug, Clone)]
pub struct FaultEvent {
    /// the failure mode observed (injected or real)
    pub kind: FaultKind,
    /// claim index (in head-claim order) the fault hit
    pub claim: usize,
    /// 0-based attempt number the failure occurred on
    pub attempt: usize,
    /// the recovery action taken
    pub action: FaultAction,
    /// human-readable error / panic message
    pub detail: String,
}

/// The ordered log of fault events for one run, surfaced through
/// `GpuJoinStats::fault_log` and `HybridReport::fault_log`.
#[derive(Debug, Clone, Default)]
pub struct FaultLog {
    /// events in the order the master observed them
    pub events: Vec<FaultEvent>,
}

impl FaultLog {
    /// Record one event.
    pub fn push(
        &mut self,
        kind: FaultKind,
        claim: usize,
        attempt: usize,
        action: FaultAction,
        detail: impl Into<String>,
    ) {
        self.events.push(FaultEvent { kind, claim, attempt, action, detail: detail.into() });
    }

    /// Number of events with the given action.
    pub fn count(&self, action: FaultAction) -> usize {
        self.events.iter().filter(|e| e.action == action).count()
    }
}

/// Render a `catch_unwind` payload as a readable message (panics carry
/// `&str` or `String` in practice; anything else gets a placeholder).
pub fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked (non-string payload)".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn transient_fires_exactly_once_at_its_coordinates() {
        let plan =
            FaultPlan::one(FaultSpec::transient(FaultKind::ExecError, 2, 1));
        assert!(plan.exec_round(0, 0).is_ok());
        assert!(plan.exec_round(2, 0).is_ok());
        let err = plan.exec_round(2, 1).unwrap_err();
        let inj = err.downcast_ref::<InjectedFault>().unwrap();
        assert_eq!(inj.kind, FaultKind::ExecError);
        assert_eq!((inj.claim, inj.round), (2, 1));
        // disarmed: the retry of the same (claim, round) succeeds
        assert!(plan.exec_round(2, 1).is_ok());
        assert!(plan.exec_round(3, 1).is_ok());
    }

    #[test]
    fn persistent_fires_on_every_attempt_from_its_claim() {
        let plan =
            FaultPlan::one(FaultSpec::persistent(FaultKind::TransferError, 1));
        assert!(plan.transfer_fault(0, 0).is_none());
        for claim in 1..4 {
            for round in 0..3 {
                assert!(
                    plan.transfer_fault(claim, round).is_some(),
                    "persistent fault must fire at claim {claim} round {round}"
                );
            }
        }
    }

    #[test]
    fn none_plan_is_inert() {
        let plan = FaultPlan::none();
        assert!(plan.is_empty());
        for claim in 0..4 {
            assert!(plan.exec_round(claim, 0).is_ok());
            assert!(plan.transfer_fault(claim, 0).is_none());
            assert!(!plan.filter_panic(claim, 0));
        }
    }

    #[test]
    fn filter_panic_is_transient_too() {
        let plan =
            FaultPlan::one(FaultSpec::transient(FaultKind::FilterPanic, 0, 0));
        assert!(plan.filter_panic(0, 0));
        assert!(!plan.filter_panic(0, 0), "disarmed after the first trigger");
    }

    #[test]
    fn random_plans_are_reproducible_per_seed() {
        prop::cases(16, 0xFA17, |rng| {
            let seed = rng.next_u64();
            let a = FaultPlan::random(&mut crate::util::rng::Rng::new(seed));
            let b = FaultPlan::random(&mut crate::util::rng::Rng::new(seed));
            assert_eq!(a.specs.len(), b.specs.len());
            for (x, y) in a.specs.iter().zip(&b.specs) {
                assert_eq!(x.kind, y.kind);
                assert_eq!((x.claim, x.round), (y.claim, y.round));
                assert_eq!(x.stall_secs, y.stall_secs);
            }
        });
    }

    #[test]
    fn backoff_is_bounded_and_monotone() {
        let p = RecoveryPolicy::default();
        let mut last = -1.0;
        for a in 0..12 {
            let b = p.backoff_secs(a);
            assert!(b >= last, "backoff must be non-decreasing");
            assert!(b <= p.backoff_cap_secs, "backoff must respect the cap");
            last = b;
        }
        assert_eq!(p.backoff_secs(0), p.backoff_base_secs);
        assert_eq!(p.backoff_secs(1), p.backoff_base_secs * 2.0);
    }

    #[test]
    fn fault_log_counts_by_action() {
        let mut log = FaultLog::default();
        log.push(FaultKind::ExecError, 0, 0, FaultAction::Retried, "x");
        log.push(FaultKind::ExecError, 0, 1, FaultAction::Reclaimed, "x");
        log.push(FaultKind::ExecError, 1, 0, FaultAction::Demoted, "x");
        assert_eq!(log.count(FaultAction::Retried), 1);
        assert_eq!(log.count(FaultAction::Reclaimed), 1);
        assert_eq!(log.count(FaultAction::Demoted), 1);
        assert_eq!(log.events.len(), 3);
    }

    #[test]
    fn panic_messages_render() {
        let p = std::panic::catch_unwind(|| panic!("boom {}", 7)).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "boom 7");
        let p = std::panic::catch_unwind(|| panic!("static")).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "static");
    }
}
