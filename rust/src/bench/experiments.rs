//! One runner per paper table / figure (see DESIGN.md §6 for the index).
//! Every runner returns `Table`s whose rows mirror what the paper plots,
//! so `cargo bench` output can be compared against the paper shape by
//! shape (EXPERIMENTS.md records the comparison).

use anyhow::Result;

use super::{secs, Table, Workload};
use crate::cpu;
use crate::data::variance::reorder_by_variance;
use crate::epsilon::EpsilonSelector;
use crate::gpu::{self, DeviceModel, ThreadAssign};
use crate::hybrid::{HybridKnnJoin, HybridParams, HybridReport};
use crate::index::{GridIndex, KdTree};
use crate::runtime::Engine;
use crate::split;

/// Default EXACT-ANN ranks for hybrid runs (paper: 15 + 1 GPU master,
/// scaled to this host).
pub const HYBRID_RANKS: usize = 3;
/// REFIMPL ranks (one extra - the paper frees the GPU-master rank,
/// Sec. VI-C).
pub const REFIMPL_RANKS: usize = 4;

fn base_params(k: usize) -> HybridParams {
    let mut p = HybridParams::new(k);
    p.cpu_ranks = HYBRID_RANKS;
    p
}

// ---------------------------------------------------------------- Fig. 2

/// Fig. 2 (analytic): fraction of D satisfying the KNN query under a fixed
/// result budget |R| = |D|(K+1), when successful points each waste `extra`
/// result slots: x(K+e+1) + (1-x)·1 = K+1 => x = K/(K+e).
pub fn fig2(k: usize) -> Table {
    let mut t = Table::new(
        &format!("Fig 2 - fraction of D with >= K neighbors (K={k}, |R|=|D|(K+1))"),
        &["extra neighbors", "fraction satisfied"],
    );
    for e in [0usize, 1, 2, 5, 10, 20] {
        let x = k as f64 / (k + e) as f64;
        t.row(vec![e.to_string(), format!("{x:.3}")]);
    }
    t
}

// ---------------------------------------------------------------- Fig. 6

/// Fig. 6: REFIMPL scalability vs rank count on the lowest- and highest-
/// dimensional workloads, K=5. Per-rank work is measured serially
/// (single-core testbed), giving the round-robin load-balance speedup;
/// the contention-adjusted column applies the memory-bandwidth model
/// s/(1+c(p-1)) with c=0.025 calibrated to the paper's 12.26x @ 16.
pub fn fig6(workloads: &[Workload], k: usize) -> Table {
    let mut t = Table::new(
        &format!("Fig 6 - REFIMPL speedup vs |p| (K={k})"),
        &["dataset", "p", "work speedup", "contention-adjusted"],
    );
    const C: f64 = 0.025;
    for w in workloads {
        let data = w.dataset();
        let (data, _) = reorder_by_variance(&data);
        let tree = KdTree::build(&data);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        for p in [1usize, 2, 4, 8, 16] {
            let times = cpu::rank_work_times(&data, &tree, &queries, k, p);
            let total: f64 = times.iter().sum();
            let max = times.iter().cloned().fold(0.0, f64::max);
            let s = total / max.max(1e-12);
            let adj = s / (1.0 + C * (p as f64 - 1.0));
            t.row(vec![
                w.name.into(),
                p.to_string(),
                format!("{s:.2}"),
                format!("{adj:.2}"),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------- Fig. 7

/// Fig. 7: GPU-JOINLINEAR kernel time vs ε (normalised to the median) -
/// brute-force work is independent of ε.
pub fn fig7(engine: &Engine, workloads: &[Workload]) -> Result<Table> {
    let mut t = Table::new(
        "Fig 7 - GPU-JOINLINEAR response time vs eps (flat expected)",
        &["dataset", "eps/eps_med", "kernel time (s)", "tiles"],
    );
    for w in workloads {
        let data = w.dataset();
        let sel = EpsilonSelector::default().select(engine, &data, w.table_k, 0.0)?;
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        for mult in [0.5f64, 1.0, 2.0] {
            let eps = sel.eps * mult;
            let out = gpu::brute_join_linear(engine, &data, &queries, eps, None)?;
            t.row(vec![
                w.name.into(),
                format!("{mult:.1}"),
                secs(out.kernel_time),
                out.tiles.to_string(),
            ]);
        }
    }
    Ok(t)
}

// -------------------------------------------------------------- Table III

/// Table III: TSTATIC / TDYNAMIC kernel granularity. The per-query
/// candidate workload comes from the real grid/split (β=γ=ρ=0); the warp
/// model evaluates every ThreadAssign on that one workload. Also reports
/// the measured PJRT response for context.
pub fn table3(engine: &Engine, workloads: &[Workload]) -> Result<Table> {
    let mut t = Table::new(
        "Table III - modeled GPU kernel seconds by thread granularity (beta=gamma=rho=0)",
        &[
            "dataset", "K", "|Q_gpu|",
            "TS 1", "TS 8", "TS 32",
            "TD 1e5", "TD 1e6", "TD 1e7",
            "measured resp (s)",
        ],
    );
    for w in workloads {
        let k = w.table_k;
        let data = w.dataset();
        let (data, _) = reorder_by_variance(&data);
        let sel = EpsilonSelector::default().select(engine, &data, k, 0.0)?;
        let grid = GridIndex::build(&data, 6, sel.eps);
        let sp = split::split_work(&data, &grid, k, 0.0, 0.0, true);
        let work = gpu::join::workload_vector(&grid, &sp.q_gpu);
        let model = DeviceModel::default();
        let assigns = [
            ThreadAssign::Static(1),
            ThreadAssign::Static(8),
            ThreadAssign::Static(32),
            ThreadAssign::Dynamic(100_000),
            ThreadAssign::Dynamic(1_000_000),
            ThreadAssign::Dynamic(10_000_000),
        ];
        let est: Vec<String> = assigns
            .iter()
            .map(|&a| format!("{:.2e}", model.estimate(&work, a).seconds))
            .collect();
        // one measured hybrid run for context
        let rep = HybridKnnJoin::run(engine, &data, &base_params(k))?;
        let mut row = vec![w.name.to_string(), k.to_string(), sp.q_gpu.len().to_string()];
        row.extend(est);
        row.push(secs(rep.response_time));
        t.row(row);
    }
    Ok(t)
}

// ---------------------------------------------------------------- Fig. 8

/// Fig. 8: response time vs β for a range of γ (ρ=0).
pub fn fig8(
    engine: &Engine,
    workloads: &[Workload],
    betas: &[f64],
    gammas: &[f64],
) -> Result<Table> {
    let mut t = Table::new(
        "Fig 8 - response time (s) vs beta for a range of gamma (rho=0)",
        &["dataset", "K", "beta", "gamma", "time (s)", "|Q_gpu|", "|Q_fail|"],
    );
    for w in workloads {
        for &gamma in gammas {
            for &beta in betas {
                let mut p = base_params(w.table_k);
                p.beta = beta;
                p.gamma = gamma;
                let rep = HybridKnnJoin::run(engine, &w.dataset(), &p)?;
                t.row(vec![
                    w.name.into(),
                    w.table_k.to_string(),
                    format!("{beta:.2}"),
                    format!("{gamma:.2}"),
                    secs(rep.response_time),
                    rep.q_gpu.to_string(),
                    rep.q_fail.to_string(),
                ]);
            }
        }
    }
    Ok(t)
}

// ---------------------------------------------------------------- Fig. 9

/// Fig. 9: response time vs β for a range of ρ (γ=0.6).
pub fn fig9(
    engine: &Engine,
    workloads: &[Workload],
    betas: &[f64],
    rhos: &[f64],
) -> Result<Table> {
    let mut t = Table::new(
        "Fig 9 - response time (s) vs beta for a range of rho (gamma=0.6)",
        &["dataset", "K", "beta", "rho", "time (s)", "|Q_cpu|", "|Q_fail|"],
    );
    for w in workloads {
        for &rho in rhos {
            for &beta in betas {
                let mut p = base_params(w.table_k);
                p.beta = beta;
                p.gamma = 0.6;
                p.rho = rho;
                let rep = HybridKnnJoin::run(engine, &w.dataset(), &p)?;
                t.row(vec![
                    w.name.into(),
                    w.table_k.to_string(),
                    format!("{beta:.2}"),
                    format!("{rho:.2}"),
                    secs(rep.response_time),
                    rep.q_cpu.to_string(),
                    rep.q_fail.to_string(),
                ]);
            }
        }
    }
    Ok(t)
}

// -------------------------------------------------------------- Table IV

/// One Table IV cell run; also used by Tables V/VI.
pub fn run_cell(
    engine: &Engine,
    w: &Workload,
    beta: f64,
    gamma: f64,
    rho: f64,
    fraction: f64,
) -> Result<HybridReport> {
    let mut p = base_params(w.table_k);
    p.beta = beta;
    p.gamma = gamma;
    p.rho = rho;
    p.query_fraction = fraction;
    HybridKnnJoin::run(engine, &w.dataset(), &p)
}

/// Table IV: the β x γ grid at ρ=0.5.
pub fn table4(engine: &Engine, workloads: &[Workload]) -> Result<Table> {
    let mut t = Table::new(
        "Table IV - response time (s), beta x gamma grid, rho=0.5",
        &["beta", "gamma", "SuSy*", "CHist*", "Songs*", "FMA*"],
    );
    for (beta, gamma) in [(0.0, 0.0), (0.0, 0.8), (1.0, 0.0), (1.0, 0.8)] {
        let mut row = vec![format!("{beta:.1}"), format!("{gamma:.1}")];
        for w in workloads {
            let rep = run_cell(engine, w, beta, gamma, 0.5, 1.0)?;
            row.push(secs(rep.response_time));
        }
        t.row(row);
    }
    Ok(t)
}

/// Pick the best (β,γ) for a workload by running the Table IV grid
/// (optionally on a query fraction).
pub fn best_params(
    engine: &Engine,
    w: &Workload,
    fraction: f64,
) -> Result<(f64, f64, HybridReport)> {
    let mut best: Option<(f64, f64, HybridReport)> = None;
    for (beta, gamma) in [(0.0, 0.0), (0.0, 0.8), (1.0, 0.0), (1.0, 0.8)] {
        let rep = run_cell(engine, w, beta, gamma, 0.5, fraction)?;
        if best
            .as_ref()
            .map(|(_, _, b)| rep.response_time < b.response_time)
            .unwrap_or(true)
        {
            best = Some((beta, gamma, rep));
        }
    }
    Ok(best.unwrap())
}

// -------------------------------------------------------------- Table V

/// Table V: derive ρ^Model from the ρ=0.5 run's T1/T2, re-run, report the
/// speedup of model-balanced ρ over the arbitrary ρ=0.5.
pub fn table5(engine: &Engine, workloads: &[Workload]) -> Result<Table> {
    let mut t = Table::new(
        "Table V - rho^Model load balancing",
        &[
            "dataset", "K", "beta", "gamma", "t(rho=0.5)",
            "T1 (s/q)", "T2 (s/q)", "rho_model", "t(rho_model)", "speedup",
        ],
    );
    for w in workloads {
        let (beta, gamma, rep05) = best_params(engine, w, 1.0)?;
        let rho_m = rep05.rho_model;
        let rep_m = run_cell(engine, w, beta, gamma, rho_m, 1.0)?;
        t.row(vec![
            w.name.into(),
            w.table_k.to_string(),
            format!("{beta:.1}"),
            format!("{gamma:.1}"),
            secs(rep05.response_time),
            format!("{:.3e}", rep05.t1),
            format!("{:.3e}", rep05.t2),
            format!("{rho_m:.3}"),
            secs(rep_m.response_time),
            format!("{:.2}", rep05.response_time / rep_m.response_time.max(1e-12)),
        ]);
    }
    Ok(t)
}

// -------------------------------------------------------------- Table VI

/// Table VI: recover the best (β,γ) from a fraction f of the queries.
pub fn table6(engine: &Engine, workloads: &[Workload], fractions: &[f64]) -> Result<Table> {
    let mut t = Table::new(
        "Table VI - parameter recovery from a query fraction f (rho=0.5)",
        &["dataset", "K", "f", "beta", "gamma", "time (s)", "best?"],
    );
    for (w, &f) in workloads.iter().zip(fractions) {
        // full-run best for comparison
        let (fb, fg, _) = best_params(engine, w, 1.0)?;
        let mut cells = Vec::new();
        for (beta, gamma) in [(0.0, 0.0), (0.0, 0.8), (1.0, 0.0), (1.0, 0.8)] {
            let rep = run_cell(engine, w, beta, gamma, 0.5, f)?;
            cells.push((beta, gamma, rep.response_time));
        }
        let best = cells
            .iter()
            .cloned()
            .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .unwrap();
        for (beta, gamma, time) in cells {
            let is_best = beta == best.0 && gamma == best.1;
            let recovered = is_best && beta == fb && gamma == fg;
            t.row(vec![
                w.name.into(),
                w.table_k.to_string(),
                format!("{f:.2}"),
                format!("{beta:.1}"),
                format!("{gamma:.1}"),
                secs(time),
                if recovered {
                    "best=full-run best".into()
                } else if is_best {
                    "best (differs from full)".into()
                } else {
                    String::new()
                },
            ]);
        }
    }
    Ok(t)
}

// --------------------------------------------------------------- Fig. 10

/// Fig. 10: ρ^Model vs K per dataset (sampled runs at ρ=0.5).
pub fn fig10(
    engine: &Engine,
    workloads: &[Workload],
    ks: &[usize],
    fraction: f64,
) -> Result<Table> {
    let mut t = Table::new(
        "Fig 10 - rho_model vs K",
        &["dataset", "K", "rho_model", "T1 (s/q)", "T2 (s/q)"],
    );
    for w in workloads {
        for &k in ks {
            let mut p = base_params(k);
            p.rho = 0.5;
            p.query_fraction = fraction;
            let rep = HybridKnnJoin::run(engine, &w.dataset(), &p)?;
            t.row(vec![
                w.name.into(),
                k.to_string(),
                format!("{:.3}", rep.rho_model),
                format!("{:.3e}", rep.t1),
                format!("{:.3e}", rep.t2),
            ]);
        }
    }
    Ok(t)
}

// --------------------------------------------------------------- Fig. 11

/// Fig. 11: response time vs K - HYBRIDKNN-JOIN vs REFIMPL vs
/// GPU-JOINLINEAR. ρ comes from a sampled ρ^Model estimate per K
/// (the paper's derivation from Fig. 10).
pub fn fig11(engine: &Engine, workloads: &[Workload], ks: &[usize]) -> Result<Table> {
    let mut t = Table::new(
        "Fig 11 - response time (s) vs K: hybrid vs REFIMPL vs GPU-JOINLINEAR",
        &[
            "dataset", "K", "rho", "hybrid (s)", "refimpl (s)",
            "linear kernel (s)", "speedup vs refimpl",
        ],
    );
    for w in workloads {
        let data = w.dataset();
        let (rdata, _) = reorder_by_variance(&data);
        let tree = KdTree::build(&rdata);
        // brute-force lower bound once per dataset (independent of eps/K)
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let sel = EpsilonSelector::default().select(engine, &rdata, w.table_k, 0.0)?;
        let brute = gpu::brute_join_linear(engine, &rdata, &queries, sel.eps, None)?;
        for &k in ks {
            // sampled rho^model estimate
            let mut ps = base_params(k);
            ps.rho = 0.5;
            ps.query_fraction = 0.2;
            let probe = HybridKnnJoin::run(engine, &data, &ps)?;
            let rho = probe.rho_model;
            // full hybrid run at the derived rho
            let mut p = base_params(k);
            p.rho = rho;
            let rep = HybridKnnJoin::run(engine, &data, &p)?;
            // REFIMPL with one extra rank
            let r = cpu::ref_impl(&rdata, &tree, k, REFIMPL_RANKS);
            t.row(vec![
                w.name.into(),
                k.to_string(),
                format!("{rho:.2}"),
                secs(rep.response_time),
                secs(r.total_time),
                secs(brute.kernel_time),
                format!("{:.2}", r.total_time / rep.response_time.max(1e-12)),
            ]);
        }
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads_quick;

    #[test]
    fn fig2_matches_closed_form() {
        let t = fig2(5);
        assert_eq!(t.rows.len(), 6);
        assert_eq!(t.rows[0][1], "1.000"); // e=0
        assert_eq!(t.rows[5][1], "0.200"); // e=20 -> 20%
        let e1: f64 = t.rows[1][1].parse().unwrap();
        assert!((e1 - 5.0 / 6.0).abs() < 1e-3, "e=1 -> ~83%");
    }

    #[test]
    fn fig6_speedup_monotone() {
        let ws = workloads_quick();
        let t = fig6(&ws[..1], 5);
        let speedups: Vec<f64> = t.rows.iter().map(|r| r[2].parse().unwrap()).collect();
        assert_eq!(speedups.len(), 5);
        assert!((speedups[0] - 1.0).abs() < 1e-6);
        for w in speedups.windows(2) {
            assert!(w[1] >= w[0] * 0.95, "speedup should not collapse: {speedups:?}");
        }
    }

    #[test]
    fn smoke_device_tables() {
        // fig7 + table3 on the smallest quick workloads (engine required)
        let engine = Engine::load_default().unwrap();
        let ws = workloads_quick();
        let t7 = fig7(&engine, &ws[1..2]).unwrap();
        assert_eq!(t7.rows.len(), 3);
        // flat in eps: identical tile counts
        let tiles: Vec<&String> = t7.rows.iter().map(|r| &r[3]).collect();
        assert!(tiles.iter().all(|x| *x == tiles[0]));
        let t3 = table3(&engine, &ws[..1]).unwrap();
        assert_eq!(t3.rows.len(), 1);
    }
}
