//! Shared bench harness: the workload suite (surrogate datasets at bench
//! scale), an aligned table printer, and one runner per paper table /
//! figure (experiments::*). The `benches/` binaries and the CLI
//! `experiments` subcommand are thin wrappers over this module.

/// One runner per paper table / figure.
pub mod experiments;

use crate::core::Dataset;
use crate::data::synthetic::{self, DatasetSpec};

/// Bench-scale workload suite. Sizes are scaled from the paper's datasets
/// (DESIGN.md §2) so the full suite runs in minutes on one core; the
/// HKNN_SCALE env var scales them globally (e.g. HKNN_SCALE=5 for a
/// longer, more faithful run).
#[derive(Debug, Clone)]
pub struct Workload {
    /// display name (paper dataset it surrogates, starred)
    pub name: &'static str,
    /// generator recipe (dims, clusters, size)
    pub spec: DatasetSpec,
    /// the paper's per-dataset K for Tables III/IV/V/VI
    pub table_k: usize,
}

/// Global scale factor (default 1.0).
pub fn scale() -> f64 {
    std::env::var("HKNN_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

fn scaled(n: usize) -> usize {
    ((n as f64 * scale()) as usize).max(64)
}

/// The four surrogate workloads (paper Table I).
pub fn workloads() -> Vec<Workload> {
    vec![
        Workload { name: "SuSy*", spec: synthetic::susy_like(scaled(20_000)), table_k: 1 },
        Workload { name: "CHist*", spec: synthetic::chist_like(scaled(8_000)), table_k: 10 },
        Workload { name: "Songs*", spec: synthetic::songs_like(scaled(5_000)), table_k: 1 },
        Workload { name: "FMA*", spec: synthetic::fma_like(scaled(2_500)), table_k: 10 },
    ]
}

/// A smaller suite for smoke tests and quick iterations.
pub fn workloads_quick() -> Vec<Workload> {
    vec![
        Workload { name: "SuSy*", spec: synthetic::susy_like(2_000), table_k: 1 },
        Workload { name: "CHist*", spec: synthetic::chist_like(1_000), table_k: 10 },
        Workload { name: "Songs*", spec: synthetic::songs_like(800), table_k: 1 },
        Workload { name: "FMA*", spec: synthetic::fma_like(400), table_k: 10 },
    ]
}

impl Workload {
    /// Generate the workload's dataset (deterministic per spec).
    pub fn dataset(&self) -> Dataset {
        self.spec.generate(0xDA7A ^ self.spec.dims as u64)
    }
}

/// Aligned text table accumulating rows; printed by the bench binaries and
/// pasted into EXPERIMENTS.md.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// table heading (printed as a `##` line)
    pub title: String,
    /// column names
    pub header: Vec<String>,
    /// data rows; each must match the header arity
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New empty table with the given title and column names.
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
    }

    /// Render the table as aligned monospace text.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("## {}\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format seconds with sensible precision.
pub fn secs(t: f64) -> String {
    if t >= 100.0 {
        format!("{t:.1}")
    } else if t >= 1.0 {
        format!("{t:.2}")
    } else {
        format!("{t:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_suite_shapes() {
        let ws = workloads_quick();
        assert_eq!(ws.len(), 4);
        let d = ws[0].dataset();
        assert_eq!(d.dims(), 18);
        assert_eq!(d.len(), 2000);
    }

    #[test]
    fn table_render_aligns() {
        let mut t = Table::new("T", &["a", "bbb"]);
        t.row(vec!["xx".into(), "1".into()]);
        let r = t.render();
        assert!(r.contains("## T"));
        assert!(r.contains("a   bbb"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(secs(123.456), "123.5");
        assert_eq!(secs(1.234), "1.23");
        assert_eq!(secs(0.01234), "0.0123");
    }
}
