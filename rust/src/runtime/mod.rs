//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the L3 hot path.
//!
//! One `Engine` per process: a PJRT CPU client (the stand-in "device"),
//! the artifact manifest, and a cache of compiled executables keyed by
//! artifact name. Artifacts are compiled lazily on first use and reused
//! for the life of the process - python never runs at request time.

/// Tile plans: which artifact family fits a workload's dims/shape.
pub mod tiles;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;
use crate::util::pool::lock_unpoisoned;

/// Sentinel coordinate for padded rows (mirrors kernels/dist_tile.py).
/// Padded-vs-real pair distances are ~1e30, failing every eps test.
pub const PAD_SENTINEL: f32 = 1.0e15;

/// Artifact descriptor from manifest.json.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    /// artifact name (cache key, e.g. `dist_q32_c256_d24`)
    pub name: String,
    /// HLO text file relative to the artifacts dir
    pub file: String,
    /// artifact family (`dist`, `disttopk`, `hist`, ...)
    pub kind: String,
    /// static params (qt/ct/d/k/s/bins as present)
    pub params: HashMap<String, usize>,
}

impl ArtifactInfo {
    /// A required static param; panics when the manifest lacks it.
    pub fn param(&self, key: &str) -> usize {
        *self
            .params
            .get(key)
            .unwrap_or_else(|| panic!("artifact {} missing param {key}", self.name))
    }
}

/// The PJRT engine: client + manifest + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    artifacts: HashMap<String, ArtifactInfo>,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    /// executions performed (telemetry for benches/EXPERIMENTS)
    exec_count: std::sync::atomic::AtomicU64,
}

impl Engine {
    /// Load the manifest from `dir` (e.g. "artifacts/") and connect the
    /// PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {manifest_path:?} - run `make artifacts`"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        if json.get("format").and_then(|f| f.as_str()) != Some("hlo-text") {
            bail!("unexpected manifest format");
        }
        let mut artifacts = HashMap::new();
        for a in json
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .context("manifest missing artifacts")?
        {
            let name = a
                .get("name")
                .and_then(|x| x.as_str())
                .context("artifact missing name")?
                .to_string();
            let file = a
                .get("file")
                .and_then(|x| x.as_str())
                .context("artifact missing file")?
                .to_string();
            let kind = a
                .get("kind")
                .and_then(|x| x.as_str())
                .context("artifact missing kind")?
                .to_string();
            let mut params = HashMap::new();
            if let Some(Json::Obj(m)) = a.get("params") {
                for (k, v) in m {
                    if let Some(n) = v.as_usize() {
                        params.insert(k.clone(), n);
                    }
                }
            }
            artifacts.insert(name.clone(), ArtifactInfo { name, file, kind, params });
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Engine {
            client,
            dir: dir.to_path_buf(),
            artifacts,
            cache: Mutex::new(HashMap::new()),
            exec_count: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Default artifacts directory: $HKNN_ARTIFACTS or ./artifacts.
    pub fn load_default() -> Result<Engine> {
        let dir = std::env::var("HKNN_ARTIFACTS").unwrap_or_else(|_| {
            // walk up from cwd to find artifacts/manifest.json (tests run
            // from the workspace root already; examples may not)
            for base in [".", "..", "../.."] {
                let p = Path::new(base).join("artifacts").join("manifest.json");
                if p.exists() {
                    return Path::new(base)
                        .join("artifacts")
                        .to_string_lossy()
                        .into_owned();
                }
            }
            "artifacts".to_string()
        });
        Engine::load(Path::new(&dir))
    }

    /// Manifest entry for `name`, if present.
    pub fn artifact(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.get(name)
    }

    /// All artifact names in the manifest (unordered).
    pub fn artifact_names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }

    /// Device executions performed so far (telemetry).
    pub fn executions(&self) -> u64 {
        self.exec_count.load(std::sync::atomic::Ordering::Relaxed)
    }

    fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        // lock_unpoisoned: the cache outlives any one join (a resident
        // engine serves many flushes), so a worker that panicked near a
        // cache access must not poison compilation for every later
        // session - the executables are Arc-shared and always whole.
        if let Some(e) = lock_unpoisoned(&self.cache).get(name) {
            return Ok(e.clone());
        }
        let info = self
            .artifacts
            .get(name)
            .with_context(|| format!("unknown artifact {name}"))?;
        let path = self.dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let exe = Arc::new(exe);
        lock_unpoisoned(&self.cache).insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Build an input literal (host->device upload analogue). Callers on
    /// the hot path pre-build candidate literals once per cell and reuse
    /// them across query tiles (EXPERIMENTS.md Perf#2).
    pub fn literal(data: &[f32], shape: &[i64]) -> Result<xla::Literal> {
        xla::Literal::vec1(data)
            .reshape(shape)
            .map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))
    }

    /// Execute an artifact with f32 input buffers of the given shapes.
    /// Returns the flat f32 contents of each tuple element (i32 outputs
    /// are converted; see `exec_raw` for typed access).
    pub fn exec(
        &self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<xla::Literal>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| Self::literal(data, shape))
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        self.exec_lits(name, &refs)
    }

    /// Execute with pre-built literals (no input copies on this path).
    pub fn exec_lits(
        &self,
        name: &str,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<&xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        self.exec_count
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True: root is always a tuple
        root.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
    }

    /// f32 vector from a literal.
    pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
    }

    /// i32 vector from a literal.
    pub fn to_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
        lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Engine {
        Engine::load_default().expect("artifacts built? run `make artifacts`")
    }

    #[test]
    fn manifest_loads_and_lists_families() {
        let e = engine();
        let names = e.artifact_names();
        assert!(names.iter().any(|n| n.starts_with("dist_q128")));
        assert!(names.iter().any(|n| n.starts_with("disttopk_")));
        assert!(names.iter().any(|n| n.starts_with("hist_")));
        let a = e.artifact("dist_q32_c256_d24").unwrap();
        assert_eq!(a.param("qt"), 32);
        assert_eq!(a.param("ct"), 256);
        assert_eq!(a.param("d"), 24);
    }

    #[test]
    fn dist_artifact_executes_and_matches_host() {
        let e = engine();
        let (qt, ct, d) = (32usize, 256usize, 24usize);
        let mut rng = crate::util::rng::Rng::new(42);
        let q: Vec<f32> = (0..qt * d).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let c: Vec<f32> = (0..ct * d).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let out = e
            .exec(
                "dist_q32_c256_d24",
                &[(&q, &[qt as i64, d as i64]), (&c, &[ct as i64, d as i64])],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let d2 = Engine::to_f32(&out[0]).unwrap();
        assert_eq!(d2.len(), qt * ct);
        // spot-check against host distance
        for &(i, j) in &[(0usize, 0usize), (3, 100), (31, 255)] {
            let host = crate::core::sqdist(&q[i * d..(i + 1) * d], &c[j * d..(j + 1) * d]);
            let dev = d2[i * ct + j] as f64;
            assert!(
                (host - dev).abs() < 1e-3 * (1.0 + host),
                "({i},{j}): host={host} dev={dev}"
            );
        }
    }

    #[test]
    fn topk_artifact_sorted_and_consistent() {
        let e = engine();
        let (qt, ct, d, k) = (128usize, 512usize, 24usize, 64usize);
        let mut rng = crate::util::rng::Rng::new(43);
        let q: Vec<f32> = (0..qt * d).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let c: Vec<f32> = (0..ct * d).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let out = e
            .exec(
                "disttopk_q128_c512_d24_k64",
                &[(&q, &[qt as i64, d as i64]), (&c, &[ct as i64, d as i64])],
            )
            .unwrap();
        assert_eq!(out.len(), 2);
        let vals = Engine::to_f32(&out[0]).unwrap();
        let idx = Engine::to_i32(&out[1]).unwrap();
        assert_eq!(vals.len(), qt * k);
        assert_eq!(idx.len(), qt * k);
        for q_i in [0usize, 64, 127] {
            let row = &vals[q_i * k..(q_i + 1) * k];
            for w in row.windows(2) {
                assert!(w[0] <= w[1] + 1e-4, "row not ascending");
            }
            for (slot, &ci) in idx[q_i * k..(q_i + 1) * k].iter().enumerate() {
                assert!((ci as usize) < ct);
                let host = crate::core::sqdist(
                    &q[q_i * d..(q_i + 1) * d],
                    &c[ci as usize * d..(ci as usize + 1) * d],
                );
                let dev = row[slot] as f64;
                assert!((host - dev).abs() < 1e-3 * (1.0 + host));
            }
        }
    }

    #[test]
    fn hist_artifact_counts_cumulative() {
        let e = engine();
        let (s, ct, d, bins) = (64usize, 512usize, 24usize, 64usize);
        let mut rng = crate::util::rng::Rng::new(44);
        let q: Vec<f32> = (0..s * d).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let c: Vec<f32> = (0..ct * d).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let edges2: Vec<f32> = (1..=bins).map(|b| b as f32 * 2.0).collect();
        let out = e
            .exec(
                "hist_s64_c512_d24_b64",
                &[
                    (&q, &[s as i64, d as i64]),
                    (&c, &[ct as i64, d as i64]),
                    (&edges2, &[bins as i64]),
                ],
            )
            .unwrap();
        let counts = Engine::to_f32(&out[0]).unwrap();
        assert_eq!(counts.len(), bins);
        for w in counts.windows(2) {
            assert!(w[0] <= w[1], "cumulative counts must be monotone");
        }
        let npairs = Engine::to_f32(&out[2]).unwrap()[0];
        assert_eq!(npairs, (s * ct) as f32);
        assert!(counts[bins - 1] <= npairs);
    }

    #[test]
    fn executable_cache_reuses() {
        let e = engine();
        let (qt, ct, d) = (32usize, 256usize, 24usize);
        let q = vec![0.5f32; qt * d];
        let c = vec![0.25f32; ct * d];
        let args: [(&[f32], &[i64]); 2] =
            [(&q, &[qt as i64, d as i64]), (&c, &[ct as i64, d as i64])];
        e.exec("dist_q32_c256_d24", &args).unwrap();
        let n0 = e.executions();
        e.exec("dist_q32_c256_d24", &args).unwrap();
        assert_eq!(e.executions(), n0 + 1);
        assert_eq!(e.cache.lock().unwrap().len(), 1);
    }

    #[test]
    fn unknown_artifact_is_error() {
        let e = engine();
        assert!(e.exec("nope", &[]).is_err());
    }
}
