//! Tile planning + padding for artifact execution.
//!
//! Artifacts are shape-static; real workloads are not. A `TilePlan` picks
//! the artifact family matching the dataset dimensionality (smallest
//! padded dim >= n) and the tile size class, and `pack` copies points into
//! the static tile layout: extra dims are zero (distance-preserving since
//! both sides pad with zeros), unused candidate rows carry PAD_SENTINEL
//! coordinates so their distances fail every filter.

use anyhow::{bail, Result};

use super::{Engine, PAD_SENTINEL};
use crate::core::Dataset;

/// Which artifact tile the caller will drive.
#[derive(Debug, Clone)]
pub struct TilePlan {
    /// query rows per tile
    pub qt: usize,
    /// candidate rows per tile
    pub ct: usize,
    /// padded dimensionality of the artifact
    pub d: usize,
    /// distance-tile artifact name
    pub dist_name: String,
    /// topk variant (same qt/ct/d), when the manifest has one
    pub topk_name: Option<String>,
    /// k of the topk variant (0 when absent)
    pub topk_k: usize,
}

/// Tile size class. Large saturates the "device"; small keeps padding
/// waste low for thin workloads (paper Sec. V-G's granularity trade-off).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileClass {
    /// 128 x 512 tiles - saturate the device
    Large,
    /// 32 x 256 tiles - low padding waste for thin workloads
    Small,
}

/// Choose the tile plan for a dataset dimensionality.
pub fn plan_for(engine: &Engine, dims: usize, class: TileClass) -> Result<TilePlan> {
    let (qt, ct) = match class {
        TileClass::Large => (128usize, 512usize),
        TileClass::Small => (32usize, 256usize),
    };
    // smallest artifact dim >= dims among dist artifacts with this tile
    let mut best: Option<usize> = None;
    for name in engine.artifact_names() {
        if let Some(info) = engine.artifact(name) {
            if info.kind == "dist" && info.param("qt") == qt && info.param("ct") == ct {
                let d = info.param("d");
                if d >= dims && best.map(|b| d < b).unwrap_or(true) {
                    best = Some(d);
                }
            }
        }
    }
    let Some(d) = best else {
        bail!("no dist artifact for dims={dims} tile {qt}x{ct}; rebuild artifacts");
    };
    let dist_name = format!("dist_q{qt}_c{ct}_d{d}");
    let topk_name = engine
        .artifact_names()
        .into_iter()
        .find(|n| n.starts_with(&format!("disttopk_q{qt}_c{ct}_d{d}_k")))
        .map(|s| s.to_string());
    let topk_k = topk_name
        .as_deref()
        .and_then(|n| engine.artifact(n))
        .map(|i| i.param("k"))
        .unwrap_or(0);
    Ok(TilePlan { qt, ct, d, dist_name, topk_name, topk_k })
}

/// Pack point rows (by id) into a `rows x d_pad` tile. Ids beyond
/// `ids.len()` are filled with `fill` in every coordinate.
pub fn pack(
    out: &mut Vec<f32>,
    data: &Dataset,
    ids: &[u32],
    rows: usize,
    d_pad: usize,
    fill: f32,
) {
    debug_assert!(ids.len() <= rows);
    let dims = data.dims().min(d_pad);
    out.clear();
    out.resize(rows * d_pad, 0.0);
    for (r, &id) in ids.iter().enumerate() {
        let src = data.point(id as usize);
        let dst = &mut out[r * d_pad..r * d_pad + dims];
        dst.copy_from_slice(&src[..dims]);
        // dims..d_pad remain zero (distance-preserving)
    }
    if fill != 0.0 {
        for r in ids.len()..rows {
            out[r * d_pad..(r + 1) * d_pad].fill(fill);
        }
    }
}

/// Pack candidate rows with the sentinel fill.
pub fn pack_candidates(
    out: &mut Vec<f32>,
    data: &Dataset,
    ids: &[u32],
    rows: usize,
    d_pad: usize,
) {
    pack(out, data, ids, rows, d_pad, PAD_SENTINEL);
}

/// Pack the contiguous candidate id range `start..start+len` into a
/// `rows x d_pad` tile with sentinel fill, without materialising an id
/// list. The brute tier's packer: its candidate chunks are always
/// contiguous corpus ranges, so the `Vec<u32>` id buffer of
/// [`pack_candidates`] would be pure overhead.
pub fn pack_candidate_range(
    out: &mut Vec<f32>,
    data: &Dataset,
    start: u32,
    len: usize,
    rows: usize,
    d_pad: usize,
) {
    debug_assert!(len <= rows);
    debug_assert!(start as usize + len <= data.len());
    let dims = data.dims().min(d_pad);
    out.clear();
    out.resize(rows * d_pad, 0.0);
    for r in 0..len {
        let src = data.point(start as usize + r);
        out[r * d_pad..r * d_pad + dims].copy_from_slice(&src[..dims]);
        // dims..d_pad remain zero (distance-preserving)
    }
    for r in len..rows {
        out[r * d_pad..(r + 1) * d_pad].fill(PAD_SENTINEL);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::susy_like;

    fn engine() -> Engine {
        Engine::load_default().unwrap()
    }

    #[test]
    fn plan_picks_smallest_covering_dim() {
        let e = engine();
        assert_eq!(plan_for(&e, 18, TileClass::Large).unwrap().d, 24);
        assert_eq!(plan_for(&e, 24, TileClass::Large).unwrap().d, 24);
        assert_eq!(plan_for(&e, 25, TileClass::Small).unwrap().d, 32);
        assert_eq!(plan_for(&e, 90, TileClass::Large).unwrap().d, 96);
        assert_eq!(plan_for(&e, 518, TileClass::Large).unwrap().d, 520);
        assert!(plan_for(&e, 521, TileClass::Large).is_err());
    }

    #[test]
    fn plan_finds_topk_for_large_tiles() {
        let e = engine();
        let p = plan_for(&e, 18, TileClass::Large).unwrap();
        assert!(p.topk_name.is_some());
        assert_eq!(p.topk_k, 64);
        // small tiles have no topk variant in the default manifest
        let ps = plan_for(&e, 18, TileClass::Small).unwrap();
        assert!(ps.topk_name.is_none());
    }

    #[test]
    fn pack_pads_dims_and_rows() {
        let d = susy_like(10).generate(1);
        let mut buf = Vec::new();
        pack_candidates(&mut buf, &d, &[0, 5, 9], 5, 24);
        assert_eq!(buf.len(), 5 * 24);
        // real row: first 18 coords match, rest zero
        assert_eq!(&buf[0..18], d.point(0));
        assert!(buf[18..24].iter().all(|&x| x == 0.0));
        // padded rows are sentinel
        assert!(buf[3 * 24..5 * 24].iter().all(|&x| x == PAD_SENTINEL));
    }

    #[test]
    fn pack_range_matches_pack_with_explicit_ids() {
        let d = susy_like(64).generate(3);
        let (mut by_ids, mut by_range) = (Vec::new(), Vec::new());
        for (start, len, rows) in [(0u32, 8usize, 8usize), (17, 5, 12), (60, 4, 16)] {
            let ids: Vec<u32> = (start..start + len as u32).collect();
            pack_candidates(&mut by_ids, &d, &ids, rows, 24);
            pack_candidate_range(&mut by_range, &d, start, len, rows, 24);
            assert_eq!(by_ids, by_range, "range packer diverged at start={start}");
        }
    }

    #[test]
    fn padded_tile_distance_via_engine_matches_host() {
        // end-to-end: pack an 18-D dataset into the d=24 artifact; device
        // distances must equal host distances on real rows.
        let e = engine();
        let data = susy_like(40).generate(2);
        let plan = plan_for(&e, data.dims(), TileClass::Small).unwrap();
        let qids: Vec<u32> = (0..10).collect();
        let cids: Vec<u32> = (0..40).collect();
        let mut q = Vec::new();
        let mut c = Vec::new();
        pack(&mut q, &data, &qids, plan.qt, plan.d, 0.0);
        pack_candidates(&mut c, &data, &cids, plan.ct, plan.d);
        let out = e
            .exec(
                &plan.dist_name,
                &[
                    (&q, &[plan.qt as i64, plan.d as i64]),
                    (&c, &[plan.ct as i64, plan.d as i64]),
                ],
            )
            .unwrap();
        let d2 = Engine::to_f32(&out[0]).unwrap();
        for qi in 0..10usize {
            for ci in 0..40usize {
                let host = crate::core::sqdist(data.point(qi), data.point(ci));
                let dev = d2[qi * plan.ct + ci] as f64;
                assert!(
                    (host - dev).abs() < 1e-2 + 1e-3 * host,
                    "({qi},{ci}) host={host} dev={dev}"
                );
            }
            // padded candidates are huge
            assert!(d2[qi * plan.ct + 40] > 1e20);
        }
    }
}
