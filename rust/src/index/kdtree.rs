//! Exact-KNN kd-tree: the EXACT-ANN substrate (paper Sec. V-B).
//!
//! The paper uses Mount & Arya's ANN library in exact mode; this is a
//! from-scratch equivalent: sliding-midpoint splits (ANN's default bucket
//! kd-tree construction) and a branch-and-bound descent with a bounded
//! max-heap, pruning subtrees whose bounding box is farther than the
//! current K-th best distance. Exact for any K.
//!
//! The query hot path is `knn_into`: an *iterative* traversal over a
//! reusable [`KnnScratch`] (bounded heap + explicit far-subtree stack)
//! that performs zero heap allocations in steady state. Construction is
//! allocation-lean: one bounding-box scan at the root, with shrunk cell
//! boxes passed down (and restored) in place of per-node rescans -
//! sliding-midpoint over cell boxes, exactly as ANN does it.
//!
//! **Churn (DESIGN.md §12):** the tree is mutable through a buffered
//! delta set in the Bigger Buffer k-d Trees style (arxiv 1512.02831):
//! [`KdTree::insert`] appends to a side buffer that `knn_into`
//! brute-scans after the tree descent, [`KdTree::remove`] tombstones a
//! tree id (or evicts a not-yet-merged buffered insert), and
//! [`KdTree::maybe_merge`] folds everything back into a fresh tree once
//! the deferred set crosses the merge threshold. Queries are exact - and
//! *bit-identical* to a from-scratch rebuild over the live set - at
//! every point in between: the bounded heap keeps the canonical k
//! smallest `(dist², id)` pairs regardless of candidate order, and both
//! distance kernels share one accumulation order (see `core`).

use crate::core::{sqdist, sqdist_short_circuit, BoundedHeap, Dataset, Neighbor};

/// `leaf_rank` sentinel for ids the tree does not index (buffered
/// inserts, ids past the build-time corpus).
const NO_LEAF_RANK: u32 = u32::MAX;

/// Default [`KdTree::maybe_merge`] threshold: deferred mutations
/// (buffered inserts + tombstones) tolerated before the delta is folded
/// into a rebuilt tree. Small enough that the O(buffer) per-query delta
/// scan stays marginal next to a leaf visit, large enough to amortise
/// the O(n log n) rebuild over many mutations.
const DEFAULT_MERGE_LIMIT: usize = 128;

const LEAF_SIZE: usize = 16;

#[derive(Debug)]
enum Node {
    Leaf {
        /// range into `ids`
        start: u32,
        end: u32,
    },
    Split {
        dim: u16,
        value: f32,
        left: u32,  // node index
        right: u32, // node index
    },
}

/// Reusable per-rank search state: the bounded result heap plus the
/// explicit stack of deferred far subtrees. After the first few queries
/// have sized both buffers, `knn_into` allocates nothing.
#[derive(Debug)]
pub struct KnnScratch {
    heap: BoundedHeap,
    /// (node index, min possible dist² of its box to the query)
    stack: Vec<(u32, f64)>,
}

impl KnnScratch {
    /// New empty scratch (buffers grow to steady size on first use).
    pub fn new() -> Self {
        KnnScratch { heap: BoundedHeap::new(1), stack: Vec::with_capacity(64) }
    }

    /// The result heap of the last `knn_into` call (unsorted).
    pub fn heap_mut(&mut self) -> &mut BoundedHeap {
        &mut self.heap
    }

    /// Drain the last result sorted ascending (allocates the output Vec;
    /// the zero-alloc path drains the heap into a SoA slot instead).
    pub fn take_sorted(&mut self) -> Vec<Neighbor> {
        self.heap.drain_sorted()
    }
}

impl Default for KnnScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Bucket kd-tree over a dataset (borrows nothing; stores point ids).
#[derive(Debug)]
pub struct KdTree {
    nodes: Vec<Node>,
    ids: Vec<u32>,
    root: u32,
    dims: usize,
    /// position of each point id in `ids` - the leaf-major spatial order
    /// used to block self-join queries for cache locality.
    /// `NO_LEAF_RANK` marks ids the tree does not index.
    leaf_rank: Vec<u32>,
    /// buffered delta set: inserted ids not yet merged into the tree,
    /// brute-scanned by every query after the tree descent
    buffer: Vec<u32>,
    /// tombstones for tree ids removed since the last merge, indexed by
    /// id (same extent as `leaf_rank`); `scan_leaf` skips them
    dead: Vec<bool>,
    /// live tombstone count (keeps `deferred` O(1))
    dead_count: usize,
    /// `maybe_merge` threshold on `deferred()`
    merge_limit: usize,
}

impl KdTree {
    /// Build over the full dataset.
    pub fn build(d: &Dataset) -> KdTree {
        Self::build_from_ids(d, (0..d.len() as u32).collect())
    }

    /// Build over a subset of point ids (duplicate-free, each < `d.len()`,
    /// any order). The churn substrate: merges and the rebuild-reference
    /// engines index the *live* id set of a corpus whose dead rows stay in
    /// place, so ids - and therefore result lanes - never shift.
    pub fn build_from_ids(d: &Dataset, mut ids: Vec<u32>) -> KdTree {
        let mut nodes = Vec::new();
        let dims = d.dims();
        let root = if ids.is_empty() {
            nodes.push(Node::Leaf { start: 0, end: 0 });
            0
        } else {
            // the only full bounding-box scan; children receive shrunk
            // copies of this cell box via in-place mutation + restore
            let mut mins = vec![f32::INFINITY; dims];
            let mut maxs = vec![f32::NEG_INFINITY; dims];
            for &i in &ids {
                let p = d.point(i as usize);
                for j in 0..dims {
                    if p[j] < mins[j] {
                        mins[j] = p[j];
                    }
                    if p[j] > maxs[j] {
                        maxs[j] = p[j];
                    }
                }
            }
            Self::build_rec(d, &mut nodes, &mut ids, 0, &mut mins, &mut maxs)
        };
        let mut leaf_rank = vec![NO_LEAF_RANK; d.len()];
        for (pos, &id) in ids.iter().enumerate() {
            leaf_rank[id as usize] = pos as u32;
        }
        let dead = vec![false; d.len()];
        KdTree {
            nodes,
            ids,
            root,
            dims,
            leaf_rank,
            buffer: Vec::new(),
            dead,
            dead_count: 0,
            merge_limit: DEFAULT_MERGE_LIMIT,
        }
    }

    /// Count of ids with coordinate satisfying `pred`, partitioned to the
    /// front of `ids`.
    fn partition_ids<F: Fn(f32) -> bool>(
        d: &Dataset,
        ids: &mut [u32],
        dim: usize,
        pred: F,
    ) -> usize {
        let mut lt = 0usize;
        for i in 0..ids.len() {
            if pred(d.coord(ids[i] as usize, dim)) {
                ids.swap(lt, i);
                lt += 1;
            }
        }
        lt
    }

    fn build_rec(
        d: &Dataset,
        nodes: &mut Vec<Node>,
        ids: &mut [u32],
        offset: usize,
        mins: &mut [f32],
        maxs: &mut [f32],
    ) -> u32 {
        let len = ids.len();
        if len <= LEAF_SIZE {
            nodes.push(Node::Leaf {
                start: offset as u32,
                end: (offset + len) as u32,
            });
            return (nodes.len() - 1) as u32;
        }

        // sliding midpoint over the cell box: split the widest cell side
        // at its midpoint, sliding to the nearest point coordinate if one
        // side would come up empty (ANN's default rule).
        let dim = (0..d.dims())
            .max_by(|&a, &b| {
                (maxs[a] - mins[a])
                    .partial_cmp(&(maxs[b] - mins[b]))
                    .unwrap()
            })
            .unwrap();
        if !(maxs[dim] - mins[dim] > 0.0) {
            // degenerate cell box (all remaining spread is zero): make a
            // (possibly oversized) leaf to guarantee progress
            nodes.push(Node::Leaf {
                start: offset as u32,
                end: (offset + len) as u32,
            });
            return (nodes.len() - 1) as u32;
        }
        let mut split = 0.5 * (mins[dim] + maxs[dim]);

        let mut lt = Self::partition_ids(d, ids, dim, |x| x < split);
        // Weak separation invariant (what exact search relies on): every
        // left point has coord <= split, every right point coord >= split.
        if lt == 0 {
            // left side empty: slide down to the minimum point coordinate;
            // points equal to it go left
            let mut best = f32::INFINITY;
            for &i in ids.iter() {
                let x = d.coord(i as usize, dim);
                if x < best {
                    best = x;
                }
            }
            split = best;
            lt = Self::partition_ids(d, ids, dim, |x| x <= split);
            if lt == len {
                // every point identical in this dim: any cut keeps weak
                // separation because both sides sit exactly on the plane
                lt = len / 2;
            }
        } else if lt == len {
            // right side empty: slide up to the maximum point coordinate;
            // points equal to it go right
            let mut best = f32::NEG_INFINITY;
            for &i in ids.iter() {
                let x = d.coord(i as usize, dim);
                if x > best {
                    best = x;
                }
            }
            split = best;
            lt = Self::partition_ids(d, ids, dim, |x| x < split);
            if lt == 0 {
                lt = len / 2; // all identical in this dim (see above)
            }
        }
        debug_assert!(lt > 0 && lt < len, "split must make progress");

        let (left_ids, right_ids) = ids.split_at_mut(lt);
        let placeholder = nodes.len();
        nodes.push(Node::Leaf { start: 0, end: 0 }); // reserve slot
        let saved_max = maxs[dim];
        maxs[dim] = split;
        let left = Self::build_rec(d, nodes, left_ids, offset, mins, maxs);
        maxs[dim] = saved_max;
        let saved_min = mins[dim];
        mins[dim] = split;
        let right =
            Self::build_rec(d, nodes, right_ids, offset + lt, mins, maxs);
        mins[dim] = saved_min;
        nodes[placeholder] = Node::Split {
            dim: dim as u16,
            value: split,
            left,
            right,
        };
        placeholder as u32
    }

    /// Exact K nearest neighbors of `query`, excluding `exclude_id`
    /// (pass u32::MAX to keep all). Returns ascending by distance.
    /// Convenience wrapper over `knn_into` (allocates a fresh scratch);
    /// batch callers reuse a [`KnnScratch`] instead.
    pub fn knn(
        &self,
        d: &Dataset,
        query: &[f32],
        k: usize,
        exclude_id: u32,
    ) -> Vec<Neighbor> {
        let mut scratch = KnnScratch::new();
        self.knn_into(d, query, k, exclude_id, &mut scratch);
        scratch.take_sorted()
    }

    /// Exact K nearest neighbors into `scratch.heap_mut()` (unsorted;
    /// drain via `BoundedHeap::drain_sorted_into` or a SoA slot). The
    /// steady-state zero-allocation query path: iterative branch-and-bound
    /// over the reusable explicit stack, identical pruning (and results)
    /// to the recursive formulation.
    pub fn knn_into(
        &self,
        d: &Dataset,
        query: &[f32],
        k: usize,
        exclude_id: u32,
        scratch: &mut KnnScratch,
    ) {
        assert_eq!(query.len(), self.dims);
        scratch.heap.reset(k);
        scratch.stack.clear();
        if !self.ids.is_empty() {
            let mut node = self.root;
            let mut min_d2 = 0.0f64;
            loop {
                // a deferred subtree may have been beaten by a bound that
                // tightened after it was pushed
                if min_d2 <= scratch.heap.bound() {
                    match &self.nodes[node as usize] {
                        Node::Leaf { start, end } => {
                            self.scan_leaf(
                                d, *start, *end, query, exclude_id,
                                &mut scratch.heap,
                            );
                        }
                        Node::Split { dim, value, left, right } => {
                            let diff = (query[*dim as usize] - value) as f64;
                            let (near, far) = if diff < 0.0 {
                                (*left, *right)
                            } else {
                                (*right, *left)
                            };
                            // crossing the split plane costs at least diff^2
                            let cross = min_d2.max(diff * diff);
                            if cross <= scratch.heap.bound() {
                                scratch.stack.push((far, cross));
                            }
                            node = near;
                            continue; // descend the near side first
                        }
                    }
                }
                match scratch.stack.pop() {
                    Some((n, d2)) => {
                        node = n;
                        min_d2 = d2;
                    }
                    None => break,
                }
            }
        }
        // Delta pass (Bigger Buffer k-d Trees): brute-scan the buffered
        // inserts with the exact same offer logic the leaves use. The
        // heap's canonical (dist², id) tie rule makes the outcome
        // independent of whether a point is met here or inside a leaf -
        // the delta tree and a rebuilt tree return identical bits.
        for &i in &self.buffer {
            if i != exclude_id {
                Self::offer(d, i, query, &mut scratch.heap);
            }
        }
    }

    /// Offer candidate `i` to `heap`: SHORTC (paper Sec. IV-E) once the
    /// heap is full, the full kernel while it is filling. The two kernels
    /// share one accumulation order (see `core::sqdist`), and the `<=`
    /// gate admits bound ties so the heap's id tie-break - not arrival
    /// order - decides them.
    #[inline]
    fn offer(d: &Dataset, i: u32, q: &[f32], heap: &mut BoundedHeap) {
        let bound = heap.bound();
        if bound.is_finite() {
            if let Some(dd) = sqdist_short_circuit(q, d.point(i as usize), bound)
            {
                if dd <= bound {
                    heap.push(Neighbor { id: i, dist2: dd });
                }
            }
        } else {
            let dd = sqdist(q, d.point(i as usize));
            heap.push(Neighbor { id: i, dist2: dd });
        }
    }

    #[inline]
    fn scan_leaf(
        &self,
        d: &Dataset,
        start: u32,
        end: u32,
        q: &[f32],
        exclude: u32,
        heap: &mut BoundedHeap,
    ) {
        for &i in &self.ids[start as usize..end as usize] {
            if i == exclude || self.dead[i as usize] {
                continue;
            }
            Self::offer(d, i, q, heap);
        }
    }

    // ---- churn: the buffered delta set (DESIGN.md §12) ----

    /// Is `id` indexed by the tree proper (merged; possibly tombstoned)?
    #[inline]
    fn in_tree(&self, id: u32) -> bool {
        self.leaf_rank
            .get(id as usize)
            .is_some_and(|&r| r != NO_LEAF_RANK)
    }

    /// Stage point `id` of `d` for queries: resurrects a tombstoned tree
    /// id in place, otherwise appends to the delta buffer (scanned by
    /// every query until [`Self::maybe_merge`] folds it in). `id` must
    /// not currently be live.
    pub fn insert(&mut self, d: &Dataset, id: u32) {
        debug_assert!((id as usize) < d.len(), "insert of id past the corpus");
        if self.in_tree(id) {
            debug_assert!(self.dead[id as usize], "insert of a live tree id");
            if self.dead[id as usize] {
                self.dead[id as usize] = false;
                self.dead_count -= 1;
            }
            return;
        }
        debug_assert!(
            !self.buffer.contains(&id),
            "insert of an already-buffered id"
        );
        self.buffer.push(id);
    }

    /// Unindex point `id`: evicts a not-yet-merged buffered insert
    /// outright, or tombstones a tree id (skipped by `scan_leaf` until
    /// the next merge drops it). Returns false when `id` was not live.
    pub fn remove(&mut self, id: u32) -> bool {
        if let Some(pos) = self.buffer.iter().position(|&b| b == id) {
            self.buffer.swap_remove(pos);
            return true;
        }
        if self.in_tree(id) && !self.dead[id as usize] {
            self.dead[id as usize] = true;
            self.dead_count += 1;
            return true;
        }
        false
    }

    /// Deferred mutations: buffered inserts + tombstones. The per-query
    /// overhead the delta scheme carries until the next merge.
    #[inline]
    pub fn deferred(&self) -> usize {
        self.buffer.len() + self.dead_count
    }

    /// Override the `maybe_merge` threshold (default 128 deferred
    /// mutations). Queries stay exact for any value - the knob trades
    /// per-query delta-scan cost against rebuild amortisation only.
    pub fn set_merge_limit(&mut self, limit: usize) {
        self.merge_limit = limit.max(1);
    }

    /// The live id set (tree minus tombstones, plus the buffer), sorted
    /// ascending. What a from-scratch rebuild would index.
    pub fn live_ids(&self) -> Vec<u32> {
        let mut live: Vec<u32> = self
            .ids
            .iter()
            .copied()
            .filter(|&i| !self.dead[i as usize])
            .chain(self.buffer.iter().copied())
            .collect();
        live.sort_unstable();
        live
    }

    /// Fold the delta set into a fresh tree over the live ids. A no-op
    /// for queries (bit-identical before and after); only the cost
    /// profile changes.
    pub fn merge(&mut self, d: &Dataset) {
        let limit = self.merge_limit;
        *self = Self::build_from_ids(d, self.live_ids());
        self.merge_limit = limit;
    }

    /// Merge when the deferred set exceeds the threshold (the Bigger
    /// Buffer amortisation rule). Returns true when a merge ran.
    pub fn maybe_merge(&mut self, d: &Dataset) -> bool {
        if self.deferred() > self.merge_limit {
            self.merge(d);
            true
        } else {
            false
        }
    }

    /// A from-scratch tree over this tree's live set (empty delta) - the
    /// rebuild half of the churn equivalence harness.
    pub fn rebuilt(&self, d: &Dataset) -> KdTree {
        let mut t = Self::build_from_ids(d, self.live_ids());
        t.merge_limit = self.merge_limit;
        t
    }

    /// Position of point `id` in the tree's leaf-major id order. Sorting a
    /// self-join query list by this key visits queries leaf block by leaf
    /// block, so consecutive queries traverse near-identical node paths
    /// and touch the same candidate cache lines. Ids the tree does not
    /// index (buffered inserts, ids past the build-time corpus) sort
    /// last with `u32::MAX`.
    #[inline]
    pub fn leaf_order_key(&self, id: u32) -> u32 {
        self.leaf_rank
            .get(id as usize)
            .copied()
            .unwrap_or(NO_LEAF_RANK)
    }

    /// Number of live indexed points (tree minus tombstones, plus the
    /// delta buffer).
    pub fn len(&self) -> usize {
        self.ids.len() - self.dead_count + self.buffer.len()
    }

    /// True when the tree indexes no live points.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::KnnResult;
    use crate::data::synthetic::{chist_like, susy_like};
    use crate::util::{prop, rng::Rng};

    fn brute_knn(d: &Dataset, q: &[f32], k: usize, exclude: u32) -> Vec<Neighbor> {
        let mut all: Vec<Neighbor> = (0..d.len() as u32)
            .filter(|&i| i != exclude)
            .map(|i| Neighbor { id: i, dist2: sqdist(q, d.point(i as usize)) })
            .collect();
        all.sort();
        all.truncate(k);
        all
    }

    fn random_dataset(rng: &mut Rng, n: usize, dims: usize) -> Dataset {
        let data: Vec<f32> = (0..n * dims)
            .map(|_| rng.normal(0.0, 2.0) as f32)
            .collect();
        Dataset::new(data, dims)
    }

    #[test]
    fn knn_matches_bruteforce_property() {
        prop::cases(40, 0x7D73, |rng| {
            let n = 30 + rng.below(300);
            let dims = 1 + rng.below(8);
            let d = random_dataset(rng, n, dims);
            let t = KdTree::build(&d);
            let k = 1 + rng.below(10);
            let q = rng.below(d.len());
            let got = t.knn(&d, d.point(q), k, q as u32);
            let want = brute_knn(&d, d.point(q), k, q as u32);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                // ids may differ under distance ties; distances must match
                assert!(
                    (g.dist2 - w.dist2).abs() < 1e-9 * (1.0 + w.dist2),
                    "got {g:?} want {w:?}"
                );
            }
        });
    }

    /// The tentpole invariant: the zero-allocation path (`knn_into` over a
    /// reused scratch, drained into a SoA `KnnResult`) is result-identical
    /// to brute force across self-join vs bipartite, k > n, duplicate
    /// datasets, sort order, and self-exclusion.
    #[test]
    fn knn_into_soa_matches_bruteforce_property() {
        prop::cases(30, 0x50A0, |rng| {
            let dims = 1 + rng.below(10);
            let n = 1 + rng.below(200);
            let duplicates = rng.below(4) == 0;
            let d = if duplicates {
                // few distinct locations, heavy duplication
                let spots = random_dataset(rng, 1 + rng.below(4), dims);
                let rows: Vec<Vec<f32>> = (0..n)
                    .map(|_| spots.point(rng.below(spots.len())).to_vec())
                    .collect();
                Dataset::from_rows(&rows)
            } else {
                random_dataset(rng, n, dims)
            };
            let bipartite = rng.below(2) == 1;
            let r_data = if bipartite {
                random_dataset(rng, 1 + rng.below(50), dims)
            } else {
                d.clone()
            };
            let k = 1 + rng.below(2 * n.min(12)); // sometimes k > n
            let t = KdTree::build(&d);

            let mut res = KnnResult::new(r_data.len(), k);
            let mut scratch = KnnScratch::new();
            for q in 0..r_data.len() {
                let excl = if bipartite { u32::MAX } else { q as u32 };
                t.knn_into(&d, r_data.point(q), k, excl, &mut scratch);
                res.write_heap(q, scratch.heap_mut());
            }

            for q in 0..r_data.len() {
                let excl = if bipartite { u32::MAX } else { q as u32 };
                let want = brute_knn(&d, r_data.point(q), k, excl);
                let got = res.get(q);
                assert_eq!(got.len(), want.len(), "count (k > n included)");
                let mut prev = f64::NEG_INFINITY;
                for (g, w) in got.iter().zip(&want) {
                    assert!(
                        (g.dist2 - w.dist2).abs() < 1e-9 * (1.0 + w.dist2),
                        "q={q}: {g:?} vs {w:?}"
                    );
                    assert!(g.dist2 >= prev, "ascending order");
                    prev = g.dist2;
                    if !bipartite {
                        assert_ne!(g.id, q as u32, "self-exclusion");
                    }
                }
            }
        });
    }

    #[test]
    fn knn_into_scratch_reuse_is_stateless() {
        // interleave queries of wildly different k: the scratch must not
        // leak state between calls
        let mut rng = Rng::new(77);
        let d = random_dataset(&mut rng, 120, 4);
        let t = KdTree::build(&d);
        let mut scratch = KnnScratch::new();
        for (q, k) in [(3usize, 9usize), (11, 1), (3, 9), (40, 120), (11, 1)] {
            t.knn_into(&d, d.point(q), k, q as u32, &mut scratch);
            let got = scratch.take_sorted();
            let want = brute_knn(&d, d.point(q), k, q as u32);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert!((g.dist2 - w.dist2).abs() < 1e-9 * (1.0 + w.dist2));
            }
        }
    }

    #[test]
    fn knn_exact_on_clustered_data() {
        let d = susy_like(800).generate(5);
        let t = KdTree::build(&d);
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let q = rng.below(d.len());
            let got = t.knn(&d, d.point(q), 5, q as u32);
            let want = brute_knn(&d, d.point(q), 5, q as u32);
            for (g, w) in got.iter().zip(&want) {
                assert!((g.dist2 - w.dist2).abs() < 1e-9 * (1.0 + w.dist2));
            }
        }
    }

    #[test]
    fn high_dim_still_exact() {
        // 32-D clustered: kd-tree prunes poorly but must stay exact
        let d = chist_like(400).generate(6);
        let t = KdTree::build(&d);
        let got = t.knn(&d, d.point(7), 10, 7);
        let want = brute_knn(&d, d.point(7), 10, 7);
        for (g, w) in got.iter().zip(&want) {
            assert!((g.dist2 - w.dist2).abs() < 1e-7 * (1.0 + w.dist2));
        }
    }

    #[test]
    fn duplicate_points_handled() {
        // all-identical dataset: tree must terminate and return k results
        let d = Dataset::new(vec![1.0f32; 3 * 100], 3);
        let t = KdTree::build(&d);
        let got = t.knn(&d, d.point(0), 5, 0);
        assert_eq!(got.len(), 5);
        assert!(got.iter().all(|n| n.dist2 == 0.0));
    }

    #[test]
    fn duplicate_heavy_axis_slides() {
        // one dim spread, the rest constant, with duplicate clumps: forces
        // the sliding branches of the cell-box build
        let mut rows = Vec::new();
        for i in 0..60 {
            let x = if i < 40 { 0.0f32 } else { 10.0 };
            rows.push(vec![x, 5.0, 5.0]);
        }
        let d = Dataset::from_rows(&rows);
        let t = KdTree::build(&d);
        let got = t.knn(&d, d.point(0), 45, 0);
        let want = brute_knn(&d, d.point(0), 45, 0);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.dist2, w.dist2);
        }
    }

    #[test]
    fn k_larger_than_dataset() {
        let mut rng = Rng::new(2);
        let d = random_dataset(&mut rng, 8, 3);
        let t = KdTree::build(&d);
        let got = t.knn(&d, d.point(0), 20, 0);
        assert_eq!(got.len(), 7, "everything except the excluded point");
    }

    #[test]
    fn exclude_self_semantics() {
        let mut rng = Rng::new(3);
        let d = random_dataset(&mut rng, 50, 4);
        let t = KdTree::build(&d);
        let got = t.knn(&d, d.point(9), 5, 9);
        assert!(got.iter().all(|n| n.id != 9));
        let with_self = t.knn(&d, d.point(9), 5, u32::MAX);
        assert_eq!(with_self[0].id, 9);
        assert_eq!(with_self[0].dist2, 0.0);
    }

    #[test]
    fn empty_tree() {
        let d = Dataset::new(Vec::new(), 4);
        let t = KdTree::build(&d);
        assert!(t.knn(&d, &[0.0; 4], 3, u32::MAX).is_empty());
    }

    #[test]
    fn delta_insert_remove_stays_exact() {
        // random interleaving of inserts/removes/queries vs a brute-force
        // oracle over the live set; includes the merge path
        prop::cases(20, 0xD317, |rng| {
            let dims = 1 + rng.below(6);
            let n = 40 + rng.below(120);
            let d = random_dataset(rng, n, dims);
            let n0 = n / 2;
            let mut t = KdTree::build_from_ids(&d, (0..n0 as u32).collect());
            t.set_merge_limit(1 + rng.below(20));
            let mut live: Vec<u32> = (0..n0 as u32).collect();
            for _ in 0..30 {
                match rng.below(3) {
                    0 => {
                        // insert a random not-live id
                        let dead: Vec<u32> = (0..n as u32)
                            .filter(|i| !live.contains(i))
                            .collect();
                        if let Some(&id) = dead.get(rng.below(dead.len().max(1)))
                        {
                            t.insert(&d, id);
                            live.push(id);
                            t.maybe_merge(&d);
                        }
                    }
                    1 => {
                        if !live.is_empty() {
                            let pos = rng.below(live.len());
                            let id = live.swap_remove(pos);
                            assert!(t.remove(id));
                            t.maybe_merge(&d);
                        }
                    }
                    _ => {
                        let k = 1 + rng.below(8);
                        let q = rng.below(n);
                        let got = t.knn(&d, d.point(q), k, u32::MAX);
                        let mut want: Vec<Neighbor> = live
                            .iter()
                            .map(|&i| Neighbor {
                                id: i,
                                dist2: sqdist(d.point(q), d.point(i as usize)),
                            })
                            .collect();
                        want.sort();
                        want.truncate(k);
                        assert_eq!(got, want, "delta tree vs live oracle");
                    }
                }
                assert_eq!(t.len(), live.len());
            }
        });
    }

    #[test]
    fn leaf_order_key_is_a_permutation() {
        let mut rng = Rng::new(4);
        let d = random_dataset(&mut rng, 500, 5);
        let t = KdTree::build(&d);
        let mut seen = vec![false; d.len()];
        for id in 0..d.len() as u32 {
            let r = t.leaf_order_key(id) as usize;
            assert!(!seen[r], "rank {r} assigned twice");
            seen[r] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
