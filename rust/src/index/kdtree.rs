//! Exact-KNN kd-tree: the EXACT-ANN substrate (paper Sec. V-B).
//!
//! The paper uses Mount & Arya's ANN library in exact mode; this is a
//! from-scratch equivalent: sliding-midpoint splits (ANN's default bucket
//! kd-tree construction) and a branch-and-bound descent with a bounded
//! max-heap, pruning subtrees whose bounding box is farther than the
//! current K-th best distance. Exact for any K.

use crate::core::{sqdist, sqdist_short_circuit, BoundedHeap, Dataset, Neighbor};

const LEAF_SIZE: usize = 16;

#[derive(Debug)]
enum Node {
    Leaf {
        /// range into `ids`
        start: u32,
        end: u32,
    },
    Split {
        dim: u16,
        value: f32,
        left: u32,  // node index
        right: u32, // node index
    },
}

/// Bucket kd-tree over a dataset (borrows nothing; stores point ids).
#[derive(Debug)]
pub struct KdTree {
    nodes: Vec<Node>,
    ids: Vec<u32>,
    root: u32,
    dims: usize,
}

impl KdTree {
    /// Build over the full dataset.
    pub fn build(d: &Dataset) -> KdTree {
        let mut ids: Vec<u32> = (0..d.len() as u32).collect();
        let mut nodes = Vec::new();
        let dims = d.dims();
        let root = if ids.is_empty() {
            nodes.push(Node::Leaf { start: 0, end: 0 });
            0
        } else {
            let n = ids.len();
            Self::build_rec(d, &mut nodes, &mut ids, 0, n)
        };
        KdTree { nodes, ids, root, dims }
    }

    fn build_rec(
        d: &Dataset,
        nodes: &mut Vec<Node>,
        ids: &mut [u32],
        offset: usize,
        _len_hint: usize,
    ) -> u32 {
        let len = ids.len();
        if len <= LEAF_SIZE {
            nodes.push(Node::Leaf {
                start: offset as u32,
                end: (offset + len) as u32,
            });
            return (nodes.len() - 1) as u32;
        }

        // sliding-midpoint: split the widest dimension at the box midpoint,
        // sliding to the nearest point if one side would be empty.
        let mut mins = vec![f32::INFINITY; d.dims()];
        let mut maxs = vec![f32::NEG_INFINITY; d.dims()];
        for &i in ids.iter() {
            let p = d.point(i as usize);
            for j in 0..d.dims() {
                if p[j] < mins[j] {
                    mins[j] = p[j];
                }
                if p[j] > maxs[j] {
                    maxs[j] = p[j];
                }
            }
        }
        let dim = (0..d.dims())
            .max_by(|&a, &b| {
                (maxs[a] - mins[a])
                    .partial_cmp(&(maxs[b] - mins[b]))
                    .unwrap()
            })
            .unwrap();
        if maxs[dim] <= mins[dim] {
            // all points identical in every dim: make a (possibly oversized)
            // leaf to guarantee progress
            nodes.push(Node::Leaf {
                start: offset as u32,
                end: (offset + len) as u32,
            });
            return (nodes.len() - 1) as u32;
        }
        let mut split = 0.5 * (mins[dim] + maxs[dim]);

        // partition around `split`
        let mut lt = 0usize;
        for i in 0..len {
            if d.coord(ids[i] as usize, dim) < split {
                ids.swap(lt, i);
                lt += 1;
            }
        }
        // slide if empty side
        if lt == 0 {
            // slide split up to the minimum coordinate > split
            let mut best = f32::INFINITY;
            for &i in ids.iter() {
                let x = d.coord(i as usize, dim);
                if x < best {
                    best = x;
                }
            }
            split = best + (maxs[dim] - mins[dim]) * 1e-6 + f32::EPSILON;
            lt = 0;
            for i in 0..len {
                if d.coord(ids[i] as usize, dim) < split {
                    ids.swap(lt, i);
                    lt += 1;
                }
            }
            if lt == 0 {
                lt = 1; // degenerate duplicates; force progress
            }
        } else if lt == len {
            let mut best = f32::NEG_INFINITY;
            for &i in ids.iter() {
                let x = d.coord(i as usize, dim);
                if x > best {
                    best = x;
                }
            }
            split = best;
            lt = 0;
            for i in 0..len {
                if d.coord(ids[i] as usize, dim) < split {
                    ids.swap(lt, i);
                    lt += 1;
                }
            }
            if lt == len {
                lt = len - 1;
            }
        }

        let (left_ids, right_ids) = ids.split_at_mut(lt);
        let placeholder = nodes.len();
        nodes.push(Node::Leaf { start: 0, end: 0 }); // reserve slot
        let left = Self::build_rec(d, nodes, left_ids, offset, lt);
        let right = Self::build_rec(d, nodes, right_ids, offset + lt, len - lt);
        nodes[placeholder] = Node::Split {
            dim: dim as u16,
            value: split,
            left,
            right,
        };
        placeholder as u32
    }

    /// Exact K nearest neighbors of `query`, excluding `exclude_id`
    /// (pass u32::MAX to keep all). Returns ascending by distance.
    pub fn knn(
        &self,
        d: &Dataset,
        query: &[f32],
        k: usize,
        exclude_id: u32,
    ) -> Vec<Neighbor> {
        assert_eq!(query.len(), self.dims);
        if self.ids.is_empty() {
            return Vec::new();
        }
        let mut heap = BoundedHeap::new(k);
        self.search(d, self.root, query, exclude_id, &mut heap, 0.0);
        heap.into_sorted()
    }

    fn search(
        &self,
        d: &Dataset,
        node: u32,
        q: &[f32],
        exclude: u32,
        heap: &mut BoundedHeap,
        min_dist2: f64,
    ) {
        if min_dist2 > heap.bound() {
            return;
        }
        match &self.nodes[node as usize] {
            Node::Leaf { start, end } => {
                for &i in &self.ids[*start as usize..*end as usize] {
                    if i == exclude {
                        continue;
                    }
                    // SHORTC (paper Sec. IV-E) applied to the CPU side:
                    // abandon the accumulation once it exceeds the current
                    // k-th best - the dominant win in high dimensions.
                    let bound = heap.bound();
                    if bound.is_finite() {
                        if let Some(dd) =
                            sqdist_short_circuit(q, d.point(i as usize), bound)
                        {
                            if dd < bound {
                                heap.push(Neighbor { id: i, dist2: dd });
                            }
                        }
                    } else {
                        let dd = sqdist(q, d.point(i as usize));
                        heap.push(Neighbor { id: i, dist2: dd });
                    }
                }
            }
            Node::Split { dim, value, left, right } => {
                let diff = (q[*dim as usize] - value) as f64;
                let (near, far) = if diff < 0.0 {
                    (*left, *right)
                } else {
                    (*right, *left)
                };
                self.search(d, near, q, exclude, heap, min_dist2);
                // crossing the split plane costs at least diff^2 more
                let cross = min_dist2.max(diff * diff);
                if cross <= heap.bound() {
                    self.search(d, far, q, exclude, heap, cross);
                }
            }
        }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{chist_like, susy_like};
    use crate::util::{prop, rng::Rng};

    fn brute_knn(d: &Dataset, q: &[f32], k: usize, exclude: u32) -> Vec<Neighbor> {
        let mut all: Vec<Neighbor> = (0..d.len() as u32)
            .filter(|&i| i != exclude)
            .map(|i| Neighbor { id: i, dist2: sqdist(q, d.point(i as usize)) })
            .collect();
        all.sort();
        all.truncate(k);
        all
    }

    fn random_dataset(rng: &mut Rng, n: usize, dims: usize) -> Dataset {
        let data: Vec<f32> = (0..n * dims)
            .map(|_| rng.normal(0.0, 2.0) as f32)
            .collect();
        Dataset::new(data, dims)
    }

    #[test]
    fn knn_matches_bruteforce_property() {
        prop::cases(40, 0x7D73, |rng| {
            let n = 30 + rng.below(300);
            let dims = 1 + rng.below(8);
            let d = random_dataset(rng, n, dims);
            let t = KdTree::build(&d);
            let k = 1 + rng.below(10);
            let q = rng.below(d.len());
            let got = t.knn(&d, d.point(q), k, q as u32);
            let want = brute_knn(&d, d.point(q), k, q as u32);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                // ids may differ under distance ties; distances must match
                assert!(
                    (g.dist2 - w.dist2).abs() < 1e-9 * (1.0 + w.dist2),
                    "got {g:?} want {w:?}"
                );
            }
        });
    }

    #[test]
    fn knn_exact_on_clustered_data() {
        let d = susy_like(800).generate(5);
        let t = KdTree::build(&d);
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let q = rng.below(d.len());
            let got = t.knn(&d, d.point(q), 5, q as u32);
            let want = brute_knn(&d, d.point(q), 5, q as u32);
            for (g, w) in got.iter().zip(&want) {
                assert!((g.dist2 - w.dist2).abs() < 1e-9 * (1.0 + w.dist2));
            }
        }
    }

    #[test]
    fn high_dim_still_exact() {
        // 32-D clustered: kd-tree prunes poorly but must stay exact
        let d = chist_like(400).generate(6);
        let t = KdTree::build(&d);
        let got = t.knn(&d, d.point(7), 10, 7);
        let want = brute_knn(&d, d.point(7), 10, 7);
        for (g, w) in got.iter().zip(&want) {
            assert!((g.dist2 - w.dist2).abs() < 1e-7 * (1.0 + w.dist2));
        }
    }

    #[test]
    fn duplicate_points_handled() {
        // all-identical dataset: tree must terminate and return k results
        let d = Dataset::new(vec![1.0f32; 3 * 100], 3);
        let t = KdTree::build(&d);
        let got = t.knn(&d, d.point(0), 5, 0);
        assert_eq!(got.len(), 5);
        assert!(got.iter().all(|n| n.dist2 == 0.0));
    }

    #[test]
    fn k_larger_than_dataset() {
        let mut rng = Rng::new(2);
        let d = random_dataset(&mut rng, 8, 3);
        let t = KdTree::build(&d);
        let got = t.knn(&d, d.point(0), 20, 0);
        assert_eq!(got.len(), 7, "everything except the excluded point");
    }

    #[test]
    fn exclude_self_semantics() {
        let mut rng = Rng::new(3);
        let d = random_dataset(&mut rng, 50, 4);
        let t = KdTree::build(&d);
        let got = t.knn(&d, d.point(9), 5, 9);
        assert!(got.iter().all(|n| n.id != 9));
        let with_self = t.knn(&d, d.point(9), 5, u32::MAX);
        assert_eq!(with_self[0].id, 9);
        assert_eq!(with_self[0].dist2, 0.0);
    }

    #[test]
    fn empty_tree() {
        let d = Dataset::new(Vec::new(), 4);
        let t = KdTree::build(&d);
        assert!(t.knn(&d, &[0.0; 4], 3, u32::MAX).is_empty());
    }
}
