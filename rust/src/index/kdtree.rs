//! Exact-KNN kd-tree: the EXACT-ANN substrate (paper Sec. V-B).
//!
//! The paper uses Mount & Arya's ANN library in exact mode; this is a
//! from-scratch equivalent: sliding-midpoint splits (ANN's default bucket
//! kd-tree construction) and a branch-and-bound descent with a bounded
//! max-heap, pruning subtrees whose bounding box is farther than the
//! current K-th best distance. Exact for any K.
//!
//! The query hot path is `knn_into`: an *iterative* traversal over a
//! reusable [`KnnScratch`] (bounded heap + explicit far-subtree stack)
//! that performs zero heap allocations in steady state. Construction is
//! allocation-lean: one bounding-box scan at the root, with shrunk cell
//! boxes passed down (and restored) in place of per-node rescans -
//! sliding-midpoint over cell boxes, exactly as ANN does it.

use crate::core::{sqdist, sqdist_short_circuit, BoundedHeap, Dataset, Neighbor};

const LEAF_SIZE: usize = 16;

#[derive(Debug)]
enum Node {
    Leaf {
        /// range into `ids`
        start: u32,
        end: u32,
    },
    Split {
        dim: u16,
        value: f32,
        left: u32,  // node index
        right: u32, // node index
    },
}

/// Reusable per-rank search state: the bounded result heap plus the
/// explicit stack of deferred far subtrees. After the first few queries
/// have sized both buffers, `knn_into` allocates nothing.
#[derive(Debug)]
pub struct KnnScratch {
    heap: BoundedHeap,
    /// (node index, min possible dist² of its box to the query)
    stack: Vec<(u32, f64)>,
}

impl KnnScratch {
    /// New empty scratch (buffers grow to steady size on first use).
    pub fn new() -> Self {
        KnnScratch { heap: BoundedHeap::new(1), stack: Vec::with_capacity(64) }
    }

    /// The result heap of the last `knn_into` call (unsorted).
    pub fn heap_mut(&mut self) -> &mut BoundedHeap {
        &mut self.heap
    }

    /// Drain the last result sorted ascending (allocates the output Vec;
    /// the zero-alloc path drains the heap into a SoA slot instead).
    pub fn take_sorted(&mut self) -> Vec<Neighbor> {
        self.heap.drain_sorted()
    }
}

impl Default for KnnScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Bucket kd-tree over a dataset (borrows nothing; stores point ids).
#[derive(Debug)]
pub struct KdTree {
    nodes: Vec<Node>,
    ids: Vec<u32>,
    root: u32,
    dims: usize,
    /// position of each point id in `ids` - the leaf-major spatial order
    /// used to block self-join queries for cache locality.
    leaf_rank: Vec<u32>,
}

impl KdTree {
    /// Build over the full dataset.
    pub fn build(d: &Dataset) -> KdTree {
        let mut ids: Vec<u32> = (0..d.len() as u32).collect();
        let mut nodes = Vec::new();
        let dims = d.dims();
        let root = if ids.is_empty() {
            nodes.push(Node::Leaf { start: 0, end: 0 });
            0
        } else {
            // the only full bounding-box scan; children receive shrunk
            // copies of this cell box via in-place mutation + restore
            let mut mins = vec![f32::INFINITY; dims];
            let mut maxs = vec![f32::NEG_INFINITY; dims];
            for i in 0..d.len() {
                let p = d.point(i);
                for j in 0..dims {
                    if p[j] < mins[j] {
                        mins[j] = p[j];
                    }
                    if p[j] > maxs[j] {
                        maxs[j] = p[j];
                    }
                }
            }
            Self::build_rec(d, &mut nodes, &mut ids, 0, &mut mins, &mut maxs)
        };
        let mut leaf_rank = vec![0u32; d.len()];
        for (pos, &id) in ids.iter().enumerate() {
            leaf_rank[id as usize] = pos as u32;
        }
        KdTree { nodes, ids, root, dims, leaf_rank }
    }

    /// Count of ids with coordinate satisfying `pred`, partitioned to the
    /// front of `ids`.
    fn partition_ids<F: Fn(f32) -> bool>(
        d: &Dataset,
        ids: &mut [u32],
        dim: usize,
        pred: F,
    ) -> usize {
        let mut lt = 0usize;
        for i in 0..ids.len() {
            if pred(d.coord(ids[i] as usize, dim)) {
                ids.swap(lt, i);
                lt += 1;
            }
        }
        lt
    }

    fn build_rec(
        d: &Dataset,
        nodes: &mut Vec<Node>,
        ids: &mut [u32],
        offset: usize,
        mins: &mut [f32],
        maxs: &mut [f32],
    ) -> u32 {
        let len = ids.len();
        if len <= LEAF_SIZE {
            nodes.push(Node::Leaf {
                start: offset as u32,
                end: (offset + len) as u32,
            });
            return (nodes.len() - 1) as u32;
        }

        // sliding midpoint over the cell box: split the widest cell side
        // at its midpoint, sliding to the nearest point coordinate if one
        // side would come up empty (ANN's default rule).
        let dim = (0..d.dims())
            .max_by(|&a, &b| {
                (maxs[a] - mins[a])
                    .partial_cmp(&(maxs[b] - mins[b]))
                    .unwrap()
            })
            .unwrap();
        if !(maxs[dim] - mins[dim] > 0.0) {
            // degenerate cell box (all remaining spread is zero): make a
            // (possibly oversized) leaf to guarantee progress
            nodes.push(Node::Leaf {
                start: offset as u32,
                end: (offset + len) as u32,
            });
            return (nodes.len() - 1) as u32;
        }
        let mut split = 0.5 * (mins[dim] + maxs[dim]);

        let mut lt = Self::partition_ids(d, ids, dim, |x| x < split);
        // Weak separation invariant (what exact search relies on): every
        // left point has coord <= split, every right point coord >= split.
        if lt == 0 {
            // left side empty: slide down to the minimum point coordinate;
            // points equal to it go left
            let mut best = f32::INFINITY;
            for &i in ids.iter() {
                let x = d.coord(i as usize, dim);
                if x < best {
                    best = x;
                }
            }
            split = best;
            lt = Self::partition_ids(d, ids, dim, |x| x <= split);
            if lt == len {
                // every point identical in this dim: any cut keeps weak
                // separation because both sides sit exactly on the plane
                lt = len / 2;
            }
        } else if lt == len {
            // right side empty: slide up to the maximum point coordinate;
            // points equal to it go right
            let mut best = f32::NEG_INFINITY;
            for &i in ids.iter() {
                let x = d.coord(i as usize, dim);
                if x > best {
                    best = x;
                }
            }
            split = best;
            lt = Self::partition_ids(d, ids, dim, |x| x < split);
            if lt == 0 {
                lt = len / 2; // all identical in this dim (see above)
            }
        }
        debug_assert!(lt > 0 && lt < len, "split must make progress");

        let (left_ids, right_ids) = ids.split_at_mut(lt);
        let placeholder = nodes.len();
        nodes.push(Node::Leaf { start: 0, end: 0 }); // reserve slot
        let saved_max = maxs[dim];
        maxs[dim] = split;
        let left = Self::build_rec(d, nodes, left_ids, offset, mins, maxs);
        maxs[dim] = saved_max;
        let saved_min = mins[dim];
        mins[dim] = split;
        let right =
            Self::build_rec(d, nodes, right_ids, offset + lt, mins, maxs);
        mins[dim] = saved_min;
        nodes[placeholder] = Node::Split {
            dim: dim as u16,
            value: split,
            left,
            right,
        };
        placeholder as u32
    }

    /// Exact K nearest neighbors of `query`, excluding `exclude_id`
    /// (pass u32::MAX to keep all). Returns ascending by distance.
    /// Convenience wrapper over `knn_into` (allocates a fresh scratch);
    /// batch callers reuse a [`KnnScratch`] instead.
    pub fn knn(
        &self,
        d: &Dataset,
        query: &[f32],
        k: usize,
        exclude_id: u32,
    ) -> Vec<Neighbor> {
        let mut scratch = KnnScratch::new();
        self.knn_into(d, query, k, exclude_id, &mut scratch);
        scratch.take_sorted()
    }

    /// Exact K nearest neighbors into `scratch.heap_mut()` (unsorted;
    /// drain via `BoundedHeap::drain_sorted_into` or a SoA slot). The
    /// steady-state zero-allocation query path: iterative branch-and-bound
    /// over the reusable explicit stack, identical pruning (and results)
    /// to the recursive formulation.
    pub fn knn_into(
        &self,
        d: &Dataset,
        query: &[f32],
        k: usize,
        exclude_id: u32,
        scratch: &mut KnnScratch,
    ) {
        assert_eq!(query.len(), self.dims);
        scratch.heap.reset(k);
        scratch.stack.clear();
        if self.ids.is_empty() {
            return;
        }
        let mut node = self.root;
        let mut min_d2 = 0.0f64;
        loop {
            // a deferred subtree may have been beaten by a bound that
            // tightened after it was pushed
            if min_d2 <= scratch.heap.bound() {
                match &self.nodes[node as usize] {
                    Node::Leaf { start, end } => {
                        self.scan_leaf(
                            d, *start, *end, query, exclude_id, &mut scratch.heap,
                        );
                    }
                    Node::Split { dim, value, left, right } => {
                        let diff = (query[*dim as usize] - value) as f64;
                        let (near, far) = if diff < 0.0 {
                            (*left, *right)
                        } else {
                            (*right, *left)
                        };
                        // crossing the split plane costs at least diff^2
                        let cross = min_d2.max(diff * diff);
                        if cross <= scratch.heap.bound() {
                            scratch.stack.push((far, cross));
                        }
                        node = near;
                        continue; // descend the near side first
                    }
                }
            }
            match scratch.stack.pop() {
                Some((n, d2)) => {
                    node = n;
                    min_d2 = d2;
                }
                None => break,
            }
        }
    }

    #[inline]
    fn scan_leaf(
        &self,
        d: &Dataset,
        start: u32,
        end: u32,
        q: &[f32],
        exclude: u32,
        heap: &mut BoundedHeap,
    ) {
        for &i in &self.ids[start as usize..end as usize] {
            if i == exclude {
                continue;
            }
            // SHORTC (paper Sec. IV-E) applied to the CPU side: abandon
            // the accumulation once it exceeds the current k-th best -
            // the dominant win in high dimensions.
            let bound = heap.bound();
            if bound.is_finite() {
                if let Some(dd) =
                    sqdist_short_circuit(q, d.point(i as usize), bound)
                {
                    if dd < bound {
                        heap.push(Neighbor { id: i, dist2: dd });
                    }
                }
            } else {
                let dd = sqdist(q, d.point(i as usize));
                heap.push(Neighbor { id: i, dist2: dd });
            }
        }
    }

    /// Position of point `id` in the tree's leaf-major id order. Sorting a
    /// self-join query list by this key visits queries leaf block by leaf
    /// block, so consecutive queries traverse near-identical node paths
    /// and touch the same candidate cache lines.
    #[inline]
    pub fn leaf_order_key(&self, id: u32) -> u32 {
        self.leaf_rank[id as usize]
    }

    /// Number of indexed points.
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    /// True when the tree indexes no points.
    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::KnnResult;
    use crate::data::synthetic::{chist_like, susy_like};
    use crate::util::{prop, rng::Rng};

    fn brute_knn(d: &Dataset, q: &[f32], k: usize, exclude: u32) -> Vec<Neighbor> {
        let mut all: Vec<Neighbor> = (0..d.len() as u32)
            .filter(|&i| i != exclude)
            .map(|i| Neighbor { id: i, dist2: sqdist(q, d.point(i as usize)) })
            .collect();
        all.sort();
        all.truncate(k);
        all
    }

    fn random_dataset(rng: &mut Rng, n: usize, dims: usize) -> Dataset {
        let data: Vec<f32> = (0..n * dims)
            .map(|_| rng.normal(0.0, 2.0) as f32)
            .collect();
        Dataset::new(data, dims)
    }

    #[test]
    fn knn_matches_bruteforce_property() {
        prop::cases(40, 0x7D73, |rng| {
            let n = 30 + rng.below(300);
            let dims = 1 + rng.below(8);
            let d = random_dataset(rng, n, dims);
            let t = KdTree::build(&d);
            let k = 1 + rng.below(10);
            let q = rng.below(d.len());
            let got = t.knn(&d, d.point(q), k, q as u32);
            let want = brute_knn(&d, d.point(q), k, q as u32);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                // ids may differ under distance ties; distances must match
                assert!(
                    (g.dist2 - w.dist2).abs() < 1e-9 * (1.0 + w.dist2),
                    "got {g:?} want {w:?}"
                );
            }
        });
    }

    /// The tentpole invariant: the zero-allocation path (`knn_into` over a
    /// reused scratch, drained into a SoA `KnnResult`) is result-identical
    /// to brute force across self-join vs bipartite, k > n, duplicate
    /// datasets, sort order, and self-exclusion.
    #[test]
    fn knn_into_soa_matches_bruteforce_property() {
        prop::cases(30, 0x50A0, |rng| {
            let dims = 1 + rng.below(10);
            let n = 1 + rng.below(200);
            let duplicates = rng.below(4) == 0;
            let d = if duplicates {
                // few distinct locations, heavy duplication
                let spots = random_dataset(rng, 1 + rng.below(4), dims);
                let rows: Vec<Vec<f32>> = (0..n)
                    .map(|_| spots.point(rng.below(spots.len())).to_vec())
                    .collect();
                Dataset::from_rows(&rows)
            } else {
                random_dataset(rng, n, dims)
            };
            let bipartite = rng.below(2) == 1;
            let r_data = if bipartite {
                random_dataset(rng, 1 + rng.below(50), dims)
            } else {
                d.clone()
            };
            let k = 1 + rng.below(2 * n.min(12)); // sometimes k > n
            let t = KdTree::build(&d);

            let mut res = KnnResult::new(r_data.len(), k);
            let mut scratch = KnnScratch::new();
            for q in 0..r_data.len() {
                let excl = if bipartite { u32::MAX } else { q as u32 };
                t.knn_into(&d, r_data.point(q), k, excl, &mut scratch);
                res.write_heap(q, scratch.heap_mut());
            }

            for q in 0..r_data.len() {
                let excl = if bipartite { u32::MAX } else { q as u32 };
                let want = brute_knn(&d, r_data.point(q), k, excl);
                let got = res.get(q);
                assert_eq!(got.len(), want.len(), "count (k > n included)");
                let mut prev = f64::NEG_INFINITY;
                for (g, w) in got.iter().zip(&want) {
                    assert!(
                        (g.dist2 - w.dist2).abs() < 1e-9 * (1.0 + w.dist2),
                        "q={q}: {g:?} vs {w:?}"
                    );
                    assert!(g.dist2 >= prev, "ascending order");
                    prev = g.dist2;
                    if !bipartite {
                        assert_ne!(g.id, q as u32, "self-exclusion");
                    }
                }
            }
        });
    }

    #[test]
    fn knn_into_scratch_reuse_is_stateless() {
        // interleave queries of wildly different k: the scratch must not
        // leak state between calls
        let mut rng = Rng::new(77);
        let d = random_dataset(&mut rng, 120, 4);
        let t = KdTree::build(&d);
        let mut scratch = KnnScratch::new();
        for (q, k) in [(3usize, 9usize), (11, 1), (3, 9), (40, 120), (11, 1)] {
            t.knn_into(&d, d.point(q), k, q as u32, &mut scratch);
            let got = scratch.take_sorted();
            let want = brute_knn(&d, d.point(q), k, q as u32);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert!((g.dist2 - w.dist2).abs() < 1e-9 * (1.0 + w.dist2));
            }
        }
    }

    #[test]
    fn knn_exact_on_clustered_data() {
        let d = susy_like(800).generate(5);
        let t = KdTree::build(&d);
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let q = rng.below(d.len());
            let got = t.knn(&d, d.point(q), 5, q as u32);
            let want = brute_knn(&d, d.point(q), 5, q as u32);
            for (g, w) in got.iter().zip(&want) {
                assert!((g.dist2 - w.dist2).abs() < 1e-9 * (1.0 + w.dist2));
            }
        }
    }

    #[test]
    fn high_dim_still_exact() {
        // 32-D clustered: kd-tree prunes poorly but must stay exact
        let d = chist_like(400).generate(6);
        let t = KdTree::build(&d);
        let got = t.knn(&d, d.point(7), 10, 7);
        let want = brute_knn(&d, d.point(7), 10, 7);
        for (g, w) in got.iter().zip(&want) {
            assert!((g.dist2 - w.dist2).abs() < 1e-7 * (1.0 + w.dist2));
        }
    }

    #[test]
    fn duplicate_points_handled() {
        // all-identical dataset: tree must terminate and return k results
        let d = Dataset::new(vec![1.0f32; 3 * 100], 3);
        let t = KdTree::build(&d);
        let got = t.knn(&d, d.point(0), 5, 0);
        assert_eq!(got.len(), 5);
        assert!(got.iter().all(|n| n.dist2 == 0.0));
    }

    #[test]
    fn duplicate_heavy_axis_slides() {
        // one dim spread, the rest constant, with duplicate clumps: forces
        // the sliding branches of the cell-box build
        let mut rows = Vec::new();
        for i in 0..60 {
            let x = if i < 40 { 0.0f32 } else { 10.0 };
            rows.push(vec![x, 5.0, 5.0]);
        }
        let d = Dataset::from_rows(&rows);
        let t = KdTree::build(&d);
        let got = t.knn(&d, d.point(0), 45, 0);
        let want = brute_knn(&d, d.point(0), 45, 0);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.dist2, w.dist2);
        }
    }

    #[test]
    fn k_larger_than_dataset() {
        let mut rng = Rng::new(2);
        let d = random_dataset(&mut rng, 8, 3);
        let t = KdTree::build(&d);
        let got = t.knn(&d, d.point(0), 20, 0);
        assert_eq!(got.len(), 7, "everything except the excluded point");
    }

    #[test]
    fn exclude_self_semantics() {
        let mut rng = Rng::new(3);
        let d = random_dataset(&mut rng, 50, 4);
        let t = KdTree::build(&d);
        let got = t.knn(&d, d.point(9), 5, 9);
        assert!(got.iter().all(|n| n.id != 9));
        let with_self = t.knn(&d, d.point(9), 5, u32::MAX);
        assert_eq!(with_self[0].id, 9);
        assert_eq!(with_self[0].dist2, 0.0);
    }

    #[test]
    fn empty_tree() {
        let d = Dataset::new(Vec::new(), 4);
        let t = KdTree::build(&d);
        assert!(t.knn(&d, &[0.0; 4], 3, u32::MAX).is_empty());
    }

    #[test]
    fn leaf_order_key_is_a_permutation() {
        let mut rng = Rng::new(4);
        let d = random_dataset(&mut rng, 500, 5);
        let t = KdTree::build(&d);
        let mut seen = vec![false; d.len()];
        for id in 0..d.len() as u32 {
            let r = t.leaf_order_key(id) as usize;
            assert!(!seen[r], "rank {r} assigned twice");
            seen[r] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
