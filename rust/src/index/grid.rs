//! The GPU-JOIN grid index (paper Sec. IV-A), rebuilt as a CSR
//! cell-adjacency engine.
//!
//! A grid of cell length ε over the first m ≤ n (REORDERed) dimensions.
//! Only *non-empty* cells are materialised: sorted linearised ids in `B`,
//! per-cell [min,max) ranges in `G` into the point lookup array `A` of
//! point ids - the paper's layout, kept verbatim. On top of B/G/A the
//! build precomputes what every hot path used to re-derive per query:
//!
//! * a **point→cell rank** map (`point_rank`): for each indexed point,
//!   the rank of its cell in `B`. `cell_rank_of` / `cell_id_of_id` /
//!   `cell_population_of_id` are O(1) array reads - no coordinate
//!   recompute, no binary search, no allocation;
//! * a **CSR cell-adjacency table** (`adj_off`/`adj_ranks`): for each
//!   non-empty cell, the ranks of its non-empty 3^m neighbors, computed
//!   exactly once (in parallel via `util::pool`) because every point in a
//!   cell shares the same neighborhood - the precomputation the GPU
//!   self-join literature applies per cell. The adjacent-block walk
//!   (steps (ii)-(vi) of the paper's search procedure) becomes flat slice
//!   iteration: zero binary searches, zero per-query allocation;
//! * a memoized **adjacent population** per cell (`adj_pop`), so the
//!   Sec. V-B per-query work estimate the scheduler prices queues with is
//!   one array read instead of a 3^m walk.
//!
//! Space: B/G/A stay O(|D|) as the paper requires. The CSR table adds
//! O(Σ_c |adjacent(c)|) ≤ O(|B|·3^m) - see DESIGN.md §8 for why this is
//! bounded by one pricing pass's work and small in the join regime.
//! Because that worst case is exponential in m, the build takes a
//! **byte budget** ([`GridIndex::build_with_budget`]): when
//! |B|·3^m·4 bytes would exceed it, the CSR *rows* are not
//! materialised and every adjacency walk recomputes its block on
//! demand (the same walk the empty-cell fallback already uses). The
//! memoized per-cell populations (`adj_pop`) are kept in both modes,
//! so scheduler pricing stays O(1). The mode is a pure function of
//! (|B|, m, budget), so incremental patches and the rebuild oracle
//! always agree on it.
//!
//! Coordinate-keyed lookups (arbitrary points - the bipartite R side)
//! clamp cell coordinates into the grid box per dimension. Clamping is
//! monotone and non-expansive, so true in-ε neighbors (in the indexed
//! projection) still land in the clamped cell's adjacent block: the walk
//! stays a candidate *superset*, and linearised ids stay injective - the
//! former unclamped ids could collide under `wrapping_mul` for points
//! beyond the grid extent. Build-time validation degrades `m` (dropping
//! trailing, lowest-variance dims) when the widths product would overflow
//! `u64`, instead of silently corrupting ids.
//!
//! **Churn:** the index is mutable behind an epoch scheme.
//! [`GridIndex::insert`] / [`GridIndex::remove`] patch B/G/A, the
//! point→rank map, the CSR adjacency table and the memoized populations
//! *in canonical form*: after any patch the arrays are field-by-field
//! identical to a from-scratch [`GridIndex::rebuilt`] over the live ids
//! with the geometry (mins, widths, m, eps) frozen at build time — the
//! invariant the churn harness (rust/tests/churn.rs) asserts at every
//! flush boundary. A mutation in cell c touches only c's own CSR row:
//! the clipped `{-1,0,1}^m` neighborhood is symmetric, so the cells
//! whose adjacent population changes are exactly the cells listed in
//! c's row. Cell birth/death splices B/G and rebuilds the CSR table in
//! one O(E) remap pass. Every mutation bumps `epoch`, which consumers
//! (queue generation stamps, the GPU brute tile cache, R-side rank
//! caches) use to invalidate derived snapshots; a dirty-fraction
//! threshold amortizes splice debt with a full re-sort
//! ([`GridIndex::maybe_rebuild`]) that is observably a no-op.

use std::cell::RefCell;
use std::collections::HashSet;

use crate::core::Dataset;
use crate::util::pool;

/// Per-dimension width cap: keeps a single dimension's cell count (and
/// therefore any in-range coordinate) representable in `i64` arithmetic
/// even before the cross-dimension product check. Clamping a width only
/// merges far-apart coordinates into the boundary cell, which keeps the
/// walk a candidate superset (see module docs).
const MAX_WIDTH: u64 = 1 << 62;

thread_local! {
    /// Scratch (base coords, mixed-radix offsets) for the recompute walk
    /// used when a query point's clamped cell is empty (no CSR row):
    /// reused across calls so the fallback allocates nothing per query.
    static WALK_SCRATCH: RefCell<(Vec<u64>, Vec<i64>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Visit the linearised ids of the in-range `{-1,0,1}^m` block around
/// `base`, in ascending id order (the walk's order contract). `offs` is
/// caller scratch of length `base.len()`; its prior contents are ignored.
fn walk_block(base: &[u64], widths: &[u64], offs: &mut [i64], mut f: impl FnMut(u64)) {
    debug_assert_eq!(base.len(), offs.len());
    debug_assert_eq!(base.len(), widths.len());
    for o in offs.iter_mut() {
        *o = -1;
    }
    'outer: loop {
        let mut id = 0u64;
        let mut ok = true;
        for j in 0..base.len() {
            // base < MAX_WIDTH, so the i64 arithmetic cannot overflow
            let c = base[j] as i64 + offs[j];
            if c < 0 || (c as u64) >= widths[j] {
                ok = false;
                break;
            }
            id = id * widths[j] + c as u64;
        }
        if ok {
            f(id);
        }
        // increment the mixed-radix counter over {-1,0,1}
        for j in (0..offs.len()).rev() {
            if offs[j] < 1 {
                offs[j] += 1;
                continue 'outer;
            }
            offs[j] = -1;
        }
        break;
    }
}

/// Invert the row-major linearisation of an in-range cell id.
fn delinearise(mut id: u64, widths: &[u64], out: &mut [u64]) {
    for j in (0..widths.len()).rev() {
        out[j] = id % widths[j];
        id /= widths[j];
    }
}

/// Sentinel rank for query points whose clamped cell holds no indexed
/// point (possible only for bipartite R queries outside the S extent),
/// and for corpus ids not currently indexed (removed, or never
/// inserted) on the churn path.
const NO_RANK: u32 = u32::MAX;

/// Default dirty-fraction threshold for [`GridIndex::maybe_rebuild`]:
/// re-canonicalize with a full re-sort once mutations since the last
/// (re)build exceed this fraction of the indexed population.
const DEFAULT_REBUILD_FRAC: f64 = 0.25;

/// Default drift threshold for [`GridIndex::maybe_rebuild`]: re-derive
/// the grid geometry (origin + widths) once this fraction of the live
/// points clamp into boundary cells because they fell outside the
/// build-time extent. Clamping keeps the walk a correct superset, but
/// a drifted corpus piles into ever-fatter boundary cells - the
/// refresh restores the paper's ε-cell resolution.
const DEFAULT_DRIFT_FRAC: f64 = 0.2;

/// Default CSR byte budget for [`GridIndex::build`]: the worst-case
/// row storage |B|·3^m·4 bytes must stay under this or the build keeps
/// populations only and walks adjacency on demand.
const DEFAULT_ADJ_BUDGET_BYTES: usize = 1 << 30;

/// Precomputed R-side cell lookups for a bipartite join against an
/// S-grid (ROADMAP carried item (n)): for every point of a query
/// relation R, its clamped cell id and that cell's rank in the
/// non-empty-cell table `B` (or a sentinel when the cell is empty),
/// resolved exactly once. With the cache in hand, `build_queue`
/// grouping, queue pricing and claim-time candidate walks are O(1) per
/// R query - the same complexity the native id-keyed self-join path
/// enjoys - instead of one coordinate recompute plus binary search per
/// touch.
#[derive(Debug, Clone)]
pub struct QueryRankCache {
    /// clamped linearised cell id per R point
    cell_ids: Vec<u64>,
    /// rank of that cell in `B`, or [`NO_RANK`] when the cell is empty
    ranks: Vec<u32>,
    /// grid epoch the cache was resolved against (staleness stamp)
    epoch: u64,
}

impl QueryRankCache {
    /// Number of cached query points (= |R| at build time).
    pub fn len(&self) -> usize {
        self.cell_ids.len()
    }

    /// Grid epoch this cache was resolved against. Using the cache
    /// against a grid whose [`GridIndex::epoch`] has moved on reads a
    /// stale snapshot; consumers compare stamps and rebuild.
    #[inline]
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// True when the cache covers zero query points.
    pub fn is_empty(&self) -> bool {
        self.cell_ids.is_empty()
    }

    /// Cached cell id of query `q`.
    #[inline]
    pub fn cell_id(&self, q: u32) -> u64 {
        self.cell_ids[q as usize]
    }

    /// Cached cell rank of query `q`, if its clamped cell is non-empty.
    #[inline]
    pub fn rank(&self, q: u32) -> Option<usize> {
        match self.ranks[q as usize] {
            NO_RANK => None,
            r => Some(r as usize),
        }
    }
}

/// How a consumer keys per-query lookups into a grid - the one seam
/// shared by queue building, claim grouping and candidate walks, so the
/// grouping key and the walk can never diverge per caller.
#[derive(Debug, Clone, Copy)]
pub enum QueryKey<'a> {
    /// Queries index the grid's own dataset (the self-join case):
    /// O(1) id-keyed reads off `point_rank`.
    Native,
    /// Bipartite R side with a precomputed [`QueryRankCache`]: O(1)
    /// cached reads, no coordinate recompute, no binary search.
    Cached(&'a QueryRankCache),
    /// Coordinate recompute per lookup (one binary search each) - the
    /// cache-free fallback and the ablation baseline the cached path is
    /// property-tested against.
    Coords,
}

/// Non-empty-cell grid over the first `m` dims, with O(1) point→cell
/// lookups and a precomputed CSR cell-adjacency table.
#[derive(Debug, Clone)]
pub struct GridIndex {
    /// cell edge length (= ε of the join)
    pub eps: f64,
    /// number of indexed dims m ≤ n (may be lower than requested when
    /// build-time validation degraded it - see module docs)
    pub m: usize,
    /// minimum coordinate per indexed dim (grid origin)
    mins: Vec<f64>,
    /// number of cells along each indexed dim
    widths: Vec<u64>,
    /// sorted linearised ids of non-empty cells (the paper's B)
    cell_ids: Vec<u64>,
    /// per non-empty cell: [start, end) into `point_ids` (the paper's G)
    ranges: Vec<(u32, u32)>,
    /// point ids grouped by cell (the paper's A)
    point_ids: Vec<u32>,
    /// point id -> rank of its cell in `cell_ids` (O(1) point→cell map)
    point_rank: Vec<u32>,
    /// CSR offsets into `adj_ranks`, one slot per cell rank plus the tail
    adj_off: Vec<usize>,
    /// CSR payload: for each cell rank, the ranks of its non-empty 3^m
    /// neighbors (itself included), ascending by cell id
    adj_ranks: Vec<u32>,
    /// memoized adjacent-block population per cell rank (≤ |D| each)
    adj_pop: Vec<u32>,
    /// mutation counter: bumped once per insert/remove, never reset -
    /// the generation stamp consumers snapshot against
    epoch: u64,
    /// mutations since the last canonical (re)build - the splice debt
    /// [`GridIndex::maybe_rebuild`] amortizes
    dirty: usize,
    /// dirty-fraction threshold for the amortized re-sort
    rebuild_frac: f64,
    /// live ids whose unclamped coordinates fall outside the frozen
    /// grid box in some indexed dim (they clamp into boundary cells).
    /// A pure function of (live set, geometry): assemble derives it,
    /// insert/remove patch it, so patched and rebuilt always agree.
    out_ids: HashSet<u32>,
    /// out-of-extent fraction that triggers a geometry refresh in
    /// [`GridIndex::maybe_rebuild`]
    drift_frac: f64,
    /// CSR byte budget the build was given (worst-case row bytes)
    adj_budget: usize,
    /// true when the budget ruled out materialised CSR rows: adjacency
    /// walks recompute their 3^m block on demand, `adj_off`/`adj_ranks`
    /// stay empty, `adj_pop` is still maintained
    adj_on_demand: bool,
}

impl GridIndex {
    /// Build the index. `m` is clamped to the dataset dimensionality and
    /// may be *degraded* further (trailing dims dropped, with a warning
    /// on stderr) when the per-dim cell counts would overflow the u64
    /// linearisation; `eps` must be positive and finite. The CSR
    /// adjacency table is computed here, in parallel over cells.
    pub fn build(d: &Dataset, m: usize, eps: f64) -> GridIndex {
        Self::build_with_budget(d, m, eps, DEFAULT_ADJ_BUDGET_BYTES)
    }

    /// [`GridIndex::build`] with an explicit CSR byte budget: when the
    /// worst-case row storage |B|·3^m·4 bytes would exceed `budget`
    /// (pathological ε/m regimes - tiny cells over many dims), the
    /// rows are not materialised and adjacency walks recompute their
    /// clipped `{-1,0,1}^m` block on demand. Candidate *lists* are
    /// identical in both modes; the memoized per-cell populations are
    /// kept either way, so scheduler pricing stays O(1).
    pub fn build_with_budget(d: &Dataset, m: usize, eps: f64, budget: usize) -> GridIndex {
        assert!(eps.is_finite() && eps > 0.0, "bad eps {eps}");
        let requested_m = m.clamp(1, d.dims());
        let ids: Vec<u32> = (0..d.len() as u32).collect();
        let (m, mins, widths) = Self::derive_geometry(d, &ids, requested_m, eps);
        Self::assemble(d, &ids, eps, m, mins, widths, budget)
    }

    /// Derive the grid geometry (origin, per-dim cell counts, possibly
    /// degraded m) over an id subset: the build-time scan, factored out
    /// so a drift refresh ([`GridIndex::maybe_rebuild`]) can re-derive
    /// it over the *live* set under churn.
    fn derive_geometry(
        d: &Dataset,
        ids: &[u32],
        requested_m: usize,
        eps: f64,
    ) -> (usize, Vec<f64>, Vec<u64>) {
        let mut mins = vec![f64::INFINITY; requested_m];
        let mut maxs = vec![f64::NEG_INFINITY; requested_m];
        for &i in ids {
            let p = d.point(i as usize);
            for j in 0..requested_m {
                let x = p[j] as f64;
                if x < mins[j] {
                    mins[j] = x;
                }
                if x > maxs[j] {
                    maxs[j] = x;
                }
            }
        }
        if ids.is_empty() {
            mins.iter_mut().for_each(|x| *x = 0.0);
            maxs.iter_mut().for_each(|x| *x = 0.0);
        }
        // per-dim widths as f64 first: the f64->u64 cast saturates, and
        // MAX_WIDTH caps any single dimension before the product check
        let mut widths: Vec<u64> = (0..requested_m)
            .map(|j| {
                let w = ((maxs[j] - mins[j]) / eps).floor() + 1.0;
                if w.is_finite() && w >= 1.0 {
                    (w as u64).min(MAX_WIDTH)
                } else {
                    1
                }
            })
            .collect();

        // Validate the linearisation: the widths product must fit u64 or
        // ids would collide under wrapping arithmetic. Degrade m by
        // dropping trailing dims (the lowest-variance ones after REORDER)
        // until it fits - the grid over fewer dims is a coarser but still
        // complete candidate filter.
        let fits = |ws: &[u64]| {
            ws.iter()
                .try_fold(1u64, |acc, &w| acc.checked_mul(w))
                .is_some()
        };
        let mut m = requested_m;
        while m > 1 && !fits(&widths[..m]) {
            m -= 1;
        }
        if m < requested_m {
            eprintln!(
                "[grid] widths product overflows u64 for m={requested_m} \
                 (per-dim cell counts {:?}); degrading to m={m} indexed dims",
                &widths[..requested_m]
            );
            widths.truncate(m);
            mins.truncate(m);
        }
        (m, mins, widths)
    }

    /// True when |B|·3^m CSR entries (4 bytes each, the worst case over
    /// `n_cells` non-empty cells) fit the byte budget. A pure function
    /// of the cell count, so an incremental patch and the rebuild
    /// oracle can never disagree on the adjacency mode.
    fn csr_fits(n_cells: usize, m: usize, budget: usize) -> bool {
        (n_cells as u64)
            .saturating_mul(3u64.saturating_pow(m as u32))
            .saturating_mul(std::mem::size_of::<u32>() as u64)
            <= budget as u64
    }

    /// Assemble the full index layout (B/G/A, point→rank, CSR adjacency,
    /// memoized populations) over a given id subset with a fixed
    /// geometry. [`GridIndex::build`] calls this over all ids after
    /// deriving the geometry; [`GridIndex::rebuilt`] over the live ids
    /// with the geometry frozen - the canonical form every incremental
    /// patch must land back on exactly.
    fn assemble(
        d: &Dataset,
        ids: &[u32],
        eps: f64,
        m: usize,
        mins: Vec<f64>,
        widths: Vec<u64>,
        budget: usize,
    ) -> GridIndex {
        // (cell id, point id) pairs, sorted by cell -> B/G/A arrays.
        let coord = |x: f32, j: usize| -> u64 {
            let c = ((x as f64 - mins[j]) / eps).floor();
            if c > 0.0 {
                (c as u64).min(widths[j] - 1)
            } else {
                0 // negatives (sub-min rounding) and NaN clamp to cell 0
            }
        };
        let mut pairs: Vec<(u64, u32)> = ids
            .iter()
            .map(|&i| {
                let p = d.point(i as usize);
                let mut id = 0u64;
                for j in 0..m {
                    id = id * widths[j] + coord(p[j], j);
                }
                (id, i)
            })
            .collect();
        pairs.sort_unstable();

        let mut cell_ids = Vec::new();
        let mut ranges: Vec<(u32, u32)> = Vec::new();
        let mut point_ids = Vec::with_capacity(ids.len());
        for (cell, pid) in pairs {
            if cell_ids.last() != Some(&cell) {
                cell_ids.push(cell);
                let s = point_ids.len() as u32;
                ranges.push((s, s));
            }
            point_ids.push(pid);
            ranges.last_mut().unwrap().1 += 1;
        }

        // point -> cell rank (filled off the already-sorted layout);
        // ids outside the subset keep the sentinel
        let mut point_rank = vec![NO_RANK; d.len()];
        for (rank, &(s, e)) in ranges.iter().enumerate() {
            for idx in s..e {
                point_rank[point_ids[idx as usize] as usize] = rank as u32;
            }
        }

        // CSR cell adjacency, computed once per cell, in parallel: each
        // worker takes a contiguous slab of cell ranks (deterministic
        // stitching) and walks the 3^m block with one binary search per
        // adjacent candidate - the last time anyone searches B for a
        // neighborhood. When the byte budget rules out materialised
        // rows, the same walk fills the memoized populations only.
        let n_cells = cell_ids.len();
        let with_rows = Self::csr_fits(n_cells, m, budget);
        let workers = std::thread::available_parallelism()
            .map(|t| t.get())
            .unwrap_or(1)
            .clamp(1, n_cells.max(1));
        let slab = n_cells.div_ceil(workers);
        let parts: Vec<(Vec<u32>, Vec<u32>, Vec<u32>)> = {
            let (cell_ids, ranges, widths) = (&cell_ids, &ranges, &widths);
            pool::run_ranks(workers, move |r| {
                let lo = (r * slab).min(n_cells);
                let hi = ((r + 1) * slab).min(n_cells);
                let mut counts: Vec<u32> = Vec::with_capacity(hi - lo);
                let mut flat: Vec<u32> = Vec::new();
                let mut pops: Vec<u32> = Vec::with_capacity(hi - lo);
                let mut coords = vec![0u64; m];
                let mut offs = vec![0i64; m];
                for rank in lo..hi {
                    delinearise(cell_ids[rank], widths, &mut coords);
                    let start = flat.len();
                    let mut pop = 0u32;
                    walk_block(&coords, widths, &mut offs, |id| {
                        if let Ok(nr) = cell_ids.binary_search(&id) {
                            if with_rows {
                                flat.push(nr as u32);
                            }
                            let (s, e) = ranges[nr];
                            pop += e - s;
                        }
                    });
                    counts.push((flat.len() - start) as u32);
                    pops.push(pop);
                }
                (counts, flat, pops)
            })
        };
        let total_entries: usize = parts.iter().map(|p| p.1.len()).sum();
        let mut adj_off = Vec::new();
        let mut adj_ranks = Vec::with_capacity(total_entries);
        let mut adj_pop = Vec::with_capacity(n_cells);
        if with_rows {
            adj_off.reserve(n_cells + 1);
            adj_off.push(0usize);
        }
        let mut running = 0usize;
        for (counts, flat, pops) in parts {
            if with_rows {
                for c in counts {
                    running += c as usize;
                    adj_off.push(running);
                }
            }
            adj_ranks.extend_from_slice(&flat);
            adj_pop.extend_from_slice(&pops);
        }
        if with_rows {
            debug_assert_eq!(adj_off.len(), n_cells + 1);
            debug_assert_eq!(*adj_off.last().unwrap(), adj_ranks.len());
        }

        // out-of-extent inventory: which live points clamp (in some
        // indexed dim) because they fall outside the frozen grid box -
        // the drift signal maybe_rebuild watches
        let out_ids: HashSet<u32> = ids
            .iter()
            .copied()
            .filter(|&i| {
                let p = d.point(i as usize);
                (0..m).any(|j| {
                    let c = ((p[j] as f64 - mins[j]) / eps).floor();
                    c < 0.0 || c >= widths[j] as f64
                })
            })
            .collect();

        GridIndex {
            eps,
            m,
            mins,
            widths,
            cell_ids,
            ranges,
            point_ids,
            point_rank,
            adj_off,
            adj_ranks,
            adj_pop,
            epoch: 0,
            dirty: 0,
            rebuild_frac: DEFAULT_REBUILD_FRAC,
            out_ids,
            drift_frac: DEFAULT_DRIFT_FRAC,
            adj_budget: budget,
            adj_on_demand: !with_rows,
        }
    }

    /// Clamped cell coordinate of `x` along indexed dim `j` (see module
    /// docs for why clamping into the grid box is the safe superset
    /// semantics for out-of-range points).
    #[inline]
    fn coord_of(&self, x: f32, j: usize) -> u64 {
        let c = ((x as f64 - self.mins[j]) / self.eps).floor();
        if c > 0.0 {
            (c as u64).min(self.widths[j] - 1)
        } else {
            0
        }
    }

    // ---------------------------------------------------------------
    // coordinate-keyed entry points (any point, incl. the bipartite R
    // side) - allocation-free
    // ---------------------------------------------------------------

    /// Linearised (clamped) cell id of an arbitrary point. Injective over
    /// clamped coordinates: distinct cells never collide.
    pub fn cell_id_of(&self, p: &[f32]) -> u64 {
        let mut id = 0u64;
        for j in 0..self.m {
            id = id * self.widths[j] + self.coord_of(p[j], j);
        }
        id
    }

    /// Rank of a linearised cell id in the non-empty-cell table `B`, if
    /// the cell is non-empty. One binary search - the only search left on
    /// any coordinate-keyed path.
    pub fn rank_of_cell_id(&self, cell_id: u64) -> Option<usize> {
        self.cell_ids.binary_search(&cell_id).ok()
    }

    /// Rank of the (clamped) cell containing an arbitrary point, if that
    /// cell is non-empty.
    pub fn cell_rank_of_point(&self, p: &[f32]) -> Option<usize> {
        self.rank_of_cell_id(self.cell_id_of(p))
    }

    /// Number of points in the cell containing `p` (0 if cell is empty).
    /// This is the |C| of the splitter predicate (paper Sec. V-D).
    pub fn cell_population(&self, p: &[f32]) -> usize {
        match self.cell_rank_of_point(p) {
            Some(r) => self.rank_population(r),
            None => 0,
        }
    }

    /// Walk the adjacent-cell block of `p` (3^m neighborhood clipped to
    /// the grid), invoking `visit` with each non-empty cell's point ids,
    /// ascending by cell id. Non-empty query cells take the precomputed
    /// CSR row (flat slice iteration, no searches, no allocation); empty
    /// cells - possible only for points outside the indexed data, e.g.
    /// bipartite R queries - fall back to the recompute walk over
    /// thread-local scratch.
    pub fn visit_adjacent(&self, p: &[f32], visit: impl FnMut(&[u32])) {
        match self.cell_rank_of_point(p) {
            Some(r) => self.visit_adjacent_of_rank(r, visit),
            None => self.visit_adjacent_fallback(p, visit),
        }
    }

    /// Collect the candidate ids of `p`'s adjacent block into `out`
    /// (cleared first; reserved to the exact candidate count when the
    /// query cell is non-empty). The scratch-buffer form of
    /// [`GridIndex::candidates_of`].
    pub fn candidates_into(&self, p: &[f32], out: &mut Vec<u32>) {
        match self.cell_rank_of_point(p) {
            Some(r) => self.candidates_into_rank(r, out),
            None => {
                out.clear();
                self.visit_adjacent_fallback(p, |ids| out.extend_from_slice(ids));
            }
        }
    }

    /// All candidate ids within the adjacent block of `p` (allocating
    /// convenience wrapper over [`GridIndex::candidates_into`]).
    pub fn candidates_of(&self, p: &[f32]) -> Vec<u32> {
        let mut out = Vec::new();
        self.candidates_into(p, &mut out);
        out
    }

    /// Number of candidates the adjacent-block walk of `p` would scan -
    /// the per-query work estimate of the Sec. V-B batch estimator. O(1)
    /// off the memoized per-cell population when the query cell is
    /// non-empty; the recompute walk otherwise.
    pub fn adjacent_population(&self, p: &[f32]) -> usize {
        match self.cell_rank_of_point(p) {
            Some(r) => self.adj_pop[r] as usize,
            None => {
                let mut n = 0usize;
                self.visit_adjacent_fallback(p, |ids| n += ids.len());
                n
            }
        }
    }

    /// The recompute walk for query points whose clamped cell is empty:
    /// enumerate the 3^m block and binary-search each member in `B`.
    /// Coordinates and offsets live in thread-local scratch, so the walk
    /// allocates nothing per query (a `visit` closure that re-enters the
    /// grid degrades to a one-off local buffer instead of panicking).
    fn visit_adjacent_fallback(&self, p: &[f32], mut visit: impl FnMut(&[u32])) {
        WALK_SCRATCH.with(|s| {
            let mut local = (Vec::new(), Vec::new());
            let mut guard = s.try_borrow_mut().ok();
            let (coords, offs) = match guard.as_deref_mut() {
                Some(t) => (&mut t.0, &mut t.1),
                None => (&mut local.0, &mut local.1),
            };
            coords.clear();
            coords.extend((0..self.m).map(|j| self.coord_of(p[j], j)));
            offs.resize(self.m, 0);
            walk_block(coords, &self.widths, offs, |id| {
                if let Ok(nr) = self.cell_ids.binary_search(&id) {
                    let (s, e) = self.ranges[nr];
                    visit(&self.point_ids[s as usize..e as usize]);
                }
            });
        });
    }

    // ---------------------------------------------------------------
    // id-keyed entry points: `point_id` indexes the dataset the grid was
    // built over (the self-join hot paths) - O(1), no searches
    // ---------------------------------------------------------------

    /// Rank (index into the non-empty-cell table) of the cell holding an
    /// indexed point. O(1) array read.
    #[inline]
    pub fn cell_rank_of(&self, point_id: u32) -> usize {
        self.point_rank[point_id as usize] as usize
    }

    /// Linearised cell id of an indexed point. O(1).
    #[inline]
    pub fn cell_id_of_id(&self, point_id: u32) -> u64 {
        self.cell_ids[self.cell_rank_of(point_id)]
    }

    /// Population of the cell holding an indexed point (≥ 1). O(1).
    #[inline]
    pub fn cell_population_of_id(&self, point_id: u32) -> usize {
        self.rank_population(self.cell_rank_of(point_id))
    }

    /// Adjacent-block population of an indexed point's cell - the
    /// Sec. V-B per-query work estimate. O(1) off the memoized table.
    #[inline]
    pub fn adjacent_population_of_id(&self, point_id: u32) -> usize {
        self.adj_pop[self.cell_rank_of(point_id)] as usize
    }

    /// Walk the adjacent block of an indexed point's cell through the CSR
    /// row: flat slice iteration, zero searches, zero allocation.
    pub fn visit_adjacent_of_id(&self, point_id: u32, visit: impl FnMut(&[u32])) {
        self.visit_adjacent_of_rank(self.cell_rank_of(point_id), visit);
    }

    /// Collect the candidates of an indexed point's adjacent block into
    /// `out` (cleared, then reserved to the exact candidate count).
    pub fn candidates_into_id(&self, point_id: u32, out: &mut Vec<u32>) {
        self.candidates_into_rank(self.cell_rank_of(point_id), out);
    }

    // ---------------------------------------------------------------
    // query-keyed entry points: one seam for consumers that process a
    // query set which is EITHER the grid's own dataset (`native`, the
    // self-join case - O(1) id-keyed) OR an arbitrary relation R against
    // this S-grid (coordinate-keyed). Keeping the branch here means the
    // grouping key and the candidate walk can never diverge per caller.
    // ---------------------------------------------------------------

    /// Build a [`QueryRankCache`] over an arbitrary query relation: one
    /// coordinate linearisation plus one binary search per R point,
    /// paid once, after which every keyed lookup below is O(1).
    pub fn build_query_ranks(&self, r_data: &Dataset) -> QueryRankCache {
        let n = r_data.len();
        let mut cell_ids = Vec::with_capacity(n);
        let mut ranks = Vec::with_capacity(n);
        for q in 0..n {
            let id = self.cell_id_of(r_data.point(q));
            cell_ids.push(id);
            ranks.push(match self.rank_of_cell_id(id) {
                Some(r) => r as u32,
                None => NO_RANK,
            });
        }
        QueryRankCache {
            cell_ids,
            ranks,
            epoch: self.epoch,
        }
    }

    /// Cell id of query `q` (an id into `r_data`) under a [`QueryKey`].
    /// `Native` asserts that the grid was built over `r_data` itself and
    /// `Cached` that the cache was built over `r_data` against this
    /// grid; debug builds verify both claims against the coordinate
    /// recompute.
    #[inline]
    pub fn query_cell_id_keyed(&self, key: QueryKey, r_data: &Dataset, q: u32) -> u64 {
        match key {
            QueryKey::Native => {
                let id = self.cell_id_of_id(q);
                debug_assert_eq!(
                    id,
                    self.cell_id_of(r_data.point(q as usize)),
                    "native key misuse: query {q} does not index the grid's dataset"
                );
                id
            }
            QueryKey::Cached(c) => {
                let id = c.cell_id(q);
                debug_assert_eq!(
                    id,
                    self.cell_id_of(r_data.point(q as usize)),
                    "stale rank cache: query {q} cell id diverges from recompute"
                );
                id
            }
            QueryKey::Coords => self.cell_id_of(r_data.point(q as usize)),
        }
    }

    /// Rank of query `q`'s (clamped) cell, if non-empty, under a
    /// [`QueryKey`]: O(1) for `Native` and `Cached`, one binary search
    /// for `Coords`.
    #[inline]
    pub fn query_rank_keyed(&self, key: QueryKey, r_data: &Dataset, q: u32) -> Option<usize> {
        match key {
            QueryKey::Native => Some(self.cell_rank_of(q)),
            QueryKey::Cached(c) => c.rank(q),
            QueryKey::Coords => self.rank_of_cell_id(self.cell_id_of(r_data.point(q as usize))),
        }
    }

    /// Adjacent-block population of query `q` - the Sec. V-B per-query
    /// work estimate - under a [`QueryKey`]. O(1) off the memoized table
    /// whenever the rank resolves.
    pub fn query_adjacent_population_keyed(&self, key: QueryKey, r_data: &Dataset, q: u32) -> usize {
        match self.query_rank_keyed(key, r_data, q) {
            Some(r) => self.adj_pop[r] as usize,
            None => {
                let mut n = 0usize;
                self.visit_adjacent_fallback(r_data.point(q as usize), |ids| n += ids.len());
                n
            }
        }
    }

    /// Candidate list of query `q` (an id into `r_data`) into `out` -
    /// the query-keyed form of [`GridIndex::candidates_into`]; see
    /// [`GridIndex::query_cell_id_keyed`] for the key contracts.
    pub fn query_candidates_into_keyed(
        &self,
        key: QueryKey,
        r_data: &Dataset,
        q: u32,
        out: &mut Vec<u32>,
    ) {
        match self.query_rank_keyed(key, r_data, q) {
            Some(r) => self.candidates_into_rank(r, out),
            None => {
                out.clear();
                self.visit_adjacent_fallback(r_data.point(q as usize), |ids| {
                    out.extend_from_slice(ids)
                });
            }
        }
    }

    /// Bool-keyed wrapper over [`GridIndex::query_cell_id_keyed`] kept
    /// for call sites that only distinguish self-join (`native`) from
    /// coordinate recompute.
    #[inline]
    pub fn query_cell_id(&self, native: bool, r_data: &Dataset, q: u32) -> u64 {
        let key = if native {
            QueryKey::Native
        } else {
            QueryKey::Coords
        };
        self.query_cell_id_keyed(key, r_data, q)
    }

    /// Bool-keyed wrapper over [`GridIndex::query_candidates_into_keyed`].
    pub fn query_candidates_into(
        &self,
        native: bool,
        r_data: &Dataset,
        q: u32,
        out: &mut Vec<u32>,
    ) {
        let key = if native {
            QueryKey::Native
        } else {
            QueryKey::Coords
        };
        self.query_candidates_into_keyed(key, r_data, q, out);
    }

    // ---------------------------------------------------------------
    // rank-keyed core (what both keyed forms resolve to)
    // ---------------------------------------------------------------

    /// Linearised cell id at a given rank.
    #[inline]
    pub fn rank_cell_id(&self, rank: usize) -> u64 {
        self.cell_ids[rank]
    }

    /// Point ids of the cell at a given rank.
    #[inline]
    pub fn rank_points(&self, rank: usize) -> &[u32] {
        let (s, e) = self.ranges[rank];
        &self.point_ids[s as usize..e as usize]
    }

    /// Population of the cell at a given rank.
    #[inline]
    pub fn rank_population(&self, rank: usize) -> usize {
        let (s, e) = self.ranges[rank];
        (e - s) as usize
    }

    /// CSR row of a cell: the ranks of its non-empty 3^m neighbors
    /// (itself included), ascending by cell id. Panics when the build
    /// budget ruled out materialised rows
    /// ([`GridIndex::adj_is_on_demand`]) - use
    /// [`GridIndex::visit_adjacent_of_rank`], which works in both
    /// modes, when the consumer only needs to walk the block.
    #[inline]
    pub fn adjacent_ranks(&self, rank: usize) -> &[u32] {
        assert!(
            !self.adj_on_demand,
            "adjacent_ranks: CSR rows not materialised (byte budget exceeded)"
        );
        &self.adj_ranks[self.adj_off[rank]..self.adj_off[rank + 1]]
    }

    /// Memoized adjacent-block population of the cell at a given rank.
    #[inline]
    pub fn adjacent_population_of_rank(&self, rank: usize) -> usize {
        self.adj_pop[rank] as usize
    }

    /// Enumerate the ranks of a cell's non-empty 3^m neighbors (itself
    /// included), ascending by cell id, by recomputing the clipped
    /// block walk - the on-demand replacement for a materialised CSR
    /// row. Thread-local scratch keeps it allocation-free per call.
    fn walk_rank_on_demand(&self, rank: usize, mut f: impl FnMut(usize)) {
        WALK_SCRATCH.with(|s| {
            let mut local = (Vec::new(), Vec::new());
            let mut guard = s.try_borrow_mut().ok();
            let (coords, offs) = match guard.as_deref_mut() {
                Some(t) => (&mut t.0, &mut t.1),
                None => (&mut local.0, &mut local.1),
            };
            coords.resize(self.m, 0);
            delinearise(self.cell_ids[rank], &self.widths, coords);
            offs.resize(self.m, 0);
            walk_block(coords, &self.widths, offs, |id| {
                if let Ok(nr) = self.cell_ids.binary_search(&id) {
                    f(nr);
                }
            });
        });
    }

    /// Walk a cell's adjacent block, invoking `visit` with each
    /// non-empty neighbor's point ids, ascending by cell id: flat CSR
    /// row iteration (zero searches) when rows are materialised, the
    /// recompute walk otherwise - identical output either way.
    pub fn visit_adjacent_of_rank(&self, rank: usize, mut visit: impl FnMut(&[u32])) {
        if self.adj_on_demand {
            self.walk_rank_on_demand(rank, |nr| {
                let (s, e) = self.ranges[nr];
                visit(&self.point_ids[s as usize..e as usize]);
            });
            return;
        }
        for &nr in self.adjacent_ranks(rank) {
            let (s, e) = self.ranges[nr as usize];
            visit(&self.point_ids[s as usize..e as usize]);
        }
    }

    /// Collect a cell's adjacent-block candidates into `out`: cleared,
    /// reserved to the exact (memoized) candidate count, then filled by
    /// flat slice copies - one allocation at most, ever, per buffer.
    pub fn candidates_into_rank(&self, rank: usize, out: &mut Vec<u32>) {
        out.clear();
        out.reserve(self.adj_pop[rank] as usize);
        self.visit_adjacent_of_rank(rank, |ids| out.extend_from_slice(ids));
    }

    // ---------------------------------------------------------------
    // churn: epoch-stamped incremental maintenance. Every patch lands
    // the arrays back on the exact canonical form `assemble` produces
    // (the rebuild-equivalence invariant the churn harness locks down).
    // ---------------------------------------------------------------

    /// Mutation epoch: bumped once per [`GridIndex::insert`] /
    /// [`GridIndex::remove`], never reset. Consumers (queue generation
    /// stamps, the GPU brute tile cache, [`QueryRankCache`]) snapshot
    /// this and invalidate when it moves.
    #[inline]
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// True when `id` is currently indexed (inserted and not removed).
    #[inline]
    pub fn is_indexed(&self, id: u32) -> bool {
        self.point_rank.get(id as usize).copied().unwrap_or(NO_RANK) != NO_RANK
    }

    /// Number of currently indexed points.
    #[inline]
    pub fn indexed_points(&self) -> usize {
        self.point_ids.len()
    }

    /// Currently indexed ids, ascending - the live set a
    /// [`GridIndex::rebuilt`] oracle is assembled over.
    pub fn indexed_ids(&self) -> Vec<u32> {
        (0..self.point_rank.len() as u32)
            .filter(|&i| self.point_rank[i as usize] != NO_RANK)
            .collect()
    }

    /// Index a (new) corpus point under the *frozen* geometry: the
    /// grid origin, widths, m and eps never move, so points beyond the
    /// original extent clamp into boundary cells - the same superset
    /// semantics the bipartite R side already relies on, and exactly
    /// what the frozen-geometry rebuild oracle produces.
    ///
    /// A point landing in an existing cell dirties only that cell's
    /// B/G/A slots plus the memoized populations along its own CSR row
    /// (the clipped `{-1,0,1}^m` neighborhood is symmetric, so those
    /// are precisely the cells whose adjacent population changed).
    /// Cell birth splices B/G and re-stitches the CSR table in one
    /// O(E) remap pass.
    pub fn insert(&mut self, d: &Dataset, id: u32) {
        let cid = self.cell_id_of(d.point(id as usize));
        if self.point_rank.len() <= id as usize {
            self.point_rank.resize(id as usize + 1, NO_RANK);
        }
        debug_assert_eq!(
            self.point_rank[id as usize],
            NO_RANK,
            "insert of already-indexed id {id}"
        );
        match self.cell_ids.binary_search(&cid) {
            Ok(r) => {
                // existing cell: splice A at the id-sorted slot, bump
                // this and all later ranges, bump adj_pop along the
                // cell's own CSR row
                let (s, e) = self.ranges[r];
                let pos = s as usize
                    + self.point_ids[s as usize..e as usize].partition_point(|&x| x < id);
                self.point_ids.insert(pos, id);
                self.ranges[r].1 += 1;
                for rr in self.ranges[r + 1..].iter_mut() {
                    rr.0 += 1;
                    rr.1 += 1;
                }
                if self.adj_on_demand {
                    let mut touched = Vec::new();
                    self.walk_rank_on_demand(r, |nr| touched.push(nr));
                    for nr in touched {
                        self.adj_pop[nr] += 1;
                    }
                } else {
                    for i in self.adj_off[r]..self.adj_off[r + 1] {
                        self.adj_pop[self.adj_ranks[i] as usize] += 1;
                    }
                }
                self.point_rank[id as usize] = r as u32;
            }
            Err(nr) => self.insert_new_cell(nr, cid, id),
        }
        if self.out_of_extent(d.point(id as usize)) {
            self.out_ids.insert(id);
        }
        self.epoch += 1;
        self.dirty += 1;
    }

    /// True when `p`'s unclamped coordinate falls outside the frozen
    /// grid box in some indexed dim (the point clamps into a boundary
    /// cell). Must mirror the filter `assemble` derives `out_ids` with,
    /// bit for bit, so patched and rebuilt inventories agree.
    fn out_of_extent(&self, p: &[f32]) -> bool {
        (0..self.m).any(|j| {
            let c = ((p[j] as f64 - self.mins[j]) / self.eps).floor();
            c < 0.0 || c >= self.widths[j] as f64
        })
    }

    /// Cell birth: splice the new cell into B/G/A at rank `nr`, shift
    /// the point→rank map, then re-stitch the CSR table - old ranks at
    /// or above `nr` shift up by one, every neighbor row gains an
    /// entry for the new cell at its sorted slot (and one point of
    /// adjacent population), and the new cell's own row is computed by
    /// the 3^m walk over the updated B.
    fn insert_new_cell(&mut self, nr: usize, cid: u64, id: u32) {
        self.cell_ids.insert(nr, cid);
        let s = if nr == 0 { 0 } else { self.ranges[nr - 1].1 };
        self.ranges.insert(nr, (s, s + 1));
        for rr in self.ranges[nr + 1..].iter_mut() {
            rr.0 += 1;
            rr.1 += 1;
        }
        self.point_ids.insert(s as usize, id);
        for pr in self.point_rank.iter_mut() {
            if *pr != NO_RANK && *pr >= nr as u32 {
                *pr += 1;
            }
        }
        self.point_rank[id as usize] = nr as u32;

        // the new cell's own CSR row, over the updated (spliced) B
        let mut coords = vec![0u64; self.m];
        delinearise(cid, &self.widths, &mut coords);
        let mut offs = vec![0i64; self.m];
        let mut row: Vec<u32> = Vec::new();
        walk_block(&coords, &self.widths, &mut offs, |nid| {
            if let Ok(x) = self.cell_ids.binary_search(&nid) {
                row.push(x as u32);
            }
        });
        debug_assert!(row.binary_search(&(nr as u32)).is_ok());

        // Cell birth can push |B|·3^m past the byte budget: the mode is
        // re-derived from the new cell count (the same predicate the
        // rebuild oracle applies). Births never flip on-demand back to
        // CSR - the count only grew - so the two on-demand cases share
        // one pop-only patch: the old populations were canonical, the
        // new cell's own pop is the row sum, every *other* walked
        // neighbor gains the one new point.
        if !Self::csr_fits(self.cell_ids.len(), self.m, self.adj_budget) {
            let own_pop: u32 = row
                .iter()
                .map(|&x| {
                    let (a, b) = self.ranges[x as usize];
                    b - a
                })
                .sum();
            self.adj_pop.insert(nr, own_pop);
            for &x in &row {
                if x != nr as u32 {
                    self.adj_pop[x as usize] += 1;
                }
            }
            self.adj_ranks = Vec::new();
            self.adj_off = Vec::new();
            self.adj_on_demand = true;
            return;
        }

        let member = |x: u32| row.binary_search(&x).is_ok();
        let n_new = self.cell_ids.len();
        let mut flat = Vec::with_capacity(self.adj_ranks.len() + 2 * row.len());
        let mut off = Vec::with_capacity(n_new + 1);
        off.push(0usize);
        let mut pop = Vec::with_capacity(n_new);
        for rank in 0..n_new {
            if rank == nr {
                flat.extend_from_slice(&row);
                pop.push(
                    row.iter()
                        .map(|&x| {
                            let (a, b) = self.ranges[x as usize];
                            b - a
                        })
                        .sum(),
                );
            } else {
                let old = if rank > nr { rank - 1 } else { rank };
                let adjacent = member(rank as u32);
                let mut placed = false;
                for i in self.adj_off[old]..self.adj_off[old + 1] {
                    let mut x = self.adj_ranks[i];
                    if x >= nr as u32 {
                        x += 1;
                    }
                    if adjacent && !placed && x > nr as u32 {
                        flat.push(nr as u32);
                        placed = true;
                    }
                    flat.push(x);
                }
                if adjacent && !placed {
                    flat.push(nr as u32);
                }
                pop.push(self.adj_pop[old] + u32::from(adjacent));
            }
            off.push(flat.len());
        }
        self.adj_ranks = flat;
        self.adj_off = off;
        self.adj_pop = pop;
    }

    /// Un-index a corpus point. Returns false (and changes nothing)
    /// when `id` is not currently indexed. Mirrors
    /// [`GridIndex::insert`]: a survivor cell dirties only its own
    /// B/G/A slots plus the populations along its CSR row; removing a
    /// cell's last point is cell death, re-stitching the CSR table in
    /// one O(E) remap pass.
    pub fn remove(&mut self, id: u32) -> bool {
        let pr = self.point_rank.get(id as usize).copied().unwrap_or(NO_RANK);
        if pr == NO_RANK {
            return false;
        }
        let r = pr as usize;
        let (s, e) = self.ranges[r];
        if e - s == 1 {
            self.remove_last_in_cell(r, id);
        } else {
            let pos = s as usize
                + self.point_ids[s as usize..e as usize]
                    .binary_search(&id)
                    .expect("point_rank out of sync with A");
            self.point_ids.remove(pos);
            self.ranges[r].1 -= 1;
            for rr in self.ranges[r + 1..].iter_mut() {
                rr.0 -= 1;
                rr.1 -= 1;
            }
            if self.adj_on_demand {
                let mut touched = Vec::new();
                self.walk_rank_on_demand(r, |nr| touched.push(nr));
                for nr in touched {
                    self.adj_pop[nr] -= 1;
                }
            } else {
                for i in self.adj_off[r]..self.adj_off[r + 1] {
                    self.adj_pop[self.adj_ranks[i] as usize] -= 1;
                }
            }
        }
        self.point_rank[id as usize] = NO_RANK;
        self.out_ids.remove(&id);
        self.epoch += 1;
        self.dirty += 1;
        true
    }

    /// Cell death: drop the B/G/A slots of rank `r` (whose sole point
    /// is `id`), remap ranks above it down by one, and re-stitch the
    /// CSR table without it - every former neighbor loses the row
    /// entry and the one point of adjacent population.
    fn remove_last_in_cell(&mut self, r: usize, id: u32) {
        let (s, _) = self.ranges[r];
        debug_assert_eq!(self.point_ids[s as usize], id);
        if self.adj_on_demand {
            // walk the dying cell's block over the *current* B before
            // splicing it out: those neighbors each lose one point of
            // adjacent population (the dying cell's sole occupant)
            let mut row = Vec::new();
            self.walk_rank_on_demand(r, |nr| row.push(nr));
            self.point_ids.remove(s as usize);
            self.cell_ids.remove(r);
            self.ranges.remove(r);
            for rr in self.ranges[r..].iter_mut() {
                rr.0 -= 1;
                rr.1 -= 1;
            }
            for pr in self.point_rank.iter_mut() {
                if *pr != NO_RANK && *pr > r as u32 {
                    *pr -= 1;
                }
            }
            for &nr in &row {
                if nr != r {
                    let shifted = if nr > r { nr - 1 } else { nr };
                    self.adj_pop[shifted] -= 1;
                }
            }
            self.adj_pop.remove(r);
            // death may bring |B|·3^m back under the byte budget: flip
            // home to materialised rows at the same boundary the
            // rebuild oracle would
            if Self::csr_fits(self.cell_ids.len(), self.m, self.adj_budget) {
                self.recompute_rows();
            }
            return;
        }
        self.point_ids.remove(s as usize);
        self.cell_ids.remove(r);
        self.ranges.remove(r);
        for rr in self.ranges[r..].iter_mut() {
            rr.0 -= 1;
            rr.1 -= 1;
        }
        for pr in self.point_rank.iter_mut() {
            if *pr != NO_RANK && *pr > r as u32 {
                *pr -= 1;
            }
        }
        let n_new = self.cell_ids.len();
        let mut flat = Vec::with_capacity(self.adj_ranks.len());
        let mut off = Vec::with_capacity(n_new + 1);
        off.push(0usize);
        let mut pop = Vec::with_capacity(n_new);
        for rank in 0..n_new {
            let old = if rank >= r { rank + 1 } else { rank };
            let mut was_adjacent = false;
            for i in self.adj_off[old]..self.adj_off[old + 1] {
                let x = self.adj_ranks[i];
                if x == r as u32 {
                    was_adjacent = true;
                    continue;
                }
                flat.push(if x > r as u32 { x - 1 } else { x });
            }
            off.push(flat.len());
            pop.push(self.adj_pop[old] - u32::from(was_adjacent));
        }
        self.adj_ranks = flat;
        self.adj_off = off;
        self.adj_pop = pop;
    }

    /// Recompute the materialised CSR rows (offsets, rows, populations)
    /// from B/G in place and leave on-demand mode - the one-off cost of
    /// a cell death that brings the worst-case table back under the
    /// byte budget. Sequential: flips are rare (they happen exactly at
    /// the budget boundary), and the boundary cell count is budget/3^m.
    fn recompute_rows(&mut self) {
        let n_cells = self.cell_ids.len();
        let mut adj_off = Vec::with_capacity(n_cells + 1);
        adj_off.push(0usize);
        let mut adj_ranks = Vec::new();
        let mut adj_pop = Vec::with_capacity(n_cells);
        let mut coords = vec![0u64; self.m];
        let mut offs = vec![0i64; self.m];
        for rank in 0..n_cells {
            delinearise(self.cell_ids[rank], &self.widths, &mut coords);
            let mut pop = 0u32;
            walk_block(&coords, &self.widths, &mut offs, |id| {
                if let Ok(nr) = self.cell_ids.binary_search(&id) {
                    adj_ranks.push(nr as u32);
                    let (a, b) = self.ranges[nr];
                    pop += b - a;
                }
            });
            adj_off.push(adj_ranks.len());
            adj_pop.push(pop);
        }
        self.adj_off = adj_off;
        self.adj_ranks = adj_ranks;
        self.adj_pop = adj_pop;
        self.adj_on_demand = false;
    }

    /// From-scratch rebuild over the currently indexed ids with the
    /// geometry *frozen* - the canonical-form oracle every incremental
    /// patch is asserted bit-equal to. Carries the epoch forward (the
    /// live set is the same snapshot), clears the splice debt.
    pub fn rebuilt(&self, d: &Dataset) -> GridIndex {
        let mut g = Self::assemble(
            d,
            &self.indexed_ids(),
            self.eps,
            self.m,
            self.mins.clone(),
            self.widths.clone(),
            self.adj_budget,
        );
        g.epoch = self.epoch;
        g.rebuild_frac = self.rebuild_frac;
        g.drift_frac = self.drift_frac;
        g
    }

    /// Re-derive the grid geometry (origin + widths, possibly degrading
    /// m further) over the live set and reassemble - the drift escape
    /// hatch of [`GridIndex::maybe_rebuild`]. Unlike the canonical
    /// re-sort, the geometry *moves*, so cell ids are not comparable
    /// across the refresh and the epoch bumps once to invalidate every
    /// derived snapshot (rank caches, tile caches, queue stamps).
    fn refresh_geometry(&mut self, d: &Dataset) {
        let ids = self.indexed_ids();
        let (m, mins, widths) = Self::derive_geometry(d, &ids, self.m, self.eps);
        let mut g = Self::assemble(d, &ids, self.eps, m, mins, widths, self.adj_budget);
        g.epoch = self.epoch + 1;
        g.rebuild_frac = self.rebuild_frac;
        g.drift_frac = self.drift_frac;
        *self = g;
    }

    /// Set the dirty-fraction threshold of [`GridIndex::maybe_rebuild`]
    /// (clamped to be positive; default 0.25).
    pub fn set_rebuild_frac(&mut self, frac: f64) {
        self.rebuild_frac = frac.max(1e-9);
    }

    /// Set the out-of-extent fraction that triggers a geometry refresh
    /// in [`GridIndex::maybe_rebuild`] (clamped positive; default 0.2).
    pub fn set_drift_frac(&mut self, frac: f64) {
        self.drift_frac = frac.max(1e-9);
    }

    /// Fraction of live points currently clamping into boundary cells
    /// because they fall outside the frozen build-time extent.
    pub fn out_of_extent_fraction(&self) -> f64 {
        self.out_ids.len() as f64 / self.point_ids.len().max(1) as f64
    }

    /// Mutations applied since the last canonical (re)build, as a
    /// fraction of the indexed population.
    pub fn dirty_fraction(&self) -> f64 {
        self.dirty as f64 / self.point_ids.len().max(1) as f64
    }

    /// Amortized maintenance, checked at flush boundaries. Two
    /// escalating triggers:
    ///
    /// 1. **Drift refresh**: when more than `drift_frac` of the live
    ///    points fall outside the frozen build-time extent, the
    ///    geometry itself (origin + widths, possibly a further-degraded
    ///    m) is re-derived over the live set - boundary-cell pileup
    ///    would otherwise degrade the walk toward a scan. This moves
    ///    the epoch (cell ids change meaning), invalidating every
    ///    derived snapshot exactly like a mutation does.
    /// 2. **Canonical re-sort**: once the dirty fraction trips
    ///    `rebuild_frac`, the accumulated splice debt is replaced with
    ///    one canonical `assemble`. Because patches already keep the
    ///    arrays canonical, this is observably a no-op (same layout,
    ///    same epoch) - the churn harness asserts exactly that - but
    ///    it restores compact allocations and bounds worst-case splice
    ///    cost amortized.
    pub fn maybe_rebuild(&mut self, d: &Dataset) -> bool {
        let live = self.point_ids.len().max(1) as f64;
        if self.out_ids.len() as f64 > self.drift_frac * live {
            self.refresh_geometry(d);
            return true;
        }
        if self.dirty as f64 <= self.rebuild_frac * live {
            return false;
        }
        *self = self.rebuilt(d);
        true
    }

    /// Assert structural equality of the complete index layout - B, G,
    /// A, the point→rank map (padded with the sentinel to the longer
    /// extent), the CSR adjacency table, the memoized populations and
    /// the frozen geometry - panicking with the diverging field named.
    /// The rebuild-equivalence oracle of the churn harness. Epoch and
    /// debt counters are bookkeeping, not layout, and are not compared.
    pub fn assert_same_layout(&self, other: &GridIndex) {
        assert_eq!(self.m, other.m, "m diverged");
        assert_eq!(self.eps.to_bits(), other.eps.to_bits(), "eps diverged");
        assert_eq!(self.mins, other.mins, "grid origin diverged");
        assert_eq!(self.widths, other.widths, "grid widths diverged");
        assert_eq!(self.cell_ids, other.cell_ids, "B (cell_ids) diverged");
        assert_eq!(self.ranges, other.ranges, "G (ranges) diverged");
        assert_eq!(self.point_ids, other.point_ids, "A (point_ids) diverged");
        let n = self.point_rank.len().max(other.point_rank.len());
        for i in 0..n {
            let a = self.point_rank.get(i).copied().unwrap_or(NO_RANK);
            let b = other.point_rank.get(i).copied().unwrap_or(NO_RANK);
            assert_eq!(a, b, "point_rank[{i}] diverged");
        }
        assert_eq!(self.adj_off, other.adj_off, "CSR offsets diverged");
        assert_eq!(self.adj_ranks, other.adj_ranks, "CSR rows diverged");
        assert_eq!(self.adj_pop, other.adj_pop, "adj_pop diverged");
        assert_eq!(
            self.adj_on_demand, other.adj_on_demand,
            "adjacency mode diverged"
        );
        let sorted = |s: &HashSet<u32>| {
            let mut v: Vec<u32> = s.iter().copied().collect();
            v.sort_unstable();
            v
        };
        assert_eq!(
            sorted(&self.out_ids),
            sorted(&other.out_ids),
            "out-of-extent inventory diverged"
        );
    }

    // ---------------------------------------------------------------
    // inventory
    // ---------------------------------------------------------------

    /// Number of non-empty cells.
    pub fn non_empty_cells(&self) -> usize {
        self.cell_ids.len()
    }

    /// Number of materialised CSR row entries (0 in on-demand mode).
    pub fn adj_table_entries(&self) -> usize {
        self.adj_ranks.len()
    }

    /// True when the build byte budget ruled out materialised CSR rows
    /// and adjacency walks recompute their block on demand.
    pub fn adj_is_on_demand(&self) -> bool {
        self.adj_on_demand
    }

    /// Population of every non-empty cell alongside its id
    /// (used by the ρ reassignment which drains the sparsest cells).
    pub fn cell_sizes(&self) -> impl Iterator<Item = (u64, usize)> + '_ {
        self.cell_ids
            .iter()
            .zip(&self.ranges)
            .map(|(&id, &(s, e))| (id, (e - s) as usize))
    }

    /// Point ids in a given (linearised) cell.
    pub fn cell_points(&self, cell_id: u64) -> &[u32] {
        match self.rank_of_cell_id(cell_id) {
            Some(rank) => self.rank_points(rank),
            None => &[],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{sqdist, sqdist_prefix};
    use crate::data::synthetic::{chist_like, susy_like};
    use crate::util::{prop, rng::Rng};

    fn random_dataset(rng: &mut Rng, n: usize, dims: usize, scale: f64) -> Dataset {
        let data: Vec<f32> = (0..n * dims)
            .map(|_| rng.normal(0.0, scale) as f32)
            .collect();
        Dataset::new(data, dims)
    }

    /// The pre-refactor reference walk: recompute coordinates, enumerate
    /// the {-1,0,1}^m block, binary-search each member cell. What the CSR
    /// rows must be bit-equivalent to.
    fn reference_candidates(g: &GridIndex, p: &[f32]) -> Vec<u32> {
        let base: Vec<u64> = (0..g.m).map(|j| g.coord_of(p[j], j)).collect();
        let mut offs = vec![0i64; g.m];
        let mut out = Vec::new();
        walk_block(&base, &g.widths, &mut offs, |id| {
            if let Ok(nr) = g.cell_ids.binary_search(&id) {
                let (s, e) = g.ranges[nr];
                out.extend_from_slice(&g.point_ids[s as usize..e as usize]);
            }
        });
        out
    }

    #[test]
    fn every_point_indexed_exactly_once() {
        prop::cases(25, 0x6121D, |rng| {
            let n = 50 + rng.below(200);
            let dims = 2 + rng.below(6);
            let d = random_dataset(rng, n, dims, 5.0);
            let m = 1 + rng.below(d.dims());
            let g = GridIndex::build(&d, m, 0.5 + rng.f64() * 3.0);
            let mut seen = vec![0usize; d.len()];
            let total: usize = g.cell_sizes().map(|(_, s)| s).sum();
            assert_eq!(total, d.len());
            for (id, _) in g.cell_sizes() {
                for &p in g.cell_points(id) {
                    seen[p as usize] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1));
        });
    }

    #[test]
    fn adjacent_walk_superset_of_eps_ball_in_indexed_dims() {
        // Completeness invariant: every point within eps of the query *in
        // the indexed m-dim projection* must be found by the walk.
        prop::cases(20, 0xAD7A, |rng| {
            let n = 100 + rng.below(150);
            let dims = 2 + rng.below(4);
            let d = random_dataset(rng, n, dims, 3.0);
            let m = 1 + rng.below(d.dims());
            let eps = 0.8 + rng.f64() * 2.0;
            let g = GridIndex::build(&d, m, eps);
            for _ in 0..5 {
                let q = rng.below(d.len());
                let cands: std::collections::HashSet<u32> =
                    g.candidates_of(d.point(q)).into_iter().collect();
                for i in 0..d.len() {
                    let dm = sqdist_prefix(d.point(q), d.point(i), m);
                    if dm <= eps * eps {
                        assert!(
                            cands.contains(&(i as u32)),
                            "point {i} within eps of {q} missed by grid walk"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn grid_range_query_equals_bruteforce() {
        // end-to-end range query: walk + full-dim filter == brute force
        prop::cases(15, 0x5E1F, |rng| {
            let dims = 2 + rng.below(3);
            let d = random_dataset(rng, 120, dims, 2.0);
            let eps = 0.5 + rng.f64() * 1.5;
            let g = GridIndex::build(&d, d.dims(), eps);
            let q = rng.below(d.len());
            let mut got: Vec<u32> = g
                .candidates_of(d.point(q))
                .into_iter()
                .filter(|&i| sqdist(d.point(q), d.point(i as usize)) <= eps * eps)
                .collect();
            got.sort_unstable();
            let mut want: Vec<u32> = (0..d.len() as u32)
                .filter(|&i| sqdist(d.point(q), d.point(i as usize)) <= eps * eps)
                .collect();
            want.sort_unstable();
            assert_eq!(got, want);
        });
    }

    #[test]
    fn csr_walk_bit_equivalent_to_reference_walk() {
        // The tentpole invariant: the precomputed CSR rows reproduce the
        // recompute walk exactly - same candidate multiset, same order -
        // for every point, across random data shapes, m and eps.
        prop::cases(20, 0xC5A9, |rng| {
            let n = 80 + rng.below(250);
            let dims = 2 + rng.below(5);
            let d = random_dataset(rng, n, dims, 2.0 + rng.f64() * 4.0);
            let m = 1 + rng.below(d.dims());
            let g = GridIndex::build(&d, m, 0.4 + rng.f64() * 2.5);
            for i in 0..d.len() {
                let want = reference_candidates(&g, d.point(i));
                assert_eq!(
                    g.candidates_of(d.point(i)),
                    want,
                    "coordinate-keyed walk, point {i}"
                );
                let mut got = Vec::new();
                g.candidates_into_id(i as u32, &mut got);
                assert_eq!(got, want, "id-keyed walk, point {i}");
            }
        });
    }

    #[test]
    fn id_keyed_lookups_match_coordinate_keyed_over_every_point() {
        // O(1) array reads vs recompute: identical for every indexed point.
        prop::cases(15, 0x01DA, |rng| {
            let n = 100 + rng.below(300);
            let dims = 2 + rng.below(5);
            let d = random_dataset(rng, n, dims, 3.0);
            let m = 1 + rng.below(d.dims());
            let g = GridIndex::build(&d, m, 0.5 + rng.f64() * 2.0);
            for i in 0..d.len() {
                let p = d.point(i);
                let rank = g.cell_rank_of_point(p).expect("own cell non-empty");
                assert_eq!(g.cell_rank_of(i as u32), rank);
                assert_eq!(g.cell_id_of_id(i as u32), g.cell_id_of(p));
                assert_eq!(g.cell_population_of_id(i as u32), g.cell_population(p));
                assert_eq!(
                    g.adjacent_population_of_id(i as u32),
                    g.adjacent_population(p)
                );
                assert!(g.rank_points(rank).contains(&(i as u32)));
            }
        });
    }

    #[test]
    fn memoized_adjacent_population_matches_csr_rows() {
        let d = chist_like(900).generate(17);
        let g = GridIndex::build(&d, 6, 1.5);
        for rank in 0..g.non_empty_cells() {
            let from_rows: usize = g
                .adjacent_ranks(rank)
                .iter()
                .map(|&nr| g.rank_population(nr as usize))
                .sum();
            assert_eq!(g.adjacent_population_of_rank(rank), from_rows);
            // a cell is always its own neighbor
            assert!(g.adjacent_ranks(rank).contains(&(rank as u32)));
        }
    }

    #[test]
    fn adjacent_population_matches_candidate_list() {
        let d = susy_like(600).generate(12);
        let g = GridIndex::build(&d, 6, 2.0);
        for i in (0..d.len()).step_by(41) {
            assert_eq!(
                g.adjacent_population(d.point(i)),
                g.candidates_of(d.point(i)).len()
            );
        }
    }

    #[test]
    fn cell_population_matches_cell_points() {
        let d = susy_like(500).generate(11);
        let g = GridIndex::build(&d, 6, 2.0);
        for i in (0..d.len()).step_by(37) {
            let pop = g.cell_population(d.point(i));
            let id = g.cell_id_of(d.point(i));
            assert_eq!(pop, g.cell_points(id).len());
            assert!(pop >= 1, "own cell contains the point itself");
        }
    }

    #[test]
    fn out_of_range_points_get_superset_candidates_and_injective_ids() {
        // The bipartite R side: query points outside the grid extent.
        // Clamped coordinates must (a) never collide two distinct cells
        // into one id - the wrapping_mul hazard - and (b) keep the walk a
        // superset of the true in-eps neighbors in the indexed projection.
        prop::cases(15, 0x0FFB, |rng| {
            let s = random_dataset(rng, 150 + rng.below(150), 3, 2.0);
            let m = 1 + rng.below(3);
            let eps = 0.6 + rng.f64() * 1.5;
            let g = GridIndex::build(&s, m, eps);
            // R points on a much wilder extent, both sides of the S box
            let r = random_dataset(rng, 60, 3, 25.0);
            let mut by_id: std::collections::HashMap<u64, Vec<u32>> =
                std::collections::HashMap::new();
            for q in 0..r.len() {
                let p = r.point(q);
                by_id.entry(g.cell_id_of(p)).or_default().push(q as u32);
                let cands: std::collections::HashSet<u32> =
                    g.candidates_of(p).into_iter().collect();
                for i in 0..s.len() {
                    if sqdist_prefix(p, s.point(i), m) <= eps * eps {
                        assert!(
                            cands.contains(&(i as u32)),
                            "R point {q}: S neighbor {i} missed"
                        );
                    }
                }
            }
            // queries sharing a cell id must share the exact candidate
            // list - the contract the join's cell grouping relies on
            for qs in by_id.values() {
                let first = g.candidates_of(r.point(qs[0] as usize));
                for &q in &qs[1..] {
                    assert_eq!(
                        g.candidates_of(r.point(q as usize)),
                        first,
                        "cell-id collision broke candidate sharing"
                    );
                }
            }
        });
    }

    #[test]
    fn cached_query_key_matches_coordinate_path() {
        // Carried item (n): the R-side rank cache must reproduce the
        // coordinate-keyed path exactly - same cell ids, same ranks,
        // same candidate lists, same population estimates - including
        // for R points far outside the S extent (empty clamped cells).
        prop::cases(15, 0xCAC8E, |rng| {
            let s = random_dataset(rng, 120 + rng.below(200), 4, 2.0);
            let m = 1 + rng.below(4);
            let g = GridIndex::build(&s, m, 0.5 + rng.f64() * 2.0);
            let r = random_dataset(rng, 80, 4, 1.0 + rng.f64() * 20.0);
            let cache = g.build_query_ranks(&r);
            assert_eq!(cache.len(), r.len());
            let (mut got, mut want) = (Vec::new(), Vec::new());
            for q in 0..r.len() as u32 {
                let (ck, xk) = (QueryKey::Cached(&cache), QueryKey::Coords);
                assert_eq!(
                    g.query_cell_id_keyed(ck, &r, q),
                    g.query_cell_id_keyed(xk, &r, q),
                    "cell id diverged for query {q}"
                );
                assert_eq!(
                    g.query_rank_keyed(ck, &r, q),
                    g.query_rank_keyed(xk, &r, q),
                    "rank diverged for query {q}"
                );
                assert_eq!(
                    g.query_adjacent_population_keyed(ck, &r, q),
                    g.query_adjacent_population_keyed(xk, &r, q),
                    "population diverged for query {q}"
                );
                g.query_candidates_into_keyed(ck, &r, q, &mut got);
                g.query_candidates_into_keyed(xk, &r, q, &mut want);
                assert_eq!(got, want, "candidate list diverged for query {q}");
            }
            // native self-join queries agree with the cache built over
            // the grid's own dataset
            let own = g.build_query_ranks(&s);
            for q in (0..s.len() as u32).step_by(17) {
                assert_eq!(
                    g.query_cell_id_keyed(QueryKey::Native, &s, q),
                    g.query_cell_id_keyed(QueryKey::Cached(&own), &s, q)
                );
                assert_eq!(
                    g.query_rank_keyed(QueryKey::Native, &s, q),
                    g.query_rank_keyed(QueryKey::Cached(&own), &s, q)
                );
            }
        });
    }

    #[test]
    fn overflowing_extents_degrade_m_with_completeness_kept() {
        // Adversarial extents: 4 dims x ~2^40 cells each would need a
        // 2^160 id space. Build must degrade m (not wrap ids) and the
        // degraded grid must still satisfy the superset invariant over
        // its *own* (reduced) projection.
        let mut rng = Rng::new(0xDE64);
        let rows: Vec<Vec<f32>> = (0..120)
            .map(|_| {
                (0..4)
                    .map(|_| (rng.f64() * 1.0e6) as f32)
                    .collect::<Vec<f32>>()
            })
            .collect();
        let d = Dataset::from_rows(&rows);
        let eps = 1.0e-6; // ~1e12 cells per dim
        let g = GridIndex::build(&d, 4, eps);
        assert!(g.m < 4, "m must degrade, got m={}", g.m);
        assert_eq!(g.m, 1, "only a single ~2^40 dim fits u64");
        // index is still consistent over the degraded projection
        let total: usize = g.cell_sizes().map(|(_, s)| s).sum();
        assert_eq!(total, d.len());
        for i in (0..d.len()).step_by(13) {
            let cands: std::collections::HashSet<u32> =
                g.candidates_of(d.point(i)).into_iter().collect();
            for j in 0..d.len() {
                if sqdist_prefix(d.point(i), d.point(j), g.m) <= eps * eps {
                    assert!(cands.contains(&(j as u32)));
                }
            }
        }

        // benign extents must NOT degrade
        let d2 = susy_like(200).generate(3);
        let g2 = GridIndex::build(&d2, 6, 2.0);
        assert_eq!(g2.m, 6);
    }

    #[test]
    fn patched_grid_identical_to_rebuild_under_churn() {
        // The tentpole invariant: after ANY interleaving of inserts
        // (incl. cell births and far-out-of-extent clamped points) and
        // removes (incl. cell deaths), every array of the patched grid
        // is identical to a frozen-geometry rebuild over the live set.
        prop::cases(12, 0xC0_54A7, |rng| {
            let n0 = 40 + rng.below(120);
            let dims = 2 + rng.below(4);
            let mut d = random_dataset(rng, n0, dims, 3.0);
            let m = 1 + rng.below(dims);
            let mut g = GridIndex::build(&d, m, 0.5 + rng.f64() * 2.0);
            let mut live: Vec<u32> = (0..n0 as u32).collect();
            let mut mutations = 0u64;
            for step in 0..60 {
                if live.is_empty() || rng.below(5) < 3 {
                    // occasionally far outside the frozen box, to
                    // exercise boundary-cell clamping
                    let scale = if rng.below(4) == 0 { 40.0 } else { 3.0 };
                    let row: Vec<f32> =
                        (0..dims).map(|_| rng.normal(0.0, scale) as f32).collect();
                    let id = d.push_row(&row);
                    g.insert(&d, id);
                    live.push(id);
                } else {
                    let id = live.swap_remove(rng.below(live.len()));
                    assert!(g.remove(id));
                    assert!(!g.remove(id), "double remove must be a no-op");
                }
                mutations += 1;
                assert_eq!(g.epoch(), mutations);
                if step % 9 == 0 {
                    g.assert_same_layout(&g.rebuilt(&d));
                }
            }
            assert_eq!(g.indexed_points(), live.len());
            let mut sorted = live.clone();
            sorted.sort_unstable();
            assert_eq!(g.indexed_ids(), sorted);
            g.assert_same_layout(&g.rebuilt(&d));
        });
    }

    #[test]
    fn dirty_threshold_rebuild_is_canonical_noop() {
        let d = susy_like(300).generate(9);
        let mut g = GridIndex::build(&d, 4, 2.0);
        g.set_rebuild_frac(0.05);
        let mut fired = false;
        for id in 0..40u32 {
            assert!(g.remove(id));
            let before = g.clone();
            if g.maybe_rebuild(&d) {
                fired = true;
                before.assert_same_layout(&g);
                assert_eq!(g.epoch(), before.epoch(), "re-sort must not move the epoch");
                assert_eq!(g.dirty, 0, "re-sort must clear the splice debt");
            }
        }
        assert!(fired, "threshold must trip well before 40 removals of 300");
    }

    #[test]
    fn drain_and_refill_through_empty() {
        // remove every point (through the last cell death), then
        // re-insert: the patched grid must come back canonical.
        let mut d = random_dataset(&mut Rng::new(0xE1_77), 30, 3, 2.0);
        let mut g = GridIndex::build(&d, 3, 1.0);
        for id in 0..30u32 {
            assert!(g.remove(id));
        }
        assert_eq!(g.non_empty_cells(), 0);
        assert_eq!(g.indexed_points(), 0);
        g.assert_same_layout(&g.rebuilt(&d));
        for id in 0..30u32 {
            g.insert(&d, id);
        }
        let fresh = d.push_row(&[9.0, -9.0, 9.0]);
        g.insert(&d, fresh);
        g.assert_same_layout(&g.rebuilt(&d));
        assert_eq!(g.indexed_points(), 31);
    }

    #[test]
    fn rank_cache_epoch_stamps_staleness() {
        let mut d = susy_like(200).generate(5);
        let mut g = GridIndex::build(&d, 4, 2.0);
        let r = susy_like(40).generate(6);
        let cache = g.build_query_ranks(&r);
        assert_eq!(cache.epoch(), g.epoch());
        let id = d.push_row(&d.point(0).to_vec());
        g.insert(&d, id);
        assert_ne!(cache.epoch(), g.epoch(), "mutation must outdate the cache");
        assert_eq!(g.build_query_ranks(&r).epoch(), g.epoch());
    }

    #[test]
    fn space_linear_in_points() {
        let d = chist_like(2000).generate(4);
        let g = GridIndex::build(&d, 6, 1.0);
        assert!(g.non_empty_cells() <= d.len());
        let total: usize = g.cell_sizes().map(|(_, s)| s).sum();
        assert_eq!(total, d.len());
        // CSR rows are clipped to non-empty cells: never wider than the
        // full 3^m block or the cell inventory
        let cap = 3usize.pow(g.m as u32).min(g.non_empty_cells());
        for rank in 0..g.non_empty_cells() {
            assert!(g.adjacent_ranks(rank).len() <= cap);
        }
    }

    #[test]
    fn empty_and_single_point_datasets() {
        let d1 = Dataset::new(vec![1.0, 2.0], 2);
        let g = GridIndex::build(&d1, 2, 1.0);
        assert_eq!(g.non_empty_cells(), 1);
        assert_eq!(g.candidates_of(d1.point(0)), vec![0]);
        assert_eq!(g.cell_rank_of(0), 0);
        assert_eq!(g.adjacent_population_of_id(0), 1);

        let d0 = Dataset::new(Vec::new(), 2);
        let g0 = GridIndex::build(&d0, 2, 1.0);
        assert_eq!(g0.non_empty_cells(), 0);
        assert!(g0.candidates_of(&[0.5, 0.5]).is_empty());
    }

    #[test]
    fn out_of_extent_accounting_tracks_churn() {
        // the drift inventory is a pure function of (live set, frozen
        // geometry): inserts mark out-of-extent points, removes unmark,
        // in-extent churn never touches it - and the patched inventory
        // matches the rebuild oracle's at every step
        let mut d = susy_like(50).generate(0xD81);
        let mut g = GridIndex::build(&d, 4, 2.0);
        assert_eq!(g.out_of_extent_fraction(), 0.0);
        let twin = d.push_row(&d.point(3).to_vec());
        g.insert(&d, twin);
        assert_eq!(g.out_of_extent_fraction(), 0.0, "in-extent insert");
        let far = d.push_row(&vec![1.0e6f32; d.dims()]);
        g.insert(&d, far);
        assert!(g.out_ids.contains(&far));
        assert_eq!(g.out_of_extent_fraction(), 1.0 / 52.0);
        g.assert_same_layout(&g.rebuilt(&d));
        assert!(g.remove(far));
        assert_eq!(g.out_of_extent_fraction(), 0.0, "remove unmarks");
        g.assert_same_layout(&g.rebuilt(&d));
    }

    #[test]
    fn drift_refresh_rederives_geometry() {
        // satellite (a): a corpus walking out of the build extent piles
        // into boundary cells; once >drift_frac of live points are
        // outside, maybe_rebuild re-derives the origin/widths over the
        // live set, bumps the epoch exactly once and clears the drift
        let mut rng = Rng::new(0xD81F7);
        let mut d = random_dataset(&mut rng, 100, 3, 2.0);
        let mut g = GridIndex::build(&d, 3, 1.0);
        assert_eq!(g.out_of_extent_fraction(), 0.0);
        let mut steps = 0u32;
        while g.out_of_extent_fraction() <= 0.2 {
            steps += 1;
            assert!(steps <= 100, "drift fraction must accumulate");
            let x = 20.0 + steps as f32;
            let id = d.push_row(&[x, x, x]);
            g.insert(&d, id);
            assert!(g.remove(steps - 1), "retire one in-extent original");
        }
        let epoch_before = g.epoch();
        let widths_before = g.widths.clone();
        assert!(g.maybe_rebuild(&d), "drift must trip the refresh");
        assert_eq!(
            g.epoch(),
            epoch_before + 1,
            "geometry move = one epoch bump"
        );
        assert!(
            g.widths[0] > widths_before[0],
            "widths re-derived to cover the drifted extent \
             (before {}, after {})",
            widths_before[0],
            g.widths[0]
        );
        assert_eq!(
            g.out_of_extent_fraction(),
            0.0,
            "the refreshed extent covers the live set"
        );
        // the refreshed grid is canonical over its new geometry, and
        // the walk is still a complete eps-ball superset
        g.assert_same_layout(&g.rebuilt(&d));
        let live = g.indexed_ids();
        for &q in live.iter().step_by(7) {
            let cands: std::collections::HashSet<u32> =
                g.candidates_of(d.point(q as usize)).into_iter().collect();
            for &i in &live {
                let dm = sqdist_prefix(d.point(q as usize), d.point(i as usize), g.m);
                if dm <= g.eps * g.eps {
                    assert!(
                        cands.contains(&i),
                        "post-refresh walk missed neighbor {i} of {q}"
                    );
                }
            }
        }
        // a second check right away is a no-op: no drift, no debt
        assert!(!g.maybe_rebuild(&d));
    }

    #[test]
    fn on_demand_budget_walks_match_csr() {
        // carried item (o): a zero byte budget forces on-demand
        // adjacency; candidate lists, visit order and memoized
        // populations must be identical to the materialised-CSR build
        prop::cases(8, 0xB5D6E7, |rng| {
            let n = 80 + rng.below(150);
            let dims = 2 + rng.below(4);
            let d = random_dataset(rng, n, dims, 3.0);
            let m = 1 + rng.below(dims);
            let eps = 0.5 + rng.f64() * 2.0;
            let full = GridIndex::build(&d, m, eps);
            let lean = GridIndex::build_with_budget(&d, m, eps, 0);
            assert!(!full.adj_is_on_demand());
            assert!(lean.adj_is_on_demand());
            assert_eq!(lean.adj_table_entries(), 0, "no rows materialised");
            let mut buf = Vec::new();
            for i in 0..d.len() as u32 {
                assert_eq!(
                    lean.candidates_of(d.point(i as usize)),
                    full.candidates_of(d.point(i as usize)),
                    "coordinate-keyed candidates, point {i}"
                );
                lean.candidates_into_id(i, &mut buf);
                let mut visited = Vec::new();
                lean.visit_adjacent_of_id(i, |ids| visited.extend_from_slice(ids));
                assert_eq!(buf, visited, "walk order, point {i}");
                assert_eq!(
                    lean.adjacent_population_of_id(i),
                    full.adjacent_population_of_id(i),
                    "memoized population, point {i}"
                );
            }
        });
    }

    #[test]
    fn on_demand_churn_flips_modes_at_the_budget_boundary() {
        // births past the budget boundary must flip CSR -> on-demand
        // and deaths back under it must flip home, both landing on the
        // exact canonical form of the rebuild oracle
        prop::cases(6, 0xB5DF11, |rng| {
            let n0 = 30 + rng.below(60);
            let dims = 2 + rng.below(3);
            let mut d = random_dataset(rng, n0, dims, 3.0);
            let m = 1 + rng.below(dims);
            let eps = 0.5 + rng.f64() * 1.5;
            let probe = GridIndex::build(&d, m, eps);
            // budget that fits exactly the build-time cell count: the
            // first net birth crosses it
            let budget = (probe.non_empty_cells() as u64
                * 3u64.pow(m as u32)
                * std::mem::size_of::<u32>() as u64) as usize;
            let mut g = GridIndex::build_with_budget(&d, m, eps, budget);
            assert!(!g.adj_is_on_demand());
            let mut inserted = Vec::new();
            // scattered inserts until one is a cell birth that crosses
            // the budget (bounded: births are near-certain at this
            // spread, but placement is random)
            while !g.adj_is_on_demand() {
                assert!(
                    inserted.len() < 200,
                    "scattered inserts over {} cells never crossed the budget",
                    probe.non_empty_cells()
                );
                let row: Vec<f32> =
                    (0..dims).map(|_| rng.normal(0.0, 30.0) as f32).collect();
                let id = d.push_row(&row);
                g.insert(&d, id);
                inserted.push(id);
            }
            g.assert_same_layout(&g.rebuilt(&d));
            // steady-state on-demand churn: more births and same-cell
            // twins, all landing canonical
            for k in 0..6 {
                let row: Vec<f32> = if k % 2 == 0 {
                    (0..dims).map(|_| rng.normal(0.0, 30.0) as f32).collect()
                } else {
                    d.point(inserted[0] as usize).to_vec()
                };
                let id = d.push_row(&row);
                g.insert(&d, id);
                inserted.push(id);
                assert!(g.adj_is_on_demand());
            }
            g.assert_same_layout(&g.rebuilt(&d));
            for id in inserted.into_iter().rev() {
                assert!(g.remove(id));
            }
            assert!(
                !g.adj_is_on_demand(),
                "back under the boundary must flip home to CSR"
            );
            g.assert_same_layout(&g.rebuilt(&d));
            // and the lean walks stayed semantically intact throughout
            for i in (0..n0).step_by(9) {
                assert_eq!(
                    g.candidates_of(d.point(i)),
                    reference_candidates(&g, d.point(i)),
                    "post-churn walk, point {i}"
                );
            }
        });
    }
}
