//! The GPU-JOIN grid index (paper Sec. IV-A).
//!
//! A grid of cell length ε over the first m ≤ n (REORDERed) dimensions.
//! Only *non-empty* cells are materialised: sorted linearised ids in `B`
//! (binary-searched during the walk), per-cell [min,max) ranges in `G`
//! into the point lookup array `A` of point ids. Space O(|D|), matching
//! the paper's requirement that the index be a small fraction of device
//! memory.
//!
//! A range query walks the 3^m adjacent-cell block of the query's cell
//! (step (ii)-(vi) of the paper's search procedure) and hands candidate id
//! ranges to the caller - the caller (gpu::join) does the distance work on
//! the "device".

use crate::core::Dataset;

/// Non-empty-cell grid over the first `m` dims.
#[derive(Debug, Clone)]
pub struct GridIndex {
    /// cell edge length (= ε of the join)
    pub eps: f64,
    /// number of indexed dims m ≤ n
    pub m: usize,
    /// minimum coordinate per indexed dim (grid origin)
    mins: Vec<f64>,
    /// number of cells along each indexed dim
    widths: Vec<u64>,
    /// sorted linearised ids of non-empty cells (the paper's B)
    cell_ids: Vec<u64>,
    /// per non-empty cell: [start, end) into `point_ids` (the paper's G)
    ranges: Vec<(u32, u32)>,
    /// point ids grouped by cell (the paper's A)
    point_ids: Vec<u32>,
}

impl GridIndex {
    /// Build the index. `m` is clamped to the dataset dimensionality;
    /// `eps` must be positive and finite.
    pub fn build(d: &Dataset, m: usize, eps: f64) -> GridIndex {
        assert!(eps.is_finite() && eps > 0.0, "bad eps {eps}");
        let m = m.clamp(1, d.dims());
        let n = d.len();

        let mut mins = vec![f64::INFINITY; m];
        let mut maxs = vec![f64::NEG_INFINITY; m];
        for i in 0..n {
            let p = d.point(i);
            for j in 0..m {
                let x = p[j] as f64;
                if x < mins[j] {
                    mins[j] = x;
                }
                if x > maxs[j] {
                    maxs[j] = x;
                }
            }
        }
        if n == 0 {
            mins.iter_mut().for_each(|x| *x = 0.0);
            maxs.iter_mut().for_each(|x| *x = 0.0);
        }
        let widths: Vec<u64> = (0..m)
            .map(|j| (((maxs[j] - mins[j]) / eps).floor() as u64 + 1).max(1))
            .collect();

        // (cell id, point id) pairs, sorted by cell -> B/G/A arrays.
        let mut pairs: Vec<(u64, u32)> = (0..n)
            .map(|i| {
                let cell = Self::linearise_coords(
                    &Self::cell_coords_of(d.point(i), &mins, eps, m),
                    &widths,
                );
                (cell, i as u32)
            })
            .collect();
        pairs.sort_unstable();

        let mut cell_ids = Vec::new();
        let mut ranges: Vec<(u32, u32)> = Vec::new();
        let mut point_ids = Vec::with_capacity(n);
        for (cell, pid) in pairs {
            if cell_ids.last() != Some(&cell) {
                cell_ids.push(cell);
                let s = point_ids.len() as u32;
                ranges.push((s, s));
            }
            point_ids.push(pid);
            ranges.last_mut().unwrap().1 += 1;
        }

        GridIndex { eps, m, mins, widths, cell_ids, ranges, point_ids }
    }

    #[inline]
    fn cell_coords_of(p: &[f32], mins: &[f64], eps: f64, m: usize) -> Vec<u64> {
        (0..m)
            .map(|j| (((p[j] as f64 - mins[j]) / eps).floor().max(0.0)) as u64)
            .collect()
    }

    #[inline]
    fn linearise_coords(coords: &[u64], widths: &[u64]) -> u64 {
        // row-major linearisation; widths are small enough in practice
        // (m <= 6 indexed dims) that this cannot overflow u64 for real data
        let mut id = 0u64;
        for (c, w) in coords.iter().zip(widths) {
            id = id.wrapping_mul(*w).wrapping_add(*c);
        }
        id
    }

    /// Cell coordinates of a point.
    pub fn cell_of(&self, p: &[f32]) -> Vec<u64> {
        Self::cell_coords_of(p, &self.mins, self.eps, self.m)
    }

    /// Number of points in the cell containing `p` (0 if cell is empty).
    /// This is the |C| of the splitter predicate (paper Sec. V-D).
    pub fn cell_population(&self, p: &[f32]) -> usize {
        let id = Self::linearise_coords(&self.cell_of(p), &self.widths);
        match self.cell_ids.binary_search(&id) {
            Ok(pos) => {
                let (s, e) = self.ranges[pos];
                (e - s) as usize
            }
            Err(_) => 0,
        }
    }

    /// Number of non-empty cells.
    pub fn non_empty_cells(&self) -> usize {
        self.cell_ids.len()
    }

    /// Population of every non-empty cell alongside its id
    /// (used by the ρ reassignment which drains the sparsest cells).
    pub fn cell_sizes(&self) -> impl Iterator<Item = (u64, usize)> + '_ {
        self.cell_ids
            .iter()
            .zip(&self.ranges)
            .map(|(&id, &(s, e))| (id, (e - s) as usize))
    }

    /// Point ids in a given (linearised) cell.
    pub fn cell_points(&self, cell_id: u64) -> &[u32] {
        match self.cell_ids.binary_search(&cell_id) {
            Ok(pos) => {
                let (s, e) = self.ranges[pos];
                &self.point_ids[s as usize..e as usize]
            }
            Err(_) => &[],
        }
    }

    /// Linearised cell id of a point.
    pub fn cell_id_of(&self, p: &[f32]) -> u64 {
        Self::linearise_coords(&self.cell_of(p), &self.widths)
    }

    /// Walk the adjacent-cell block of `p` (3^m neighborhood clipped to the
    /// grid), invoking `visit` with each non-empty cell's point ids. This
    /// is steps (ii)-(iv) of the paper's range query: the linearised id of
    /// each adjacent cell is binary-searched in B; non-empty hits yield
    /// their A-ranges.
    pub fn visit_adjacent(&self, p: &[f32], mut visit: impl FnMut(&[u32])) {
        let base = self.cell_of(p);
        // iterate the mixed-radix neighborhood {-1,0,1}^m
        let m = self.m;
        let mut offs = vec![-1i64; m];
        'outer: loop {
            // compute candidate cell coords, skip out-of-range
            let mut coords = Vec::with_capacity(m);
            let mut ok = true;
            for j in 0..m {
                let c = base[j] as i64 + offs[j];
                if c < 0 || c >= self.widths[j] as i64 {
                    ok = false;
                    break;
                }
                coords.push(c as u64);
            }
            if ok {
                let id = Self::linearise_coords(&coords, &self.widths);
                if let Ok(pos) = self.cell_ids.binary_search(&id) {
                    let (s, e) = self.ranges[pos];
                    visit(&self.point_ids[s as usize..e as usize]);
                }
            }
            // increment mixed-radix counter over {-1,0,1}
            for j in (0..m).rev() {
                if offs[j] < 1 {
                    offs[j] += 1;
                    continue 'outer;
                }
                offs[j] = -1;
            }
            break;
        }
    }

    /// All candidate ids within the adjacent block of `p` (allocating
    /// convenience wrapper over `visit_adjacent`).
    pub fn candidates_of(&self, p: &[f32]) -> Vec<u32> {
        let mut out = Vec::new();
        self.visit_adjacent(p, |ids| out.extend_from_slice(ids));
        out
    }

    /// Number of candidates the adjacent-block walk of `p` would scan -
    /// the per-query work estimate of the Sec. V-B batch estimator,
    /// computed without materialising the candidate list. This is what
    /// the density-ordered work queue (`sched`) uses to price each cell.
    pub fn adjacent_population(&self, p: &[f32]) -> usize {
        let mut n = 0usize;
        self.visit_adjacent(p, |ids| n += ids.len());
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{sqdist, sqdist_prefix};
    use crate::data::synthetic::{chist_like, susy_like};
    use crate::util::{prop, rng::Rng};

    fn random_dataset(rng: &mut Rng, n: usize, dims: usize, scale: f64) -> Dataset {
        let data: Vec<f32> = (0..n * dims)
            .map(|_| rng.normal(0.0, scale) as f32)
            .collect();
        Dataset::new(data, dims)
    }

    #[test]
    fn every_point_indexed_exactly_once() {
        prop::cases(25, 0x6121D, |rng| {
            let n = 50 + rng.below(200);
            let dims = 2 + rng.below(6);
            let d = random_dataset(rng, n, dims, 5.0);
            let m = 1 + rng.below(d.dims());
            let g = GridIndex::build(&d, m, 0.5 + rng.f64() * 3.0);
            let mut seen = vec![0usize; d.len()];
            let total: usize = g.cell_sizes().map(|(_, s)| s).sum();
            assert_eq!(total, d.len());
            for (id, _) in g.cell_sizes() {
                for &p in g.cell_points(id) {
                    seen[p as usize] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1));
        });
    }

    #[test]
    fn adjacent_walk_superset_of_eps_ball_in_indexed_dims() {
        // Completeness invariant: every point within eps of the query *in
        // the indexed m-dim projection* must be found by the walk.
        prop::cases(20, 0xAD7A, |rng| {
            let n = 100 + rng.below(150);
            let dims = 2 + rng.below(4);
            let d = random_dataset(rng, n, dims, 3.0);
            let m = 1 + rng.below(d.dims());
            let eps = 0.8 + rng.f64() * 2.0;
            let g = GridIndex::build(&d, m, eps);
            for _ in 0..5 {
                let q = rng.below(d.len());
                let cands: std::collections::HashSet<u32> =
                    g.candidates_of(d.point(q)).into_iter().collect();
                for i in 0..d.len() {
                    let dm = sqdist_prefix(d.point(q), d.point(i), m);
                    if dm <= eps * eps {
                        assert!(
                            cands.contains(&(i as u32)),
                            "point {i} within eps of {q} missed by grid walk"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn grid_range_query_equals_bruteforce() {
        // end-to-end range query: walk + full-dim filter == brute force
        prop::cases(15, 0x5E1F, |rng| {
            let dims = 2 + rng.below(3);
            let d = random_dataset(rng, 120, dims, 2.0);
            let eps = 0.5 + rng.f64() * 1.5;
            let g = GridIndex::build(&d, d.dims(), eps);
            let q = rng.below(d.len());
            let mut got: Vec<u32> = g
                .candidates_of(d.point(q))
                .into_iter()
                .filter(|&i| sqdist(d.point(q), d.point(i as usize)) <= eps * eps)
                .collect();
            got.sort_unstable();
            let mut want: Vec<u32> = (0..d.len() as u32)
                .filter(|&i| sqdist(d.point(q), d.point(i as usize)) <= eps * eps)
                .collect();
            want.sort_unstable();
            assert_eq!(got, want);
        });
    }

    #[test]
    fn adjacent_population_matches_candidate_list() {
        let d = susy_like(600).generate(12);
        let g = GridIndex::build(&d, 6, 2.0);
        for i in (0..d.len()).step_by(41) {
            assert_eq!(
                g.adjacent_population(d.point(i)),
                g.candidates_of(d.point(i)).len()
            );
        }
    }

    #[test]
    fn cell_population_matches_cell_points() {
        let d = susy_like(500).generate(11);
        let g = GridIndex::build(&d, 6, 2.0);
        for i in (0..d.len()).step_by(37) {
            let pop = g.cell_population(d.point(i));
            let id = g.cell_id_of(d.point(i));
            assert_eq!(pop, g.cell_points(id).len());
            assert!(pop >= 1, "own cell contains the point itself");
        }
    }

    #[test]
    fn space_linear_in_points() {
        let d = chist_like(2000).generate(4);
        let g = GridIndex::build(&d, 6, 1.0);
        assert!(g.non_empty_cells() <= d.len());
        let total: usize = g.cell_sizes().map(|(_, s)| s).sum();
        assert_eq!(total, d.len());
    }

    #[test]
    fn empty_and_single_point_datasets() {
        let d1 = Dataset::new(vec![1.0, 2.0], 2);
        let g = GridIndex::build(&d1, 2, 1.0);
        assert_eq!(g.non_empty_cells(), 1);
        assert_eq!(g.candidates_of(d1.point(0)), vec![0]);

        let d0 = Dataset::new(Vec::new(), 2);
        let g0 = GridIndex::build(&d0, 2, 1.0);
        assert_eq!(g0.non_empty_cells(), 0);
    }
}
