//! Spatial indexes: the data-oblivious ε-grid used by GPU-JOIN (paper
//! Sec. IV-A) and the data-aware kd-tree used by EXACT-ANN (the CPU side).

pub mod grid;
pub mod kdtree;

pub use grid::GridIndex;
pub use kdtree::{KdTree, KnnScratch};
