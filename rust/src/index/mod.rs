//! Spatial indexes: the data-oblivious ε-grid used by GPU-JOIN (paper
//! Sec. IV-A) and the data-aware kd-tree used by EXACT-ANN (the CPU side).

/// The non-hierarchical ε-grid over m indexed dims (Sec. IV-A/C).
pub mod grid;
/// Sliding-midpoint kd-tree, the EXACT-ANN substrate (Sec. V-B).
pub mod kdtree;

pub use grid::{GridIndex, QueryKey, QueryRankCache};
pub use kdtree::{KdTree, KnnScratch};
