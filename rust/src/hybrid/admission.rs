//! Admission control for the online service (DESIGN.md §13).
//!
//! PR 8's [`Ingress`](super::service::Ingress) had exactly one
//! backpressure mechanism: implicit pile-up. Every submitted request
//! was eventually served, and an offered load above the engine's
//! capacity grew the pending queue (and every client's latency)
//! without bound. This module adds the explicit admission layer the
//! ROADMAP names as direction 1's follow-up:
//!
//! * [`AdmissionPolicy`] - a bounded pending queue (global and
//!   per-client query caps), an optional default deadline, and a
//!   [`ShedPolicy`] choosing which queued requests die first when the
//!   serve loop must shed.
//! * [`ClientQuota`] / [`TokenBucket`] - per-client token-bucket rate
//!   limiting, so one aggressive client exhausts its own bucket
//!   instead of the shared queue.
//! * [`Rejected`] - the typed error every non-answered request
//!   receives, exactly once. Clients downcast it from the `anyhow`
//!   error chain ([`Client::query`](super::service::Client::query)
//!   keeps its signature) and read the `retry_after` hints for
//!   bounded backoff.
//! * [`CapacityController`] - an EWMA throughput estimate over flush
//!   telemetry that *tightens* the effective global bound while the
//!   engine is degraded (GPU demoted by the §9 recovery ladder, so
//!   the service is running on CPU-only throughput) and loosens it
//!   again on recovery.
//!
//! Everything here is host-side bookkeeping under the ingress mutex;
//! the shed *points* - where in the serve cycle a queued request may
//! be dropped - live in `service.rs` and are deliberately outside any
//! flush, so exactly-once accounting and replay-mode bit-identity are
//! untouched (DESIGN.md §13 gives the argument).

use std::fmt;
use std::time::{Duration, Instant};

/// Which queued query requests the serve loop sheds first when the
/// pending set exceeds the (possibly tightened) admission bound.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Shed the most recently enqueued query requests first (LIFO):
    /// the oldest waiters have accumulated the most queueing delay and
    /// keep their place, the newest absorb the overload.
    NewestFirst,
    /// Shed the requests with the *nearest* deadlines first - they are
    /// the least likely to be answered in time, so dropping them
    /// converts certain deadline misses into immediate typed
    /// rejections. Requests without a deadline are shed last (newest
    /// first among themselves).
    ByDeadline,
}

/// Per-client token-bucket quota: a sustained rate plus a burst
/// allowance, charged one token per query row at admission.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClientQuota {
    /// sustained refill rate, in queries per second
    pub rate_qps: f64,
    /// bucket capacity: how many queries a client may burst above the
    /// sustained rate (also the initial fill)
    pub burst: f64,
}

/// Admission policy for an [`Ingress`](super::service::Ingress).
///
/// The default is fully permissive - unbounded queue, no quota, no
/// deadline - which reproduces PR 8's implicit-pile-up behavior
/// exactly; every bound is opt-in.
#[derive(Debug, Clone)]
pub struct AdmissionPolicy {
    /// global bound on queued (admitted, not yet flushed) query rows;
    /// a submission that would exceed it is rejected with
    /// [`Rejected::Overloaded`]
    pub max_pending_queries: usize,
    /// per-client bound on queued query rows, limiting how much of the
    /// global queue one client can occupy
    pub max_pending_per_client: usize,
    /// deadline stamped on every query request that does not carry its
    /// own ([`Client::query_with_deadline`](super::service::Client::query_with_deadline));
    /// expired requests are shed before pricing
    pub default_deadline: Option<Duration>,
    /// which queued requests die first when the serve loop sheds
    pub shed_policy: ShedPolicy,
    /// per-client token-bucket quota (applies to query rows only;
    /// mutations are never rate-limited - they are corpus state, not
    /// load)
    pub quota: Option<ClientQuota>,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy {
            max_pending_queries: usize::MAX,
            max_pending_per_client: usize::MAX,
            default_deadline: None,
            shed_policy: ShedPolicy::NewestFirst,
            quota: None,
        }
    }
}

/// The typed rejection a non-answered request receives - exactly once,
/// either synchronously at admission (`Overloaded` at the bound,
/// `QuotaExceeded` from the token bucket, `Terminated` after the serve
/// loop exited) or asynchronously when the serve loop sheds a queued
/// request (`Overloaded` under a tightened bound, `DeadlineExpired`).
///
/// Carried through the `anyhow` chain so `Client::query` keeps its
/// `Result<BatchReply>` signature; recover it with
/// `err.downcast_ref::<Rejected>()`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Rejected {
    /// The global or per-client pending bound is full. The hint is the
    /// estimated time for the engine to drain the current backlog -
    /// the natural base interval for client-side backoff.
    Overloaded {
        /// suggested wait before retrying (backlog / service rate)
        retry_after_hint: Duration,
    },
    /// The client's token bucket is empty.
    QuotaExceeded {
        /// time until the bucket refills enough for this request
        retry_after: Duration,
    },
    /// The request's deadline passed while it was queued; it was shed
    /// before pricing, unserved.
    DeadlineExpired {
        /// how far past the deadline the shed happened
        missed_by: Duration,
    },
    /// The serve loop has terminated (normally or by error); no flush
    /// will ever answer this ingress again.
    Terminated,
}

impl fmt::Display for Rejected {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Rejected::Overloaded { retry_after_hint } => write!(
                f,
                "rejected: pending queue full (retry after ~{:.0} ms)",
                retry_after_hint.as_secs_f64() * 1e3
            ),
            Rejected::QuotaExceeded { retry_after } => write!(
                f,
                "rejected: client quota exhausted (retry after ~{:.0} ms)",
                retry_after.as_secs_f64() * 1e3
            ),
            Rejected::DeadlineExpired { missed_by } => write!(
                f,
                "shed: deadline expired {:.0} ms before pricing",
                missed_by.as_secs_f64() * 1e3
            ),
            Rejected::Terminated => {
                write!(f, "rejected: service has terminated")
            }
        }
    }
}

impl std::error::Error for Rejected {}

/// A standard token bucket: capacity `burst`, refilled continuously at
/// `rate_qps`, charged one token per query row.
#[derive(Debug, Clone)]
pub struct TokenBucket {
    tokens: f64,
    last: Instant,
    rate_qps: f64,
    burst: f64,
}

impl TokenBucket {
    /// A full bucket for `quota`, refilling from `now`.
    pub fn new(quota: &ClientQuota, now: Instant) -> TokenBucket {
        TokenBucket {
            tokens: quota.burst.max(0.0),
            last: now,
            rate_qps: quota.rate_qps.max(0.0),
            burst: quota.burst.max(0.0),
        }
    }

    /// Take `n` tokens at `now`, or report how long until the bucket
    /// will have refilled enough.
    pub fn try_take(&mut self, n: f64, now: Instant) -> Result<(), Duration> {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.tokens = (self.tokens + dt * self.rate_qps).min(self.burst);
        self.last = now;
        if self.tokens + 1e-9 >= n {
            self.tokens -= n;
            return Ok(());
        }
        let deficit = n - self.tokens;
        let secs = if self.rate_qps > 0.0 {
            deficit / self.rate_qps
        } else {
            3600.0 // rate 0: effectively never; cap the hint at an hour
        };
        Err(Duration::from_secs_f64(secs.clamp(1e-3, 3600.0)))
    }

    /// Tokens currently available (after a zero-cost refill to `now`).
    pub fn available(&mut self, now: Instant) -> f64 {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.tokens = (self.tokens + dt * self.rate_qps).min(self.burst);
        self.last = now;
        self.tokens
    }
}

/// Cumulative admission telemetry of an ingress, folded into the
/// [`ServiceReport`](super::service::ServiceReport) when the serve
/// loop exits. All counters are in query rows except the two request
/// counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct AdmissionStats {
    /// query rows admitted into the pending queue
    pub admitted: usize,
    /// query requests admitted
    pub admitted_requests: usize,
    /// query rows rejected or shed because a pending bound was full
    pub shed_overload: usize,
    /// query rows rejected by a per-client token bucket
    pub shed_quota: usize,
    /// query rows shed because their deadline expired while queued
    pub shed_deadline: usize,
    /// query requests rejected or shed (one typed [`Rejected`] each)
    pub rejected_requests: usize,
}

/// Overload-triggered degradation (ISSUE 10 tentpole (iv)): an EWMA
/// service-rate estimate over flush telemetry that tightens the
/// effective global pending bound while the engine is degraded.
///
/// When the GPU master demotes itself (§9's recovery ladder) flushes
/// finish CPU-only and the flush telemetry reports `degraded = true`;
/// the controller then caps the pending queue at roughly what the
/// *live CPU-only throughput* can drain within one admission horizon
/// (the policy's default deadline, else one second) - admitting work
/// the degraded engine cannot serve in time would only convert
/// rejections into deadline misses. The first non-degraded flush
/// restores the configured bound.
#[derive(Debug, Clone)]
pub struct CapacityController {
    configured_max: usize,
    horizon: Duration,
    rate_qps: f64,
    effective_max: usize,
}

impl CapacityController {
    /// EWMA weight of the newest flush observation.
    const ALPHA: f64 = 0.3;

    /// A controller for a configured bound and admission horizon.
    pub fn new(configured_max: usize, horizon: Duration) -> CapacityController {
        CapacityController {
            configured_max,
            horizon,
            rate_qps: 0.0,
            effective_max: configured_max,
        }
    }

    /// Fold one flush observation (queries, wall seconds, degraded
    /// flag) into the rate estimate and recompute the effective bound.
    pub fn note_flush(&mut self, queries: usize, secs: f64, degraded: bool) {
        if queries > 0 && secs > 0.0 {
            let inst = queries as f64 / secs;
            self.rate_qps = if self.rate_qps > 0.0 {
                (1.0 - Self::ALPHA) * self.rate_qps + Self::ALPHA * inst
            } else {
                inst
            };
        }
        self.effective_max = if degraded && self.rate_qps > 0.0 {
            let h = self.horizon.as_secs_f64().max(1e-3);
            (((self.rate_qps * h).floor() as usize).max(1))
                .min(self.configured_max)
        } else {
            self.configured_max
        };
    }

    /// The effective global pending bound: the configured maximum,
    /// tightened while the engine is degraded.
    pub fn effective_max(&self) -> usize {
        self.effective_max
    }

    /// The policy's configured (untightened) bound.
    pub fn configured_max(&self) -> usize {
        self.configured_max
    }

    /// The EWMA service-rate estimate, queries per second (0 before
    /// the first flush).
    pub fn rate_qps(&self) -> f64 {
        self.rate_qps
    }

    /// Suggested client backoff when rejecting at a full queue: the
    /// time to drain the current backlog at the estimated service
    /// rate, clamped to [1 ms, 10 s] (50 ms before any flush has
    /// calibrated the rate).
    pub fn retry_after_hint(&self, pending_queries: usize) -> Duration {
        let secs = if self.rate_qps > 0.0 {
            pending_queries.max(1) as f64 / self.rate_qps
        } else {
            0.05
        };
        Duration::from_secs_f64(secs.clamp(1e-3, 10.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_bucket_burst_then_refill() {
        let q = ClientQuota { rate_qps: 100.0, burst: 4.0 };
        let t0 = Instant::now();
        let mut b = TokenBucket::new(&q, t0);
        // the burst admits 4 tokens at once, then the bucket is empty
        assert!(b.try_take(4.0, t0).is_ok());
        let wait = b.try_take(1.0, t0).unwrap_err();
        // one token at 100/s refills in ~10 ms
        assert!(wait.as_secs_f64() <= 0.011, "wait {wait:?}");
        // after 20 ms of refill two tokens are available again
        let t1 = t0 + Duration::from_millis(20);
        assert!(b.try_take(2.0, t1).is_ok());
        assert!(b.try_take(1.0, t1).is_err());
        // refill never exceeds the burst capacity
        let t2 = t1 + Duration::from_secs(60);
        assert!((b.available(t2) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn zero_rate_bucket_is_burst_only() {
        let q = ClientQuota { rate_qps: 0.0, burst: 2.0 };
        let t0 = Instant::now();
        let mut b = TokenBucket::new(&q, t0);
        assert!(b.try_take(2.0, t0).is_ok());
        let wait = b.try_take(1.0, t0 + Duration::from_secs(10)).unwrap_err();
        assert!(wait >= Duration::from_secs(3600));
    }

    #[test]
    fn capacity_controller_tightens_when_degraded_and_recovers() {
        let mut c = CapacityController::new(1000, Duration::from_millis(500));
        assert_eq!(c.effective_max(), 1000);
        // healthy flushes: bound stays configured, rate calibrates
        c.note_flush(200, 0.1, false); // 2000 qps
        assert_eq!(c.effective_max(), 1000);
        assert!(c.rate_qps() > 0.0);
        // degraded flush at CPU-only speed: bound tightens to roughly
        // rate * horizon, floored at 1 and capped at the configured max
        c.note_flush(10, 1.0, true); // inst 10 qps drags the EWMA down
        assert!(c.effective_max() < 1000, "max {}", c.effective_max());
        assert!(c.effective_max() >= 1);
        let tightened = c.effective_max();
        // a second degraded flush tightens further as the EWMA settles
        c.note_flush(10, 1.0, true);
        assert!(c.effective_max() <= tightened);
        // recovery: the first non-degraded flush restores the bound
        c.note_flush(200, 0.1, false);
        assert_eq!(c.effective_max(), 1000);
    }

    #[test]
    fn retry_hint_tracks_backlog_drain_time() {
        let mut c = CapacityController::new(64, Duration::from_secs(1));
        // uncalibrated: the default hint
        assert_eq!(c.retry_after_hint(100), Duration::from_millis(50));
        c.note_flush(100, 1.0, false); // 100 qps
        let hint = c.retry_after_hint(50).as_secs_f64();
        assert!((hint - 0.5).abs() < 0.05, "hint {hint}");
        // clamped below at 1 ms, above at 10 s
        assert!(c.retry_after_hint(0) >= Duration::from_millis(1));
        assert!(c.retry_after_hint(1_000_000) <= Duration::from_secs(10));
    }

    #[test]
    fn rejected_is_a_typed_std_error() {
        let e: anyhow::Error = anyhow::Error::new(Rejected::Overloaded {
            retry_after_hint: Duration::from_millis(7),
        });
        match e.downcast_ref::<Rejected>() {
            Some(Rejected::Overloaded { retry_after_hint }) => {
                assert_eq!(*retry_after_hint, Duration::from_millis(7));
            }
            other => panic!("wrong downcast: {other:?}"),
        }
        assert!(e.to_string().contains("pending queue full"));
    }
}
