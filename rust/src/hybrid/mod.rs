//! HYBRIDKNN-JOIN - Algorithm 1 of the paper, orchestrated end to end
//! around a *density-ordered shared work queue* (see `sched`):
//!
//! 1. REORDER by variance (Sec. IV-D)                       [timed]
//! 2. select ε on the device (Sec. V-C)                     [timed]
//! 3. build the ε-grid over m dims (Sec. IV-A/C)            [excluded*]
//! 4. build the EXACT-ANN kd-tree                           [excluded*]
//! 5. build the work queue: cells priced by the Sec. V-B
//!    work estimator, sorted densest first; γ seeds the
//!    GPU's first batch, ρ reserves the sparse tail         [timed]
//! 6. drain the queue concurrently: the GPU master (this
//!    thread owns the PJRT client) claims work-sized batches
//!    off the dense head - pipelined three stages deep, so
//!    device exec of claim i+1, the device-to-host transfer
//!    of claim i and host filtering of claim i-1 all overlap
//!    (DESIGN.md §5) - CPU ranks chunk through the sparse
//!    tail, and the two fronts meet in the middle; Q^Fail
//!    recirculates into the live queue and is absorbed by
//!    the CPU ranks while the join runs - the serial Q^Fail
//!    post-pass of Algorithm 1 no longer exists             [timed]
//! 7. record per-claim telemetry, T1/T2 and ρ^Model (Eq. 6 -
//!    which also ran *live* inside step 6, sizing each GPU
//!    batch from the measured work rates). There is no merge
//!    step: every writer owns disjoint query slots of one SoA
//!    `KnnResult` (see core::result::SoaSlots and DESIGN.md §3/§4).
//!
//! The paper's one-shot static split (γ threshold + ρ floor, Sec. V-D/F)
//! survives as [`Scheduler::StaticSplit`] - the ablation baseline that
//! `benches/scheduler.rs` measures the queue against. On single-core
//! hosts the dynamic path runs the GPU master first, capped at the γ
//! dense prefix, then the CPU ranks - the sequential schedule degenerates
//! to exactly the static split (same work, same accounting).
//!
//! *The paper's response-time measurements exclude dataset loading and
//! index construction (Sec. VI-B); `HybridReport::response_time` follows
//! the same convention, with the raw phase times kept in `timers`.

pub mod admission;
pub mod service;

use anyhow::Result;

use crate::core::{Dataset, KnnResult};
use crate::cpu;
use crate::data::variance::reorder_by_variance;
use crate::epsilon::{EpsilonSelection, EpsilonSelector};
use crate::fault::{FaultLog, FaultPlan, RecoveryPolicy};
use crate::gpu::{self, DrainMode, GpuJoinParams, GpuJoinStats, ThreadAssign};
use crate::index::{GridIndex, KdTree, QueryKey};
use crate::runtime::{tiles::TileClass, Engine};
use crate::sched::{self, BackendMode, ClaimRecord};
use crate::split::{self, WorkSplit};
use crate::util::timer::PhaseTimer;

/// How the work is divided between the architectures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheduler {
    /// Density-ordered shared work queue with two-ended dynamic claims
    /// (the default): the split is discovered at run time, γ only seeds
    /// the GPU's first batch and ρ only reserves the sparse tail.
    DynamicQueue,
    /// The paper's one-shot static partition (γ threshold + ρ floor,
    /// Sec. V-D/F) with the serial Q^Fail post-pass - kept as the
    /// ablation baseline.
    StaticSplit,
}

/// Parameters of the hybrid join (paper Table II).
#[derive(Debug, Clone)]
pub struct HybridParams {
    /// neighbors per query
    pub k: usize,
    /// indexed dimensions m <= n (paper uses m = 6 everywhere)
    pub m: usize,
    /// ε inflation (Sec. V-C2), in [0,1]
    pub beta: f64,
    /// GPU density threshold (Sec. V-D), in [0,1]. Under the dynamic
    /// queue this seeds the GPU's first batch instead of fixing the split.
    pub gamma: f64,
    /// minimum CPU query fraction (Sec. V-F), in [0,1]. Under the dynamic
    /// queue this reserves the sparse tail for CPU ranks.
    pub rho: f64,
    /// EXACT-ANN ranks (paper: 15 + 1 GPU master)
    pub cpu_ranks: usize,
    /// REORDER on/off (ablation)
    pub reorder: bool,
    /// SHORTC equivalent: on-device top-k path vs full distance tiles
    pub use_topk: bool,
    /// device tile family (large/small qt x ct shapes)
    pub tile_class: TileClass,
    /// kernel granularity strategy (Table III; device-model accounting)
    pub assign: ThreadAssign,
    /// batch buffer size b_s in result pairs (Sec. IV-B)
    pub buffer_pairs: u64,
    /// stream workers overlapping device exec and host filtering
    pub streams: usize,
    /// GPU master drain mode (dynamic queue only): the three-stage
    /// pipeline (default - device exec of claim i+1 / device-to-host
    /// transfer of claim i / host filtering of claim i-1 all overlap),
    /// the two-stage pipeline (transfer stays on the master), or the
    /// synchronous drain (the ablation baseline benches/scheduler.rs
    /// measures against). Forced to `DrainMode::Sync` on single-core
    /// hosts; under `Scheduler::StaticSplit` the list-driven join is
    /// used instead, which ignores this field. Results are bit-identical
    /// across all modes.
    pub gpu_drain: DrainMode,
    /// GPU backend routing (dynamic queue only): `Auto` (the default)
    /// consults [`sched::route_brute`] per claim - claims whose mean
    /// per-query candidate population exceeds the m/k-dependent crossover
    /// fraction of |D| take the tiled brute-force tier, the rest the
    /// grid-hybrid candidate path; `Grid`/`Brute` force every claim onto
    /// one tier (the crossover-bench endpoints). The static split's
    /// list-driven join is grid-only and ignores this field. Routing
    /// never changes results - both tiers are exact for the queries they
    /// solve, and brute-solved queries cannot land in Q^Fail.
    pub backend: BackendMode,
    /// ε-selection tuning knobs (Sec. V-C)
    pub selector: EpsilonSelector,
    /// process only a fraction f of the queries (Table VI parameter
    /// recovery); 1.0 = all
    pub query_fraction: f64,
    /// work-division strategy (dynamic queue vs static split ablation)
    pub scheduler: Scheduler,
    /// seed for the sampled phases (ε selection)
    pub seed: u64,
    /// deterministic fault-injection plan threaded into the GPU master's
    /// drain stages (dynamic queue only; `FaultPlan::none()` - the
    /// default - makes every hook a no-op branch on the hot path)
    pub fault: FaultPlan,
    /// claim-scoped recovery policy: retry/backoff bounds, the demotion
    /// threshold, and the watchdog deadline shape (DESIGN.md §9)
    pub recovery: RecoveryPolicy,
}

impl HybridParams {
    /// Paper-default parameters for the given K.
    pub fn new(k: usize) -> Self {
        HybridParams {
            k,
            m: 6,
            beta: 0.0,
            gamma: 0.0,
            rho: 0.0,
            cpu_ranks: 3,
            reorder: true,
            // dist-tile + host filter beats the sort-based top-k tile on
            // CPU-PJRT (see gpu::join); flip for accelerator targets
            use_topk: false,
            tile_class: TileClass::Large,
            assign: ThreadAssign::Static(8),
            buffer_pairs: 10_000_000,
            streams: 3,
            gpu_drain: DrainMode::ThreeStage,
            backend: BackendMode::Auto,
            selector: EpsilonSelector::default(),
            query_fraction: 1.0,
            scheduler: Scheduler::DynamicQueue,
            seed: 0x4B1D,
            fault: FaultPlan::none(),
            recovery: RecoveryPolicy::default(),
        }
    }
}

/// Everything the evaluation section needs from one run.
#[derive(Debug)]
pub struct HybridReport {
    /// the KNN table - every processed query's neighbors, in place
    pub result: KnnResult,
    /// the ε selection that drove the grid (Sec. V-C)
    pub eps: EpsilonSelection,
    /// queries computed on the GPU side (dynamic: head claims; static:
    /// |Q^GPU|). Q^Fail queries count here, as in the paper.
    pub q_gpu: usize,
    /// queries computed on the CPU side (dynamic: tail claims; static:
    /// |Q^CPU|), excluding recirculated Q^Fail
    pub q_cpu: usize,
    /// queries the GPU failed (< K in-ε neighbors), re-solved on the CPU
    pub q_fail: usize,
    /// dynamic: the ρ tail reservation; static: queries moved GPU->CPU by
    /// the ρ floor
    pub rho_moved: usize,
    /// avg per-query seconds of EXACT-ANN (T1)
    pub t1: f64,
    /// avg per-query seconds of GPU-JOIN (T2)
    pub t2: f64,
    /// Eq. 6 load-balanced ρ estimate from this run's T1/T2
    pub rho_model: f64,
    /// paper-convention response time (excludes index construction)
    pub response_time: f64,
    /// all phases, including excluded ones
    pub timers: PhaseTimer,
    /// GPU engine telemetry: wall seconds inside PJRT execution
    pub gpu_kernel_time: f64,
    /// GPU batches/claims executed
    pub gpu_batches: usize,
    /// realised in-ε result pairs on the GPU side
    pub gpu_result_pairs: u64,
    /// modeled GPU kernel seconds for the configured ThreadAssign
    pub device_model_seconds: f64,
    /// queries the GPU solved exactly
    pub solved_on_gpu: usize,
    /// master-thread seconds materialising/packing/executing GPU claims
    /// on the device (device-to-host copies excluded - the kernel-side
    /// time the claim sizing feeds on)
    pub gpu_exec_time: f64,
    /// seconds converting device output literals into host buffers (the
    /// device-to-host transfer lane of the per-claim telemetry). Runs on
    /// the dedicated transfer stage under the three-stage drain, on the
    /// master thread otherwise.
    pub gpu_transfer_time: f64,
    /// filter-stage wall seconds over the GPU claims' flush rounds
    pub gpu_filter_time: f64,
    /// seconds of exec/filter overlap the pipelined drains achieved:
    /// `max(0, gpu_exec_time + gpu_filter_time - gpu phase wall)`. 0 on
    /// the synchronous paths - this is the observable the sync-vs-
    /// pipelined bench column tracks.
    pub gpu_filter_overlap: f64,
    /// seconds of transfer hidden behind the other stages: the total
    /// pipeline overlap `max(0, exec + transfer + filter - gpu wall)`
    /// minus `gpu_filter_overlap`. > 0 is the three-stage drain's
    /// dedicated transfer stage observably working; ~0 under the
    /// sync/two-stage drains, where the copy serialises with exec on the
    /// master thread.
    pub gpu_transfer_overlap: f64,
    /// device tiles executed by the brute tier (each one query-chunk x
    /// one corpus-chunk kernel launch)
    pub brute_tiles: u64,
    /// GPU claims routed to the tiled brute-force tier (forced or by the
    /// `sched::route_brute` heuristic)
    pub brute_claims: usize,
    /// GPU claims that took the grid-hybrid candidate path
    pub grid_claims: usize,
    /// exec-lane seconds of brute-routed claims (subset of
    /// `gpu_exec_time`; the grid tier's share is the difference)
    pub brute_exec_time: f64,
    /// transfer-lane seconds of brute-routed claims (subset of
    /// `gpu_transfer_time`)
    pub brute_transfer_time: f64,
    /// filter-lane seconds of brute-routed claims (subset of
    /// `gpu_filter_time`)
    pub brute_filter_time: f64,
    /// per-claim scheduling telemetry (dynamic queue only; empty under
    /// the static split)
    pub claims: Vec<ClaimRecord>,
    /// GPU claim attempts that failed (injected or real): every retried
    /// or reclaimed attempt counts once
    pub gpu_faults: usize,
    /// failed GPU claim attempts that were retried in place (bounded
    /// exponential backoff, synchronous re-execution)
    pub gpu_retries: usize,
    /// grid cells whose claims exhausted their retries and were pushed
    /// back through the Q^Fail recirculation buffer for the CPU ranks
    pub reclaimed_cells: usize,
    /// true when the GPU master demoted itself after
    /// `RecoveryPolicy::demote_after` consecutive claim failures and the
    /// run completed CPU-only from that point on
    pub degraded: bool,
    /// ordered per-fault recovery journal (what fired, on which claim,
    /// which action the policy took)
    pub fault_log: FaultLog,
}

/// The hybrid join engine.
pub struct HybridKnnJoin;

impl HybridKnnJoin {
    /// Run Algorithm 1 (self-join). The engine stays on this thread (PJRT
    /// client is not Send - the paper's single GPU-master rank); CPU ranks
    /// run on scoped threads.
    pub fn run(
        engine: &Engine,
        data: &Dataset,
        params: &HybridParams,
    ) -> Result<HybridReport> {
        Self::run_inner(engine, data, None, params)
    }

    /// Bipartite join R ⋈_KNN S (paper Sec. III: the self-join machinery
    /// applies directly): for every point of `r`, find its K nearest
    /// neighbors in `s`. No self-exclusion.
    pub fn run_rs(
        engine: &Engine,
        r: &Dataset,
        s: &Dataset,
        params: &HybridParams,
    ) -> Result<HybridReport> {
        anyhow::ensure!(
            r.dims() == s.dims(),
            "R and S dimensionality mismatch: {} vs {}",
            r.dims(),
            s.dims()
        );
        Self::run_inner(engine, r, Some(s), params)
    }

    fn run_inner(
        engine: &Engine,
        r_in: &Dataset,
        s_in: Option<&Dataset>,
        params: &HybridParams,
    ) -> Result<HybridReport> {
        let self_join = s_in.is_none();
        let mut timers = PhaseTimer::new();

        // 1. REORDER (timed - part of the response per Sec. VI-E1).
        // The permutation comes from the corpus S and is applied to both
        // relations so distances are preserved.
        let (r_re, s_re): (Dataset, Option<Dataset>) = if params.reorder {
            timers.time("reorder_variance", || {
                match s_in {
                    None => (reorder_by_variance(r_in).0, None),
                    Some(s) => {
                        let (s2, perm) = reorder_by_variance(s);
                        (r_in.permute_dims(&perm), Some(s2))
                    }
                }
            })
        } else {
            (r_in.clone(), s_in.cloned())
        };
        let r_data = &r_re;
        let data: &Dataset = s_re.as_ref().unwrap_or(r_data);

        // 2. ε selection on the device
        let eps_sel = timers.time("select_epsilon", || {
            params
                .selector
                .select_rs(engine, r_data, data, params.k, params.beta)
        })?;

        // 3. grid construction (excluded from response time)
        let grid = timers.time("build_grid[excluded]", || {
            GridIndex::build(data, params.m, eps_sel.eps)
        });

        // 4. kd-tree construction (excluded from response time)
        let tree = timers.time("build_kdtree[excluded]", || KdTree::build(data));

        match params.scheduler {
            Scheduler::DynamicQueue => Self::dynamic_join(
                engine, r_data, data, self_join, params, eps_sel, &grid, &tree,
                timers,
            ),
            Scheduler::StaticSplit => Self::static_join(
                engine, r_data, data, self_join, params, eps_sel, &grid, &tree,
                timers,
            ),
        }
    }

    /// Steps 5-7 under the density-ordered work queue: construction, then
    /// concurrent two-ended draining with live Q^Fail recirculation.
    #[allow(clippy::too_many_arguments)]
    fn dynamic_join(
        engine: &Engine,
        r_data: &Dataset,
        data: &Dataset,
        self_join: bool,
        params: &HybridParams,
        eps_sel: EpsilonSelection,
        grid: &GridIndex,
        tree: &KdTree,
        mut timers: PhaseTimer,
    ) -> Result<HybridReport> {
        // 5. queue construction (replaces the one-shot split)
        let mut query_ids: Vec<u32> = (0..r_data.len() as u32).collect();
        if params.query_fraction < 1.0 {
            // Table VI: process only a fraction of the queries
            let stride = (1.0 / params.query_fraction.max(1e-6)).round() as usize;
            query_ids = query_ids.into_iter().step_by(stride.max(1)).collect();
        }
        // Bipartite R side: pay one coordinate linearisation + binary
        // search per R point ONCE (timed), after which queue grouping and
        // pricing are O(1) per query - the same complexity the self-join
        // gets from the grid's native point-rank map.
        let rank_cache = (!self_join)
            .then(|| timers.time("build_rank_cache", || grid.build_query_ranks(r_data)));
        let queue = timers.time("build_queue", || {
            let key = match &rank_cache {
                None => QueryKey::Native, // self-join: O(1) id-keyed path
                Some(cache) => QueryKey::Cached(cache),
            };
            sched::build_queue_keyed(
                r_data, grid, &query_ids, params.k, params.gamma, params.rho,
                key,
            )
        });

        // Scheduling: with >1 hardware threads the GPU master and the CPU
        // ranks drain the queue concurrently; on a single-core host the
        // "concurrency" would only make the PJRT thread pool and the rank
        // threads fight over one core (~7x slowdown measured), so the GPU
        // master runs first - capped at the γ dense prefix, so the
        // sequential schedule equals the static split - and the CPU ranks
        // drain the rest plus the recirculated failures afterwards. The
        // pipelined drains are gated the same way: their transfer/filter
        // workers only pay off when they have cores to overlap on.
        let hw = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let gpu_params = GpuJoinParams {
            k: params.k,
            eps: eps_sel.eps,
            tile_class: params.tile_class,
            use_topk: params.use_topk,
            buffer_pairs: params.buffer_pairs,
            streams: params.streams,
            assign: params.assign,
            estimator_frac: 0.01,
            exclude_self: self_join,
            drain: if hw > 1 { params.gpu_drain } else { DrainMode::Sync },
            fault: params.fault.clone(),
            recovery: params.recovery,
            backend: params.backend,
        };
        let mut result = KnnResult::new(r_data.len(), params.k);
        let slots = result.slots();
        let pos_cap = if hw > 1 { queue.len() } else { queue.dense_prefix() };
        let t_main = std::time::Instant::now();
        // The CPU ranks only exit after observing gpu_done; release them on
        // every GPU exit path - normal completion, device error, or panic -
        // so an unwinding GPU master cannot leave the scope join hanging.
        struct GpuDoneGuard<'a>(&'a sched::WorkQueue);
        impl Drop for GpuDoneGuard<'_> {
            fn drop(&mut self) {
                self.0.set_gpu_done();
            }
        }
        let run_gpu = || -> Option<Result<GpuJoinStats>> {
            let _done = GpuDoneGuard(&queue);
            if queue.head_open(pos_cap) {
                Some(gpu::join::gpu_join_drain(
                    engine, r_data, data, grid, &queue, &gpu_params, &slots,
                    pos_cap,
                ))
            } else {
                None
            }
        };
        let run_cpu = || {
            cpu::exact_ann_drain(
                data, tree, r_data, &queue, params.k, params.cpu_ranks,
                self_join, &slots,
            )
        };
        let (gpu_out, cpu_out) = if hw > 1 {
            std::thread::scope(|scope| {
                let cpu_handle = scope.spawn(run_cpu);
                let gpu_out = run_gpu();
                (gpu_out, cpu_handle.join().expect("cpu ranks panicked"))
            })
        } else {
            let gpu_out = run_gpu();
            (gpu_out, run_cpu())
        };
        let gpu_stats = gpu_out.transpose()?;
        drop(slots); // all writers done; `result` is complete in place
        let main_time = t_main.elapsed().as_secs_f64();
        timers.add("join_main", main_time);

        // 7. bookkeeping from the claim telemetry
        let (mut gpu_kernel_time, mut gpu_batches, mut gpu_pairs) =
            (0.0, 0usize, 0u64);
        let (mut device_model_seconds, mut solved_on_gpu, mut gpu_total) =
            (0.0, 0usize, 0.0);
        let (mut gpu_exec_time, mut gpu_transfer_time, mut gpu_filter_time) =
            (0.0, 0.0, 0.0f64);
        let (mut gpu_filter_overlap, mut gpu_transfer_overlap) = (0.0f64, 0.0f64);
        let mut claims: Vec<ClaimRecord> = Vec::new();
        let mut q_fail = 0usize;
        let (mut gpu_faults, mut gpu_retries, mut reclaimed_cells) =
            (0usize, 0usize, 0usize);
        let (mut brute_tiles, mut brute_claims, mut grid_claims) =
            (0u64, 0usize, 0usize);
        let (mut brute_exec_time, mut brute_transfer_time, mut brute_filter_time) =
            (0.0f64, 0.0f64, 0.0f64);
        let mut degraded = false;
        let mut fault_log = FaultLog::default();
        if let Some(g) = gpu_stats {
            gpu_kernel_time = g.kernel_time;
            gpu_batches = g.batches;
            gpu_pairs = g.result_pairs;
            device_model_seconds = g.device_model.seconds;
            solved_on_gpu = g.solved;
            gpu_total = g.total_time;
            gpu_exec_time = g.exec_time;
            gpu_transfer_time = g.transfer_time;
            gpu_filter_time = g.filter_time;
            // stage seconds exceeding the GPU phase wall time is exactly
            // the pipeline's overlap made visible; the transfer lane's
            // share is what the dedicated transfer stage hides on top of
            // the exec/filter overlap
            gpu_filter_overlap = (g.exec_time + g.filter_time - g.total_time).max(0.0);
            let total_overlap = (g.exec_time + g.transfer_time + g.filter_time
                - g.total_time)
                .max(0.0);
            gpu_transfer_overlap = (total_overlap - gpu_filter_overlap).max(0.0);
            q_fail = g.failed.len();
            gpu_faults = g.gpu_faults;
            gpu_retries = g.gpu_retries;
            reclaimed_cells = g.reclaimed_cells;
            degraded = g.degraded;
            fault_log = g.fault_log;
            brute_tiles = g.brute_tiles;
            brute_claims = g.brute_claims;
            grid_claims = g.grid_claims;
            // per-backend stage lanes, split off the per-claim telemetry
            for c in g.claims.iter().filter(|c| c.brute) {
                brute_exec_time += c.exec_secs;
                brute_transfer_time += c.transfer_secs;
                brute_filter_time += c.filter_secs;
            }
            claims.extend(g.claims);
        }
        let cpu_busy: f64 = cpu_out.claims.iter().map(|c| c.secs).sum();
        let cpu_queries = cpu_out.queries + cpu_out.recirc_queries;
        let cpu_total_time = cpu_out.total_time;
        claims.extend(cpu_out.claims);

        let q_gpu = queue.claimed_head();
        let q_cpu = queue.claimed_tail();

        // T1: mean per-query EXACT-ANN time over *busy* claim seconds
        // (rank wall time includes idle waits on the GPU, so it is not
        // used). On an oversubscribed host busy time is still bounded by
        // wall x effective parallelism - take the tighter estimate.
        let eff = params.cpu_ranks.min(hw) as f64;
        let t1 = if cpu_queries > 0 {
            cpu_busy.min(cpu_total_time * eff) / cpu_queries as f64
        } else {
            0.0
        };
        let t2 = if solved_on_gpu > 0 {
            gpu_total / solved_on_gpu as f64
        } else {
            0.0
        };

        let response_time = timers.total()
            - timers.get("build_grid[excluded]")
            - timers.get("build_kdtree[excluded]");

        // ρ^Model (Eq. 6) is undefined when one side measured nothing:
        // a GPU that solved zero queries is evidence FOR the CPU (ρ→1),
        // not for ρ=0 as a literal reading of the formula would give.
        let rho_model = if q_gpu == 0 || solved_on_gpu == 0 {
            // no GPU evidence (empty or all-failed GPU side): the data is
            // telling us this workload belongs on the CPU
            1.0
        } else if cpu_queries == 0 {
            split::rho_model(0.0, t2).min(0.5)
        } else {
            split::rho_model(t1, t2)
        };

        Ok(HybridReport {
            result,
            eps: eps_sel,
            q_gpu,
            q_cpu,
            q_fail,
            rho_moved: queue.reserve(),
            t1,
            t2,
            rho_model,
            response_time,
            timers,
            gpu_kernel_time,
            gpu_batches,
            gpu_result_pairs: gpu_pairs,
            device_model_seconds,
            solved_on_gpu,
            gpu_exec_time,
            gpu_transfer_time,
            gpu_filter_time,
            gpu_filter_overlap,
            gpu_transfer_overlap,
            brute_tiles,
            brute_claims,
            grid_claims,
            brute_exec_time,
            brute_transfer_time,
            brute_filter_time,
            claims,
            gpu_faults,
            gpu_retries,
            reclaimed_cells,
            degraded,
            fault_log,
        })
    }

    /// Steps 5-8 of the original Algorithm 1: one-shot γ/ρ split, fixed
    /// concurrent passes, serial Q^Fail post-pass. The ablation baseline.
    #[allow(clippy::too_many_arguments)]
    fn static_join(
        engine: &Engine,
        r_data: &Dataset,
        data: &Dataset,
        self_join: bool,
        params: &HybridParams,
        eps_sel: EpsilonSelection,
        grid: &GridIndex,
        tree: &KdTree,
        mut timers: PhaseTimer,
    ) -> Result<HybridReport> {
        // 5. split work (queries = points of R, density from the S grid)
        let mut splitres: WorkSplit = timers.time("split_work", || {
            split::split_work(
                r_data, grid, params.k, params.gamma, params.rho, self_join,
            )
        });

        // Table VI: process only a fraction of the queries
        if params.query_fraction < 1.0 {
            let keep = |v: &mut Vec<u32>| {
                let stride = (1.0 / params.query_fraction.max(1e-6)).round() as usize;
                *v = v.iter().cloned().step_by(stride.max(1)).collect();
            };
            keep(&mut splitres.q_gpu);
            keep(&mut splitres.q_cpu);
        }
        let (q_gpu, q_cpu) = (splitres.q_gpu.clone(), splitres.q_cpu.clone());

        // 6.+7. concurrent GPU-JOIN + EXACT-ANN, then Q^Fail. All three
        // passes write disjoint query ids of ONE SoA result table through
        // `slots` - no per-engine result containers and no merge pass
        // (Q^GPU and Q^CPU partition the queries; Q^Fail slots were left
        // untouched by the GPU and are rewritten by the CPU afterwards).
        let gpu_params = GpuJoinParams {
            k: params.k,
            eps: eps_sel.eps,
            tile_class: params.tile_class,
            use_topk: params.use_topk,
            buffer_pairs: params.buffer_pairs,
            streams: params.streams,
            assign: params.assign,
            estimator_frac: 0.01,
            exclude_self: self_join,
            // the static split uses the list-driven form, which ignores
            // the queue-drain mode - the static split is the
            // whole-pipeline ablation baseline; the claim-scoped fault
            // machinery only exists for queue drains, so no plan is
            // threaded here
            drain: DrainMode::Sync,
            fault: FaultPlan::none(),
            recovery: RecoveryPolicy::default(),
            // the list-driven join routes nothing - grid tier only
            backend: BackendMode::Grid,
        };
        let mut result = KnnResult::new(r_data.len(), params.k);
        let slots = result.slots();

        // Scheduling: with >1 hardware threads the GPU master and the CPU
        // ranks run concurrently (Alg. 1); on a single-core host the two
        // components run back to back - same work, same accounting.
        let hw = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let t_main = std::time::Instant::now();
        let run_gpu = || {
            gpu::join::gpu_join_rs_into(
                engine, r_data, data, grid, &q_gpu, &gpu_params, &slots,
            )
        };
        let run_cpu = || {
            cpu::exact_ann_rs_into(
                data, tree, r_data, &q_cpu, params.k, params.cpu_ranks,
                self_join, &slots,
            )
        };
        let (gpu_out, cpu_out) = if hw > 1 {
            std::thread::scope(|scope| {
                let cpu_handle = scope.spawn(run_cpu);
                let gpu_out = if q_gpu.is_empty() { None } else { Some(run_gpu()) };
                (gpu_out, cpu_handle.join().expect("cpu ranks panicked"))
            })
        } else {
            let gpu_out = if q_gpu.is_empty() { None } else { Some(run_gpu()) };
            (gpu_out, run_cpu())
        };
        let gpu_out = gpu_out.transpose()?;

        // Q^Fail -> EXACT-ANN (Sec. V-E)
        let failed: Vec<u32> = gpu_out
            .as_ref()
            .map(|g| g.failed.clone())
            .unwrap_or_default();
        if !failed.is_empty() {
            timers.time("q_fail_exact_ann", || {
                cpu::exact_ann_rs_into(
                    data, tree, r_data, &failed, params.k, params.cpu_ranks,
                    self_join, &slots,
                )
            });
        }
        drop(slots); // all writers done; `result` is complete in place
        let main_time = t_main.elapsed().as_secs_f64();
        timers.add("join_main", main_time);

        // 8. bookkeeping (no merge - see above)
        let (mut gpu_kernel_time, mut gpu_batches, mut gpu_pairs) = (0.0, 0usize, 0u64);
        let (mut device_model_seconds, mut solved_on_gpu, mut gpu_total) =
            (0.0, 0usize, 0.0);
        let (mut gpu_exec_time, mut gpu_transfer_time, mut gpu_filter_time) =
            (0.0, 0.0, 0.0);
        if let Some(g) = gpu_out {
            gpu_kernel_time = g.kernel_time;
            gpu_batches = g.batches;
            gpu_pairs = g.result_pairs;
            device_model_seconds = g.device_model.seconds;
            solved_on_gpu = g.solved;
            gpu_total = g.total_time;
            gpu_exec_time = g.exec_time;
            gpu_transfer_time = g.transfer_time;
            gpu_filter_time = g.filter_time;
        }

        // T1: mean per-query EXACT-ANN time (Sec. VI-E2). On an
        // oversubscribed host (ranks > hardware threads) the per-rank wall
        // times overlap, so busy time is bounded by wall x effective
        // parallelism - take the tighter of the two estimates.
        let cpu_busy: f64 = cpu_out.per_rank_time.iter().sum();
        let eff = params.cpu_ranks.min(hw) as f64;
        let t1 = if cpu_out.queries > 0 {
            cpu_busy.min(cpu_out.total_time * eff) / cpu_out.queries as f64
        } else {
            0.0
        };
        let t2 = if solved_on_gpu > 0 {
            gpu_total / solved_on_gpu as f64
        } else {
            0.0
        };

        let response_time = timers.total()
            - timers.get("build_grid[excluded]")
            - timers.get("build_kdtree[excluded]");

        // ρ^Model (Eq. 6) is undefined when one side measured nothing:
        // a GPU that solved zero queries is evidence FOR the CPU (ρ→1),
        // not for ρ=0 as a literal reading of the formula would give.
        let rho_model = if q_gpu.is_empty() || solved_on_gpu == 0 {
            // no GPU evidence (empty or all-failed GPU side): the data is
            // telling us this workload belongs on the CPU
            1.0
        } else if q_cpu.is_empty() && solved_on_gpu > 0 {
            split::rho_model(0.0, t2).min(0.5)
        } else {
            split::rho_model(t1, t2)
        };

        Ok(HybridReport {
            result,
            eps: eps_sel,
            q_gpu: q_gpu.len(),
            q_cpu: q_cpu.len(),
            q_fail: failed.len(),
            rho_moved: splitres.rho_moved,
            t1,
            t2,
            rho_model,
            response_time,
            timers,
            gpu_kernel_time,
            gpu_batches,
            gpu_result_pairs: gpu_pairs,
            device_model_seconds,
            solved_on_gpu,
            gpu_exec_time,
            gpu_transfer_time,
            gpu_filter_time,
            // the list form derives exec as wall minus transfer/filter,
            // so overlap is identically 0 by construction here
            gpu_filter_overlap: 0.0,
            gpu_transfer_overlap: 0.0,
            brute_tiles: 0,
            brute_claims: 0,
            grid_claims: 0,
            brute_exec_time: 0.0,
            brute_transfer_time: 0.0,
            brute_filter_time: 0.0,
            claims: Vec::new(),
            gpu_faults: 0,
            gpu_retries: 0,
            reclaimed_cells: 0,
            degraded: false,
            fault_log: FaultLog::default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{chist_like, susy_like};
    use crate::sched::Arch;

    fn engine() -> Engine {
        Engine::load_default().unwrap()
    }

    fn params(k: usize) -> HybridParams {
        let mut p = HybridParams::new(k);
        p.cpu_ranks = 2;
        p
    }

    #[test]
    fn hybrid_equals_exact_knn() {
        // The headline correctness invariant: hybrid output == kd-tree
        // exact KNN for EVERY query, regardless of the (β, γ, ρ) seeding -
        // dynamic scheduling changes *who* computes each query, never the
        // result.
        let e = engine();
        let data = susy_like(900).generate(51);
        for (beta, gamma, rho) in [(0.0, 0.0, 0.0), (0.4, 0.6, 0.3), (1.0, 0.8, 0.0)] {
            let mut p = params(4);
            p.beta = beta;
            p.gamma = gamma;
            p.rho = rho;
            let rep = HybridKnnJoin::run(&e, &data, &p).unwrap();
            assert_eq!(
                rep.result.solved_count(p.k.min(data.len() - 1)),
                data.len(),
                "every query solved (β={beta} γ={gamma} ρ={rho})"
            );
            // exact check vs kd-tree on the reordered data
            let (rdata, _) = reorder_by_variance(&data);
            let tree = KdTree::build(&rdata);
            for q in (0..data.len()).step_by(101) {
                let got = rep.result.get(q);
                let want = tree.knn(&rdata, rdata.point(q), p.k, q as u32);
                assert_eq!(got.len(), want.len());
                for (g, w) in got.iter().zip(&want) {
                    assert!(
                        (g.dist2 - w.dist2).abs() < 1e-3 * (1.0 + w.dist2),
                        "q={q}: got {g:?} want {w:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn static_and_dynamic_schedulers_agree() {
        // The work division must be invisible in the output: the paper's
        // static split and the dynamic queue produce identical neighbor
        // distances.
        let e = engine();
        let data = susy_like(700).generate(57);
        let mut p_dyn = params(4);
        p_dyn.gamma = 0.3;
        p_dyn.rho = 0.1;
        let mut p_stat = p_dyn.clone();
        p_stat.scheduler = Scheduler::StaticSplit;
        let a = HybridKnnJoin::run(&e, &data, &p_dyn).unwrap();
        let b = HybridKnnJoin::run(&e, &data, &p_stat).unwrap();
        assert_eq!(a.result.solved_count(4), data.len());
        assert_eq!(b.result.solved_count(4), data.len());
        for q in (0..data.len()).step_by(43) {
            let (x, y) = (a.result.get(q), b.result.get(q));
            assert_eq!(x.len(), y.len(), "q={q}");
            for (m, n) in x.iter().zip(y) {
                assert!((m.dist2 - n.dist2).abs() < 1e-4 * (1.0 + n.dist2), "q={q}");
            }
        }
        // static path reports no claim telemetry
        assert!(b.claims.is_empty());
    }

    #[test]
    fn split_accounting_consistent() {
        let e = engine();
        let data = susy_like(800).generate(52);
        let mut p = params(5);
        p.gamma = 0.2;
        let rep = HybridKnnJoin::run(&e, &data, &p).unwrap();
        assert_eq!(rep.q_gpu + rep.q_cpu, data.len());
        assert!(rep.q_fail <= rep.q_gpu);
        assert_eq!(rep.solved_on_gpu + rep.q_fail, rep.q_gpu);
        assert!(rep.rho_model >= 0.0 && rep.rho_model <= 1.0);
        assert!(rep.response_time > 0.0);
        assert!(rep.response_time <= rep.timers.total());
        // claim telemetry covers exactly the computed queries
        let claimed: usize = rep.claims.iter().map(|c| c.queries).sum();
        assert_eq!(claimed, data.len() + rep.q_fail, "claims + recirculated");
    }

    #[test]
    fn rho_one_is_pure_cpu() {
        let e = engine();
        let data = susy_like(400).generate(53);
        let mut p = params(3);
        p.rho = 1.0;
        let rep = HybridKnnJoin::run(&e, &data, &p).unwrap();
        assert_eq!(rep.q_gpu, 0);
        assert_eq!(rep.q_fail, 0);
        assert_eq!(rep.gpu_batches, 0);
        assert_eq!(rep.result.solved_count(3), data.len());
        assert!(rep.claims.iter().all(|c| matches!(c.arch, Arch::Cpu)));
    }

    #[test]
    fn query_fraction_processes_subset() {
        let e = engine();
        let data = susy_like(600).generate(54);
        let mut p = params(3);
        p.query_fraction = 0.25;
        let rep = HybridKnnJoin::run(&e, &data, &p).unwrap();
        let processed = rep.q_gpu + rep.q_cpu;
        assert!(
            processed >= data.len() / 5 && processed <= data.len() / 3,
            "fraction off: {processed} of {}",
            data.len()
        );
        assert!(rep.result.solved_count(3) >= processed.min(rep.result.len()) - rep.q_fail);
    }

    #[test]
    fn high_dim_dataset_route() {
        let e = engine();
        let data = chist_like(400).generate(55);
        let mut p = params(3);
        p.beta = 0.3;
        let rep = HybridKnnJoin::run(&e, &data, &p).unwrap();
        assert_eq!(rep.result.solved_count(3), data.len());
        assert!(rep.eps.eps > 0.0);
    }

    #[test]
    fn reorder_ablation_still_exact() {
        let e = engine();
        let data = chist_like(300).generate(56);
        let mut p = params(3);
        p.reorder = false;
        let rep = HybridKnnJoin::run(&e, &data, &p).unwrap();
        assert_eq!(rep.result.solved_count(3), data.len());
        // without reorder, ids refer to the original data
        let tree = KdTree::build(&data);
        let got = rep.result.get(7);
        let want = tree.knn(&data, data.point(7), 3, 7);
        for (g, w) in got.iter().zip(&want) {
            assert!((g.dist2 - w.dist2).abs() < 1e-3 * (1.0 + w.dist2));
        }
    }
}
