//! Online KNN service: a resident engine serving streaming query
//! micro-batches (ROADMAP direction 1, DESIGN.md §11).
//!
//! The one-shot joins ([`super::HybridKnnJoin`]) rebuild everything per
//! call: grid, kd-tree, ε selection, GPU tile plans, drain arenas. The
//! north-star workload is the opposite shape - a long-lived process
//! holding one corpus resident while query *streams* arrive from many
//! concurrent clients. This module separates "engine" from "run once":
//!
//! * [`KnnEngine`] owns the resident state - the (dimension-reordered)
//!   corpus, its `GridIndex` + `KdTree`, the ε selection, the PJRT
//!   [`Engine`] handle with its compiled-executable cache, and a
//!   [`DrainState`](crate::gpu::join) of reusable GPU drain arenas
//!   (staging sets + packed brute-tier corpus tiles) that survive
//!   across flushes instead of being reallocated per join.
//! * [`Ingress`] is the admission layer: clients ([`Client::query`])
//!   park query batches in a shared pending queue; the serve loop
//!   coalesces *everything pending* into one micro-batch per flush -
//!   the buffered-batching design of Bigger Buffer k-d Trees (arxiv
//!   1512.02831) applied to the hybrid queue - so per-flush costs
//!   (rank cache, queue pricing, claim setup) amortize over every
//!   in-flight client. Under an [`AdmissionPolicy`] the queue is
//!   *bounded*: submissions past the global or per-client bound (or a
//!   per-client token-bucket quota) receive a typed
//!   [`Rejected`](crate::hybrid::admission::Rejected) error instead
//!   of piling up, queued requests whose deadline expires are shed
//!   before pricing, and a degraded (CPU-only) engine proactively
//!   tightens the bound from its live throughput estimate
//!   (DESIGN.md §13). The default policy is fully permissive.
//! * [`KnnEngine::flush`] prices one micro-batch with the same
//!   machinery as the batch path (`GridIndex::build_query_ranks` +
//!   `sched::build_queue_keyed`, densest cells first) and drains it
//!   through the session-owned three-stage GPU pipeline, with CPU
//!   ranks chunking the sparse tail - dense micro-batches go to the
//!   device, sparse singletons resolve on the host.
//! * [`KnnEngine::serve`] runs the flush loop until every client has
//!   disconnected and returns a [`ServiceReport`] with per-request
//!   p50/p99 latency next to the throughput numbers.
//!
//! # Churn
//!
//! The resident corpus is mutable: [`Client::insert`] appends points
//! (ids are append-only - every id a client ever received stays
//! valid), [`Client::remove`] un-indexes them, and the serve loop
//! serializes mutations against query flushes in strict FIFO order.
//! Under the hood [`KnnEngine::insert`] / [`KnnEngine::remove`] patch
//! the resident `GridIndex` in place (canonical CSR row patches) and
//! buffer deltas on the `KdTree` (brute-scanned at query time, merged
//! at a threshold - the Bigger Buffer k-d Trees design), while the
//! grid's mutation epoch flows through the queue generation stamp into
//! the GPU drain state, invalidating the packed brute corpus tiles so
//! every flush reads one consistent snapshot.
//! [`KnnEngine::rebuilt`] derives a rebuild-from-scratch twin over the
//! same live set - the oracle the churn harness (rust/tests/churn.rs)
//! asserts bit-equivalence against at every flush boundary.
//!
//! The serve loop additionally bounds each coalesced micro-batch by a
//! query-count cap ([`KnnEngine::set_flush_cap`]): a deep backlog is
//! chopped into capped flushes instead of one giant join, so a late
//! client's request lands within a bounded number of flushes
//! (regression-tested in `rust/tests/service.rs`).
//!
//! # Determinism
//!
//! `cpu_ranks == 0` selects the *deterministic replay* mode: the GPU
//! master drains the entire micro-batch queue through one pinned
//! backend tier (ρ pinned to 0; `Auto` routing - whose per-claim
//! decisions depend on batch composition - is pinned to
//! [`BackendMode::Grid`], while an explicitly forced `Grid` or `Brute`
//! backend is kept, both being per-query deterministic), and a single
//! CPU rank re-solves the recirculated Q^Fail afterwards. In that mode
//! each query's result is a pure function of (corpus, ε, k) - which
//! side computes it, and every distance bit, is independent of how the
//! stream was chopped into flushes - so any interleaving of client
//! submissions is bit-identical to the one-shot batch join on the
//! union of the queries (property-tested in `rust/tests/service.rs`
//! across all three `DrainMode`s). With `cpu_ranks > 0` the
//! dense/sparse split is discovered per flush at run time and results
//! are exact but carry the usual f32-device vs f64-host rounding
//! difference per query.

use std::collections::{HashMap, VecDeque};
use std::sync::{mpsc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::admission::{
    AdmissionPolicy, AdmissionStats, CapacityController, Rejected,
    ShedPolicy, TokenBucket,
};
use crate::core::{Dataset, KnnResult};
use crate::cpu;
use crate::data::variance::reorder_by_variance;
use crate::epsilon::EpsilonSelection;
use crate::gpu::join::{gpu_join_drain_with, DrainState};
use crate::gpu::{DrainMode, GpuJoinParams, GpuJoinStats};
use crate::index::{GridIndex, KdTree, QueryKey};
use crate::runtime::Engine;
use crate::sched::{self, BackendMode};
use crate::util::pool::lock_unpoisoned;

use super::HybridParams;

/// A resident KNN engine: one corpus, indexed once, served many times.
///
/// Construction ([`KnnEngine::build`]) pays the one-shot costs - the
/// variance REORDER, device ε selection over the corpus alone (so the
/// grid geometry never depends on which queries later arrive), grid and
/// kd-tree builds - and every subsequent [`flush`](KnnEngine::flush)
/// reuses them plus the session-owned GPU drain arenas and the PJRT
/// executable cache.
pub struct KnnEngine<'e> {
    engine: &'e Engine,
    params: HybridParams,
    /// the corpus after the variance REORDER (dimension permutation)
    corpus: Dataset,
    /// dimension permutation applied to the corpus; incoming query
    /// batches are permuted the same way so distances are preserved
    perm: Option<Vec<usize>>,
    eps: EpsilonSelection,
    grid: GridIndex,
    tree: KdTree,
    /// reusable GPU drain state: pipeline staging sets + brute-tier
    /// corpus tile cache, alive across flushes
    drain: DrainState,
    hw: usize,
    flushes: usize,
    /// serve-loop micro-batch bound, in queries (see
    /// [`KnnEngine::set_flush_cap`])
    flush_cap: usize,
}

/// Telemetry of one [`KnnEngine::flush`].
#[derive(Debug, Clone, Default)]
pub struct FlushReport {
    /// queries in this micro-batch
    pub queries: usize,
    /// queries claimed off the dense head by the GPU master
    pub q_gpu: usize,
    /// queries claimed off the sparse tail by the CPU ranks
    pub q_cpu: usize,
    /// GPU claims with < K in-ε neighbors, re-solved on the CPU via
    /// recirculation
    pub q_fail: usize,
    /// queries the GPU solved exactly
    pub solved_on_gpu: usize,
    /// GPU claims executed
    pub gpu_claims: usize,
    /// failed GPU claim attempts (injected or real)
    pub gpu_faults: usize,
    /// true when the GPU master demoted itself and this flush finished
    /// CPU-only
    pub degraded: bool,
    /// wall seconds of the flush (queue build + drain)
    pub secs: f64,
}

impl<'e> KnnEngine<'e> {
    /// Build the resident engine over `corpus` with `params`.
    ///
    /// ε is selected from the corpus alone (self-estimator), not from
    /// any query stream - a resident index cannot re-derive its grid
    /// per arrival, and corpus-only selection is what makes flush
    /// results independent of batch composition (see module docs).
    pub fn build(
        engine: &'e Engine,
        corpus: &Dataset,
        params: HybridParams,
    ) -> Result<KnnEngine<'e>> {
        let (corpus_re, perm) = if params.reorder {
            let (c, p) = reorder_by_variance(corpus);
            (c, Some(p))
        } else {
            (corpus.clone(), None)
        };
        let eps = params
            .selector
            .select(engine, &corpus_re, params.k, params.beta)?;
        let grid = GridIndex::build(&corpus_re, params.m, eps.eps);
        let tree = KdTree::build(&corpus_re);
        let hw = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Ok(KnnEngine {
            engine,
            params,
            corpus: corpus_re,
            perm,
            eps,
            grid,
            tree,
            drain: DrainState::new(),
            hw,
            flushes: 0,
            flush_cap: usize::MAX,
        })
    }

    /// The ε selection driving the resident grid.
    pub fn eps(&self) -> &EpsilonSelection {
        &self.eps
    }

    /// The parameters the engine was built with.
    pub fn params(&self) -> &HybridParams {
        &self.params
    }

    /// Corpus size (points of the resident relation S).
    pub fn corpus_len(&self) -> usize {
        self.corpus.len()
    }

    /// Corpus dimensionality; every query batch must match it.
    pub fn dims(&self) -> usize {
        self.corpus.dims()
    }

    /// Micro-batches flushed so far.
    pub fn flushes(&self) -> usize {
        self.flushes
    }

    /// Bound each coalesced serve-loop micro-batch to at most `cap`
    /// queries (floored at 1). A single client request larger than the
    /// cap still flushes whole - requests are never split - but a deep
    /// backlog of requests is chopped into capped flushes, bounding
    /// how long any one client waits behind it. Default: unbounded.
    pub fn set_flush_cap(&mut self, cap: usize) {
        self.flush_cap = cap.max(1);
    }

    /// Currently live (indexed) corpus points; `corpus_len` minus the
    /// tombstoned rows under churn.
    pub fn live_len(&self) -> usize {
        self.grid.indexed_points()
    }

    /// The resident index's mutation epoch: bumped once per inserted or
    /// removed point, threaded through the queue generation stamp into
    /// the GPU drain caches.
    pub fn epoch(&self) -> u64 {
        self.grid.epoch()
    }

    /// Insert a batch of points into the resident corpus, returning the
    /// corpus id assigned to each row (append-only: ids of earlier
    /// points never move). The points are permuted into the resident
    /// dimension order, appended to the corpus, and patched into both
    /// indexes; amortized maintenance (grid re-sort, kd-tree delta
    /// merge) runs once per batch.
    pub fn insert(&mut self, points: &Dataset) -> Result<Vec<u32>> {
        anyhow::ensure!(
            points.dims() == self.corpus.dims(),
            "insert dims {} != corpus dims {}",
            points.dims(),
            self.corpus.dims()
        );
        let pts = match &self.perm {
            Some(p) => points.permute_dims(p),
            None => points.clone(),
        };
        let mut ids = Vec::with_capacity(pts.len());
        for i in 0..pts.len() {
            let id = self.corpus.push_row(pts.point(i));
            self.grid.insert(&self.corpus, id);
            self.tree.insert(&self.corpus, id);
            ids.push(id);
        }
        self.grid.maybe_rebuild(&self.corpus);
        self.tree.maybe_merge(&self.corpus);
        Ok(ids)
    }

    /// Un-index corpus points by id, returning how many were live.
    /// Rows stay allocated (ids are append-only); removed points are
    /// invisible to every later query. Unknown or already-removed ids
    /// are ignored.
    pub fn remove(&mut self, ids: &[u32]) -> usize {
        let mut n = 0usize;
        for &id in ids {
            let g = self.grid.remove(id);
            let t = self.tree.remove(id);
            debug_assert_eq!(g, t, "grid/tree live sets diverged at id {id}");
            n += usize::from(g);
        }
        self.grid.maybe_rebuild(&self.corpus);
        self.tree.maybe_merge(&self.corpus);
        n
    }

    /// A rebuild-from-scratch twin: same engine handle, same corpus
    /// snapshot, same live set and parameters, but with both indexes
    /// assembled from scratch (frozen grid geometry) and a fresh GPU
    /// drain state. The churn harness flushes identical queries through
    /// both engines and asserts bit-equivalence at every boundary.
    pub fn rebuilt(&self) -> KnnEngine<'e> {
        KnnEngine {
            engine: self.engine,
            params: self.params.clone(),
            corpus: self.corpus.clone(),
            perm: self.perm.clone(),
            eps: self.eps.clone(),
            grid: self.grid.rebuilt(&self.corpus),
            tree: self.tree.rebuilt(&self.corpus),
            drain: DrainState::new(),
            hw: self.hw,
            flushes: self.flushes,
            flush_cap: self.flush_cap,
        }
    }

    /// Join one query micro-batch against the resident corpus: price it
    /// into a density-ordered work queue, drain the dense head through
    /// the session-owned GPU pipeline and the sparse tail through CPU
    /// ranks, and return the per-query neighbor table (row i of the
    /// result is query i of `queries`; neighbor ids index the corpus).
    ///
    /// This is the bipartite join form (no self-exclusion): queries are
    /// their own relation, never part of the corpus.
    pub fn flush(
        &mut self,
        queries: &Dataset,
    ) -> Result<(KnnResult, FlushReport)> {
        anyhow::ensure!(
            queries.dims() == self.corpus.dims(),
            "query dims {} != corpus dims {}",
            queries.dims(),
            self.corpus.dims()
        );
        let t0 = Instant::now();
        let mut result = KnnResult::new(queries.len(), self.params.k);
        if queries.is_empty() {
            self.flushes += 1;
            return Ok((
                result,
                FlushReport {
                    secs: t0.elapsed().as_secs_f64(),
                    ..FlushReport::default()
                },
            ));
        }
        let q_re = match &self.perm {
            Some(p) => queries.permute_dims(p),
            None => queries.clone(),
        };
        // deterministic replay mode: see module docs
        let deterministic = self.params.cpu_ranks == 0;
        let query_ids: Vec<u32> = (0..q_re.len() as u32).collect();
        // one rank-cache pass per flush: O(1) pricing per query after it
        let cache = self.grid.build_query_ranks(&q_re);
        let rho = if deterministic { 0.0 } else { self.params.rho };
        let queue = sched::build_queue_keyed(
            &q_re,
            &self.grid,
            &query_ids,
            self.params.k,
            self.params.gamma,
            rho,
            QueryKey::Cached(&cache),
        );

        // split borrows: the GPU master mutates the session drain state
        // on this thread while the CPU ranks read the index structures
        let engine = self.engine;
        let params = &self.params;
        let corpus = &self.corpus;
        let grid = &self.grid;
        let tree = &self.tree;
        let drain = &mut self.drain;
        let hw = self.hw;
        let eps = self.eps.eps;

        let gpu_params = GpuJoinParams {
            k: params.k,
            eps,
            tile_class: params.tile_class,
            use_topk: params.use_topk,
            buffer_pairs: params.buffer_pairs,
            streams: params.streams,
            assign: params.assign,
            estimator_frac: 0.01,
            exclude_self: false,
            drain: if hw > 1 { params.gpu_drain } else { DrainMode::Sync },
            fault: params.fault.clone(),
            recovery: params.recovery,
            // pinning a tier is part of the deterministic replay
            // contract: Auto routes per claim, and claim composition
            // depends on how the stream was chopped into flushes. Only
            // Auto needs pinning - a forced Grid or Brute backend is
            // already per-query deterministic (fixed candidate walk
            // resp. fixed id-ascending corpus tiles) and is kept, which
            // lets the churn harness replay both tiers exactly.
            backend: if deterministic && params.backend == BackendMode::Auto {
                BackendMode::Grid
            } else {
                params.backend
            },
        };
        let slots = result.slots();
        // deterministic mode drains the whole queue through the GPU
        // master; otherwise mirror the one-shot dynamic join's gating
        let pos_cap = if deterministic || hw > 1 {
            queue.len()
        } else {
            queue.dense_prefix()
        };
        // release the CPU ranks on every GPU exit path - normal, error,
        // or panic - so the scope join cannot hang
        struct GpuDoneGuard<'a>(&'a sched::WorkQueue);
        impl Drop for GpuDoneGuard<'_> {
            fn drop(&mut self) {
                self.0.set_gpu_done();
            }
        }
        let run_gpu =
            |drain: &mut DrainState| -> Option<Result<GpuJoinStats>> {
                let _done = GpuDoneGuard(&queue);
                if queue.head_open(pos_cap) {
                    Some(gpu_join_drain_with(
                        engine, &q_re, corpus, grid, &queue, &gpu_params,
                        &slots, pos_cap, drain,
                    ))
                } else {
                    None
                }
            };
        let run_cpu = |ranks: usize| {
            cpu::exact_ann_drain(
                corpus, tree, &q_re, &queue, params.k, ranks, false, &slots,
            )
        };
        let cpu_ranks = params.cpu_ranks;
        let (gpu_out, cpu_out) = if deterministic {
            // sequential: GPU first over everything, then one CPU rank
            // absorbs the recirculated Q^Fail (and any ρ'd tail)
            let g = run_gpu(drain);
            let c = run_cpu(1);
            (g, c)
        } else if hw > 1 {
            std::thread::scope(|scope| {
                let cpu_handle = scope.spawn(|| run_cpu(cpu_ranks));
                let gpu_out = run_gpu(drain);
                (gpu_out, cpu_handle.join().expect("cpu ranks panicked"))
            })
        } else {
            let g = run_gpu(drain);
            let c = run_cpu(cpu_ranks);
            (g, c)
        };
        let gpu_stats = gpu_out.transpose()?;
        drop(slots); // all writers done; `result` is complete in place

        let mut rep = FlushReport {
            queries: queries.len(),
            q_gpu: queue.claimed_head(),
            q_cpu: queue.claimed_tail(),
            secs: t0.elapsed().as_secs_f64(),
            ..FlushReport::default()
        };
        if let Some(g) = &gpu_stats {
            rep.q_fail = g.failed.len();
            rep.solved_on_gpu = g.solved;
            rep.gpu_claims = g.batches;
            rep.gpu_faults = g.gpu_faults;
            rep.degraded = g.degraded;
        }
        let _ = cpu_out; // claim telemetry not aggregated per flush
        debug_assert_eq!(
            rep.q_gpu + rep.q_cpu,
            rep.queries,
            "exactly-once: head + tail claims must partition the batch"
        );
        self.flushes += 1;
        Ok((result, rep))
    }

    /// Run the serving loop on this thread (the engine holds the PJRT
    /// client, which is not `Send` - the GPU-master rank of the paper):
    /// wait for pending requests, take a strict-FIFO prefix of them -
    /// leading mutations applied immediately, then query requests
    /// coalesced into one micro-batch bounded by the flush cap - flush,
    /// reply to each client, and repeat until every [`Client`] handle
    /// has been dropped and the pending queue is empty. Mutations never
    /// reorder against query flushes: a request sees exactly the
    /// corpus state produced by every request queued before it.
    pub fn serve(&mut self, ingress: &Ingress) -> Result<ServiceReport> {
        let t0 = Instant::now();
        let mut lat: Vec<f64> = Vec::new();
        let mut rep = ServiceReport::default();
        lock_unpoisoned(&ingress.state).terminated = false;
        // On every serve exit - normal return, error, or panic - mark
        // the ingress terminated and fail whatever is still queued with
        // one typed rejection each, so no client (present or future)
        // can ever park forever on an ingress nobody serves.
        let _term = TerminationGuard(ingress);
        loop {
            let batch: Vec<Pending> = {
                let mut st = lock_unpoisoned(&ingress.state);
                loop {
                    // shed points (DESIGN.md §13): only here, between
                    // cycles under the ingress lock - never once a
                    // request has been taken into a flush
                    Ingress::shed_expired_locked(&mut st, Instant::now());
                    Ingress::shed_over_capacity_locked(&mut st);
                    if !st.pending.is_empty() || st.open_clients == 0 {
                        break;
                    }
                    st = match ingress.cv.wait(st) {
                        Ok(g) => g,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                }
                // strict-FIFO prefix: any leading run of mutations,
                // then query requests up to the flush cap (always at
                // least one request - oversized requests flush alone)
                let mut taken: Vec<Pending> = Vec::new();
                let mut queries = 0usize;
                while let Some(front) = st.pending.front() {
                    match &front.op {
                        PendingOp::Insert { .. } | PendingOp::Remove { .. } => {
                            if queries > 0 {
                                break; // mutation after queries: next cycle
                            }
                        }
                        PendingOp::Query { n, .. } => {
                            if queries > 0 && queries + n > self.flush_cap {
                                break; // cap reached: next cycle
                            }
                        }
                    }
                    let p = st.pending.pop_front().expect("front just observed");
                    st.note_taken(&p);
                    if let PendingOp::Query { n, .. } = &p.op {
                        queries += n;
                    }
                    taken.push(p);
                }
                taken
            };
            if batch.is_empty() {
                break; // all clients disconnected, nothing queued
            }
            // apply mutations (all precede any query in the prefix),
            // then coalesce the query requests into one micro-batch
            let dims = self.corpus.dims();
            let mut flat: Vec<f32> = Vec::new();
            let mut queued: Vec<(usize, Instant, mpsc::Sender<Reply>)> = Vec::new();
            for p in batch {
                let Pending { op, submitted, reply, .. } = p;
                match op {
                    PendingOp::Insert { points, n, dims: pdims } => {
                        anyhow::ensure!(
                            pdims == dims && points.len() == n * dims,
                            "insert dims {pdims} != corpus dims {dims}"
                        );
                        let ids = self.insert(&Dataset::new(points, dims))?;
                        rep.inserts += ids.len();
                        rep.requests += 1;
                        lat.push(submitted.elapsed().as_secs_f64());
                        // a client that gave up is not a service error
                        let _ = reply.send(Reply::Inserted(ids));
                    }
                    PendingOp::Remove { ids } => {
                        let n = self.remove(&ids);
                        rep.removes += n;
                        rep.requests += 1;
                        lat.push(submitted.elapsed().as_secs_f64());
                        let _ = reply.send(Reply::Removed(n));
                    }
                    PendingOp::Query { points, n, dims: pdims } => {
                        anyhow::ensure!(
                            pdims == dims && points.len() == n * dims,
                            "request dims {pdims} != corpus dims {dims}"
                        );
                        flat.extend_from_slice(&points);
                        queued.push((n, submitted, reply));
                    }
                }
            }
            if queued.is_empty() {
                continue; // mutation-only cycle: nothing to flush
            }
            let queries = Dataset::new(flat, dims);
            let flush_seq = self.flushes;
            let (result, frep) = self.flush(&queries)?;
            // feed the capacity controller: a degraded (CPU-only)
            // flush tightens the effective admission bound to what the
            // live throughput estimate can drain within the horizon; a
            // healthy flush restores the configured bound
            lock_unpoisoned(&ingress.state).cap.note_flush(
                frep.queries,
                frep.secs,
                frep.degraded,
            );
            // slice the flush result back into per-request replies
            let mut start = 0usize;
            for (n, submitted, reply) in queued {
                let mut results = Vec::with_capacity(n);
                for q in start..start + n {
                    let ns = result.get(q);
                    results.push(QueryResult {
                        ids: ns.ids().to_vec(),
                        dist2: ns.dist2s().to_vec(),
                    });
                }
                start += n;
                let latency_secs = submitted.elapsed().as_secs_f64();
                lat.push(latency_secs);
                rep.requests += 1;
                let _ = reply.send(Reply::Batch(BatchReply {
                    results,
                    latency_secs,
                    flush_seq,
                }));
            }
            rep.queries += frep.queries;
            rep.flushes += 1;
            rep.max_flush_queries = rep.max_flush_queries.max(frep.queries);
            rep.q_gpu += frep.q_gpu;
            rep.q_cpu += frep.q_cpu;
            rep.q_fail += frep.q_fail;
            rep.gpu_faults += frep.gpu_faults;
            rep.degraded_flushes += usize::from(frep.degraded);
        }
        rep.wall_secs = t0.elapsed().as_secs_f64();
        rep.throughput_qps = if rep.wall_secs > 0.0 {
            rep.queries as f64 / rep.wall_secs
        } else {
            0.0
        };
        lat.sort_by(|a, b| a.total_cmp(b));
        rep.latency_p50 = percentile(&lat, 0.50);
        rep.latency_p99 = percentile(&lat, 0.99);
        rep.latency_mean = if lat.is_empty() {
            0.0
        } else {
            lat.iter().sum::<f64>() / lat.len() as f64
        };
        rep.mean_flush_queries = if rep.flushes > 0 {
            rep.queries as f64 / rep.flushes as f64
        } else {
            0.0
        };
        // fold the ingress's cumulative admission telemetry in (at a
        // normal exit every client has disconnected, so the counters
        // are final)
        let stats = lock_unpoisoned(&ingress.state).stats;
        rep.admitted = stats.admitted;
        rep.shed_overload = stats.shed_overload;
        rep.shed_quota = stats.shed_quota;
        rep.shed_deadline = stats.shed_deadline;
        rep.rejected_requests = stats.rejected_requests;
        Ok(rep)
    }
}

/// Serve-exit drop guard: marks the ingress terminated and fails every
/// still-queued request with one typed [`Rejected::Terminated`], on
/// normal return, error, and panic alike (the small-fix satellite of
/// ISSUE 10: a client handed out after the loop died must get a typed
/// error on first use, never a condvar deadlock).
struct TerminationGuard<'a>(&'a Ingress);

impl Drop for TerminationGuard<'_> {
    fn drop(&mut self) {
        let mut st = lock_unpoisoned(&self.0.state);
        st.terminated = true;
        while let Some(p) = st.pending.pop_front() {
            st.note_taken(&p);
            if p.queries() > 0 {
                st.stats.rejected_requests += 1;
            }
            let _ = p.reply.send(Reply::Rejected(Rejected::Terminated));
        }
        drop(st);
        self.0.cv.notify_all();
    }
}

/// One client's queued request awaiting the serve loop.
struct Pending {
    op: PendingOp,
    submitted: Instant,
    /// absolute shed deadline (queries only; mutations are corpus
    /// state and are never shed)
    deadline: Option<Instant>,
    /// owning client's session id (per-client admission bookkeeping)
    client: u64,
    reply: mpsc::Sender<Reply>,
}

impl Pending {
    /// Query rows this request contributes to the pending bound
    /// (0 for mutations).
    fn queries(&self) -> usize {
        match &self.op {
            PendingOp::Query { n, .. } => *n,
            _ => 0,
        }
    }
}

/// The request payload: a query batch to flush, or a corpus mutation
/// the serve loop serializes against flushes in FIFO order.
enum PendingOp {
    Query { points: Vec<f32>, n: usize, dims: usize },
    Insert { points: Vec<f32>, n: usize, dims: usize },
    Remove { ids: Vec<u32> },
}

/// The serve loop's answer to one request (matched by the blocking
/// client call that enqueued it).
enum Reply {
    Batch(BatchReply),
    Inserted(Vec<u32>),
    Removed(usize),
    /// typed rejection: the request was shed unserved (exactly one of
    /// these per non-answered request - the exactly-once contract)
    Rejected(Rejected),
}

struct IngressState {
    pending: VecDeque<Pending>,
    open_clients: usize,
    /// set by the serve loop's termination guard; submissions after it
    /// fail fast with [`Rejected::Terminated`]
    terminated: bool,
    policy: AdmissionPolicy,
    /// effective-bound controller (configured max, tightened while the
    /// engine is degraded)
    cap: CapacityController,
    /// queued (admitted, unflushed) query rows across all clients
    pending_queries: usize,
    /// queued query rows per client session
    per_client_pending: HashMap<u64, usize>,
    /// per-client token buckets (lazily created on first submission)
    buckets: HashMap<u64, TokenBucket>,
    next_client_id: u64,
    stats: AdmissionStats,
}

impl IngressState {
    /// Bookkeeping when a request leaves the pending queue for any
    /// reason (taken into a flush, shed, or failed at termination).
    fn note_taken(&mut self, p: &Pending) {
        let n = p.queries();
        if n == 0 {
            return;
        }
        self.pending_queries = self.pending_queries.saturating_sub(n);
        if let Some(c) = self.per_client_pending.get_mut(&p.client) {
            *c = c.saturating_sub(n);
        }
    }
}

/// The admission layer between concurrent clients and the serving
/// loop: a shared pending queue plus client bookkeeping. Clients park
/// query batches here ([`Client::query`]); [`KnnEngine::serve`]
/// coalesces everything pending into one micro-batch per flush and
/// exits once every client handle has been dropped.
///
/// All locking recovers from poisoning (`lock_unpoisoned`): a panicked
/// client thread must not brick the resident service.
pub struct Ingress {
    state: Mutex<IngressState>,
    cv: Condvar,
}

impl Default for Ingress {
    fn default() -> Self {
        Ingress::new()
    }
}

impl Ingress {
    /// An empty ingress with no registered clients and the fully
    /// permissive default policy (unbounded queue, no quota, no
    /// deadline) - PR 8's implicit-pile-up behavior, exactly.
    pub fn new() -> Self {
        Ingress::with_policy(AdmissionPolicy::default())
    }

    /// An empty ingress enforcing `policy` at admission and in the
    /// serve loop's shed points.
    pub fn with_policy(policy: AdmissionPolicy) -> Self {
        // the tightening horizon: how much queued work the degraded
        // engine should be able to drain "in time" - the deadline if
        // the policy has one, else a one-second default
        let horizon =
            policy.default_deadline.unwrap_or(Duration::from_secs(1));
        let cap = CapacityController::new(policy.max_pending_queries, horizon);
        Ingress {
            state: Mutex::new(IngressState {
                pending: VecDeque::new(),
                open_clients: 0,
                terminated: false,
                policy,
                cap,
                pending_queries: 0,
                per_client_pending: HashMap::new(),
                buckets: HashMap::new(),
                next_client_id: 0,
                stats: AdmissionStats::default(),
            }),
            cv: Condvar::new(),
        }
    }

    /// Register a client session. The serving loop runs until every
    /// handle returned here has been dropped - register all clients
    /// *before* starting [`KnnEngine::serve`], or the loop may observe
    /// zero clients and exit immediately.
    pub fn client(&self) -> Client<'_> {
        let mut st = lock_unpoisoned(&self.state);
        st.open_clients += 1;
        let id = st.next_client_id;
        st.next_client_id += 1;
        drop(st);
        Client { ingress: self, id }
    }

    /// Registered clients that have not yet disconnected.
    pub fn open_clients(&self) -> usize {
        lock_unpoisoned(&self.state).open_clients
    }

    /// Requests currently parked in the pending queue (tests use this
    /// to sequence submissions deterministically against the serve
    /// loop).
    pub fn pending_len(&self) -> usize {
        lock_unpoisoned(&self.state).pending.len()
    }

    /// Query rows currently parked in the pending queue (the quantity
    /// the admission bounds are enforced over).
    pub fn pending_queries(&self) -> usize {
        lock_unpoisoned(&self.state).pending_queries
    }

    /// The effective global pending bound right now: the policy's
    /// `max_pending_queries`, tightened while the engine is degraded
    /// (see [`CapacityController`]).
    pub fn effective_max_pending(&self) -> usize {
        lock_unpoisoned(&self.state).cap.effective_max()
    }

    /// Cumulative admission telemetry (also folded into the
    /// [`ServiceReport`] when the serve loop exits).
    pub fn admission_stats(&self) -> AdmissionStats {
        lock_unpoisoned(&self.state).stats
    }

    /// Shed queued query requests whose deadline has passed: each gets
    /// exactly one [`Rejected::DeadlineExpired`]. Runs under the
    /// ingress lock at the serve loop's cycle boundary - *before*
    /// pricing, never mid-flush, so a request is either flushed whole
    /// or shed whole.
    fn shed_expired_locked(st: &mut IngressState, now: Instant) {
        let mut i = 0;
        while i < st.pending.len() {
            let expired = match (&st.pending[i].op, st.pending[i].deadline) {
                (PendingOp::Query { .. }, Some(dl)) => dl <= now,
                _ => false,
            };
            if !expired {
                i += 1;
                continue;
            }
            let p = st.pending.remove(i).expect("index in bounds");
            st.note_taken(&p);
            st.stats.shed_deadline += p.queries();
            st.stats.rejected_requests += 1;
            let missed_by = now
                .saturating_duration_since(p.deadline.expect("expired implies dated"));
            let _ = p
                .reply
                .send(Reply::Rejected(Rejected::DeadlineExpired { missed_by }));
        }
    }

    /// Shed queued query requests until the pending rows fit the
    /// effective bound again (only ever needed after degradation
    /// tightened the bound below what admission already accepted).
    /// Victim order follows the policy: newest first, or nearest
    /// deadline first. Each victim gets one [`Rejected::Overloaded`].
    fn shed_over_capacity_locked(st: &mut IngressState) {
        while st.pending_queries > st.cap.effective_max() {
            let victim = match st.policy.shed_policy {
                ShedPolicy::NewestFirst => {
                    st.pending.iter().rposition(|p| p.queries() > 0)
                }
                ShedPolicy::ByDeadline => {
                    let mut best_idx: Option<usize> = None;
                    let mut best_dl: Option<Instant> = None;
                    for (idx, p) in st.pending.iter().enumerate() {
                        if p.queries() == 0 {
                            continue;
                        }
                        let better = match (best_idx, best_dl, p.deadline) {
                            (None, _, _) => true,
                            // nearer deadline dies first
                            (Some(_), Some(bd), Some(d)) => d < bd,
                            // any deadline beats none
                            (Some(_), None, Some(_)) => true,
                            // among undated requests, newest dies first
                            (Some(_), None, None) => true,
                            (Some(_), Some(_), None) => false,
                        };
                        if better {
                            best_idx = Some(idx);
                            best_dl = p.deadline;
                        }
                    }
                    best_idx
                }
            };
            let Some(idx) = victim else { break };
            let p = st.pending.remove(idx).expect("index in bounds");
            st.note_taken(&p);
            st.stats.shed_overload += p.queries();
            st.stats.rejected_requests += 1;
            let hint = st.cap.retry_after_hint(st.pending_queries);
            let _ = p.reply.send(Reply::Rejected(Rejected::Overloaded {
                retry_after_hint: hint,
            }));
        }
    }
}

/// One client session handle. Dropping it disconnects the client;
/// when the last client disconnects the serving loop drains what is
/// pending and returns.
///
/// Under a bounding [`AdmissionPolicy`] the blocking calls can fail
/// fast with a typed [`Rejected`] in the error chain
/// (`err.downcast_ref::<Rejected>()`) instead of queueing; see the
/// variant docs for which rejections are synchronous and which arrive
/// from the serve loop's shed points.
pub struct Client<'i> {
    ingress: &'i Ingress,
    /// ingress-assigned session id (per-client admission bookkeeping)
    id: u64,
}

impl Client<'_> {
    /// Enqueue one request and block until the serve loop answers.
    /// Admission control runs here, synchronously under the ingress
    /// lock: a rejected request never occupies a queue slot.
    fn submit(
        &self,
        op: PendingOp,
        deadline: Option<Duration>,
    ) -> Result<Reply> {
        let (tx, rx) = mpsc::channel();
        {
            let mut st = lock_unpoisoned(&self.ingress.state);
            if st.terminated {
                return Err(anyhow::Error::new(Rejected::Terminated));
            }
            let now = Instant::now();
            let n = match &op {
                PendingOp::Query { n, .. } => *n,
                _ => 0,
            };
            // mutations (n == 0) are never bounded, quota'd, or shed:
            // they are corpus state transitions, not query load, and
            // dropping one would silently fork the corpus history
            if n > 0 {
                let mine = st
                    .per_client_pending
                    .get(&self.id)
                    .copied()
                    .unwrap_or(0);
                if st.pending_queries.saturating_add(n)
                    > st.cap.effective_max()
                    || mine.saturating_add(n)
                        > st.policy.max_pending_per_client
                {
                    let hint = st.cap.retry_after_hint(st.pending_queries);
                    st.stats.shed_overload += n;
                    st.stats.rejected_requests += 1;
                    return Err(anyhow::Error::new(Rejected::Overloaded {
                        retry_after_hint: hint,
                    }));
                }
                if let Some(quota) = st.policy.quota {
                    let bucket = st
                        .buckets
                        .entry(self.id)
                        .or_insert_with(|| TokenBucket::new(&quota, now));
                    if let Err(retry_after) = bucket.try_take(n as f64, now)
                    {
                        st.stats.shed_quota += n;
                        st.stats.rejected_requests += 1;
                        return Err(anyhow::Error::new(
                            Rejected::QuotaExceeded { retry_after },
                        ));
                    }
                }
                st.pending_queries += n;
                *st.per_client_pending.entry(self.id).or_insert(0) += n;
                st.stats.admitted += n;
                st.stats.admitted_requests += 1;
            }
            let deadline = if n > 0 {
                deadline
                    .or(st.policy.default_deadline)
                    .and_then(|d| now.checked_add(d))
            } else {
                None
            };
            st.pending.push_back(Pending {
                op,
                submitted: now,
                deadline,
                client: self.id,
                reply: tx,
            });
        }
        self.ingress.cv.notify_all();
        rx.recv()
            .map_err(|_| anyhow::anyhow!("service terminated before replying"))
    }

    /// Submit one query batch and block until its results arrive from
    /// the serving loop. Rows of `batch` map 1:1 onto
    /// [`BatchReply::results`]; neighbor ids index the served corpus.
    ///
    /// Errors if the service terminated without replying, or - under a
    /// bounding [`AdmissionPolicy`] - with a typed [`Rejected`] in the
    /// error chain when the request was rejected at admission or shed
    /// from the queue.
    pub fn query(&self, batch: &Dataset) -> Result<BatchReply> {
        self.query_inner(batch, None)
    }

    /// [`Client::query`] with an explicit per-request deadline
    /// (overriding the policy's `default_deadline`): if the request is
    /// still queued when the deadline passes, the serve loop sheds it
    /// before pricing and this call returns
    /// [`Rejected::DeadlineExpired`].
    pub fn query_with_deadline(
        &self,
        batch: &Dataset,
        deadline: Duration,
    ) -> Result<BatchReply> {
        self.query_inner(batch, Some(deadline))
    }

    fn query_inner(
        &self,
        batch: &Dataset,
        deadline: Option<Duration>,
    ) -> Result<BatchReply> {
        match self.submit(
            PendingOp::Query {
                points: batch.raw().to_vec(),
                n: batch.len(),
                dims: batch.dims(),
            },
            deadline,
        )? {
            Reply::Batch(b) => Ok(b),
            Reply::Rejected(r) => Err(anyhow::Error::new(r)),
            _ => Err(anyhow::anyhow!("service answered query with wrong reply kind")),
        }
    }

    /// Submit a corpus insertion and block until it has been applied,
    /// returning the corpus id assigned to each row. The serve loop
    /// serializes mutations against query flushes in FIFO order: every
    /// query enqueued after this call sees the inserted points.
    /// Mutations are exempt from bounds, quotas, and shedding - only
    /// [`Rejected::Terminated`] can reject one.
    pub fn insert(&self, batch: &Dataset) -> Result<Vec<u32>> {
        match self.submit(
            PendingOp::Insert {
                points: batch.raw().to_vec(),
                n: batch.len(),
                dims: batch.dims(),
            },
            None,
        )? {
            Reply::Inserted(ids) => Ok(ids),
            Reply::Rejected(r) => Err(anyhow::Error::new(r)),
            _ => Err(anyhow::anyhow!("service answered insert with wrong reply kind")),
        }
    }

    /// Submit a corpus removal (by id) and block until it has been
    /// applied, returning how many of the ids were live. Unknown or
    /// already-removed ids are ignored. Exempt from bounds, quotas,
    /// and shedding like [`Client::insert`].
    pub fn remove(&self, ids: &[u32]) -> Result<usize> {
        match self.submit(PendingOp::Remove { ids: ids.to_vec() }, None)? {
            Reply::Removed(n) => Ok(n),
            Reply::Rejected(r) => Err(anyhow::Error::new(r)),
            _ => Err(anyhow::anyhow!("service answered remove with wrong reply kind")),
        }
    }
}

impl Drop for Client<'_> {
    fn drop(&mut self) {
        let mut st = lock_unpoisoned(&self.ingress.state);
        st.open_clients -= 1;
        st.per_client_pending.remove(&self.id);
        st.buckets.remove(&self.id);
        drop(st);
        self.ingress.cv.notify_all();
    }
}

/// Neighbors of one query, as returned to a client: parallel id /
/// squared-distance lanes, ascending by distance, ids indexing the
/// served corpus.
#[derive(Debug, Clone)]
pub struct QueryResult {
    /// corpus ids of the (up to) K nearest neighbors
    pub ids: Vec<u32>,
    /// squared distances, matching `ids` positionally
    pub dist2: Vec<f64>,
}

/// Reply to one [`Client::query`] call.
#[derive(Debug, Clone)]
pub struct BatchReply {
    /// one entry per submitted query row, in submission order
    pub results: Vec<QueryResult>,
    /// seconds from submission to reply (queueing + flush), as measured
    /// by the serving loop
    pub latency_secs: f64,
    /// index of the engine flush that answered this request (the
    /// flush-cap regression test asserts a late client's request lands
    /// a bounded number of flushes behind the backlog)
    pub flush_seq: usize,
}

/// Aggregate telemetry of one [`KnnEngine::serve`] run.
#[derive(Debug, Clone, Default)]
pub struct ServiceReport {
    /// total queries served
    pub queries: usize,
    /// client requests (query batches) answered
    pub requests: usize,
    /// micro-batch flushes executed
    pub flushes: usize,
    /// wall seconds of the serving loop
    pub wall_secs: f64,
    /// queries per second over the loop's wall time
    pub throughput_qps: f64,
    /// median request latency, seconds (submission to reply)
    pub latency_p50: f64,
    /// 99th-percentile request latency, seconds
    pub latency_p99: f64,
    /// mean request latency, seconds
    pub latency_mean: f64,
    /// mean coalesced micro-batch size (queries per flush)
    pub mean_flush_queries: f64,
    /// largest coalesced micro-batch (queries in one flush) - bounded
    /// by the flush cap plus at most one oversized single request
    pub max_flush_queries: usize,
    /// corpus points inserted via client mutation requests
    pub inserts: usize,
    /// corpus points removed (live at removal time) via client requests
    pub removes: usize,
    /// queries drained by the GPU master across all flushes
    pub q_gpu: usize,
    /// queries drained by the CPU ranks across all flushes
    pub q_cpu: usize,
    /// recirculated Q^Fail queries across all flushes
    pub q_fail: usize,
    /// failed GPU claim attempts across all flushes
    pub gpu_faults: usize,
    /// flushes that finished with a demoted (CPU-only) GPU master
    pub degraded_flushes: usize,
    /// query rows admitted into the pending queue (cumulative over the
    /// ingress). Every admitted row is either flushed (counted in
    /// `queries`) or later shed from the queue with a typed rejection
    /// (counted in a `shed_*` column) - exactly one of the two.
    pub admitted: usize,
    /// query rows rejected or shed at a full pending bound
    pub shed_overload: usize,
    /// query rows rejected by per-client token buckets
    pub shed_quota: usize,
    /// query rows shed because their deadline expired while queued
    pub shed_deadline: usize,
    /// query requests that received a typed rejection (exactly one
    /// each)
    pub rejected_requests: usize,
}

/// Nearest-rank percentile of an ascending-sorted sample: `q` in
/// [0, 1], 0 on an empty sample. Used for the service latency
/// telemetry and reusable by the benches.
pub fn percentile(sorted_ascending: &[f64], q: f64) -> f64 {
    if sorted_ascending.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ascending.len() - 1) as f64 * q.clamp(0.0, 1.0))
        .round() as usize;
    sorted_ascending[idx.min(sorted_ascending.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.0), 7.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 51.0);
        assert_eq!(percentile(&xs, 0.99), 99.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
    }

    #[test]
    fn ingress_client_bookkeeping() {
        let ingress = Ingress::new();
        assert_eq!(ingress.open_clients(), 0);
        let a = ingress.client();
        let b = ingress.client();
        assert_eq!(ingress.open_clients(), 2);
        drop(a);
        assert_eq!(ingress.open_clients(), 1);
        drop(b);
        assert_eq!(ingress.open_clients(), 0);
    }

    #[test]
    fn dropped_client_wakes_empty_serve() {
        // a serve loop parked on an empty pending queue must observe
        // the last disconnect and exit rather than wait forever; here
        // we model just the ingress side of that contract
        let ingress = std::sync::Arc::new(Ingress::new());
        let c = ingress.client();
        let ing = ingress.clone();
        let waiter = std::thread::spawn(move || {
            let mut st = lock_unpoisoned(&ing.state);
            while st.pending.is_empty() && st.open_clients > 0 {
                st = match ing.cv.wait(st) {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
            }
            st.open_clients
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        drop(c);
        assert_eq!(waiter.join().unwrap(), 0);
    }
}
