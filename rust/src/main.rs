//! hybrid-knn-join CLI - the L3 leader entrypoint.
//!
//! Subcommands:
//!   run          run HYBRIDKNN-JOIN on a (surrogate or file) dataset
//!   serve        resident engine + streaming load generator (open/closed loop)
//!   refimpl      run the CPU-only parallel reference implementation
//!   linear       run the GPU-JOINLINEAR brute-force lower bound
//!   gen          generate a surrogate dataset to CSV/bin
//!   experiments  regenerate a paper table/figure (fig2..fig11, table3..6)
//!   artifacts    list the loaded AOT artifacts
//!
//! Examples:
//!   hybrid-knn-join run --dataset susy --n 20000 --k 5 --beta 0 --gamma 0.6 --rho 0.5
//!   hybrid-knn-join experiments fig11
//!   hybrid-knn-join gen --dataset chist --n 10000 --out /tmp/chist.csv

use std::path::Path;

use anyhow::{bail, Context, Result};

use hybrid_knn_join::bench::{self, experiments};
use hybrid_knn_join::prelude::*;
use hybrid_knn_join::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("run") => cmd_run(args),
        Some("serve") => cmd_serve(args),
        Some("refimpl") => cmd_refimpl(args),
        Some("linear") => cmd_linear(args),
        Some("gen") => cmd_gen(args),
        Some("experiments") => cmd_experiments(args),
        Some("artifacts") => cmd_artifacts(),
        _ => {
            print!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
hybrid-knn-join - hybrid CPU/GPU KNN self-join (Gowanlock 2018 reproduction)

usage: hybrid-knn-join <run|serve|refimpl|linear|gen|experiments|artifacts> [options]

common options:
  --dataset <susy|chist|songs|fma>   surrogate workload (default susy)
  --n <points>                       dataset size (default 10000)
  --file <path>                      load dataset from .csv/.bin instead
  --k <K>                            neighbors (default 5)
options for run:
  --m <dims>      indexed dims (default 6)      --beta <0..1>   (default 0)
  --gamma <0..1>  (default 0)                   --rho <0..1>    (default 0)
  --ranks <p>     EXACT-ANN ranks (default 3)   --no-reorder    disable REORDER
  --no-topk       disable the on-device top-k path
  --backend <auto|grid|brute>  GPU tier routing (default auto: per-claim
                  crossover heuristic over m, k and candidate density)
options for serve (resident engine + streaming load generator):
  --clients <c>   concurrent client sessions (default 4)
  --requests <r>  query batches per client (default 8)
  --batch <q>     queries per batch (default 64)
  --mode <closed|open>  closed loop (back-to-back) or open loop (default closed)
  --rate <qps>    open-loop total arrival rate in queries/sec
  --ranks <p>     CPU ranks; 0 = deterministic replay mode (default 3)
  --qseed <s>     query-stream sampling seed
  --churn <b>     corpus churn: each client inserts b points per request
                  and removes its previous round's b ids (default 0)
  --flush-cap <q> bound each coalesced micro-batch to q queries (default
                  unbounded)
  --max-pending <q>        bound the pending queue to q queries; beyond it
                  submissions get a typed Overloaded rejection (default
                  unbounded)
  --max-pending-client <q> per-client pending bound (default unbounded)
  --quota <qps>   per-client token-bucket rate in queries/sec (default off)
  --quota-burst <q>        token-bucket burst capacity (default 2x --batch)
  --deadline-ms <ms>       per-request deadline; requests still queued past
                  it are shed before pricing (default none)
  --shed <newest|deadline> which queued requests die first when the serve
                  loop sheds (default newest)
  --retry <r>     client-side bounded-backoff retries per rejected request
                  (default 3)
options for experiments:
  positional: fig2 fig6 fig7 fig8 fig9 fig10 fig11 table3 table4 table5 table6 all
  --quick         use the small smoke-test workloads
";

fn load_dataset(args: &Args) -> Result<Dataset> {
    if let Some(file) = args.get("file") {
        let p = Path::new(file);
        return match p.extension().and_then(|e| e.to_str()) {
            Some("csv") => hybrid_knn_join::data::io::read_csv(p),
            _ => hybrid_knn_join::data::io::read_bin(p),
        };
    }
    let name = args.str_or("dataset", "susy");
    let n = args.usize_or("n", 10_000);
    let spec =
        by_name(&name, n).with_context(|| format!("unknown dataset {name:?}"))?;
    Ok(spec.generate(args.u64_or("seed", 0xDA7A)))
}

fn cmd_run(args: &Args) -> Result<()> {
    let engine = Engine::load_default()?;
    let data = load_dataset(args)?;
    let mut p = HybridParams::new(args.usize_or("k", 5));
    p.m = args.usize_or("m", 6);
    p.beta = args.f64_or("beta", 0.0);
    p.gamma = args.f64_or("gamma", 0.0);
    p.rho = args.f64_or("rho", 0.0);
    p.cpu_ranks = args.usize_or("ranks", 3);
    p.reorder = !args.flag("no-reorder");
    p.use_topk = args.flag("topk");
    p.backend = match args.str_or("backend", "auto").as_str() {
        "auto" => hybrid_knn_join::sched::BackendMode::Auto,
        "grid" => hybrid_knn_join::sched::BackendMode::Grid,
        "brute" => hybrid_knn_join::sched::BackendMode::Brute,
        other => bail!("unknown backend {other:?} (auto|grid|brute)"),
    };
    println!(
        "HYBRIDKNN-JOIN |D|={} n={} k={} m={} beta={} gamma={} rho={}",
        data.len(), data.dims(), p.k, p.m, p.beta, p.gamma, p.rho
    );
    let rep = HybridKnnJoin::run(&engine, &data, &p)?;
    println!(
        "eps: mean={:.4} default={:.4} beta={:.4} final={:.4}",
        rep.eps.eps_mean, rep.eps.eps_default, rep.eps.eps_beta, rep.eps.eps
    );
    println!(
        "split: |Q_gpu|={} |Q_cpu|={} (rho moved {})  Q_fail={} solved_on_gpu={}",
        rep.q_gpu, rep.q_cpu, rep.rho_moved, rep.q_fail, rep.solved_on_gpu
    );
    println!(
        "gpu: kernel={:.4}s batches={} pairs={} modeled_device={:.4}s",
        rep.gpu_kernel_time, rep.gpu_batches, rep.gpu_result_pairs,
        rep.device_model_seconds
    );
    println!(
        "backend: grid_claims={} brute_claims={} brute_tiles={} \
         brute exec/transfer/filter = {:.4}/{:.4}/{:.4}s",
        rep.grid_claims, rep.brute_claims, rep.brute_tiles,
        rep.brute_exec_time, rep.brute_transfer_time, rep.brute_filter_time
    );
    println!(
        "T1={:.3e} s/q  T2={:.3e} s/q  rho_model={:.3}",
        rep.t1, rep.t2, rep.rho_model
    );
    if !rep.claims.is_empty() {
        let gpu_claims = rep
            .claims
            .iter()
            .filter(|c| matches!(c.arch, hybrid_knn_join::sched::Arch::Gpu))
            .count();
        let recirc = rep.claims.iter().filter(|c| c.from_recirc).count();
        println!(
            "queue: {} claims (gpu {} / cpu {}, {} recirc drains)",
            rep.claims.len(),
            gpu_claims,
            rep.claims.len() - gpu_claims,
            recirc
        );
    }
    println!("phases:\n{}", rep.timers.report());
    println!(
        "response time (paper convention): {:.4}s  solved {}/{}",
        rep.response_time,
        rep.result.solved_count(p.k.min(data.len().saturating_sub(1))),
        rep.q_gpu + rep.q_cpu
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let engine = Engine::load_default()?;
    let corpus = load_dataset(args)?;
    let mut p = HybridParams::new(args.usize_or("k", 5));
    p.m = args.usize_or("m", 6);
    p.beta = args.f64_or("beta", 0.0);
    p.gamma = args.f64_or("gamma", 0.0);
    p.rho = args.f64_or("rho", 0.0);
    p.cpu_ranks = args.usize_or("ranks", 3);
    p.reorder = !args.flag("no-reorder");
    let clients = args.usize_or("clients", 4).max(1);
    let requests = args.usize_or("requests", 8).max(1);
    let batch = args.usize_or("batch", 64).max(1);
    let mode = args.str_or("mode", "closed");
    let rate = args.f64_or("rate", 0.0);
    let interval = match mode.as_str() {
        "closed" => 0.0,
        "open" => {
            anyhow::ensure!(rate > 0.0, "open loop needs --rate <qps>");
            // total arrival rate split across the client sessions
            clients as f64 * batch as f64 / rate
        }
        other => bail!("unknown mode {other:?} (closed|open)"),
    };

    // query stream: rows sampled (with replacement) from the corpus -
    // works for surrogate and file datasets alike
    let mut rng =
        hybrid_knn_join::util::rng::Rng::new(args.u64_or("qseed", 0x5EED));
    let total_q = clients * requests * batch;
    let ids: Vec<usize> =
        (0..total_q).map(|_| rng.below(corpus.len())).collect();
    let pool = corpus.gather(&ids);
    // churn stream: per request each client inserts `churn` rows
    // (corpus-like, sampled with replacement) and removes the ids it
    // inserted the round before - a steady-state live set
    let churn = args.usize_or("churn", 0);
    let churn_pool = if churn > 0 {
        let cids: Vec<usize> = (0..clients * requests * churn)
            .map(|_| rng.below(corpus.len()))
            .collect();
        Some(corpus.gather(&cids))
    } else {
        None
    };

    let mut session = KnnEngine::build(&engine, &corpus, p)?;
    let flush_cap = args.usize_or("flush-cap", 0);
    if flush_cap > 0 {
        session.set_flush_cap(flush_cap);
    }
    // admission policy: every knob defaults to the permissive PR 8
    // behavior (unbounded queue, no quota, no deadline)
    let mut policy = AdmissionPolicy::default();
    let max_pending = args.usize_or("max-pending", 0);
    if max_pending > 0 {
        policy.max_pending_queries = max_pending;
    }
    let max_pending_client = args.usize_or("max-pending-client", 0);
    if max_pending_client > 0 {
        policy.max_pending_per_client = max_pending_client;
    }
    let deadline_ms = args.f64_or("deadline-ms", 0.0);
    if deadline_ms > 0.0 {
        policy.default_deadline =
            Some(std::time::Duration::from_secs_f64(deadline_ms / 1e3));
    }
    policy.shed_policy = match args.str_or("shed", "newest").as_str() {
        "newest" => ShedPolicy::NewestFirst,
        "deadline" => ShedPolicy::ByDeadline,
        other => bail!("unknown shed policy {other:?} (newest|deadline)"),
    };
    let quota_qps = args.f64_or("quota", 0.0);
    if quota_qps > 0.0 {
        let burst = args.f64_or("quota-burst", (2 * batch) as f64);
        policy.quota = Some(ClientQuota { rate_qps: quota_qps, burst });
    }
    let retry_max = args.usize_or("retry", 3);
    println!(
        "SERVE |S|={} dims={} k={} ranks={} | {clients} clients x \
         {requests} requests x {batch} queries, {mode} loop",
        session.corpus_len(),
        session.dims(),
        session.params().k,
        session.params().cpu_ranks,
    );
    let ingress = Ingress::with_policy(policy);
    // load-generator outcome counters (client side): retries actually
    // taken, requests abandoned after the retry budget, and requests
    // that died to a queued-deadline expiry
    use std::sync::atomic::{AtomicUsize, Ordering};
    let retries = AtomicUsize::new(0);
    let gave_up = AtomicUsize::new(0);
    let deadline_missed = AtomicUsize::new(0);
    let report = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let client = ingress.client();
                let pool = &pool;
                let churn_pool = churn_pool.as_ref();
                let retries = &retries;
                let gave_up = &gave_up;
                let deadline_missed = &deadline_missed;
                s.spawn(move || {
                    let mut prev_ids: Vec<u32> = Vec::new();
                    'requests: for r in 0..requests {
                        if interval > 0.0 {
                            std::thread::sleep(
                                std::time::Duration::from_secs_f64(interval),
                            );
                        }
                        if let Some(cp) = churn_pool {
                            let cstart = (c * requests + r) * churn;
                            let rows: Vec<usize> =
                                (cstart..cstart + churn).collect();
                            match client.insert(&cp.gather(&rows)) {
                                Ok(ids) => {
                                    if !prev_ids.is_empty()
                                        && client.remove(&prev_ids).is_err()
                                    {
                                        break;
                                    }
                                    prev_ids = ids;
                                }
                                Err(_) => break, // service terminated early
                            }
                        }
                        let start = (c * requests + r) * batch;
                        let rows: Vec<usize> =
                            (start..start + batch).collect();
                        let q = pool.gather(&rows);
                        // bounded-backoff retry: a rejected request is
                        // retried up to --retry times, sleeping the
                        // service's retry hint scaled by attempt
                        let mut attempt = 0usize;
                        loop {
                            let e = match client.query(&q) {
                                Ok(_) => continue 'requests,
                                Err(e) => e,
                            };
                            let backoff = match e.downcast_ref::<Rejected>()
                            {
                                Some(Rejected::Overloaded {
                                    retry_after_hint,
                                }) => *retry_after_hint,
                                Some(Rejected::QuotaExceeded {
                                    retry_after,
                                }) => *retry_after,
                                Some(Rejected::DeadlineExpired {
                                    ..
                                }) => {
                                    deadline_missed
                                        .fetch_add(1, Ordering::Relaxed);
                                    continue 'requests;
                                }
                                // terminated service (or a non-typed
                                // error): stop this client
                                _ => break 'requests,
                            };
                            if attempt >= retry_max {
                                gave_up.fetch_add(1, Ordering::Relaxed);
                                continue 'requests;
                            }
                            attempt += 1;
                            retries.fetch_add(1, Ordering::Relaxed);
                            let pause = backoff
                                .mul_f64(attempt as f64)
                                .min(std::time::Duration::from_millis(250));
                            std::thread::sleep(pause);
                        }
                    }
                })
            })
            .collect();
        let rep = session.serve(&ingress);
        for h in handles {
            h.join().expect("client thread panicked");
        }
        rep
    })?;
    println!(
        "served {} queries in {} requests over {} flushes \
         (mean {:.1} queries/flush)",
        report.queries, report.requests, report.flushes,
        report.mean_flush_queries
    );
    println!(
        "throughput: {:.1} q/s   latency p50={:.2}ms p99={:.2}ms \
         mean={:.2}ms",
        report.throughput_qps,
        report.latency_p50 * 1e3,
        report.latency_p99 * 1e3,
        report.latency_mean * 1e3
    );
    println!(
        "split: q_gpu={} q_cpu={} q_fail={}  gpu_faults={} degraded_flushes={}",
        report.q_gpu, report.q_cpu, report.q_fail, report.gpu_faults,
        report.degraded_flushes
    );
    println!(
        "admission: admitted={} shed_overload={} shed_quota={} \
         shed_deadline={} rejected_requests={}",
        report.admitted, report.shed_overload, report.shed_quota,
        report.shed_deadline, report.rejected_requests
    );
    println!(
        "clients: retries={} gave_up={} deadline_missed={}  \
         effective_max_pending={}",
        retries.load(Ordering::Relaxed),
        gave_up.load(Ordering::Relaxed),
        deadline_missed.load(Ordering::Relaxed),
        ingress.effective_max_pending()
    );
    if churn > 0 || flush_cap > 0 {
        println!(
            "churn: inserted={} removed={} live |S|={} epoch={}  \
             max_flush_queries={}",
            report.inserts,
            report.removes,
            session.live_len(),
            session.epoch(),
            report.max_flush_queries
        );
    }
    Ok(())
}

fn cmd_refimpl(args: &Args) -> Result<()> {
    let data = load_dataset(args)?;
    let k = args.usize_or("k", 5);
    let ranks = args.usize_or("ranks", 4);
    let (data, _) = hybrid_knn_join::data::variance::reorder_by_variance(&data);
    let tree = KdTree::build(&data);
    let out = ref_impl(&data, &tree, k, ranks);
    println!(
        "REFIMPL |D|={} n={} k={} ranks={}: {:.4}s ({} solved)",
        data.len(), data.dims(), k, ranks, out.total_time,
        out.result.solved_count(k.min(data.len() - 1))
    );
    Ok(())
}

fn cmd_linear(args: &Args) -> Result<()> {
    let engine = Engine::load_default()?;
    let data = load_dataset(args)?;
    let k = args.usize_or("k", 5);
    let sel = EpsilonSelector::default().select(&engine, &data, k, 0.0)?;
    let queries: Vec<u32> = (0..data.len() as u32).collect();
    let out = brute_join_linear(&engine, &data, &queries, sel.eps, None)?;
    println!(
        "GPU-JOINLINEAR |D|={} n={}: kernel={:.4}s total={:.4}s tiles={}",
        data.len(), data.dims(), out.kernel_time, out.total_time, out.tiles
    );
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let data = load_dataset(args)?;
    let out = args.get("out").context("--out <path> required")?;
    let p = Path::new(out);
    match p.extension().and_then(|e| e.to_str()) {
        Some("csv") => hybrid_knn_join::data::io::write_csv(&data, p)?,
        _ => hybrid_knn_join::data::io::write_bin(&data, p)?,
    }
    println!("wrote {} points x {} dims to {out}", data.len(), data.dims());
    Ok(())
}

fn cmd_experiments(args: &Args) -> Result<()> {
    let which = args
        .positional()
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let ws = if args.flag("quick") {
        bench::workloads_quick()
    } else {
        bench::workloads()
    };
    let engine = Engine::load_default()?;
    let mut tables = Vec::new();
    let betas = [0.0, 0.5, 1.0];
    match which {
        "fig2" => tables.push(experiments::fig2(5)),
        "fig6" => tables.push(experiments::fig6(
            &[ws[0].clone(), ws[3].clone()],
            5,
        )),
        "fig7" => tables.push(experiments::fig7(&engine, &ws[1..])?),
        "fig8" => tables.push(experiments::fig8(
            &engine, &ws, &betas, &[0.0, 0.6, 0.8, 1.0],
        )?),
        "fig9" => tables.push(experiments::fig9(
            &engine,
            &[ws[0].clone(), ws[2].clone()],
            &betas,
            &[0.0, 0.2, 0.4, 0.6, 0.8, 1.0],
        )?),
        "fig10" => tables.push(experiments::fig10(
            &engine, &ws, &[1, 2, 4, 8, 16, 25, 32, 48, 64], 0.2,
        )?),
        "fig11" => tables.push(experiments::fig11(&engine, &ws, &[1, 4, 16, 64])?),
        "table3" => tables.push(experiments::table3(&engine, &ws)?),
        "table4" => tables.push(experiments::table4(&engine, &ws)?),
        "table5" => tables.push(experiments::table5(&engine, &ws)?),
        "table6" => tables.push(experiments::table6(
            &engine, &ws, &[0.05, 0.1, 0.05, 0.1],
        )?),
        "all" => {
            tables.push(experiments::fig2(5));
            tables.push(experiments::fig6(&[ws[0].clone(), ws[3].clone()], 5));
            tables.push(experiments::fig7(&engine, &ws[1..])?);
            tables.push(experiments::table3(&engine, &ws)?);
            tables.push(experiments::fig8(
                &engine, &ws, &betas, &[0.0, 0.6, 0.8, 1.0],
            )?);
            tables.push(experiments::fig9(
                &engine,
                &[ws[0].clone(), ws[2].clone()],
                &betas,
                &[0.0, 0.2, 0.4, 0.6, 0.8, 1.0],
            )?);
            tables.push(experiments::table4(&engine, &ws)?);
            tables.push(experiments::table5(&engine, &ws)?);
            tables.push(experiments::table6(&engine, &ws, &[0.05, 0.1, 0.05, 0.1])?);
            tables.push(experiments::fig10(
                &engine, &ws, &[1, 2, 4, 8, 16, 25, 32, 48, 64], 0.2,
            )?);
            tables.push(experiments::fig11(&engine, &ws, &[1, 4, 16, 64])?);
        }
        other => bail!("unknown experiment {other:?} (see usage)"),
    }
    for t in tables {
        println!("{}", t.render());
    }
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    let engine = Engine::load_default()?;
    let mut names = engine.artifact_names();
    names.sort();
    println!("{} artifacts:", names.len());
    for n in names {
        println!("  {n}");
    }
    Ok(())
}
