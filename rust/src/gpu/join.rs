//! GPU-JOIN (paper Sec. V-B/V-E/V-G + Alg. 1 GPUJoinKernel).
//!
//! Range-query KNN over the ε-grid, executed on the "device" (PJRT):
//!
//! * queries are grouped **by grid cell** - all queries in a cell share
//!   the same adjacent-cell candidate list, which is the tile analogue of
//!   the paper's kernel where threads of neighboring queries scan the
//!   same cells;
//! * each (cell-queries x candidate-chunk) work unit executes one dist /
//!   dist-topk artifact tile; host-side filtering (ε test, self-exclusion,
//!   per-query bounded heap merge) runs on "stream" worker threads that
//!   overlap with device execution, mirroring the paper's 3 CUDA streams
//!   overlapping transfers and host filtering (Sec. IV-B);
//! * queries that end with fewer than K in-ε neighbors are returned as
//!   Q^Fail for CPU reassignment (Sec. V-E).
//!
//! A query with >= K neighbors within ε is *exactly* solved: its true K
//! nearest all lie within ε, and the grid walk provably visits every point
//! within ε of the query in the indexed projection (see index::grid).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use super::device::{DeviceEstimate, DeviceModel, ThreadAssign};
use crate::core::{BoundedHeap, Dataset, KnnResult, Neighbor, SoaSlots};
use crate::index::GridIndex;
use crate::runtime::{tiles, tiles::TileClass, Engine};

/// Parameters of the GPU side.
#[derive(Debug, Clone)]
pub struct GpuJoinParams {
    pub k: usize,
    pub eps: f64,
    pub tile_class: TileClass,
    /// prefer the on-device top-k artifact when k allows (perf path)
    pub use_topk: bool,
    /// result buffer capacity b_s in (query, neighbor) pairs per batch
    pub buffer_pairs: u64,
    /// host-side filter worker threads ("streams"); paper uses 3
    pub streams: usize,
    /// thread-granularity strategy fed to the device model (Table III)
    pub assign: ThreadAssign,
    /// fraction of cells sampled by the batch estimator (Sec. IV-B)
    pub estimator_frac: f64,
    /// self-join semantics: drop candidate id == query id. Off for
    /// bipartite R JOIN S (Sec. III: "directly applicable to R x S").
    pub exclude_self: bool,
}

impl GpuJoinParams {
    pub fn new(k: usize, eps: f64) -> Self {
        GpuJoinParams {
            k,
            eps,
            tile_class: TileClass::Large,
            // On CPU-PJRT the sort-based top-k tile is ~40x slower than the
            // raw distance tile + host filter (see EXPERIMENTS.md Perf); on
            // a real accelerator the top-k variant trades that for a 8x
            // smaller device->host transfer. Off by default here.
            use_topk: false,
            buffer_pairs: 10_000_000,
            streams: 3,
            assign: ThreadAssign::Static(8),
            estimator_frac: 0.01,
            exclude_self: true,
        }
    }
}

/// Outcome of a GPU-JOIN run that owns its result table.
#[derive(Debug)]
pub struct GpuJoinOutcome {
    /// exact results for solved queries (others left empty)
    pub result: KnnResult,
    /// Q^Fail - queries with < K neighbors within ε
    pub failed: Vec<u32>,
    pub solved: usize,
    /// wall time inside PJRT execution
    pub kernel_time: f64,
    /// wall time of the whole join (incl. packing + filtering)
    pub total_time: f64,
    /// modeled GPU kernel time for the configured ThreadAssign
    pub device_model: DeviceEstimate,
    /// batches executed (>= 3 whenever there is work, per Sec. IV-B)
    pub batches: usize,
    /// estimator's predicted result pairs
    pub estimated_pairs: u64,
    /// realised in-ε result pairs
    pub result_pairs: u64,
    /// max pairs observed in one batch (must stay <= buffer_pairs)
    pub max_batch_pairs: u64,
}

/// Accounting of an in-place GPU-JOIN (`gpu_join_rs_into`); solved-query
/// results live in the caller's `KnnResult` slots.
#[derive(Debug)]
pub struct GpuJoinStats {
    /// Q^Fail - queries with < K neighbors within ε (slots untouched)
    pub failed: Vec<u32>,
    pub solved: usize,
    pub kernel_time: f64,
    pub total_time: f64,
    pub device_model: DeviceEstimate,
    pub batches: usize,
    pub estimated_pairs: u64,
    pub result_pairs: u64,
    pub max_batch_pairs: u64,
}

/// A unit of work: one grid cell's queries + the shared candidate list.
#[derive(Debug, Clone)]
struct WorkCell {
    queries: Vec<u32>,
    candidates: Vec<u32>,
}

/// Message from the executor to a filter worker.
enum FilterMsg {
    /// full distance tile: rows follow `qids`, cols follow `cand_ids`
    Dist {
        qids: Vec<u32>,
        cand_ids: Vec<u32>,
        d2: Vec<f32>,
        ct: usize,
    },
    /// top-k tile: `vals`/`idx` are qt x k, idx indexes into `cand_ids`
    TopK {
        qids: Vec<u32>,
        cand_ids: Vec<u32>,
        vals: Vec<f32>,
        idx: Vec<i32>,
        k: usize,
    },
}

/// Run GPU-JOIN for `queries` (ids into `data`) over the given grid
/// (self-join form; see `gpu_join_rs` for the bipartite join).
pub fn gpu_join(
    engine: &Engine,
    data: &Dataset,
    grid: &GridIndex,
    queries: &[u32],
    params: &GpuJoinParams,
) -> Result<GpuJoinOutcome> {
    gpu_join_rs(engine, data, data, grid, queries, params)
}

/// Bipartite GPU-JOIN: `queries` are ids into `r_data` (the outer
/// relation); candidates come from `data` = S via `grid` built over S.
/// With `r_data` = `data` and exclude_self this is the self-join.
pub fn gpu_join_rs(
    engine: &Engine,
    r_data: &Dataset,
    data: &Dataset,
    grid: &GridIndex,
    queries: &[u32],
    params: &GpuJoinParams,
) -> Result<GpuJoinOutcome> {
    let mut result = KnnResult::new(r_data.len(), params.k);
    let slots = result.slots();
    let s = gpu_join_rs_into(engine, r_data, data, grid, queries, params, &slots)?;
    drop(slots);
    Ok(GpuJoinOutcome {
        result,
        failed: s.failed,
        solved: s.solved,
        kernel_time: s.kernel_time,
        total_time: s.total_time,
        device_model: s.device_model,
        batches: s.batches,
        estimated_pairs: s.estimated_pairs,
        result_pairs: s.result_pairs,
        max_batch_pairs: s.max_batch_pairs,
    })
}

/// GPU-JOIN writing solved queries *in place* through `slots` (the hybrid
/// join's no-merge path). Failed queries' slots are left untouched for the
/// Q^Fail CPU pass. The caller must not concurrently write the slots of
/// `queries` elsewhere (see `SoaSlots::slot`); this function itself
/// resolves results on the calling thread only.
pub fn gpu_join_rs_into(
    engine: &Engine,
    r_data: &Dataset,
    data: &Dataset,
    grid: &GridIndex,
    queries: &[u32],
    params: &GpuJoinParams,
    slots: &SoaSlots<'_>,
) -> Result<GpuJoinStats> {
    let t_start = Instant::now();
    assert!(params.k <= slots.k(), "result stride {} < k {}", slots.k(), params.k);
    // Two tile plans: thin cells (few queries) run on the small tile to
    // cut padding waste ~4x; dense cells use the large tile. This is the
    // tile-world analogue of the paper's task-granularity tuning.
    let plan_large = tiles::plan_for(engine, data.dims(), params.tile_class)?;
    let plan_small = tiles::plan_for(engine, data.dims(), TileClass::Small)
        .unwrap_or_else(|_| plan_large.clone());
    let use_topk = params.use_topk
        && plan_large.topk_name.is_some()
        && params.k <= plan_large.topk_k;

    // ---- group queries by cell (shared candidate lists) ----
    let mut by_cell: HashMap<u64, Vec<u32>> = HashMap::new();
    for &q in queries {
        by_cell
            .entry(grid.cell_id_of(r_data.point(q as usize)))
            .or_default()
            .push(q);
    }
    let mut cells: Vec<WorkCell> = by_cell
        .into_values()
        .map(|qs| {
            let candidates = grid.candidates_of(r_data.point(qs[0] as usize));
            WorkCell { queries: qs, candidates }
        })
        .collect();
    // deterministic order (largest first helps batch balance)
    cells.sort_by_key(|c| std::cmp::Reverse(c.queries.len() * c.candidates.len()));

    // ---- device-model accounting on the real workload ----
    let work: Vec<u64> = cells
        .iter()
        .flat_map(|c| c.queries.iter().map(|_| c.candidates.len() as u64))
        .collect();
    let device_model = DeviceModel::default().estimate(&work, params.assign);

    // ---- batch estimator (Sec. IV-B) ----
    let mut kernel_time = 0f64;
    let sample_n = ((cells.len() as f64 * params.estimator_frac).ceil() as usize)
        .clamp(1.min(cells.len()), cells.len());
    let mut est_state = JoinState::new(params.k, params.eps, params.exclude_self);
    let sample: Vec<WorkCell> = cells
        .iter()
        .step_by((cells.len() / sample_n.max(1)).max(1))
        .cloned()
        .collect();
    let sampled_queries: usize = sample.iter().map(|c| c.queries.len()).sum();
    run_cells(
        engine,
        (r_data, data),
        (&plan_large, &plan_small),
        use_topk,
        &sample,
        params,
        &mut est_state,
        &mut kernel_time,
    )?;
    let estimated_pairs = if sampled_queries > 0 {
        (est_state.pairs as f64 * queries.len() as f64 / sampled_queries as f64)
            .ceil() as u64
    } else {
        0
    };

    // number of batches: >= 3 (stream overlap), 1.5x estimator slack
    let n_batches = ((estimated_pairs as f64 * 1.5 / params.buffer_pairs as f64)
        .ceil() as usize)
        .max(3)
        .min(cells.len().max(3));

    // ---- partition cells into batches (round-robin by size rank) ----
    let mut batches: Vec<Vec<WorkCell>> = vec![Vec::new(); n_batches];
    for (i, c) in cells.into_iter().enumerate() {
        batches[i % n_batches].push(c);
    }

    // ---- execute batches ----
    let mut state = JoinState::new(params.k, params.eps, params.exclude_self);
    let mut max_batch_pairs = 0u64;
    let mut executed_batches = 0usize;
    for batch in &batches {
        if batch.is_empty() {
            continue;
        }
        let pairs_before = state.pairs;
        run_cells(
            engine,
            (r_data, data),
            (&plan_large, &plan_small),
            use_topk,
            batch,
            params,
            &mut state,
            &mut kernel_time,
        )?;
        let batch_pairs = state.pairs - pairs_before;
        max_batch_pairs = max_batch_pairs.max(batch_pairs);
        executed_batches += 1;
    }

    // ---- resolve solved vs failed ----
    let mut failed = Vec::new();
    let mut solved = 0usize;
    for &q in queries {
        match state.heaps.get_mut(&q) {
            Some(h) if h.len() >= params.k => {
                // SAFETY: `queries` is duplicate-free and only this thread
                // writes GPU-side slots (caller keeps concurrent writers
                // off these ids).
                unsafe { slots.slot(q as usize) }.write_heap(h);
                solved += 1;
            }
            _ => failed.push(q),
        }
    }
    failed.sort_unstable();

    Ok(GpuJoinStats {
        failed,
        solved,
        kernel_time,
        total_time: t_start.elapsed().as_secs_f64(),
        device_model,
        batches: executed_batches,
        estimated_pairs,
        result_pairs: state.pairs,
        max_batch_pairs,
    })
}

/// Per-query candidate workload (distance calculations per query) under a
/// given grid - the input to the device model. Used by the Table III
/// granularity study to evaluate all ThreadAssign variants on one real
/// workload without re-running the join.
pub fn workload_vector(data: &Dataset, grid: &GridIndex, queries: &[u32]) -> Vec<u64> {
    // queries index `data` here (self-join accounting)
    let mut by_cell: HashMap<u64, (u64, u64)> = HashMap::new(); // cell -> (count, work)
    for &q in queries {
        let cell = grid.cell_id_of(data.point(q as usize));
        let entry = by_cell.entry(cell).or_insert_with(|| {
            let cands = grid.candidates_of(data.point(q as usize)).len() as u64;
            (0, cands)
        });
        entry.0 += 1;
    }
    let mut out = Vec::with_capacity(queries.len());
    for &q in queries {
        let cell = grid.cell_id_of(data.point(q as usize));
        out.push(by_cell[&cell].1);
    }
    out
}

/// Mutable filter state shared across batches.
struct JoinState {
    k: usize,
    eps2: f64,
    exclude_self: bool,
    heaps: HashMap<u32, BoundedHeap>,
    pairs: u64,
}

impl JoinState {
    fn new(k: usize, eps: f64, exclude_self: bool) -> Self {
        JoinState {
            k,
            eps2: eps * eps,
            exclude_self,
            heaps: HashMap::new(),
            pairs: 0,
        }
    }

    fn apply(&mut self, msg: &FilterMsg) {
        match msg {
            FilterMsg::Dist { qids, cand_ids, d2, ct } => {
                for (r, &q) in qids.iter().enumerate() {
                    let heap = self
                        .heaps
                        .entry(q)
                        .or_insert_with(|| BoundedHeap::new(self.k));
                    let row = &d2[r * ct..r * ct + cand_ids.len()];
                    // Fast path: once the heap is full, only candidates
                    // below the current k-th best can matter - track that
                    // bound as an f32 so the hot compare stays branchy-
                    // cheap and pushes become rare (EXPERIMENTS.md Perf#1).
                    // next_up: f64->f32 rounding must never exclude a
                    // candidate exactly at the bound
                    let mut gate = ((heap.bound().min(self.eps2)) as f32).next_up();
                    for (c, &dd) in row.iter().enumerate() {
                        if dd as f64 <= self.eps2 {
                            self.pairs += 1;
                        }
                        if dd <= gate {
                            let id = cand_ids[c];
                            if !(self.exclude_self && id == q) {
                                heap.push(Neighbor {
                                    id,
                                    dist2: (dd as f64).max(0.0),
                                });
                                gate = ((heap.bound().min(self.eps2)) as f32)
                                    .next_up();
                            }
                        }
                    }
                }
            }
            FilterMsg::TopK { qids, cand_ids, vals, idx, k } => {
                for (r, &q) in qids.iter().enumerate() {
                    let heap = self
                        .heaps
                        .entry(q)
                        .or_insert_with(|| BoundedHeap::new(self.k));
                    for s in 0..*k {
                        let dd = vals[r * k + s] as f64;
                        if dd > self.eps2 {
                            break; // ascending: rest of the row is farther
                        }
                        let ci = idx[r * k + s] as usize;
                        if ci >= cand_ids.len() {
                            continue; // padded candidate row
                        }
                        let id = cand_ids[ci];
                        if !(self.exclude_self && id == q) {
                            self.pairs += 1;
                            heap.push(Neighbor { id, dist2: dd.max(0.0) });
                        }
                    }
                }
            }
        }
    }
}

/// Execute the tile program over a set of cells, merging into `state`.
/// Device execution happens on this thread (the PJRT client is !Send, the
/// paper's single GPU-master rank); filtering overlaps on stream workers.
#[allow(clippy::too_many_arguments)]
fn run_cells(
    engine: &Engine,
    (r_data, data): (&Dataset, &Dataset),
    (plan_large, plan_small): (&tiles::TilePlan, &tiles::TilePlan),
    use_topk: bool,
    cells: &[WorkCell],
    params: &GpuJoinParams,
    state: &mut JoinState,
    kernel_time: &mut f64,
) -> Result<()> {
    let n_workers = params.streams.max(1);

    // worker-local states merged at the end
    let results: Vec<JoinState> = std::thread::scope(|scope| -> Result<Vec<JoinState>> {
        let mut txs = Vec::with_capacity(n_workers);
        let mut handles = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let (tx, rx) = std::sync::mpsc::sync_channel::<FilterMsg>(4);
            let (k, eps, ex) = (params.k, params.eps, params.exclude_self);
            handles.push(scope.spawn(move || {
                let mut local = JoinState::new(k, eps, ex);
                while let Ok(msg) = rx.recv() {
                    local.apply(&msg);
                }
                local
            }));
            txs.push(tx);
        }

        let mut q_buf: Vec<f32> = Vec::new();
        let mut c_buf: Vec<f32> = Vec::new();
        let mut unit = 0usize;
        for cell in cells {
            // One plan per cell: thin cells run on the small tile (less
            // padding); the small plan has no top-k variant, so it always
            // takes the dist path.
            let (plan, cell_topk) = if cell.queries.len() <= plan_small.qt {
                (plan_small, use_topk && plan_small.topk_name.is_some())
            } else {
                (plan_large, use_topk)
            };
            let (qt, ct, d_pad) = (plan.qt, plan.ct, plan.d);
            // Candidate tiles are shared by every query chunk of the cell:
            // pack + upload once (Perf#2).
            let c_lits: Vec<(&[u32], xla::Literal)> = cell
                .candidates
                .chunks(ct)
                .map(|c_chunk| {
                    tiles::pack_candidates(&mut c_buf, data, c_chunk, ct, d_pad);
                    Ok((
                        c_chunk,
                        Engine::literal(&c_buf, &[ct as i64, d_pad as i64])?,
                    ))
                })
                .collect::<Result<_>>()?;
            for q_chunk in cell.queries.chunks(qt) {
                tiles::pack(&mut q_buf, r_data, q_chunk, qt, d_pad, 0.0);
                let q_lit = Engine::literal(&q_buf, &[qt as i64, d_pad as i64])?;
                for (c_chunk, c_lit) in &c_lits {
                    let t0 = Instant::now();
                    let msg = if cell_topk {
                        let out = engine.exec_lits(
                            plan.topk_name.as_deref().unwrap(),
                            &[&q_lit, c_lit],
                        )?;
                        *kernel_time += t0.elapsed().as_secs_f64();
                        FilterMsg::TopK {
                            qids: q_chunk.to_vec(),
                            cand_ids: c_chunk.to_vec(),
                            vals: Engine::to_f32(&out[0])?,
                            idx: Engine::to_i32(&out[1])?,
                            k: plan.topk_k,
                        }
                    } else {
                        let out = engine.exec_lits(&plan.dist_name, &[&q_lit, c_lit])?;
                        *kernel_time += t0.elapsed().as_secs_f64();
                        FilterMsg::Dist {
                            qids: q_chunk.to_vec(),
                            cand_ids: c_chunk.to_vec(),
                            d2: Engine::to_f32(&out[0])?,
                            ct,
                        }
                    };
                    // all chunks of one query tile go to one worker (heap
                    // ownership); rotate workers per query tile
                    txs[unit % n_workers].send(msg).expect("worker alive");
                }
                unit += 1;
            }
        }
        drop(txs);
        Ok(handles
            .into_iter()
            .map(|h| h.join().expect("filter worker panicked"))
            .collect())
    })?;

    // merge worker-local heaps into the caller's state
    for local in results {
        state.pairs += local.pairs;
        for (q, heap) in local.heaps {
            match state.heaps.entry(q) {
                std::collections::hash_map::Entry::Vacant(v) => {
                    v.insert(heap);
                }
                std::collections::hash_map::Entry::Occupied(mut o) => {
                    for n in heap.into_sorted() {
                        o.get_mut().push(n);
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::sqdist;
    use crate::data::synthetic::{chist_like, susy_like};
    use crate::index::KdTree;

    fn setup(n: usize) -> (Engine, Dataset) {
        (Engine::load_default().unwrap(), susy_like(n).generate(21))
    }

    fn exact_ref(data: &Dataset, q: u32, k: usize) -> Vec<Neighbor> {
        let t = KdTree::build(data);
        t.knn(data, data.point(q as usize), k, q)
    }

    #[test]
    fn solved_queries_are_exact_knn() {
        let (engine, data) = setup(1200);
        let grid = GridIndex::build(&data, 6, 3.0);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let params = GpuJoinParams::new(4, 3.0);
        let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
        assert!(out.solved > 0, "nothing solved - eps too small for test");
        let mut checked = 0;
        for q in (0..data.len() as u32).step_by(97) {
            let got = out.result.get(q as usize);
            if got.len() < params.k {
                continue; // failed query - CPU's job
            }
            let want = exact_ref(&data, q, params.k);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g.dist2 - w.dist2).abs() < 1e-3 * (1.0 + w.dist2),
                    "q={q} got={g:?} want={w:?}"
                );
            }
            checked += 1;
        }
        assert!(checked > 0);
    }

    #[test]
    fn failed_queries_have_too_few_in_eps_neighbors() {
        let (engine, data) = setup(900);
        let eps = 1.0; // small: guarantees some failures
        let grid = GridIndex::build(&data, 6, eps);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let params = GpuJoinParams::new(8, eps);
        let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
        assert_eq!(out.solved + out.failed.len(), queries.len());
        // verify failure ground truth on a sample
        for &q in out.failed.iter().step_by(53) {
            let within = (0..data.len())
                .filter(|&i| i != q as usize)
                .filter(|&i| sqdist(data.point(q as usize), data.point(i)) <= eps * eps)
                .count();
            assert!(
                within < params.k,
                "query {q} has {within} >= k in-eps neighbors but was failed"
            );
        }
    }

    #[test]
    fn dist_and_topk_paths_agree() {
        let (engine, data) = setup(700);
        let grid = GridIndex::build(&data, 6, 2.5);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let mut p_topk = GpuJoinParams::new(5, 2.5);
        p_topk.use_topk = true;
        let mut p_dist = p_topk.clone();
        p_dist.use_topk = false;
        let a = gpu_join(&engine, &data, &grid, &queries, &p_topk).unwrap();
        let b = gpu_join(&engine, &data, &grid, &queries, &p_dist).unwrap();
        assert_eq!(a.solved, b.solved);
        assert_eq!(a.failed, b.failed);
        for q in (0..data.len()).step_by(31) {
            let (ga, gb) = (a.result.get(q), b.result.get(q));
            assert_eq!(ga.len(), gb.len());
            for (x, y) in ga.iter().zip(gb) {
                assert!((x.dist2 - y.dist2).abs() < 1e-4 * (1.0 + y.dist2));
            }
        }
    }

    #[test]
    fn batching_respects_buffer_and_minimum() {
        let (engine, data) = setup(1500);
        let grid = GridIndex::build(&data, 6, 3.0);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let mut params = GpuJoinParams::new(4, 3.0);
        params.buffer_pairs = 2_000; // force many batches
        let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
        assert!(out.batches >= 3, "minimum 3 batches (stream overlap)");
        assert!(
            out.max_batch_pairs <= params.buffer_pairs * 4,
            "batch result {} wildly exceeds buffer {}",
            out.max_batch_pairs,
            params.buffer_pairs
        );
        assert!(out.estimated_pairs > 0);
    }

    #[test]
    fn subset_queries_only() {
        let (engine, data) = setup(600);
        let grid = GridIndex::build(&data, 6, 3.0);
        let queries: Vec<u32> = (0..200).collect();
        let params = GpuJoinParams::new(3, 3.0);
        let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
        assert_eq!(out.solved + out.failed.len(), 200);
        // queries outside the set must remain empty
        for q in 200..data.len() {
            assert!(out.result.get(q).is_empty());
        }
    }

    #[test]
    fn high_dim_chist_route() {
        // 32-D surrogate exercises the d=32 artifact family
        let engine = Engine::load_default().unwrap();
        let data = chist_like(500).generate(8);
        let sel = crate::epsilon::EpsilonSelector::default().select_host(&data, 3, 0.2);
        let grid = GridIndex::build(&data, 6, sel.eps);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let params = GpuJoinParams::new(3, sel.eps);
        let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
        assert!(out.solved + out.failed.len() == queries.len());
        assert!(out.kernel_time > 0.0);
        assert!(out.device_model.threads > 0);
    }
}
