//! GPU-JOIN (paper Sec. V-B/V-E/V-G + Alg. 1 GPUJoinKernel).
//!
//! Range-query KNN over the ε-grid, executed on the "device" (PJRT):
//!
//! * queries are grouped **by grid cell** - all queries in a cell share
//!   the same adjacent-cell candidate list, which is the tile analogue of
//!   the paper's kernel where threads of neighboring queries scan the
//!   same cells;
//! * each (cell-queries x candidate-chunk) work unit executes one dist /
//!   dist-topk artifact tile; host-side filtering (ε test, self-exclusion,
//!   per-query bounded heap merge) runs on `streams` filter workers driven
//!   by `pool::parallel_chunks_stateful` over a **dense heap arena**
//!   indexed by the query's position in the batch - no per-query hash map
//!   and no worker-local heap merge: each query tile (and therefore each
//!   arena slot) is claimed by exactly one worker off the atomic cursor;
//! * queries that end with fewer than K in-ε neighbors are returned as
//!   Q^Fail for CPU reassignment (Sec. V-E).
//!
//! Two entry shapes exist. The list-driven form (`gpu_join_rs_into`)
//! processes a fixed query set in estimator-sized batches - the paper's
//! Sec. IV-B batching. The queue-driven form (`gpu_join_drain`) is the
//! hybrid join's GPU master: it claims batches of aggregate estimated
//! work off the dense head of the shared work queue (`sched`), sizes each
//! next claim from the live CPU/GPU work rates (Eq. 6 as feedback), and
//! *recirculates* failed queries into the queue for CPU ranks to absorb
//! while the join is still running.
//!
//! A query with >= K neighbors within ε is *exactly* solved: its true K
//! nearest all lie within ε, and the grid walk provably visits every point
//! within ε of the query in the indexed projection (see index::grid).

use std::cell::UnsafeCell;
use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use super::device::{DeviceEstimate, DeviceModel, ThreadAssign};
use crate::core::{BoundedHeap, Dataset, KnnResult, Neighbor, SoaSlots};
use crate::index::GridIndex;
use crate::runtime::{tiles, tiles::TileClass, Engine};
use crate::sched::{self, Arch, ClaimRecord, WorkQueue};
use crate::util::pool;

/// Parameters of the GPU side.
#[derive(Debug, Clone)]
pub struct GpuJoinParams {
    pub k: usize,
    pub eps: f64,
    pub tile_class: TileClass,
    /// prefer the on-device top-k artifact when k allows (perf path)
    pub use_topk: bool,
    /// result buffer capacity b_s in (query, neighbor) pairs per batch
    pub buffer_pairs: u64,
    /// host-side filter worker threads ("streams"); paper uses 3
    pub streams: usize,
    /// thread-granularity strategy fed to the device model (Table III)
    pub assign: ThreadAssign,
    /// fraction of cells sampled by the batch estimator (Sec. IV-B)
    pub estimator_frac: f64,
    /// self-join semantics: drop candidate id == query id. Off for
    /// bipartite R JOIN S (Sec. III: "directly applicable to R x S").
    pub exclude_self: bool,
}

impl GpuJoinParams {
    pub fn new(k: usize, eps: f64) -> Self {
        GpuJoinParams {
            k,
            eps,
            tile_class: TileClass::Large,
            // On CPU-PJRT the sort-based top-k tile is ~40x slower than the
            // raw distance tile + host filter (see EXPERIMENTS.md Perf); on
            // a real accelerator the top-k variant trades that for a 8x
            // smaller device->host transfer. Off by default here.
            use_topk: false,
            buffer_pairs: 10_000_000,
            streams: 3,
            assign: ThreadAssign::Static(8),
            estimator_frac: 0.01,
            exclude_self: true,
        }
    }
}

/// Outcome of a GPU-JOIN run that owns its result table.
#[derive(Debug)]
pub struct GpuJoinOutcome {
    /// exact results for solved queries (others left empty)
    pub result: KnnResult,
    /// Q^Fail - queries with < K neighbors within ε
    pub failed: Vec<u32>,
    pub solved: usize,
    /// wall time inside PJRT execution
    pub kernel_time: f64,
    /// wall time of the whole join (incl. packing + filtering)
    pub total_time: f64,
    /// modeled GPU kernel time for the configured ThreadAssign
    pub device_model: DeviceEstimate,
    /// batches executed (>= 3 whenever there is work, per Sec. IV-B)
    pub batches: usize,
    /// estimator's predicted result pairs
    pub estimated_pairs: u64,
    /// realised in-ε result pairs
    pub result_pairs: u64,
    /// max pairs observed in one batch (must stay <= buffer_pairs)
    pub max_batch_pairs: u64,
}

/// Accounting of an in-place GPU-JOIN (`gpu_join_rs_into` /
/// `gpu_join_drain`); solved-query results live in the caller's
/// `KnnResult` slots.
#[derive(Debug)]
pub struct GpuJoinStats {
    /// Q^Fail - queries with < K neighbors within ε (slots untouched)
    pub failed: Vec<u32>,
    pub solved: usize,
    pub kernel_time: f64,
    pub total_time: f64,
    pub device_model: DeviceEstimate,
    pub batches: usize,
    /// list form: estimator-predicted result pairs; queue form: estimated
    /// work actually claimed
    pub estimated_pairs: u64,
    pub result_pairs: u64,
    pub max_batch_pairs: u64,
    /// per-claim telemetry (queue-driven form only; empty for the list
    /// form)
    pub claims: Vec<ClaimRecord>,
}

/// A unit of work: one grid cell's queries + the shared candidate list.
#[derive(Debug, Clone)]
struct WorkCell {
    queries: Vec<u32>,
    candidates: Vec<u32>,
}

/// Run GPU-JOIN for `queries` (ids into `data`) over the given grid
/// (self-join form; see `gpu_join_rs` for the bipartite join).
pub fn gpu_join(
    engine: &Engine,
    data: &Dataset,
    grid: &GridIndex,
    queries: &[u32],
    params: &GpuJoinParams,
) -> Result<GpuJoinOutcome> {
    gpu_join_rs(engine, data, data, grid, queries, params)
}

/// Bipartite GPU-JOIN: `queries` are ids into `r_data` (the outer
/// relation); candidates come from `data` = S via `grid` built over S.
/// With `r_data` = `data` and exclude_self this is the self-join.
pub fn gpu_join_rs(
    engine: &Engine,
    r_data: &Dataset,
    data: &Dataset,
    grid: &GridIndex,
    queries: &[u32],
    params: &GpuJoinParams,
) -> Result<GpuJoinOutcome> {
    let mut result = KnnResult::new(r_data.len(), params.k);
    let slots = result.slots();
    let s = gpu_join_rs_into(engine, r_data, data, grid, queries, params, &slots)?;
    drop(slots);
    Ok(GpuJoinOutcome {
        result,
        failed: s.failed,
        solved: s.solved,
        kernel_time: s.kernel_time,
        total_time: s.total_time,
        device_model: s.device_model,
        batches: s.batches,
        estimated_pairs: s.estimated_pairs,
        result_pairs: s.result_pairs,
        max_batch_pairs: s.max_batch_pairs,
    })
}

/// GPU-JOIN writing solved queries *in place* through `slots` (the
/// no-merge path). Failed queries' slots are left untouched for the
/// Q^Fail CPU pass. The caller must not concurrently write the slots of
/// `queries` elsewhere (see `SoaSlots::slot`); this function itself
/// resolves results on the calling thread only.
pub fn gpu_join_rs_into(
    engine: &Engine,
    r_data: &Dataset,
    data: &Dataset,
    grid: &GridIndex,
    queries: &[u32],
    params: &GpuJoinParams,
    slots: &SoaSlots<'_>,
) -> Result<GpuJoinStats> {
    let t_start = Instant::now();
    assert!(params.k <= slots.k(), "result stride {} < k {}", slots.k(), params.k);
    // Two tile plans: thin cells (few queries) run on the small tile to
    // cut padding waste ~4x; dense cells use the large tile. This is the
    // tile-world analogue of the paper's task-granularity tuning.
    let plan_large = tiles::plan_for(engine, data.dims(), params.tile_class)?;
    let plan_small = tiles::plan_for(engine, data.dims(), TileClass::Small)
        .unwrap_or_else(|_| plan_large.clone());
    let use_topk = params.use_topk
        && plan_large.topk_name.is_some()
        && params.k <= plan_large.topk_k;

    // ---- group queries by cell (shared candidate lists) ----
    let mut by_cell: HashMap<u64, Vec<u32>> = HashMap::new();
    for &q in queries {
        by_cell
            .entry(grid.cell_id_of(r_data.point(q as usize)))
            .or_default()
            .push(q);
    }
    let mut cells: Vec<WorkCell> = by_cell
        .into_values()
        .map(|qs| {
            let candidates = grid.candidates_of(r_data.point(qs[0] as usize));
            WorkCell { queries: qs, candidates }
        })
        .collect();
    // deterministic order (largest first helps batch balance)
    cells.sort_by_key(|c| std::cmp::Reverse(c.queries.len() * c.candidates.len()));

    // ---- device-model accounting on the real workload ----
    let work: Vec<u64> = cells
        .iter()
        .flat_map(|c| c.queries.iter().map(|_| c.candidates.len() as u64))
        .collect();
    let device_model = DeviceModel::default().estimate(&work, params.assign);

    // ---- batch estimator (Sec. IV-B) ----
    let mut kernel_time = 0f64;
    let sample_n = ((cells.len() as f64 * params.estimator_frac).ceil() as usize)
        .clamp(1.min(cells.len()), cells.len());
    let sample: Vec<WorkCell> = cells
        .iter()
        .step_by((cells.len() / sample_n.max(1)).max(1))
        .cloned()
        .collect();
    let sampled_queries: usize = sample.iter().map(|c| c.queries.len()).sum();
    let (_, _, sample_pairs) = exec_filter_cells(
        engine,
        (r_data, data),
        (&plan_large, &plan_small),
        use_topk,
        &sample,
        params,
        &mut kernel_time,
    )?;
    let estimated_pairs = if sampled_queries > 0 {
        (sample_pairs as f64 * queries.len() as f64 / sampled_queries as f64)
            .ceil() as u64
    } else {
        0
    };

    // number of batches: >= 3 (stream overlap), 1.5x estimator slack
    let n_batches = ((estimated_pairs as f64 * 1.5 / params.buffer_pairs as f64)
        .ceil() as usize)
        .max(3)
        .min(cells.len().max(3));

    // ---- partition cells into batches (round-robin by size rank) ----
    let mut batches: Vec<Vec<WorkCell>> = vec![Vec::new(); n_batches];
    for (i, c) in cells.into_iter().enumerate() {
        batches[i % n_batches].push(c);
    }

    // ---- execute batches, resolving each into slots / Q^Fail ----
    let mut failed = Vec::new();
    let mut solved = 0usize;
    let mut result_pairs = 0u64;
    let mut max_batch_pairs = 0u64;
    let mut executed_batches = 0usize;
    for batch in &batches {
        if batch.is_empty() {
            continue;
        }
        let (batch_queries, mut heaps, batch_pairs) = exec_filter_cells(
            engine,
            (r_data, data),
            (&plan_large, &plan_small),
            use_topk,
            batch,
            params,
            &mut kernel_time,
        )?;
        for (pos, &q) in batch_queries.iter().enumerate() {
            let h = &mut heaps[pos];
            if h.len() >= params.k {
                // SAFETY: `queries` is duplicate-free and only this thread
                // writes GPU-side slots (caller keeps concurrent writers
                // off these ids).
                unsafe { slots.slot(q as usize) }.write_heap(h);
                solved += 1;
            } else {
                failed.push(q);
            }
        }
        result_pairs += batch_pairs;
        max_batch_pairs = max_batch_pairs.max(batch_pairs);
        executed_batches += 1;
    }
    failed.sort_unstable();

    Ok(GpuJoinStats {
        failed,
        solved,
        kernel_time,
        total_time: t_start.elapsed().as_secs_f64(),
        device_model,
        batches: executed_batches,
        estimated_pairs,
        result_pairs,
        max_batch_pairs,
        claims: Vec::new(),
    })
}

/// The hybrid join's GPU master: drain the dense head of the shared work
/// queue in work-sized claims until the head meets the CPU's tail front.
///
/// * the *seed* claim is sized from the γ dense prefix
///   (`sched::first_batch_work`) and taken **before** tile-plan setup, so
///   the GPU is guaranteed a share whenever the head is open;
/// * every subsequent claim is sized by `sched::next_batch_work` from the
///   live GPU/CPU work rates - Eq. 6 driving the schedule instead of
///   diagnosing it - and capped at `buffer_pairs` estimated work (a
///   candidate scan bounds its result pairs, so the batch buffer bound of
///   Sec. IV-B is conserved);
/// * failed queries are pushed back into the queue's recirculation buffer
///   for CPU ranks to absorb concurrently; their slots stay untouched;
/// * `pos_cap` bounds how deep into the queue the head may reach - the
///   single-core fallback passes the γ dense prefix so the sequential
///   schedule degenerates to exactly the static split.
///
/// Slot safety: identical to `gpu_join_rs_into` - head claims are
/// disjoint from tail claims by the two-ended cursor, and failed ids are
/// written by whichever CPU rank claims them from recirculation, never
/// here.
#[allow(clippy::too_many_arguments)]
pub fn gpu_join_drain(
    engine: &Engine,
    r_data: &Dataset,
    data: &Dataset,
    grid: &GridIndex,
    queue: &WorkQueue,
    params: &GpuJoinParams,
    slots: &SoaSlots<'_>,
    pos_cap: usize,
) -> Result<GpuJoinStats> {
    let t_start = Instant::now();
    assert!(params.k <= slots.k(), "result stride {} < k {}", slots.k(), params.k);
    let buffer_cap = params.buffer_pairs.max(1);

    // seed claim first: a fast CPU must not drain the queue while we
    // compile tile plans
    let mut target = sched::first_batch_work(
        queue.head_work_remaining(pos_cap),
        queue.dense_work(),
    )
    .min(buffer_cap);
    let mut pending = queue.claim_head_work(target, pos_cap);
    if pending.is_none() {
        return Ok(GpuJoinStats {
            failed: Vec::new(),
            solved: 0,
            kernel_time: 0.0,
            total_time: t_start.elapsed().as_secs_f64(),
            device_model: DeviceEstimate::default(),
            batches: 0,
            estimated_pairs: 0,
            result_pairs: 0,
            max_batch_pairs: 0,
            claims: Vec::new(),
        });
    }

    let plan_large = tiles::plan_for(engine, data.dims(), params.tile_class)?;
    let plan_small = tiles::plan_for(engine, data.dims(), TileClass::Small)
        .unwrap_or_else(|_| plan_large.clone());
    let use_topk = params.use_topk
        && plan_large.topk_name.is_some()
        && params.k <= plan_large.topk_k;

    let mut kernel_time = 0f64;
    let mut claims: Vec<ClaimRecord> = Vec::new();
    let mut failed_all: Vec<u32> = Vec::new();
    let mut work_log: Vec<u64> = Vec::new();
    let mut solved = 0usize;
    let mut result_pairs = 0u64;
    let mut max_batch_pairs = 0u64;
    let mut batches = 0usize;
    let mut gpu_busy = 0f64;
    let mut work_done = 0u64;

    while let Some(range) = pending.take() {
        let t_claim = Instant::now();
        // materialise the claim as per-cell work units (a claim may start
        // or end mid-cell when clipped by the advancing tail; the partial
        // remainder still shares its cell's candidate list)
        let mut cells: Vec<WorkCell> = Vec::new();
        for r in queue.cell_ranges(range.clone()) {
            let qs = queue.query_slice(r).to_vec();
            let candidates = grid.candidates_of(r_data.point(qs[0] as usize));
            for _ in &qs {
                work_log.push(candidates.len() as u64);
            }
            cells.push(WorkCell { queries: qs, candidates });
        }
        let (batch_queries, mut heaps, batch_pairs) = exec_filter_cells(
            engine,
            (r_data, data),
            (&plan_large, &plan_small),
            use_topk,
            &cells,
            params,
            &mut kernel_time,
        )?;
        let mut failed_batch = Vec::new();
        for (pos, &q) in batch_queries.iter().enumerate() {
            let h = &mut heaps[pos];
            if h.len() >= params.k {
                // SAFETY: head claims are disjoint from all other writers.
                unsafe { slots.slot(q as usize) }.write_heap(h);
                solved += 1;
            } else {
                failed_batch.push(q);
            }
        }
        // recirculate Q^Fail into the live queue (step 7 of Alg. 1 gone)
        queue.push_failed(&failed_batch);
        failed_all.extend_from_slice(&failed_batch);

        result_pairs += batch_pairs;
        max_batch_pairs = max_batch_pairs.max(batch_pairs);
        batches += 1;
        let secs = t_claim.elapsed().as_secs_f64();
        gpu_busy += secs;
        let est = queue.range_work(range.clone());
        work_done += est;
        claims.push(ClaimRecord {
            arch: Arch::Gpu,
            queries: range.len(),
            est_work: est,
            secs,
            from_recirc: false,
        });

        // Eq. 6 as feedback: size the next claim from live rates
        let gpu_rate = if gpu_busy > 0.0 { work_done as f64 / gpu_busy } else { 0.0 };
        target = sched::next_batch_work(
            queue.head_work_remaining(pos_cap),
            gpu_rate,
            queue.cpu_work_rate(),
        )
        .min(buffer_cap);
        pending = queue.claim_head_work(target, pos_cap);
    }

    let device_model = DeviceModel::default().estimate(&work_log, params.assign);
    failed_all.sort_unstable();
    Ok(GpuJoinStats {
        failed: failed_all,
        solved,
        kernel_time,
        total_time: t_start.elapsed().as_secs_f64(),
        device_model,
        batches,
        estimated_pairs: work_done,
        result_pairs,
        max_batch_pairs,
        claims,
    })
}

/// Per-query candidate workload (distance calculations per query) under a
/// given grid - the input to the device model. Used by the Table III
/// granularity study to evaluate all ThreadAssign variants on one real
/// workload without re-running the join.
pub fn workload_vector(data: &Dataset, grid: &GridIndex, queries: &[u32]) -> Vec<u64> {
    // queries index `data` here (self-join accounting)
    let mut by_cell: HashMap<u64, (u64, u64)> = HashMap::new(); // cell -> (count, work)
    for &q in queries {
        let cell = grid.cell_id_of(data.point(q as usize));
        let entry = by_cell.entry(cell).or_insert_with(|| {
            let cands = grid.candidates_of(data.point(q as usize)).len() as u64;
            (0, cands)
        });
        entry.0 += 1;
    }
    let mut out = Vec::with_capacity(queries.len());
    for &q in queries {
        let cell = grid.cell_id_of(data.point(q as usize));
        out.push(by_cell[&cell].1);
    }
    out
}

/// Dense per-batch heap arena: one bounded heap per query *position* in
/// the batch's flat query list (the queue-position indexing of the SoA
/// result layer, applied to the filter stage). Replaces the former
/// `HashMap<u32, BoundedHeap>` + worker-local merge: positions are dense,
/// so the arena is a flat `Vec`, and claim disjointness makes the merge
/// pass unnecessary.
struct HeapArena {
    heaps: Vec<UnsafeCell<BoundedHeap>>,
}

// SAFETY: access is partitioned by query-tile position ranges; each tile
// is claimed by exactly one filter worker (see `filter_tiles`), so no two
// threads ever touch the same slot.
unsafe impl Sync for HeapArena {}

impl HeapArena {
    fn new(n: usize, k: usize) -> Self {
        HeapArena {
            heaps: (0..n).map(|_| UnsafeCell::new(BoundedHeap::new(k))).collect(),
        }
    }

    /// Mutable access to one position's heap.
    ///
    /// # Safety
    /// No two threads may hold the same position at the same time. The
    /// filter stage guarantees this structurally: tiles carry disjoint
    /// position ranges and the chunk cursor hands each tile to one worker.
    #[allow(clippy::mut_from_ref)]
    unsafe fn heap(&self, i: usize) -> &mut BoundedHeap {
        &mut *self.heaps[i].get()
    }

    fn into_heaps(self) -> Vec<BoundedHeap> {
        self.heaps.into_iter().map(UnsafeCell::into_inner).collect()
    }
}

/// Device output of one candidate chunk of one query tile.
enum Payload {
    /// full distance tile: rows follow the tile's positions, cols follow
    /// `cand_ids`, stride `ct`
    Dist { d2: Vec<f32>, ct: usize },
    /// top-k tile: `vals`/`idx` are qt x k, idx indexes into `cand_ids`
    TopK { vals: Vec<f32>, idx: Vec<i32>, k: usize },
}

struct ChunkOut {
    cand_ids: Vec<u32>,
    payload: Payload,
}

/// All candidate-chunk outputs of one query tile: the filter work unit.
/// `pos` indexes the batch's flat query list; tiles partition it, which
/// is what makes arena access race-free.
struct TileOut {
    pos: std::ops::Range<usize>,
    chunks: Vec<ChunkOut>,
}

/// Filter a buffered set of tiles into the arena on `workers` threads via
/// the dynamic chunk scheduler (one tile per claim). Returns the in-ε
/// pair count.
fn filter_tiles(
    tiles_out: &[TileOut],
    batch_queries: &[u32],
    arena: &HeapArena,
    eps2: f64,
    exclude_self: bool,
    workers: usize,
) -> u64 {
    if tiles_out.is_empty() {
        return 0;
    }
    let per_worker = pool::parallel_chunks_stateful(
        tiles_out.len(),
        workers.max(1),
        1,
        |_w| 0u64,
        |pairs, range| {
            for ti in range {
                apply_tile(&tiles_out[ti], batch_queries, arena, eps2, exclude_self, pairs);
            }
        },
        |pairs| pairs,
    );
    per_worker.iter().sum()
}

/// Merge one tile's device output into the arena heaps (the paper's
/// host-side stream filter).
fn apply_tile(
    t: &TileOut,
    batch_queries: &[u32],
    arena: &HeapArena,
    eps2: f64,
    exclude_self: bool,
    pairs: &mut u64,
) {
    for chunk in &t.chunks {
        match &chunk.payload {
            Payload::Dist { d2, ct } => {
                for (r, pos) in t.pos.clone().enumerate() {
                    let q = batch_queries[pos];
                    // SAFETY: this tile is the sole owner of `pos` and is
                    // processed by exactly one worker (see HeapArena).
                    let heap = unsafe { arena.heap(pos) };
                    let row = &d2[r * ct..r * ct + chunk.cand_ids.len()];
                    // Fast path: once the heap is full, only candidates
                    // below the current k-th best can matter - track that
                    // bound as an f32 so the hot compare stays branchy-
                    // cheap and pushes become rare (EXPERIMENTS.md Perf#1).
                    // next_up: f64->f32 rounding must never exclude a
                    // candidate exactly at the bound
                    let mut gate = ((heap.bound().min(eps2)) as f32).next_up();
                    for (c, &dd) in row.iter().enumerate() {
                        if dd as f64 <= eps2 {
                            *pairs += 1;
                        }
                        if dd <= gate {
                            let id = chunk.cand_ids[c];
                            if !(exclude_self && id == q) {
                                heap.push(Neighbor {
                                    id,
                                    dist2: (dd as f64).max(0.0),
                                });
                                gate = ((heap.bound().min(eps2)) as f32).next_up();
                            }
                        }
                    }
                }
            }
            Payload::TopK { vals, idx, k } => {
                for (r, pos) in t.pos.clone().enumerate() {
                    let q = batch_queries[pos];
                    // SAFETY: as above.
                    let heap = unsafe { arena.heap(pos) };
                    for s in 0..*k {
                        let dd = vals[r * k + s] as f64;
                        if dd > eps2 {
                            break; // ascending: rest of the row is farther
                        }
                        let ci = idx[r * k + s] as usize;
                        if ci >= chunk.cand_ids.len() {
                            continue; // padded candidate row
                        }
                        let id = chunk.cand_ids[ci];
                        if !(exclude_self && id == q) {
                            *pairs += 1;
                            heap.push(Neighbor { id, dist2: dd.max(0.0) });
                        }
                    }
                }
            }
        }
    }
}

/// Execute the tile program over a set of cells and filter the outputs
/// into a fresh dense heap arena. Device execution happens on this thread
/// (the PJRT client is !Send, the paper's single GPU-master rank); device
/// output is buffered up to a fixed number of *chunks* — the same unit
/// the former stream channels bounded — then flushed to the `streams`
/// filter workers. A query tile whose candidate list spans more chunks
/// than the cap is split across flush rounds: rounds run sequentially, so
/// the within-round position-disjointness that makes the arena race-free
/// is preserved even when two rounds touch the same tile. The flush is
/// synchronous — exec and filtering alternate within a batch rather than
/// overlapping; overlapping them again via double-buffered queue claims
/// is ROADMAP follow-up (e). Returns the batch's flat query list (cell by
/// cell), one heap per position, and the in-ε pair count.
fn exec_filter_cells(
    engine: &Engine,
    (r_data, data): (&Dataset, &Dataset),
    (plan_large, plan_small): (&tiles::TilePlan, &tiles::TilePlan),
    use_topk: bool,
    cells: &[WorkCell],
    params: &GpuJoinParams,
    kernel_time: &mut f64,
) -> Result<(Vec<u32>, Vec<BoundedHeap>, u64)> {
    let n_queries: usize = cells.iter().map(|c| c.queries.len()).sum();
    let batch_queries: Vec<u32> = cells
        .iter()
        .flat_map(|c| c.queries.iter().copied())
        .collect();
    let arena = HeapArena::new(n_queries, params.k.max(1));
    let eps2 = params.eps * params.eps;
    let n_workers = params.streams.max(1);
    // flush threshold in buffered device chunks (each <= qt x ct x 4B):
    // enough to keep every filter worker busy, small enough that host
    // memory stays bounded regardless of any one cell's candidate count -
    // the same unit the former sync_channel depth (4/worker) bounded.
    let chunk_cap = n_workers * 8;

    let mut pairs_total = 0u64;
    let mut tiles_buf: Vec<TileOut> = Vec::new();
    let mut chunks_buffered = 0usize;
    let mut q_buf: Vec<f32> = Vec::new();
    let mut c_buf: Vec<f32> = Vec::new();
    let mut base = 0usize;
    for cell in cells {
        // One plan per cell: thin cells run on the small tile (less
        // padding); the small plan has no top-k variant, so it always
        // takes the dist path.
        let (plan, cell_topk) = if cell.queries.len() <= plan_small.qt {
            (plan_small, use_topk && plan_small.topk_name.is_some())
        } else {
            (plan_large, use_topk)
        };
        let (qt, ct, d_pad) = (plan.qt, plan.ct, plan.d);
        // Candidate tiles are shared by every query chunk of the cell:
        // pack + upload once (Perf#2).
        let c_lits: Vec<(&[u32], xla::Literal)> = cell
            .candidates
            .chunks(ct)
            .map(|c_chunk| {
                tiles::pack_candidates(&mut c_buf, data, c_chunk, ct, d_pad);
                Ok((
                    c_chunk,
                    Engine::literal(&c_buf, &[ct as i64, d_pad as i64])?,
                ))
            })
            .collect::<Result<_>>()?;
        for q_chunk in cell.queries.chunks(qt) {
            tiles::pack(&mut q_buf, r_data, q_chunk, qt, d_pad, 0.0);
            let q_lit = Engine::literal(&q_buf, &[qt as i64, d_pad as i64])?;
            let mut chunks: Vec<ChunkOut> = Vec::new();
            for (c_chunk, c_lit) in &c_lits {
                let t0 = Instant::now();
                let payload = if cell_topk {
                    let out = engine.exec_lits(
                        plan.topk_name.as_deref().unwrap(),
                        &[&q_lit, c_lit],
                    )?;
                    *kernel_time += t0.elapsed().as_secs_f64();
                    Payload::TopK {
                        vals: Engine::to_f32(&out[0])?,
                        idx: Engine::to_i32(&out[1])?,
                        k: plan.topk_k,
                    }
                } else {
                    let out = engine.exec_lits(&plan.dist_name, &[&q_lit, c_lit])?;
                    *kernel_time += t0.elapsed().as_secs_f64();
                    Payload::Dist { d2: Engine::to_f32(&out[0])?, ct }
                };
                chunks.push(ChunkOut { cand_ids: c_chunk.to_vec(), payload });
                chunks_buffered += 1;
                if chunks_buffered >= chunk_cap {
                    // emit the tile's chunks so far and flush; the next
                    // round may revisit this tile's positions - rounds run
                    // sequentially, so within-round disjointness holds
                    tiles_buf.push(TileOut {
                        pos: base..base + q_chunk.len(),
                        chunks: std::mem::take(&mut chunks),
                    });
                    pairs_total += filter_tiles(
                        &tiles_buf,
                        &batch_queries,
                        &arena,
                        eps2,
                        params.exclude_self,
                        n_workers,
                    );
                    tiles_buf.clear();
                    chunks_buffered = 0;
                }
            }
            if !chunks.is_empty() {
                tiles_buf.push(TileOut { pos: base..base + q_chunk.len(), chunks });
            }
            base += q_chunk.len();
        }
    }
    pairs_total += filter_tiles(
        &tiles_buf,
        &batch_queries,
        &arena,
        eps2,
        params.exclude_self,
        n_workers,
    );

    Ok((batch_queries, arena.into_heaps(), pairs_total))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::sqdist;
    use crate::data::synthetic::{chist_like, susy_like};
    use crate::index::KdTree;

    fn setup(n: usize) -> (Engine, Dataset) {
        (Engine::load_default().unwrap(), susy_like(n).generate(21))
    }

    fn exact_ref(data: &Dataset, q: u32, k: usize) -> Vec<Neighbor> {
        let t = KdTree::build(data);
        t.knn(data, data.point(q as usize), k, q)
    }

    #[test]
    fn solved_queries_are_exact_knn() {
        let (engine, data) = setup(1200);
        let grid = GridIndex::build(&data, 6, 3.0);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let params = GpuJoinParams::new(4, 3.0);
        let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
        assert!(out.solved > 0, "nothing solved - eps too small for test");
        let mut checked = 0;
        for q in (0..data.len() as u32).step_by(97) {
            let got = out.result.get(q as usize);
            if got.len() < params.k {
                continue; // failed query - CPU's job
            }
            let want = exact_ref(&data, q, params.k);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g.dist2 - w.dist2).abs() < 1e-3 * (1.0 + w.dist2),
                    "q={q} got={g:?} want={w:?}"
                );
            }
            checked += 1;
        }
        assert!(checked > 0);
    }

    #[test]
    fn failed_queries_have_too_few_in_eps_neighbors() {
        let (engine, data) = setup(900);
        let eps = 1.0; // small: guarantees some failures
        let grid = GridIndex::build(&data, 6, eps);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let params = GpuJoinParams::new(8, eps);
        let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
        assert_eq!(out.solved + out.failed.len(), queries.len());
        // verify failure ground truth on a sample
        for &q in out.failed.iter().step_by(53) {
            let within = (0..data.len())
                .filter(|&i| i != q as usize)
                .filter(|&i| sqdist(data.point(q as usize), data.point(i)) <= eps * eps)
                .count();
            assert!(
                within < params.k,
                "query {q} has {within} >= k in-eps neighbors but was failed"
            );
        }
    }

    #[test]
    fn dist_and_topk_paths_agree() {
        let (engine, data) = setup(700);
        let grid = GridIndex::build(&data, 6, 2.5);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let mut p_topk = GpuJoinParams::new(5, 2.5);
        p_topk.use_topk = true;
        let mut p_dist = p_topk.clone();
        p_dist.use_topk = false;
        let a = gpu_join(&engine, &data, &grid, &queries, &p_topk).unwrap();
        let b = gpu_join(&engine, &data, &grid, &queries, &p_dist).unwrap();
        assert_eq!(a.solved, b.solved);
        assert_eq!(a.failed, b.failed);
        for q in (0..data.len()).step_by(31) {
            let (ga, gb) = (a.result.get(q), b.result.get(q));
            assert_eq!(ga.len(), gb.len());
            for (x, y) in ga.iter().zip(gb) {
                assert!((x.dist2 - y.dist2).abs() < 1e-4 * (1.0 + y.dist2));
            }
        }
    }

    #[test]
    fn batching_respects_buffer_and_minimum() {
        let (engine, data) = setup(1500);
        let grid = GridIndex::build(&data, 6, 3.0);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let mut params = GpuJoinParams::new(4, 3.0);
        params.buffer_pairs = 2_000; // force many batches
        let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
        assert!(out.batches >= 3, "minimum 3 batches (stream overlap)");
        assert!(
            out.max_batch_pairs <= params.buffer_pairs * 4,
            "batch result {} wildly exceeds buffer {}",
            out.max_batch_pairs,
            params.buffer_pairs
        );
        assert!(out.estimated_pairs > 0);
    }

    #[test]
    fn subset_queries_only() {
        let (engine, data) = setup(600);
        let grid = GridIndex::build(&data, 6, 3.0);
        let queries: Vec<u32> = (0..200).collect();
        let params = GpuJoinParams::new(3, 3.0);
        let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
        assert_eq!(out.solved + out.failed.len(), 200);
        // queries outside the set must remain empty
        for q in 200..data.len() {
            assert!(out.result.get(q).is_empty());
        }
    }

    #[test]
    fn high_dim_chist_route() {
        // 32-D surrogate exercises the d=32 artifact family
        let engine = Engine::load_default().unwrap();
        let data = chist_like(500).generate(8);
        let sel = crate::epsilon::EpsilonSelector::default().select_host(&data, 3, 0.2);
        let grid = GridIndex::build(&data, 6, sel.eps);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let params = GpuJoinParams::new(3, sel.eps);
        let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
        assert!(out.solved + out.failed.len() == queries.len());
        assert!(out.kernel_time > 0.0);
        assert!(out.device_model.threads > 0);
    }

    #[test]
    fn drain_equals_list_form_and_recirculates_failures() {
        // the queue-driven GPU master must solve exactly the queries the
        // list form solves (same cells, same candidates) and push every
        // failure into the recirculation buffer
        use crate::sched::build_queue;

        let (engine, data) = setup(800);
        let eps = 2.0;
        let grid = GridIndex::build(&data, 6, eps);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let params = GpuJoinParams::new(6, eps);

        let list = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();

        let queue = build_queue(&data, &grid, &queries, params.k, 0.0, 0.0);
        let mut result = KnnResult::new(data.len(), params.k);
        let slots = result.slots();
        let out = gpu_join_drain(
            &engine, &data, &data, &grid, &queue, &params, &slots,
            queue.len(),
        )
        .unwrap();
        drop(slots);

        assert_eq!(out.solved + out.failed.len(), queries.len());
        assert_eq!(out.solved, list.solved);
        assert_eq!(out.failed, list.failed);
        assert_eq!(queue.claimed_head(), queries.len());
        assert_eq!(queue.recirc_pushed(), out.failed.len());
        assert!(!out.claims.is_empty());
        assert!(out.claims.iter().all(|c| matches!(c.arch, Arch::Gpu)));
        let claimed: usize = out.claims.iter().map(|c| c.queries).sum();
        assert_eq!(claimed, queries.len());
        for q in (0..data.len()).step_by(61) {
            let (a, b) = (result.get(q), list.result.get(q));
            assert_eq!(a.len(), b.len(), "q={q}");
            for (x, y) in a.iter().zip(b) {
                assert!((x.dist2 - y.dist2).abs() < 1e-4 * (1.0 + y.dist2));
            }
        }
    }
}
