//! GPU-JOIN (paper Sec. V-B/V-E/V-G + Alg. 1 GPUJoinKernel).
//!
//! Range-query KNN over the ε-grid, executed on the "device" (PJRT):
//!
//! * queries are grouped **by grid cell** - all queries in a cell share
//!   the same adjacent-cell candidate list, which is the tile analogue of
//!   the paper's kernel where threads of neighboring queries scan the
//!   same cells;
//! * each (cell-queries x candidate-chunk) work unit executes one dist /
//!   dist-topk artifact tile; host-side filtering (ε test, self-exclusion,
//!   per-query bounded heap merge) runs on `streams` filter workers driven
//!   by `pool::parallel_chunks_stateful` over a **dense heap arena**
//!   indexed by the query's position in the batch - no per-query hash map
//!   and no worker-local heap merge: each query tile (and therefore each
//!   arena slot) is claimed by exactly one worker off the atomic cursor;
//! * queries that end with fewer than K in-ε neighbors are returned as
//!   Q^Fail for CPU reassignment (Sec. V-E).
//!
//! Two entry shapes exist. The list-driven form (`gpu_join_rs_into`)
//! processes a fixed query set in estimator-sized batches - the paper's
//! Sec. IV-B batching - handing each flush round to a persistent
//! `pool::stage_scope` filter pool (one batch in flight at a time). The
//! queue-driven form (`gpu_join_drain`) is the hybrid join's GPU master:
//! it claims batches of aggregate estimated work off the dense head of
//! the shared work queue (`sched`), sizes each next claim from the live
//! CPU/GPU work rates (Eq. 6 as feedback), and *recirculates* failed
//! queries into the queue for CPU ranks to absorb while the join is
//! still running. The queue drain runs as a three-stage pipeline by
//! default ([`DrainMode::ThreeStage`]): device **exec** of claim i+1,
//! the device-to-host **transfer** of claim i on a dedicated transfer
//! stage, and host **filter**ing of claim i-1 all overlap, through
//! rotating staging arenas and per-claim round lanes on the shared
//! stage pool - the batching scheme's exec/transfer/filter overlap
//! (Sec. IV-B), applied to the claim loop. The two-stage drain (transfer
//! still on the master thread) and the synchronous drain survive as
//! ablation modes; the synchronous drain is also the single-core
//! schedule. All three produce bit-identical results
//! (rust/tests/pipeline.rs).
//!
//! A query with >= K neighbors within ε is *exactly* solved: its true K
//! nearest all lie within ε, and the grid walk provably visits every point
//! within ε of the query in the indexed projection (see index::grid).
//!
//! The queue drain hosts a second *backend* behind the same claim loop:
//! the tiled brute-force tier (`sched::BackendMode` / DESIGN.md §10). A
//! claim routed to the brute tier collapses to one work cell whose
//! candidate set is the whole corpus, executed from a per-drain cache of
//! pre-packed candidate tiles ([`BruteCache`]) with no ε gate - every
//! brute query with |D| - 1 >= K candidates resolves to its exact
//! K nearest (the same answer the CPU's Q^Fail pass would compute), so
//! the dense head cells whose grid candidate lists degenerate toward
//! O(|D|) stop paying the grid walk and stop recirculating failures.

use std::cell::UnsafeCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::brute::BruteCache;
use super::device::{DeviceEstimate, DeviceModel, ThreadAssign};
use crate::core::{BoundedHeap, Dataset, KnnResult, Neighbor, SoaSlots};
use crate::fault::{
    panic_message, FaultAction, FaultKind, FaultLog, FaultPlan, InjectedFault,
    RecoveryPolicy, WatchdogTimeout,
};
use crate::index::GridIndex;
use crate::runtime::{tiles, tiles::TileClass, Engine};
use crate::sched::{self, Arch, BackendMode, ClaimRecord, WorkQueue};
use crate::util::pool;

/// How the queue-driven GPU master (`gpu_join_drain`) overlaps its
/// per-claim stages. All modes produce bit-identical results and the
/// same solved/failed partition (rust/tests/pipeline.rs) - the mode only
/// moves work between threads and wall-clock phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrainMode {
    /// Device execution, device-to-host transfer and host filtering
    /// alternate within each claim. The ablation baseline of the
    /// pipelined drains, and the single-core schedule (the pipelines'
    /// extra threads would fight the PJRT pool over one core).
    Sync,
    /// Two-stage pipeline: device exec of claim i+1 overlaps host
    /// filtering of claim i through two alternating staging arenas. The
    /// device-to-host transfer stays on the master thread - the ablation
    /// that isolates what the dedicated transfer stage buys.
    TwoStage,
    /// Three-stage pipeline (the default): device exec of claim i+1, the
    /// device-to-host transfer of claim i on a dedicated transfer
    /// worker, and host filtering of claim i-1 all overlap through three
    /// rotating staging arenas and per-claim round lanes on the filter
    /// pool.
    ThreeStage,
}

/// Parameters of the GPU side.
#[derive(Debug, Clone)]
pub struct GpuJoinParams {
    /// neighbors per query
    pub k: usize,
    /// grid/search radius ε
    pub eps: f64,
    /// device tile family (large/small qt x ct shapes)
    pub tile_class: TileClass,
    /// prefer the on-device top-k artifact when k allows (perf path)
    pub use_topk: bool,
    /// result buffer capacity b_s in (query, neighbor) pairs per batch
    pub buffer_pairs: u64,
    /// host-side filter worker threads ("streams"); paper uses 3
    pub streams: usize,
    /// thread-granularity strategy fed to the device model (Table III)
    pub assign: ThreadAssign,
    /// fraction of cells sampled by the batch estimator (Sec. IV-B)
    pub estimator_frac: f64,
    /// self-join semantics: drop candidate id == query id. Off for
    /// bipartite R JOIN S (Sec. III: "directly applicable to R x S").
    pub exclude_self: bool,
    /// queue-driven drain only: how the per-claim stages overlap (the
    /// list-driven form always pipelines its flush rounds through the
    /// stage pool within one batch and ignores this field).
    pub drain: DrainMode,
    /// queue-driven drains only: the injected fault schedule. The exec /
    /// transfer / filter hooks are branch-on-empty no-ops under the
    /// default [`FaultPlan::none()`]; the list-driven form ignores the
    /// plan entirely (it has no claim to scope recovery to).
    pub fault: FaultPlan,
    /// queue-driven drains only: claim-scoped recovery policy - retry
    /// budget and backoff for transient faults, the per-claim watchdog
    /// envelope, and the consecutive-failure demotion threshold.
    pub recovery: RecoveryPolicy,
    /// queue-driven drains only: backend routing between the grid tier
    /// and the tiled brute-force tier. [`BackendMode::Auto`] consults
    /// [`sched::route_brute`] per claim on the claim's mean candidate
    /// population; `Grid`/`Brute` force one tier for the whole drain.
    /// The list-driven form is grid-only and ignores this field.
    pub backend: BackendMode,
}

impl GpuJoinParams {
    /// Paper-default parameters for the given K and ε.
    pub fn new(k: usize, eps: f64) -> Self {
        GpuJoinParams {
            k,
            eps,
            tile_class: TileClass::Large,
            // On CPU-PJRT the sort-based top-k tile is ~40x slower than the
            // raw distance tile + host filter (see EXPERIMENTS.md Perf); on
            // a real accelerator the top-k variant trades that for a 8x
            // smaller device->host transfer. Off by default here.
            use_topk: false,
            buffer_pairs: 10_000_000,
            streams: 3,
            assign: ThreadAssign::Static(8),
            estimator_frac: 0.01,
            exclude_self: true,
            drain: DrainMode::ThreeStage,
            fault: FaultPlan::none(),
            recovery: RecoveryPolicy::default(),
            backend: BackendMode::Auto,
        }
    }
}

/// Outcome of a GPU-JOIN run that owns its result table.
#[derive(Debug)]
pub struct GpuJoinOutcome {
    /// exact results for solved queries (others left empty)
    pub result: KnnResult,
    /// Q^Fail - queries with < K neighbors within ε
    pub failed: Vec<u32>,
    /// queries solved exactly
    pub solved: usize,
    /// wall time inside PJRT execution
    pub kernel_time: f64,
    /// wall time of the whole join (incl. packing + filtering)
    pub total_time: f64,
    /// modeled GPU kernel time for the configured ThreadAssign
    pub device_model: DeviceEstimate,
    /// batches executed (>= 3 whenever there is work, per Sec. IV-B)
    pub batches: usize,
    /// estimator's predicted result pairs
    pub estimated_pairs: u64,
    /// realised in-ε result pairs
    pub result_pairs: u64,
    /// max pairs observed in one batch (must stay <= buffer_pairs)
    pub max_batch_pairs: u64,
    /// byte-accurate buffered-device-output envelope actually scheduled:
    /// the largest per-batch result-pair *capacity* (Σ |queries| x
    /// |candidates| over the batch's cells; claim est-work in the queue
    /// form). `max_batch_pairs <= batch_envelope_pairs`, and the packer
    /// keeps this within `buffer_pairs` unless a single indivisible cell
    /// exceeds it.
    pub batch_envelope_pairs: u64,
}

/// Accounting of an in-place GPU-JOIN (`gpu_join_rs_into` /
/// `gpu_join_drain`); solved-query results live in the caller's
/// `KnnResult` slots.
#[derive(Debug)]
pub struct GpuJoinStats {
    /// Q^Fail - queries with < K neighbors within ε (slots untouched)
    pub failed: Vec<u32>,
    /// queries solved exactly (slots written)
    pub solved: usize,
    /// wall time inside PJRT execution
    pub kernel_time: f64,
    /// wall time of the whole join (incl. packing + filtering)
    pub total_time: f64,
    /// modeled GPU kernel time for the configured ThreadAssign
    pub device_model: DeviceEstimate,
    /// batches (list form) / claims (queue form) executed
    pub batches: usize,
    /// list form: estimator-predicted result pairs; queue form: estimated
    /// work actually claimed
    pub estimated_pairs: u64,
    /// realised in-ε result pairs
    pub result_pairs: u64,
    /// max pairs observed in one batch (must stay <= buffer_pairs)
    pub max_batch_pairs: u64,
    /// largest per-batch/per-claim result-pair capacity scheduled (the
    /// byte-accurate `buffer_pairs` envelope; see
    /// [`GpuJoinOutcome::batch_envelope_pairs`])
    pub batch_envelope_pairs: u64,
    /// master-thread seconds materialising, packing and executing tiles
    /// on the device (claim resolution included; the literal-to-host
    /// conversion excluded - see `transfer_time`). `exec_time +
    /// transfer_time + filter_time > total_time` is the observable
    /// signature of a pipelined drain actually overlapping its stages.
    pub exec_time: f64,
    /// seconds converting device output literals into flat host buffers
    /// (`to_f32`/`to_i32`), summed over flush rounds - the host half of
    /// the device-to-host path. On this PJRT-CPU stack the preceding
    /// buffer-to-literal materialisation happens inside `exec_lits`
    /// (`to_literal_sync`) and therefore stays on the master thread
    /// inside `exec_time`/`kernel_time`; a real-accelerator backend
    /// would want that DMA moved onto the transfer stage too (async
    /// PJRT transfers). On the three-stage drain this conversion runs
    /// on the dedicated transfer stage and overlaps `exec_time`; on the
    /// sync/two-stage drains and the list form it runs on the master
    /// thread.
    pub transfer_time: f64,
    /// filter-stage wall seconds (host-side ε test + heap merge) summed
    /// over flush rounds
    pub filter_time: f64,
    /// per-claim telemetry (queue-driven form only; empty for the list
    /// form)
    pub claims: Vec<ClaimRecord>,
    /// fault events observed (failed attempts: retried + reclaimed;
    /// queue-driven drains only, 0 elsewhere)
    pub gpu_faults: usize,
    /// synchronous claim retries performed after transient faults
    pub gpu_retries: usize,
    /// work-queue cells whose claims were reclaimed through Q^Fail after
    /// retries were exhausted
    pub reclaimed_cells: usize,
    /// the master demoted itself after `recovery.demote_after`
    /// consecutive claim failures; the rest of the run completed CPU-only
    pub degraded: bool,
    /// ordered log of the fault events behind the counters above
    pub fault_log: FaultLog,
    /// device chunk executions on the brute tier (queue-driven drains
    /// only; one query tile x candidate chunk = one artifact execution)
    pub brute_tiles: u64,
    /// claims routed to the tiled brute-force backend (queue form only)
    pub brute_claims: usize,
    /// claims routed to the grid backend (queue form only)
    pub grid_claims: usize,
}

/// A unit of work: one grid cell's queries + the shared candidate list.
/// A brute-routed claim collapses to a single cell with an empty
/// candidate list: the exec loop sources its candidate tiles from the
/// drain's [`BruteCache`] (the whole corpus) instead.
#[derive(Debug, Clone)]
struct WorkCell {
    queries: Vec<u32>,
    candidates: Vec<u32>,
    brute: bool,
}

/// Run GPU-JOIN for `queries` (ids into `data`) over the given grid
/// (self-join form; see `gpu_join_rs` for the bipartite join).
pub fn gpu_join(
    engine: &Engine,
    data: &Dataset,
    grid: &GridIndex,
    queries: &[u32],
    params: &GpuJoinParams,
) -> Result<GpuJoinOutcome> {
    gpu_join_rs(engine, data, data, grid, queries, params)
}

/// Bipartite GPU-JOIN: `queries` are ids into `r_data` (the outer
/// relation); candidates come from `data` = S via `grid` built over S.
/// With `r_data` = `data` and exclude_self this is the self-join.
pub fn gpu_join_rs(
    engine: &Engine,
    r_data: &Dataset,
    data: &Dataset,
    grid: &GridIndex,
    queries: &[u32],
    params: &GpuJoinParams,
) -> Result<GpuJoinOutcome> {
    let mut result = KnnResult::new(r_data.len(), params.k);
    let slots = result.slots();
    let s = gpu_join_rs_into(engine, r_data, data, grid, queries, params, &slots)?;
    drop(slots);
    Ok(GpuJoinOutcome {
        result,
        failed: s.failed,
        solved: s.solved,
        kernel_time: s.kernel_time,
        total_time: s.total_time,
        device_model: s.device_model,
        batches: s.batches,
        estimated_pairs: s.estimated_pairs,
        result_pairs: s.result_pairs,
        max_batch_pairs: s.max_batch_pairs,
        batch_envelope_pairs: s.batch_envelope_pairs,
    })
}

/// GPU-JOIN writing solved queries *in place* through `slots` (the
/// no-merge path). Failed queries' slots are left untouched for the
/// Q^Fail CPU pass. The caller must not concurrently write the slots of
/// `queries` elsewhere (see `SoaSlots::slot`); this function itself
/// resolves results on the calling thread only.
///
/// Batches flow through the same stage-pool machinery as the queue
/// drains: one persistent filter pool serves every batch, so device
/// execution overlaps filtering *within* a batch while batches stay
/// synchronous - each batch is fully filtered and resolved before the
/// next one starts, so results are identical to the former
/// inline-filtered path (and the per-round worker spawns are gone). All
/// batches share one lane and one staging arena - batches are strictly
/// sequential here, and one lane per arena is exactly the pool's
/// lane/arena contract (rounds targeting one arena stay ordered).
pub fn gpu_join_rs_into(
    engine: &Engine,
    r_data: &Dataset,
    data: &Dataset,
    grid: &GridIndex,
    queries: &[u32],
    params: &GpuJoinParams,
    slots: &SoaSlots<'_>,
) -> Result<GpuJoinStats> {
    let t_start = Instant::now();
    assert!(params.k <= slots.k(), "result stride {} < k {}", slots.k(), params.k);
    // Two tile plans: thin cells (few queries) run on the small tile to
    // cut padding waste ~4x; dense cells use the large tile. This is the
    // tile-world analogue of the paper's task-granularity tuning.
    let plan_large = tiles::plan_for(engine, data.dims(), params.tile_class)?;
    let plan_small = tiles::plan_for(engine, data.dims(), TileClass::Small)
        .unwrap_or_else(|_| plan_large.clone());
    let use_topk = params.use_topk
        && plan_large.topk_name.is_some()
        && params.k <= plan_large.topk_k;
    let plans = (&plan_large, &plan_small);

    // ---- group queries by cell (shared candidate lists) ----
    // Self-join (r_data IS the grid's dataset): O(1) id-keyed cell
    // lookups; bipartite R queries take the coordinate-keyed path (one
    // linearisation per query, no allocation). Candidates are collected
    // through the CSR walk into an exact-capacity buffer per cell.
    let native = std::ptr::eq(r_data, data);
    let mut by_cell: HashMap<u64, Vec<u32>> = HashMap::new();
    for &q in queries {
        by_cell
            .entry(grid.query_cell_id(native, r_data, q))
            .or_default()
            .push(q);
    }
    let mut cells: Vec<WorkCell> = by_cell
        .into_values()
        .map(|qs| {
            let mut candidates = Vec::new();
            grid.query_candidates_into(native, r_data, qs[0], &mut candidates);
            WorkCell { queries: qs, candidates, brute: false }
        })
        .collect();
    // deterministic order (largest first helps batch balance)
    cells.sort_by_key(|c| std::cmp::Reverse(c.queries.len() * c.candidates.len()));

    // ---- device-model accounting on the real workload ----
    let work: Vec<u64> = cells
        .iter()
        .flat_map(|c| c.queries.iter().map(|_| c.candidates.len() as u64))
        .collect();
    let device_model = DeviceModel::default().estimate(&work, params.assign);

    // ---- estimator sample (Sec. IV-B) ----
    let sample_n = ((cells.len() as f64 * params.estimator_frac).ceil() as usize)
        .clamp(1.min(cells.len()), cells.len());
    let sample: Vec<WorkCell> = cells
        .iter()
        .step_by((cells.len() / sample_n.max(1)).max(1))
        .cloned()
        .collect();
    let sampled_queries: usize = sample.iter().map(|c| c.queries.len()).sum();

    // Per-round chunk cap: half the synchronous flush envelope, so one
    // round in flight plus one being filled never exceed the former
    // buffered-output envelope (as in the two-stage drain). On a
    // single-core host each round is instead waited out inline - the
    // overlap would only thrash the one core.
    let n_workers = params.streams.max(1);
    let round_cap = (n_workers * 8 / 2).max(1);
    let overlap_rounds = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        > 1;
    let arena_k = params.k.max(1);
    let eps2 = params.eps * params.eps;
    let exclude_self = params.exclude_self;
    let n_queries_total = queries.len();

    let (master_out, _worker_units) = pool::stage_scope(
        n_workers,
        1, // bounded hand-off: one flush round queued/filtering at a time
        |_w| (),
        |_s: &mut (), job: &FilterRound, i: usize| {
            let mut pairs = 0u64;
            apply_tile(
                &job.tiles[i],
                &job.stage.batch_queries,
                &job.stage.arena,
                eps2,
                exclude_self,
                &mut pairs,
            );
            if pairs > 0 {
                job.stage.pairs.fetch_add(pairs, Ordering::Relaxed);
            }
        },
        |job: &FilterRound, wall: f64| {
            job.stage
                .filter_nanos
                .fetch_add((wall * 1e9) as u64, Ordering::Relaxed);
        },
        |_s| (),
        |handle| -> Result<(DrainAcc, u64)> {
            let mut acc = DrainAcc::default();
            let mut stage = Arc::new(ClaimStage::new(arena_k));
            // list-form cells are never brute-routed; the cache stays empty
            let mut brute_cache = BruteCache::new();

            // batch estimator: run the sample through the pool and scale
            // the in-ε pair count to the full query set
            let sample_pairs = exec_filter_batch_pooled(
                engine, (r_data, data), plans, use_topk, &sample, params,
                round_cap, handle, overlap_rounds, &mut stage,
                &mut brute_cache, &mut acc,
            )?;
            let estimated_pairs = if sampled_queries > 0 {
                (sample_pairs as f64 * n_queries_total as f64
                    / sampled_queries as f64)
                    .ceil() as u64
            } else {
                0
            };

            // ---- partition cells into batches (byte-accurate envelope) ----
            // `buffer_pairs` bounds the device output buffered per batch.
            // A cell's realized in-ε pairs can never exceed its
            // |queries| x |candidates| distance matrix, so packing cells
            // first-fit (keeping the largest-first order) against that
            // exact per-cell capacity keeps every batch's buffered
            // output within `buffer_pairs` - no estimator slack, no
            // chunk-count heuristic. A single cell larger than the
            // budget gets its own batch: its matrix is indivisible at
            // this layer, so the envelope is `buffer_pairs` or the
            // largest cell, whichever is bigger. The budget additionally
            // shrinks so the packing yields >= 3 batches (stream
            // overlap), matching the historical minimum.
            let cell_cap =
                |c: &WorkCell| (c.queries.len() * c.candidates.len()) as u64;
            let total_capacity: u64 = cells.iter().map(cell_cap).sum();
            let mut budget = params.buffer_pairs.max(1);
            if cells.len() >= 3 {
                budget = budget.min((total_capacity / 3).max(1));
            }
            let mut batches: Vec<Vec<WorkCell>> = Vec::new();
            let mut loads: Vec<u64> = Vec::new();
            for c in cells {
                let cap = cell_cap(&c);
                match loads.iter().position(|&l| l + cap <= budget) {
                    Some(i) => {
                        loads[i] += cap;
                        batches[i].push(c);
                    }
                    None => {
                        loads.push(cap);
                        batches.push(vec![c]);
                    }
                }
            }
            // oversized cells can leave fewer than 3 bins: split the
            // fullest multi-cell bins until the minimum is restored
            while batches.len() < 3 && batches.iter().any(|b| b.len() > 1) {
                let i = (0..batches.len())
                    .max_by_key(|&i| batches[i].len())
                    .expect("non-empty bins");
                let tail = batches[i].split_off(batches[i].len() / 2);
                loads[i] = batches[i].iter().map(cell_cap).sum();
                loads.push(tail.iter().map(cell_cap).sum());
                batches.push(tail);
            }
            acc.batch_envelope_pairs = loads.iter().copied().max().unwrap_or(0);

            // ---- execute batches, resolving each into slots / Q^Fail ----
            for batch in &batches {
                if batch.is_empty() {
                    continue;
                }
                let batch_pairs = exec_filter_batch_pooled(
                    engine, (r_data, data), plans, use_topk, batch, params,
                    round_cap, handle, overlap_rounds, &mut stage,
                    &mut brute_cache, &mut acc,
                )?;
                // the lane is drained: the stage is unique again and its
                // arena holds the batch's filtered heaps
                let s = Arc::get_mut(&mut stage).expect("stage shared after batch");
                for (pos, &q) in s.batch_queries.iter().enumerate() {
                    let h = s.arena.heap_mut(pos);
                    if h.len() >= params.k {
                        // SAFETY: `queries` is duplicate-free and only this
                        // thread writes GPU-side slots (caller keeps
                        // concurrent writers off these ids).
                        unsafe { slots.slot(q as usize) }.write_heap(h);
                        acc.solved += 1;
                    } else {
                        acc.failed.push(q);
                    }
                }
                acc.result_pairs += batch_pairs;
                acc.max_batch_pairs = acc.max_batch_pairs.max(batch_pairs);
                acc.batches += 1;
            }
            Ok((acc, estimated_pairs))
        },
    );

    let (mut acc, estimated_pairs) = master_out?;
    acc.failed.sort_unstable();
    let total_time = t_start.elapsed().as_secs_f64();
    Ok(GpuJoinStats {
        failed: acc.failed,
        solved: acc.solved,
        kernel_time: acc.kernel_time,
        total_time,
        device_model,
        batches: acc.batches,
        estimated_pairs,
        result_pairs: acc.result_pairs,
        max_batch_pairs: acc.max_batch_pairs,
        batch_envelope_pairs: acc.batch_envelope_pairs,
        // list form: master time is not separately clocked - exec is the
        // wall minus the measured transfer/filter components
        exec_time: (total_time - acc.transfer_time - acc.filter_time).max(0.0),
        transfer_time: acc.transfer_time,
        filter_time: acc.filter_time,
        claims: Vec::new(),
        gpu_faults: 0,
        gpu_retries: 0,
        reclaimed_cells: 0,
        degraded: false,
        fault_log: FaultLog::default(),
        brute_tiles: 0,
        brute_claims: 0,
        grid_claims: 0,
    })
}

/// Execute + filter one batch of cells through the shared stage pool
/// (the list-driven path): refill `stage` (unique at entry), execute the
/// batch's tiles - converting device output on this thread (the
/// master-side transfer) and handing each flush round to the pool's
/// filter workers - then wait the lane out, so at return the stage is
/// unique again and its arena holds the batch's filtered heaps. Every
/// batch reuses one lane and one arena (batches are sequential; one
/// lane per arena is the pool's lane/arena contract). With
/// `overlap_rounds` the filter workers run concurrently with the next
/// device call (within-batch exec/filter overlap); without it each
/// round is waited out inline (the single-core schedule). Adds
/// kernel/transfer/filter seconds to `acc` and returns the batch's in-ε
/// pair count.
#[allow(clippy::too_many_arguments)]
fn exec_filter_batch_pooled(
    engine: &Engine,
    (r_data, data): (&Dataset, &Dataset),
    plans: (&tiles::TilePlan, &tiles::TilePlan),
    use_topk: bool,
    cells: &[WorkCell],
    params: &GpuJoinParams,
    round_cap: usize,
    handle: &pool::StageHandle<FilterRound>,
    overlap_rounds: bool,
    stage: &mut Arc<ClaimStage>,
    brute_cache: &mut BruteCache,
    acc: &mut DrainAcc,
) -> Result<u64> {
    // the list form's single lane: one arena, sequential batches
    let lane = 0u64;
    let arena_k = params.k.max(1);
    let n_queries: usize = cells.iter().map(|c| c.queries.len()).sum();
    {
        let s = Arc::get_mut(stage).expect("stage shared at batch refill");
        s.batch_queries.clear();
        s.batch_queries
            .extend(cells.iter().flat_map(|c| c.queries.iter().copied()));
        s.arena.reset(n_queries, arena_k);
        s.pairs.store(0, Ordering::Relaxed);
        s.filter_nanos.store(0, Ordering::Relaxed);
        s.transfer_nanos.store(0, Ordering::Relaxed);
    }
    let mut transfer_secs = 0f64;
    {
        let stage_arc = &*stage;
        exec_cells_into_rounds(
            engine,
            (r_data, data),
            plans,
            use_topk,
            cells,
            params,
            round_cap,
            brute_cache,
            &mut acc.kernel_time,
            &mut acc.brute_tiles,
            &mut |raw: Vec<RawTile>| {
                let t0 = Instant::now();
                let tiles = convert_tiles(raw)?;
                transfer_secs += t0.elapsed().as_secs_f64();
                let len = tiles.len();
                handle.submit(
                    FilterRound {
                        stage: Arc::clone(stage_arc),
                        tiles,
                        claim: 0,
                        round: 0,
                    },
                    len,
                    lane,
                );
                if !overlap_rounds {
                    handle.wait_lane(lane);
                }
                Ok(())
            },
        )?;
    }
    handle.wait_lane(lane);
    acc.transfer_time += transfer_secs;
    let s = Arc::get_mut(stage).expect("stage shared after lane wait");
    acc.filter_time += s.filter_nanos.load(Ordering::Relaxed) as f64 / 1e9;
    Ok(s.pairs.load(Ordering::Relaxed))
}

/// The hybrid join's GPU master: drain the dense head of the shared work
/// queue in work-sized claims until the head meets the CPU's tail front.
///
/// * the *seed* claim is sized from the γ dense prefix
///   (`sched::first_batch_work`) and taken **before** tile-plan setup, so
///   the GPU is guaranteed a share whenever the head is open;
/// * every subsequent claim is sized by `sched::next_batch_work` from the
///   live GPU/CPU work rates - Eq. 6 driving the schedule instead of
///   diagnosing it - and capped at `buffer_pairs` estimated work (a
///   candidate scan bounds its result pairs, so the batch buffer bound of
///   Sec. IV-B is conserved);
/// * failed queries are pushed back into the queue's recirculation buffer
///   for CPU ranks to absorb concurrently; their slots stay untouched;
/// * `pos_cap` bounds how deep into the queue the head may reach - the
///   single-core fallback passes the γ dense prefix so the sequential
///   schedule degenerates to exactly the static split.
///
/// Slot safety: identical to `gpu_join_rs_into` - head claims are
/// disjoint from tail claims by the two-ended cursor, and failed ids are
/// written by whichever CPU rank claims them from recirculation, never
/// here.
///
/// `params.drain` picks the claim-level overlap: the three-stage
/// pipeline (default - device exec of claim i+1, device-to-host transfer
/// of claim i, host filtering of claim i-1), the two-stage pipeline
/// (transfer stays on the master - the ablation isolating the dedicated
/// transfer stage), or the synchronous drain (`drain_sync`, where all
/// stages alternate per claim - the ablation baseline). All modes
/// produce bit-identical results (rust/tests/pipeline.rs); see DESIGN.md
/// §5 for the hand-off contract.
#[allow(clippy::too_many_arguments)]
pub fn gpu_join_drain(
    engine: &Engine,
    r_data: &Dataset,
    data: &Dataset,
    grid: &GridIndex,
    queue: &WorkQueue,
    params: &GpuJoinParams,
    slots: &SoaSlots<'_>,
    pos_cap: usize,
) -> Result<GpuJoinStats> {
    gpu_join_drain_with(
        engine,
        r_data,
        data,
        grid,
        queue,
        params,
        slots,
        pos_cap,
        &mut DrainState::new(),
    )
}

/// Session-owned reusable state of the queue-driven GPU drains: the
/// brute tier's packed corpus tile cache and the pipelined drains'
/// rotating staging sets (query lists + heap arenas). A one-shot join
/// builds a fresh one per call ([`gpu_join_drain`]); a resident
/// streaming session keeps one across flushes
/// ([`gpu_join_drain_with`]) so corpus tiles stay packed and arena heap
/// storage is reused instead of reallocated on every micro-batch.
pub(crate) struct DrainState {
    brute_cache: BruteCache,
    stages: Vec<Arc<ClaimStage>>,
    /// arena stride the stored stages were built for; a flush with a
    /// different k drops them
    stage_k: usize,
    /// index epoch (queue generation stamp) the cached corpus tiles
    /// were packed against; a drain over a newer stamp invalidates
    generation: u64,
}

impl DrainState {
    /// Empty state: nothing cached yet.
    pub(crate) fn new() -> Self {
        DrainState {
            brute_cache: BruteCache::new(),
            stages: Vec::new(),
            stage_k: 0,
            generation: 0,
        }
    }

    /// Align the resident caches with the index snapshot a drain is
    /// about to read: on a generation (index epoch) change the brute
    /// tile cache is dropped and repacked over `live` - the churn
    /// path's consistent-snapshot guarantee. `live` is the ascending
    /// live-id set when the corpus holds removed (tombstoned) points,
    /// `None` for the static whole-corpus case.
    pub(crate) fn sync_generation(&mut self, generation: u64, live: Option<Vec<u32>>) {
        if self.generation != generation {
            self.brute_cache.invalidate();
            self.generation = generation;
        }
        self.brute_cache.set_live(live);
    }

    /// Take `depth` staging sets for a drain, reusing stored ones when
    /// the arena stride matches and topping up with fresh allocations.
    fn take_stages(
        &mut self,
        depth: usize,
        arena_k: usize,
    ) -> Vec<Arc<ClaimStage>> {
        if self.stage_k != arena_k {
            self.stages.clear();
            self.stage_k = arena_k;
        }
        let mut out = std::mem::take(&mut self.stages);
        out.truncate(depth);
        while out.len() < depth {
            out.push(Arc::new(ClaimStage::new(arena_k)));
        }
        out
    }

    /// Store staging sets back after a drain for the next flush. Only
    /// uniquely-owned sets are kept: an abandoned error path may leave
    /// one shared with a parked round, and such a set must not be
    /// handed to a later flush (it is simply dropped instead).
    fn store_stages(&mut self, stages: Vec<Arc<ClaimStage>>) {
        self.stages = stages
            .into_iter()
            .filter(|s| Arc::strong_count(s) == 1)
            .collect();
    }
}

/// [`gpu_join_drain`] over caller-owned [`DrainState`] - the re-entrant
/// form the streaming session uses, where one `DrainState` outlives
/// many flushes.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gpu_join_drain_with(
    engine: &Engine,
    r_data: &Dataset,
    data: &Dataset,
    grid: &GridIndex,
    queue: &WorkQueue,
    params: &GpuJoinParams,
    slots: &SoaSlots<'_>,
    pos_cap: usize,
    state: &mut DrainState,
) -> Result<GpuJoinStats> {
    let t_start = Instant::now();
    assert!(params.k <= slots.k(), "result stride {} < k {}", slots.k(), params.k);
    let buffer_cap = params.buffer_pairs.max(1);

    // Churn snapshot alignment: invalidate the cross-flush brute tile
    // cache when the index epoch (queue generation stamp) moved, and
    // pack only the live ids whenever the corpus holds tombstoned
    // points - a removed point must never resurface as a neighbor.
    let live = if grid.indexed_points() == data.len() {
        None
    } else {
        Some(grid.indexed_ids())
    };
    state.sync_generation(queue.generation(), live);

    // seed claim first: a fast CPU must not drain the queue while we
    // compile tile plans
    let target = sched::first_batch_work(
        queue.head_work_remaining(pos_cap),
        queue.dense_work(),
    )
    .min(buffer_cap);
    let Some(first) = queue.claim_head_work(target, pos_cap) else {
        return Ok(GpuJoinStats {
            failed: Vec::new(),
            solved: 0,
            kernel_time: 0.0,
            total_time: t_start.elapsed().as_secs_f64(),
            device_model: DeviceEstimate::default(),
            batches: 0,
            estimated_pairs: 0,
            result_pairs: 0,
            max_batch_pairs: 0,
            batch_envelope_pairs: 0,
            exec_time: 0.0,
            transfer_time: 0.0,
            filter_time: 0.0,
            claims: Vec::new(),
            gpu_faults: 0,
            gpu_retries: 0,
            reclaimed_cells: 0,
            degraded: false,
            fault_log: FaultLog::default(),
            brute_tiles: 0,
            brute_claims: 0,
            grid_claims: 0,
        });
    };

    let plan_large = tiles::plan_for(engine, data.dims(), params.tile_class)?;
    let plan_small = tiles::plan_for(engine, data.dims(), TileClass::Small)
        .unwrap_or_else(|_| plan_large.clone());
    let use_topk = params.use_topk
        && plan_large.topk_name.is_some()
        && params.k <= plan_large.topk_k;
    let plans = (&plan_large, &plan_small);

    match params.drain {
        DrainMode::Sync => drain_sync(
            engine, r_data, data, grid, queue, params, slots, pos_cap, plans,
            use_topk, first, t_start, state,
        ),
        DrainMode::TwoStage => drain_pipelined(
            engine, r_data, data, grid, queue, params, slots, pos_cap, plans,
            use_topk, first, t_start, false, state,
        ),
        DrainMode::ThreeStage => drain_pipelined(
            engine, r_data, data, grid, queue, params, slots, pos_cap, plans,
            use_topk, first, t_start, true, state,
        ),
    }
}

/// The per-claim backend decision: `Auto` consults [`sched::route_brute`]
/// on the claim's *mean* candidate population (the queue's memoized
/// CSR adjacent populations aggregated over the range - an O(1) read, no
/// candidate materialisation) against the corpus size; `Grid`/`Brute`
/// force one tier. Deterministic in the range alone, so a recovery
/// retry or reclaim of the same range always re-derives the same tier.
fn route_claim(
    queue: &WorkQueue,
    grid: &GridIndex,
    params: &GpuJoinParams,
    n_data: usize,
    range: &std::ops::Range<usize>,
) -> bool {
    match params.backend {
        BackendMode::Grid => false,
        BackendMode::Brute => true,
        BackendMode::Auto => {
            let mean = queue.range_work(range.clone()) as f64
                / range.len().max(1) as f64;
            sched::route_brute(mean, n_data, grid.m, params.k)
        }
    }
}

/// Materialise a claimed position range as per-cell work units (a claim
/// may start or end mid-cell when clipped by the advancing tail; the
/// partial remainder still shares its cell's candidate list). Appends
/// each query's candidate count to `work_log` for the device model.
/// `native` marks queue queries as ids into the grid's own dataset
/// (self-join), enabling the O(1) id-keyed CSR walk.
///
/// A claim routed to the brute tier ([`route_claim`]) collapses to one
/// [`WorkCell`] spanning the claim's whole query slice with an empty
/// candidate list - the exec loop substitutes the corpus-wide
/// [`BruteCache`] tiles - and logs |D| candidates per query (the true
/// brute workload) for the device model.
#[allow(clippy::too_many_arguments)]
fn claim_cells(
    queue: &WorkQueue,
    grid: &GridIndex,
    r_data: &Dataset,
    native: bool,
    range: std::ops::Range<usize>,
    work_log: &mut Vec<u64>,
    params: &GpuJoinParams,
    n_data: usize,
) -> Vec<WorkCell> {
    if route_claim(queue, grid, params, n_data, &range) {
        let queries = queue.query_slice(range).to_vec();
        for _ in &queries {
            work_log.push(n_data as u64);
        }
        return vec![WorkCell { queries, candidates: Vec::new(), brute: true }];
    }
    let mut cells: Vec<WorkCell> = Vec::new();
    for r in queue.cell_ranges(range) {
        let qs = queue.query_slice(r).to_vec();
        let mut candidates = Vec::new();
        grid.query_candidates_into(native, r_data, qs[0], &mut candidates);
        for _ in &qs {
            work_log.push(candidates.len() as u64);
        }
        cells.push(WorkCell { queries: qs, candidates, brute: false });
    }
    cells
}

/// The synchronous queue drain: device execution and host filtering
/// alternate within each claim. Kept as the ablation baseline of the
/// pipelined drain and as the single-core schedule (where the pipeline's
/// extra concurrency would only thrash one core).
///
/// Fault handling is claim-scoped: a failed attempt (injected or real)
/// enters [`recover_claim`] - synchronous retries with backoff, then
/// reclamation through Q^Fail - and `demote_after` consecutive reclaims
/// stop the claim loop entirely, leaving the rest of the queue to the
/// CPU ranks (the caller's `gpu_done` release is what lets them finish
/// the recirculated work).
#[allow(clippy::too_many_arguments)]
fn drain_sync(
    engine: &Engine,
    r_data: &Dataset,
    data: &Dataset,
    grid: &GridIndex,
    queue: &WorkQueue,
    params: &GpuJoinParams,
    slots: &SoaSlots<'_>,
    pos_cap: usize,
    plans: (&tiles::TilePlan, &tiles::TilePlan),
    use_topk: bool,
    first: std::ops::Range<usize>,
    t_start: Instant,
    state: &mut DrainState,
) -> Result<GpuJoinStats> {
    let buffer_cap = params.buffer_pairs.max(1);
    let policy = &params.recovery;
    let mut acc = DrainAcc::default();
    let brute_cache = &mut state.brute_cache;
    let mut gpu_busy = 0f64;
    let mut consecutive = 0usize;
    let mut claim_idx = 0usize;

    let native = std::ptr::eq(r_data, data);
    let mut pending = Some(first);
    while let Some(range) = pending.take() {
        // the watchdog envelope for this claim, from the live rates (the
        // first claim has no evidence and gets an infinite deadline)
        let est = queue.range_work(range.clone());
        let gpu_rate =
            if gpu_busy > 0.0 { acc.work_done as f64 / gpu_busy } else { 0.0 };
        let deadline = sched::claim_deadline_secs(
            est,
            gpu_rate,
            queue.cpu_work_rate(),
            policy.watchdog_slack,
            policy.watchdog_min_secs,
        );
        let t_claim = Instant::now();
        let cells = claim_cells(
            queue, grid, r_data, native, range.clone(), &mut acc.work_log,
            params, data.len(),
        );
        let mut demote = false;
        match sync_cells_attempt(
            engine,
            (r_data, data),
            plans,
            use_topk,
            &cells,
            params,
            queue,
            slots,
            claim_idx,
            range.clone(),
            est,
            deadline,
            brute_cache,
            &mut acc,
        ) {
            Ok(()) => consecutive = 0,
            Err(first_err) => {
                demote = recover_claim(
                    engine,
                    (r_data, data),
                    grid,
                    queue,
                    params,
                    slots,
                    plans,
                    use_topk,
                    claim_idx,
                    range,
                    est,
                    deadline,
                    first_err,
                    &mut consecutive,
                    brute_cache,
                    &mut acc,
                );
            }
        }
        gpu_busy += t_claim.elapsed().as_secs_f64();
        claim_idx += 1;
        if demote {
            break;
        }

        // Eq. 6 as feedback: size the next claim from live rates. The
        // sync drain really does pay exec + transfer + filter serially
        // per claim, so its honest throughput is work over *total* busy
        // seconds (unlike the pipelined drains, which size from the
        // kernel-only rate because their other stages overlap).
        let gpu_rate =
            if gpu_busy > 0.0 { acc.work_done as f64 / gpu_busy } else { 0.0 };
        let target = sched::next_batch_work(
            queue.head_work_remaining(pos_cap),
            gpu_rate,
            queue.cpu_work_rate(),
        )
        .min(buffer_cap);
        pending = queue.claim_head_work(target, pos_cap);
    }

    let device_model = DeviceModel::default().estimate(&acc.work_log, params.assign);
    acc.failed.sort_unstable();
    let brute_claims = acc.claims.iter().filter(|c| c.brute).count();
    let grid_claims = acc.claims.len() - brute_claims;
    Ok(GpuJoinStats {
        failed: acc.failed,
        solved: acc.solved,
        kernel_time: acc.kernel_time,
        total_time: t_start.elapsed().as_secs_f64(),
        device_model,
        batches: acc.batches,
        estimated_pairs: acc.work_done,
        result_pairs: acc.result_pairs,
        max_batch_pairs: acc.max_batch_pairs,
        batch_envelope_pairs: acc.batch_envelope_pairs,
        exec_time: acc.exec_time,
        transfer_time: acc.transfer_time,
        filter_time: acc.filter_time,
        claims: acc.claims,
        gpu_faults: acc.fault_log.count(FaultAction::Retried)
            + acc.fault_log.count(FaultAction::Reclaimed),
        gpu_retries: acc.retries,
        reclaimed_cells: acc.reclaimed_cells,
        degraded: acc.degraded,
        fault_log: acc.fault_log,
        brute_tiles: acc.brute_tiles,
        brute_claims,
        grid_claims,
    })
}

/// Shared staging half of one in-flight claim (or list-form batch): the
/// claim's flat query list, the dense heap arena its filter rounds
/// write, and the accumulators the stage workers feed. The pipelined
/// drains rotate two (two-stage) or three (three-stage) of these between
/// the master (filling claim i), the transfer stage (converting claim
/// i-1) and the filter stage (draining claim i-2). The plain fields are
/// only mutated through `Arc::get_mut`, i.e. while no round holds a
/// clone - uniqueness *is* the proof that the stages are done with it.
struct ClaimStage {
    batch_queries: Vec<u32>,
    arena: HeapArena,
    /// in-ε pairs found in this claim (filter workers accumulate)
    pairs: AtomicU64,
    /// transfer nanoseconds over this claim's rounds, accumulated by the
    /// dedicated transfer worker (three-stage drain only; the sync/
    /// two-stage/list paths time the master-side conversion directly)
    transfer_nanos: AtomicU64,
    /// filter wall nanoseconds over this claim's rounds (stage-pool
    /// retire hook; overlaps later claims' exec under the pipelines)
    filter_nanos: AtomicU64,
    /// first device-to-host conversion error of the transfer stage, if
    /// any - surfaced as the claim's resolve error (three-stage drain
    /// only; the other paths convert on the master and propagate
    /// directly)
    transfer_err: Mutex<Option<anyhow::Error>>,
}

impl ClaimStage {
    fn new(k: usize) -> Self {
        ClaimStage {
            batch_queries: Vec::new(),
            arena: HeapArena::new(0, k.max(1)),
            pairs: AtomicU64::new(0),
            transfer_nanos: AtomicU64::new(0),
            filter_nanos: AtomicU64::new(0),
            transfer_err: Mutex::new(None),
        }
    }
}

/// Filter sublanes per claim on the pipelined drains: the transfer stage
/// (three-stage) or the master (two-stage) converts device output *per
/// tile* and submits each converted tile as its own single-tile filter
/// round, fanned over this many pool lanes so tiles of one claim filter
/// concurrently. The sublane is keyed by the tile's first queue position
/// ([`filter_sublane`]): a tile split across flush rounds re-appears
/// with the same position start, lands on the same sublane, and the
/// pool's per-lane FIFO keeps its parts ordered - the position-
/// disjointness that makes the heap arena race-free. The synchronous
/// drain and the list form keep whole-round hand-off on one lane.
const FILTER_SUBLANES: u64 = 8;

/// The filter-pool lane of one converted tile of claim `claim_lane`.
fn filter_sublane(claim_lane: u64, pos_start: usize) -> u64 {
    claim_lane * FILTER_SUBLANES + pos_start as u64 % FILTER_SUBLANES
}

/// One converted flush round handed to the filter pool: a set of
/// position-disjoint tiles targeting `stage`'s arena (a tile split
/// across rounds re-appears in the lane's next round; the pool's
/// per-lane round ordering keeps that safe, and rounds of different
/// lanes target different stages' arenas, so cross-lane overlap cannot
/// alias a position). The pipelined drains submit single-tile rounds on
/// per-claim sublanes ([`FILTER_SUBLANES`]); the list form and the
/// synchronous retry path submit whole rounds on one lane.
struct FilterRound {
    stage: Arc<ClaimStage>,
    tiles: Vec<TileOut>,
    /// claim ordinal the round belongs to - the filter-stage fault hook's
    /// trigger coordinate (0 on the list-driven path, which has no
    /// claims and never consults the hook)
    claim: usize,
    /// flush-round ordinal within the claim (same caveat)
    round: usize,
}

/// One raw flush round handed to the dedicated transfer stage
/// (three-stage drain): device output literals to convert into host
/// buffers and re-submit to the filter pool on the same lane. A single
/// item per round - the single transfer worker processes rounds in lane
/// order, so filter rounds arrive at the filter pool in claim/round
/// order.
struct TransferRound {
    stage: Arc<ClaimStage>,
    /// the claim lane the converted filter round is submitted on (the
    /// lane IS the claim ordinal - the transfer fault hook's coordinate)
    lane: u64,
    /// flush-round ordinal within the claim (fault hook coordinate)
    round: usize,
    /// consumed (once) by the transfer worker; `Mutex<Option<..>>` so the
    /// tiles can be moved out through the pool's shared job reference
    tiles: Mutex<Option<Vec<RawTile>>>,
}

/// Master-side half of an in-flight claim (never seen by the workers).
struct ClaimMeta {
    range: std::ops::Range<usize>,
    est_work: u64,
    /// master-thread seconds materialising + packing + executing on the
    /// device (submit backpressure and master-side transfer excluded)
    exec_secs: f64,
    /// master-thread seconds converting device output (two-stage drain;
    /// the three-stage drain transfers off-master into
    /// `ClaimStage::transfer_nanos` instead)
    transfer_secs: f64,
    /// the claim's stage-pool lane (claim ordinal)
    lane: u64,
    /// the claim ran on the tiled brute-force tier
    brute: bool,
}

/// Accumulators of the pipelined drains and the list-form batch loop,
/// shared with the resolve path.
#[derive(Default)]
struct DrainAcc {
    claims: Vec<ClaimRecord>,
    failed: Vec<u32>,
    work_log: Vec<u64>,
    solved: usize,
    result_pairs: u64,
    max_batch_pairs: u64,
    batch_envelope_pairs: u64,
    batches: usize,
    exec_time: f64,
    transfer_time: f64,
    filter_time: f64,
    kernel_time: f64,
    work_done: u64,
    fault_log: FaultLog,
    retries: usize,
    reclaimed_cells: usize,
    degraded: bool,
    brute_tiles: u64,
}

/// Classify a claim-stage error for the fault log: injected faults carry
/// their own kind, watchdog trips are stalls, caught worker panics read
/// as filter faults, anything else is charged to the exec stage (the
/// device call is the only remaining failure source).
fn fault_kind_of(e: &anyhow::Error) -> FaultKind {
    if let Some(inj) = e.downcast_ref::<InjectedFault>() {
        return inj.kind;
    }
    if e.downcast_ref::<WatchdogTimeout>().is_some() {
        return FaultKind::StallTimeout;
    }
    if format!("{e:#}").contains("panicked") {
        return FaultKind::FilterPanic;
    }
    FaultKind::ExecError
}

/// Reclaim a failed claim: push its queries back through the queue's
/// Q^Fail recirculation buffer for CPU ranks to absorb, and log a
/// `failed` ClaimRecord so the accounting invariants (`claimed ==
/// solved + q_fail` per architecture) keep closing. Exactly-once holds
/// because a failed claim published nothing: every error path surfaces
/// *before* any slot write or `push_failed` of the attempt, so each of
/// the claim's queries is published here at most once. The claim's
/// estimated work is deliberately NOT credited to `work_done` - a
/// reclaimed claim produced nothing, and crediting it would inflate the
/// GPU rate the next claim (and watchdog deadline) is sized from.
fn reclaim_claim(
    queue: &WorkQueue,
    range: std::ops::Range<usize>,
    est_work: u64,
    brute: bool,
    acc: &mut DrainAcc,
) {
    let qs: Vec<u32> = queue.query_slice(range.clone()).to_vec();
    queue.push_failed(&qs);
    acc.failed.extend_from_slice(&qs);
    acc.reclaimed_cells += queue.cell_ranges(range.clone()).count();
    acc.batches += 1;
    acc.claims.push(ClaimRecord {
        arch: Arch::Gpu,
        queries: range.len(),
        est_work,
        secs: 0.0,
        exec_secs: 0.0,
        transfer_secs: 0.0,
        filter_secs: 0.0,
        from_recirc: false,
        failed: true,
        brute,
    });
}

/// One synchronous exec + filter + resolve attempt of one claim: the
/// sync drain's per-claim body, and the retry body of claim recovery on
/// *every* drain mode (a retried claim's staging rounds have been
/// quiesced, so there is nothing left for a pipeline to overlap with).
/// Runs the stage work under `catch_unwind` - on the synchronous path an
/// injected filter panic unwinds the calling thread itself - and on
/// success fully resolves the claim into slots / Q^Fail and logs it. On
/// failure nothing was published (no slot write, no recirculation): the
/// error surfaces before the resolve loop.
#[allow(clippy::too_many_arguments)]
fn sync_cells_attempt(
    engine: &Engine,
    (r_data, data): (&Dataset, &Dataset),
    plans: (&tiles::TilePlan, &tiles::TilePlan),
    use_topk: bool,
    cells: &[WorkCell],
    params: &GpuJoinParams,
    queue: &WorkQueue,
    slots: &SoaSlots<'_>,
    claim: usize,
    range: std::ops::Range<usize>,
    est_work: u64,
    deadline_secs: f64,
    brute_cache: &mut BruteCache,
    acc: &mut DrainAcc,
) -> std::result::Result<(), (anyhow::Error, FaultKind)> {
    // the backend decision is claim-wide: a brute claim is exactly one
    // brute cell, a grid claim holds only grid cells
    let claim_brute = cells.first().is_some_and(|c| c.brute);
    let t_claim = Instant::now();
    let mut kernel = 0f64;
    let mut btiles = 0u64;
    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        exec_filter_cells(
            engine,
            (r_data, data),
            plans,
            use_topk,
            cells,
            params,
            brute_cache,
            &mut kernel,
            &mut btiles,
            claim,
            deadline_secs,
        )
    }));
    acc.kernel_time += kernel;
    acc.brute_tiles += btiles;
    let (batch_queries, mut heaps, batch_pairs, transfer_secs, filter_secs) =
        match out {
            Ok(Ok(t)) => t,
            Ok(Err(e)) => {
                let kind = fault_kind_of(&e);
                return Err((e, kind));
            }
            Err(p) => {
                return Err((
                    anyhow!(
                        "filter stage panicked: {}",
                        panic_message(p.as_ref())
                    ),
                    FaultKind::FilterPanic,
                ));
            }
        };
    let mut failed_batch = Vec::new();
    for (pos, &q) in batch_queries.iter().enumerate() {
        let h = &mut heaps[pos];
        if h.len() >= params.k {
            // SAFETY: head claims are disjoint from all other writers.
            unsafe { slots.slot(q as usize) }.write_heap(h);
            acc.solved += 1;
        } else {
            failed_batch.push(q);
        }
    }
    // recirculate Q^Fail into the live queue (step 7 of Alg. 1 gone)
    queue.push_failed(&failed_batch);
    acc.failed.extend_from_slice(&failed_batch);

    acc.result_pairs += batch_pairs;
    acc.max_batch_pairs = acc.max_batch_pairs.max(batch_pairs);
    // queue claims are sized by est-work = exact adjacent-candidate
    // counts, an upper bound on the claim's realised pairs - the claim
    // form of the byte-accurate envelope
    acc.batch_envelope_pairs = acc.batch_envelope_pairs.max(est_work);
    acc.batches += 1;
    let secs = t_claim.elapsed().as_secs_f64();
    let exec_secs = (secs - transfer_secs - filter_secs).max(0.0);
    acc.exec_time += exec_secs;
    acc.transfer_time += transfer_secs;
    acc.filter_time += filter_secs;
    acc.work_done += est_work;
    acc.claims.push(ClaimRecord {
        arch: Arch::Gpu,
        queries: range.len(),
        est_work,
        secs,
        exec_secs,
        transfer_secs,
        filter_secs,
        from_recirc: false,
        failed: false,
        brute: claim_brute,
    });
    Ok(())
}

/// Claim-scoped recovery, entered after an attempt of claim `claim`
/// failed with `first_err`: retry synchronously with bounded exponential
/// backoff up to the policy's retry limit, then reclaim the claim
/// through Q^Fail and count it toward demotion. Returns `true` when the
/// master must demote itself (`demote_after` consecutive reclaims): the
/// caller stops claiming and the run completes CPU-only. Persistent
/// faults fail every retry and drive straight through reclaim to
/// demotion; transient faults disarm after firing, so the first retry
/// succeeds and resets the consecutive-failure count.
#[allow(clippy::too_many_arguments)]
fn recover_claim(
    engine: &Engine,
    (r_data, data): (&Dataset, &Dataset),
    grid: &GridIndex,
    queue: &WorkQueue,
    params: &GpuJoinParams,
    slots: &SoaSlots<'_>,
    plans: (&tiles::TilePlan, &tiles::TilePlan),
    use_topk: bool,
    claim: usize,
    range: std::ops::Range<usize>,
    est_work: u64,
    deadline_secs: f64,
    first_err: (anyhow::Error, FaultKind),
    consecutive: &mut usize,
    brute_cache: &mut BruteCache,
    acc: &mut DrainAcc,
) -> bool {
    let policy = &params.recovery;
    let native = std::ptr::eq(r_data, data);
    // retries work off a fresh cell materialisation (the failed
    // attempt's cells may live inside a pipeline staging set) but must
    // not re-log the claim's workload - the device model already saw it
    // at claim time. Routing is deterministic in the range, so the retry
    // lands on the same backend tier as the failed attempt.
    let mut scratch_log = Vec::new();
    let cells = claim_cells(
        queue, grid, r_data, native, range.clone(), &mut scratch_log, params,
        data.len(),
    );
    let claim_brute = cells.first().is_some_and(|c| c.brute);
    let (mut err, mut kind) = first_err;
    let mut attempt = 0usize;
    loop {
        if attempt >= policy.retry_limit {
            acc.fault_log.push(
                kind,
                claim,
                attempt,
                FaultAction::Reclaimed,
                format!("{err:#}"),
            );
            reclaim_claim(queue, range, est_work, claim_brute, acc);
            *consecutive += 1;
            if *consecutive >= policy.demote_after {
                acc.degraded = true;
                acc.fault_log.push(
                    kind,
                    claim,
                    attempt,
                    FaultAction::Demoted,
                    format!("{} consecutive claim failures", *consecutive),
                );
                return true;
            }
            return false;
        }
        acc.fault_log.push(
            kind,
            claim,
            attempt,
            FaultAction::Retried,
            format!("{err:#}"),
        );
        acc.retries += 1;
        let backoff = policy.backoff_secs(attempt);
        if backoff > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(backoff));
        }
        attempt += 1;
        match sync_cells_attempt(
            engine,
            (r_data, data),
            plans,
            use_topk,
            &cells,
            params,
            queue,
            slots,
            claim,
            range.clone(),
            est_work,
            deadline_secs,
            brute_cache,
            acc,
        ) {
            Ok(()) => {
                *consecutive = 0;
                return false;
            }
            Err((e, k)) => {
                err = e;
                kind = k;
            }
        }
    }
}

/// Wait out a claim's outstanding transfer and filter rounds, then
/// resolve its arena into result slots / Q^Fail and log the claim. Runs
/// on the master thread only: slot writes and `push_failed` keep their
/// single-writer / single-producer contracts. Under the pipelines this
/// runs *after* later claims were already taken off the head, so a
/// claim's Q^Fail may recirculate several claims behind its successor -
/// the reordering the failure-injection suite pins down.
///
/// Every failure path of the claim surfaces here, *before* any slot
/// write or recirculation - a worker panic recorded against the claim's
/// lane (recoverable stage pool), a transfer-stage error parked in
/// `transfer_err` - so a failed claim publishes nothing and recovery may
/// retry or reclaim it without double-publishing a query. The panic /
/// error records are drained even on the error path, so a retried lane
/// starts clean.
#[allow(clippy::too_many_arguments)]
fn resolve_stage(
    stage: &mut Arc<ClaimStage>,
    meta: &ClaimMeta,
    transfer_handle: Option<&pool::StageHandle<TransferRound>>,
    filter_handle: &pool::StageHandle<FilterRound>,
    queue: &WorkQueue,
    k: usize,
    slots: &SoaSlots<'_>,
    acc: &mut DrainAcc,
) -> Result<()> {
    // dependency order: once the transfer lane is empty, every filter
    // round of the claim has been submitted (the transfer worker submits
    // before its round retires); once all of the claim's filter sublanes
    // are empty, the arena is quiescent and the Arc is unique again
    if let Some(th) = transfer_handle {
        th.wait_lane(meta.lane);
    }
    for s in 0..FILTER_SUBLANES {
        filter_handle.wait_lane(meta.lane * FILTER_SUBLANES + s);
    }
    if let Some(th) = transfer_handle {
        if let Some(msg) = th.take_lane_panic(meta.lane) {
            while th.take_lane_panic(meta.lane).is_some() {}
            return Err(anyhow!("transfer stage panicked: {msg}"));
        }
    }
    let mut filter_panic = None;
    for s in 0..FILTER_SUBLANES {
        let lane = meta.lane * FILTER_SUBLANES + s;
        while let Some(msg) = filter_handle.take_lane_panic(lane) {
            filter_panic.get_or_insert(msg);
        }
    }
    if let Some(msg) = filter_panic {
        return Err(anyhow!("filter stage panicked: {msg}"));
    }
    let stage = Arc::get_mut(stage)
        .expect("claim rounds retired but stage still shared");
    // lock_unpoisoned: a filter worker that panicked while a sibling
    // held this mutex must surface as the *first* error, not as a
    // second opaque poisoning panic on the master
    if let Some(e) = pool::lock_unpoisoned(&stage.transfer_err).take() {
        return Err(e);
    }
    let mut failed_batch = Vec::new();
    for (pos, &q) in stage.batch_queries.iter().enumerate() {
        let h = stage.arena.heap_mut(pos);
        if h.len() >= k {
            // SAFETY: head claims are disjoint from all other writers,
            // and only the master thread resolves GPU-side slots.
            unsafe { slots.slot(q as usize) }.write_heap(h);
            acc.solved += 1;
        } else {
            failed_batch.push(q);
        }
    }
    queue.push_failed(&failed_batch);
    acc.failed.extend_from_slice(&failed_batch);

    let batch_pairs = stage.pairs.load(Ordering::Relaxed);
    let transfer_secs = meta.transfer_secs
        + stage.transfer_nanos.load(Ordering::Relaxed) as f64 / 1e9;
    let filter_secs = stage.filter_nanos.load(Ordering::Relaxed) as f64 / 1e9;
    acc.result_pairs += batch_pairs;
    acc.max_batch_pairs = acc.max_batch_pairs.max(batch_pairs);
    acc.batch_envelope_pairs = acc.batch_envelope_pairs.max(meta.est_work);
    acc.batches += 1;
    acc.exec_time += meta.exec_secs;
    acc.transfer_time += transfer_secs;
    acc.filter_time += filter_secs;
    acc.claims.push(ClaimRecord {
        arch: Arch::Gpu,
        queries: meta.range.len(),
        est_work: meta.est_work,
        secs: meta.exec_secs + transfer_secs + filter_secs,
        exec_secs: meta.exec_secs,
        transfer_secs,
        filter_secs,
        from_recirc: false,
        failed: false,
        brute: meta.brute,
    });
    Ok(())
}

/// Watchdog deadline for one pipelined claim, from the kernel-side rate
/// (resolved claims' exec seconds plus the in-flight claims' - the same
/// evidence claim-ahead sizing feeds on) against the live CPU rate.
fn pipelined_deadline(
    acc: &DrainAcc,
    metas: &[Option<ClaimMeta>],
    est_work: u64,
    policy: &RecoveryPolicy,
    cpu_rate: f64,
) -> f64 {
    let exec_busy = acc.exec_time
        + metas.iter().flatten().map(|m| m.exec_secs).sum::<f64>();
    let gpu_rate =
        if exec_busy > 0.0 { acc.work_done as f64 / exec_busy } else { 0.0 };
    sched::claim_deadline_secs(
        est_work,
        gpu_rate,
        cpu_rate,
        policy.watchdog_slack,
        policy.watchdog_min_secs,
    )
}

/// The pipelined queue drains: device execution of claim i+1 overlaps
/// the downstream stages of earlier claims.
///
/// * the master thread (PJRT client is !Send) claims, materialises and
///   executes tiles, emitting flush rounds of ≤ `round_cap` device
///   chunks on the claim's *lane*;
/// * **two-stage** (`three_stage = false`): the master converts each
///   round's device output itself and hands it to a persistent pool of
///   `streams` filter workers - exec of claim i+1 overlaps filtering of
///   claim i through two rotating [`ClaimStage`] staging sets;
/// * **three-stage** (`three_stage = true`): raw rounds go to a
///   dedicated transfer worker that converts the literals off the master
///   thread and re-submits the converted round to the filter pool on the
///   same lane - exec of claim i+1, transfer of claim i and filtering of
///   claim i-1 all overlap through three rotating staging sets, and the
///   filter pool (adaptive cross-claim capacity, per-lane ordering - see
///   [`filter_pool_capacity`]) may interleave rounds of adjacent claims
///   for extra tail parallelism;
/// * before staging set i mod depth is refilled for claim i, claim
///   i-depth is waited out and resolved - at most `depth` claims are
///   live, and their arenas can never alias a queue position because
///   their queue claims are disjoint intervals;
/// * the hand-off is bounded: the per-round chunk cap divides the former
///   synchronous flush envelope by the number of rounds that can be
///   buffered at once, so total buffered device output stays within the
///   old `chunk_cap` envelope and backpressure degrades the pipeline
///   gracefully toward the synchronous schedule;
/// * the next claim is sized at claim time from the *kernel-side* work
///   rate (`exec_secs` excludes transfer and backpressure) against the
///   live CPU rate - the telemetry split that makes claim-ahead sizing
///   honest under overlap.
/// Cross-claim capacity of the pipelined filter pool, in single-tile
/// rounds.
///
/// Steady state keeps the historical bounded hand-off:
/// `filter_rounds * round_cap` tiles, i.e. the sync drain's buffered-
/// device-output envelope divided across the pipeline depth. That count
/// assumes claims actually fill their rounds - under a streaming
/// session's micro-batch flushes the whole head may be a handful of
/// queries, every claim emits one partial round of one or two tiles,
/// and a rounds-counted cap computed from `round_cap` can drop below
/// one in-flight tile per filter worker, serialising the pool exactly
/// when cross-claim interleaving is the only parallelism left. When the
/// head's query volume cannot fill one tile row per worker
/// (`head_queries <= n_workers * tile_qt`), widen the cap to the sync
/// envelope of `n_workers * 8` tiles: tiny tiles make the byte bound
/// moot and occupancy is what matters.
fn filter_pool_capacity(
    n_workers: usize,
    round_cap: usize,
    filter_rounds: usize,
    head_queries: usize,
    tile_qt: usize,
) -> usize {
    let steady = (filter_rounds * round_cap).max(1);
    if head_queries <= n_workers * tile_qt.max(1) {
        steady.max(n_workers * 8)
    } else {
        steady
    }
}

#[allow(clippy::too_many_arguments)]
fn drain_pipelined(
    engine: &Engine,
    r_data: &Dataset,
    data: &Dataset,
    grid: &GridIndex,
    queue: &WorkQueue,
    params: &GpuJoinParams,
    slots: &SoaSlots<'_>,
    pos_cap: usize,
    plans: (&tiles::TilePlan, &tiles::TilePlan),
    use_topk: bool,
    first: std::ops::Range<usize>,
    t_start: Instant,
    three_stage: bool,
    state: &mut DrainState,
) -> Result<GpuJoinStats> {
    let eps2 = params.eps * params.eps;
    let exclude_self = params.exclude_self;
    let fault = &params.fault;
    let n_workers = params.streams.max(1);
    // Memory envelope: the sync drain buffers up to `streams * 8` device
    // chunks at a time. Divide that envelope by the number of rounds
    // that can be buffered at once: two-stage = one in flight + one
    // filling; three-stage = one filling + one staged for transfer + two
    // in the filter pool.
    let (round_cap, filter_rounds) = if three_stage {
        ((n_workers * 8 / 4).max(1), 2usize)
    } else {
        ((n_workers * 8 / 2).max(1), 1)
    };
    // The filter pool's capacity counts its rounds, and a pipelined
    // filter round is ONE converted tile (per-tile hand-off over the
    // claim's sublanes): the former whole-round budget of `filter_rounds`
    // rounds of <= round_cap chunks each becomes `filter_rounds *
    // round_cap` single-tile rounds - the same buffered-output envelope,
    // handed off at tile granularity so filtering starts as soon as the
    // first tile of a round is converted. Actual occupancy stays bounded
    // upstream: the transfer stage holds one raw round at a time, so
    // exec can run at most one round ahead. Micro-batch flushes (a
    // streaming session's small head) widen this to the sync envelope -
    // see `filter_pool_capacity`.
    let filter_cap = filter_pool_capacity(
        n_workers,
        round_cap,
        filter_rounds,
        queue.len().min(pos_cap),
        plans.0.qt,
    );

    // recoverable pools: a worker panic (injected or real) is caught,
    // recorded against the round's lane, and surfaced as that *claim's*
    // failure at resolve - it no longer kills the whole drain
    let (master_out, _worker_units) = pool::stage_scope_recoverable(
        n_workers,
        filter_cap,
        |_w| (),
        |_s: &mut (), job: &FilterRound, i: usize| {
            if i == 0 && fault.filter_panic(job.claim, job.round) {
                panic!(
                    "injected filter panic (claim {}, round {})",
                    job.claim, job.round
                );
            }
            let mut pairs = 0u64;
            apply_tile(
                &job.tiles[i],
                &job.stage.batch_queries,
                &job.stage.arena,
                eps2,
                exclude_self,
                &mut pairs,
            );
            if pairs > 0 {
                job.stage.pairs.fetch_add(pairs, Ordering::Relaxed);
            }
        },
        |job: &FilterRound, wall: f64| {
            job.stage
                .filter_nanos
                .fetch_add((wall * 1e9) as u64, Ordering::Relaxed);
        },
        |_s| (),
        |filter_handle| -> Result<DrainAcc> {
            if three_stage {
                let (out, _transfer_units) = pool::stage_scope_recoverable(
                    1, // the dedicated transfer worker
                    1, // bounded hand-off: one raw round staged at a time
                    |_w| (),
                    |_s: &mut (), job: &TransferRound, _i: usize| {
                        // lock_unpoisoned (here and for transfer_err
                        // below): a poisoned mutex must never turn one
                        // caught fault into a second opaque panic - the
                        // parked value is still valid, the poisoning
                        // thread never left a half-written round
                        let raw = pool::lock_unpoisoned(&job.tiles)
                            .take()
                            .expect("transfer round taken twice");
                        let claim = job.lane as usize;
                        let mut err = fault.transfer_fault(claim, job.round);
                        // per-TILE conversion: each converted tile is
                        // submitted immediately as its own single-tile
                        // filter round on the claim's sublane, so
                        // filtering starts before the round's remaining
                        // tiles are converted. Submit backpressure is
                        // excluded from the transfer clock.
                        let mut conv_nanos = 0u64;
                        if err.is_none() {
                            for t in raw {
                                let t0 = Instant::now();
                                let converted = convert_tile(t);
                                conv_nanos +=
                                    (t0.elapsed().as_secs_f64() * 1e9) as u64;
                                match converted {
                                    Ok(tile) => {
                                        let lane = filter_sublane(
                                            job.lane,
                                            tile.pos.start,
                                        );
                                        filter_handle.submit(
                                            FilterRound {
                                                stage: Arc::clone(&job.stage),
                                                tiles: vec![tile],
                                                claim,
                                                round: job.round,
                                            },
                                            1,
                                            lane,
                                        );
                                    }
                                    Err(e) => {
                                        // the claim is already lost: stop
                                        // converting its remaining tiles
                                        err = Some(e);
                                        break;
                                    }
                                }
                            }
                            job.stage
                                .transfer_nanos
                                .fetch_add(conv_nanos, Ordering::Relaxed);
                        }
                        if let Some(e) = err {
                            // surface at the claim's resolve; skipping
                            // the filter submit is safe (lane waits
                            // are emptiness-based, not count-based)
                            let mut slot = pool::lock_unpoisoned(
                                &job.stage.transfer_err,
                            );
                            if slot.is_none() {
                                *slot = Some(e);
                            }
                        }
                    },
                    |_job, _wall| {},
                    |_s| (),
                    |transfer_handle| {
                        pipelined_claim_loop(
                            engine, r_data, data, grid, queue, params, slots,
                            pos_cap, plans, use_topk, first, round_cap,
                            Some(transfer_handle), filter_handle, state,
                        )
                    },
                );
                out
            } else {
                pipelined_claim_loop(
                    engine, r_data, data, grid, queue, params, slots, pos_cap,
                    plans, use_topk, first, round_cap, None, filter_handle,
                    state,
                )
            }
        },
    );

    let mut acc = master_out?;
    let device_model = DeviceModel::default().estimate(&acc.work_log, params.assign);
    acc.failed.sort_unstable();
    let brute_claims = acc.claims.iter().filter(|c| c.brute).count();
    let grid_claims = acc.claims.len() - brute_claims;
    Ok(GpuJoinStats {
        failed: acc.failed,
        solved: acc.solved,
        kernel_time: acc.kernel_time,
        total_time: t_start.elapsed().as_secs_f64(),
        device_model,
        batches: acc.batches,
        estimated_pairs: acc.work_done,
        result_pairs: acc.result_pairs,
        max_batch_pairs: acc.max_batch_pairs,
        batch_envelope_pairs: acc.batch_envelope_pairs,
        exec_time: acc.exec_time,
        transfer_time: acc.transfer_time,
        filter_time: acc.filter_time,
        claims: acc.claims,
        gpu_faults: acc.fault_log.count(FaultAction::Retried)
            + acc.fault_log.count(FaultAction::Reclaimed),
        gpu_retries: acc.retries,
        reclaimed_cells: acc.reclaimed_cells,
        degraded: acc.degraded,
        fault_log: acc.fault_log,
        brute_tiles: acc.brute_tiles,
        brute_claims,
        grid_claims,
    })
}

/// The claim loop shared by the two- and three-stage drains: rotate
/// `depth` staging sets (depth = 2 without a transfer stage, 3 with
/// one), resolving the claim `depth` back before refilling its set, and
/// size every next claim from the kernel-side rate. See
/// [`drain_pipelined`] for the stage topology.
#[allow(clippy::too_many_arguments)]
fn pipelined_claim_loop(
    engine: &Engine,
    r_data: &Dataset,
    data: &Dataset,
    grid: &GridIndex,
    queue: &WorkQueue,
    params: &GpuJoinParams,
    slots: &SoaSlots<'_>,
    pos_cap: usize,
    plans: (&tiles::TilePlan, &tiles::TilePlan),
    use_topk: bool,
    first: std::ops::Range<usize>,
    round_cap: usize,
    transfer_handle: Option<&pool::StageHandle<TransferRound>>,
    filter_handle: &pool::StageHandle<FilterRound>,
    state: &mut DrainState,
) -> Result<DrainAcc> {
    let buffer_cap = params.buffer_pairs.max(1);
    // heap bound for the staging arenas; the solved test at resolve uses
    // the RAW params.k so the partition matches the synchronous drains
    // even for the degenerate k = 0
    let arena_k = params.k.max(1);
    let native = std::ptr::eq(r_data, data);
    let fault = &params.fault;
    let policy = &params.recovery;
    let depth = if transfer_handle.is_some() { 3 } else { 2 };
    let mut acc = DrainAcc::default();
    let mut stages: Vec<Arc<ClaimStage>> = state.take_stages(depth, arena_k);
    let brute_cache = &mut state.brute_cache;
    let mut metas: Vec<Option<ClaimMeta>> = (0..depth).map(|_| None).collect();
    let mut claim_idx = 0usize;
    let mut consecutive = 0usize;
    let mut pending = Some(first);

    while let Some(range) = pending.take() {
        let si = claim_idx % depth;
        // reclaim this staging set: the claim `depth` back must be fully
        // transferred + filtered and resolved before its arena is reused.
        // A resolve failure is that *claim's* failure: recovery retries
        // it synchronously (its lane is quiesced, there is nothing left
        // to overlap with); demotion also reclaims the current,
        // not-yet-executed claim and stops the loop.
        if let Some(meta) = metas[si].take() {
            if let Err(e) = resolve_stage(
                &mut stages[si], &meta, transfer_handle, filter_handle, queue,
                params.k, slots, &mut acc,
            ) {
                let kind = fault_kind_of(&e);
                // un-credit the failed attempt's exec-time work credit;
                // recovery re-earns it (retry) or forfeits it (reclaim)
                acc.work_done = acc.work_done.saturating_sub(meta.est_work);
                let deadline = pipelined_deadline(
                    &acc, &metas, meta.est_work, policy, queue.cpu_work_rate(),
                );
                if recover_claim(
                    engine, (r_data, data), grid, queue, params, slots, plans,
                    use_topk, meta.lane as usize, meta.range.clone(),
                    meta.est_work, deadline, (e, kind), &mut consecutive,
                    brute_cache, &mut acc,
                ) {
                    let brute =
                        route_claim(queue, grid, params, data.len(), &range);
                    reclaim_claim(
                        queue,
                        range.clone(),
                        queue.range_work(range.clone()),
                        brute,
                        &mut acc,
                    );
                    break;
                }
            } else {
                consecutive = 0;
            }
        }
        let lane = claim_idx as u64;
        let est = queue.range_work(range.clone());
        // the watchdog envelope is fixed before exec and checked at
        // round boundaries inside the emit closure (`exec_lits` is
        // uninterruptible - a stalled device surfaces when its round
        // finally emits)
        let deadline =
            pipelined_deadline(&acc, &metas, est, policy, queue.cpu_work_rate());
        let t_exec = Instant::now();
        let cells = claim_cells(
            queue, grid, r_data, native, range.clone(), &mut acc.work_log,
            params, data.len(),
        );
        let claim_brute = cells.first().is_some_and(|c| c.brute);
        let n_queries: usize = cells.iter().map(|c| c.queries.len()).sum();
        {
            // unique access: all of this set's rounds have retired
            let stage = Arc::get_mut(&mut stages[si])
                .expect("stage still shared at refill");
            stage.batch_queries.clear();
            stage
                .batch_queries
                .extend(cells.iter().flat_map(|c| c.queries.iter().copied()));
            stage.arena.reset(n_queries, arena_k);
            stage.pairs.store(0, Ordering::Relaxed);
            stage.filter_nanos.store(0, Ordering::Relaxed);
            stage.transfer_nanos.store(0, Ordering::Relaxed);
            // a recovered claim may have parked a transfer error here
            // after its resolve already gave up on the stage - it must
            // not poison the next claim reusing this staging set
            *pool::lock_unpoisoned(&stage.transfer_err) = None;
        }
        // execute this claim's tiles; earlier claims' rounds keep
        // transferring/filtering on their stages while the device runs.
        // Master seconds spent BLOCKED in submit backpressure (a
        // downstream stage lagging) or - on the two-stage path -
        // converting device output are not device work, so neither may
        // inflate exec_secs (or fabricate overlap, or bias the
        // kernel-side rate the claim sizing feeds on).
        let mut submit_wait = 0f64;
        let mut transfer_master = 0f64;
        let exec_out = {
            let stage_arc = &stages[si];
            let mut round = 0usize;
            exec_cells_into_rounds(
                engine,
                (r_data, data),
                plans,
                use_topk,
                &cells,
                params,
                round_cap,
                brute_cache,
                &mut acc.kernel_time,
                &mut acc.brute_tiles,
                &mut |raw: Vec<RawTile>| {
                    fault.exec_round(claim_idx, round)?;
                    debug_assert!(
                        raw.iter().all(|t| t.pos.end <= n_queries),
                        "round tile positions exceed the claim arena"
                    );
                    if let Some(th) = transfer_handle {
                        // three-stage: raw literals to the transfer stage
                        let t_submit = Instant::now();
                        th.submit(
                            TransferRound {
                                stage: Arc::clone(stage_arc),
                                lane,
                                round,
                                tiles: Mutex::new(Some(raw)),
                            },
                            1,
                            lane,
                        );
                        submit_wait += t_submit.elapsed().as_secs_f64();
                    } else {
                        // two-stage: convert per tile here, filter on the
                        // pool over the claim's sublanes - each converted
                        // tile is handed off before the next is converted
                        if let Some(e) = fault.transfer_fault(claim_idx, round)
                        {
                            return Err(e);
                        }
                        for t in raw {
                            let t_conv = Instant::now();
                            let tile = convert_tile(t)?;
                            transfer_master += t_conv.elapsed().as_secs_f64();
                            let sublane = filter_sublane(lane, tile.pos.start);
                            let t_submit = Instant::now();
                            filter_handle.submit(
                                FilterRound {
                                    stage: Arc::clone(stage_arc),
                                    tiles: vec![tile],
                                    claim: claim_idx,
                                    round,
                                },
                                1,
                                sublane,
                            );
                            submit_wait += t_submit.elapsed().as_secs_f64();
                        }
                    }
                    round += 1;
                    let elapsed = t_exec.elapsed().as_secs_f64();
                    if elapsed > deadline {
                        return Err(WatchdogTimeout {
                            claim: claim_idx,
                            elapsed,
                            deadline,
                        }
                        .into());
                    }
                    Ok(())
                },
            )
        };
        match exec_out {
            Ok(()) => {
                let exec_secs = (t_exec.elapsed().as_secs_f64()
                    - submit_wait
                    - transfer_master)
                    .max(0.0);
                acc.work_done += est;
                metas[si] = Some(ClaimMeta {
                    range,
                    est_work: est,
                    exec_secs,
                    transfer_secs: transfer_master,
                    lane,
                    brute: claim_brute,
                });
            }
            Err(e) => {
                // quiesce the claim's lane before retrying on the sync
                // path: rounds already submitted must retire, and any
                // worker panic they suffered folds into this same claim
                // failure (drained here, never surfaced twice). The
                // partially-written staging arena is simply abandoned -
                // metas[si] stays None, so resolve never reads it, and
                // the next refill resets it.
                if let Some(th) = transfer_handle {
                    th.wait_lane(lane);
                    while th.take_lane_panic(lane).is_some() {}
                }
                for s in 0..FILTER_SUBLANES {
                    let sublane = lane * FILTER_SUBLANES + s;
                    filter_handle.wait_lane(sublane);
                    while filter_handle.take_lane_panic(sublane).is_some() {}
                }
                let kind = fault_kind_of(&e);
                if recover_claim(
                    engine, (r_data, data), grid, queue, params, slots, plans,
                    use_topk, claim_idx, range, est, deadline, (e, kind),
                    &mut consecutive, brute_cache, &mut acc,
                ) {
                    break;
                }
            }
        }
        claim_idx += 1;

        // claim-ahead sizing from the KERNEL-side rate: exec_secs is
        // known now - before this claim's transfer/filter complete - and
        // excludes the copy, so the ρ^Model feedback is no longer biased
        // by transfer cost; the CPU rate is read live off the queue
        let exec_busy = acc.exec_time
            + metas.iter().flatten().map(|m| m.exec_secs).sum::<f64>();
        let gpu_rate = if exec_busy > 0.0 {
            acc.work_done as f64 / exec_busy
        } else {
            0.0
        };
        let target = sched::next_batch_work(
            queue.head_work_remaining(pos_cap),
            gpu_rate,
            queue.cpu_work_rate(),
        )
        .min(buffer_cap);
        pending = queue.claim_head_work(target, pos_cap);
    }

    // head exhausted (or the master demoted itself): drain the in-flight
    // claims oldest-first (minimum lane). Under degradation resolves are
    // not retried - a claim that fails now is reclaimed directly, the
    // device has already been written off.
    while let Some(i) = metas
        .iter()
        .enumerate()
        .filter_map(|(i, m)| m.as_ref().map(|m| (m.lane, i)))
        .min()
        .map(|(_, i)| i)
    {
        let meta = metas[i].take().expect("in-flight meta vanished");
        if let Err(e) = resolve_stage(
            &mut stages[i], &meta, transfer_handle, filter_handle, queue,
            params.k, slots, &mut acc,
        ) {
            let kind = fault_kind_of(&e);
            acc.work_done = acc.work_done.saturating_sub(meta.est_work);
            if acc.degraded {
                acc.fault_log.push(
                    kind,
                    meta.lane as usize,
                    0,
                    FaultAction::Reclaimed,
                    format!("{e:#}"),
                );
                reclaim_claim(
                    queue,
                    meta.range.clone(),
                    meta.est_work,
                    meta.brute,
                    &mut acc,
                );
            } else {
                let deadline = pipelined_deadline(
                    &acc, &metas, meta.est_work, policy, queue.cpu_work_rate(),
                );
                // a demotion verdict here has nothing further to stop:
                // the remaining in-flight claims reclaim through the
                // degraded branch on later iterations
                recover_claim(
                    engine, (r_data, data), grid, queue, params, slots, plans,
                    use_topk, meta.lane as usize, meta.range.clone(),
                    meta.est_work, deadline, (e, kind), &mut consecutive,
                    brute_cache, &mut acc,
                );
            }
        } else {
            consecutive = 0;
        }
    }
    // hand the (now quiescent) staging sets back for the next flush;
    // any set an abandoned error path still shares is dropped inside
    state.store_stages(stages);
    Ok(acc)
}

/// Per-query candidate workload (distance calculations per query) under a
/// given grid - the input to the device model. Used by the Table III
/// granularity study to evaluate all ThreadAssign variants on one real
/// workload without re-running the join. `queries` index the dataset the
/// grid was built over (self-join accounting): each query's candidate
/// count is one O(1) read off the memoized CSR adjacent-population table.
pub fn workload_vector(grid: &GridIndex, queries: &[u32]) -> Vec<u64> {
    queries
        .iter()
        .map(|&q| grid.adjacent_population_of_id(q) as u64)
        .collect()
}

/// Dense per-batch heap arena: one bounded heap per query *position* in
/// the batch's flat query list (the queue-position indexing of the SoA
/// result layer, applied to the filter stage). Replaces the former
/// `HashMap<u32, BoundedHeap>` + worker-local merge: positions are dense,
/// so the arena is a flat `Vec`, and claim disjointness makes the merge
/// pass unnecessary.
struct HeapArena {
    heaps: Vec<UnsafeCell<BoundedHeap>>,
}

// SAFETY: access is partitioned by query-tile position ranges; each tile
// is claimed by exactly one filter worker - via the chunk cursor on the
// synchronous path (`filter_tiles`) or one stage-pool item per tile on
// the pooled paths - and rounds targeting one arena run in order (the
// pool's per-lane FIFO), so no two threads ever touch the same slot.
unsafe impl Sync for HeapArena {}

impl HeapArena {
    fn new(n: usize, k: usize) -> Self {
        HeapArena {
            heaps: (0..n).map(|_| UnsafeCell::new(BoundedHeap::new(k))).collect(),
        }
    }

    /// Mutable access to one position's heap.
    ///
    /// # Safety
    /// No two threads may hold the same position at the same time. The
    /// filter stage guarantees this structurally: tiles carry disjoint
    /// position ranges and the chunk cursor hands each tile to one worker.
    #[allow(clippy::mut_from_ref)]
    unsafe fn heap(&self, i: usize) -> &mut BoundedHeap {
        &mut *self.heaps[i].get()
    }

    fn into_heaps(self) -> Vec<BoundedHeap> {
        self.heaps.into_iter().map(UnsafeCell::into_inner).collect()
    }

    /// Re-arm positions [0, n) for a new batch with bound `k`, reusing
    /// allocations (the double-buffered staging path; positions beyond
    /// `n` may hold stale heaps from a larger previous batch - they are
    /// never read, resolve walks exactly the batch's query list).
    fn reset(&mut self, n: usize, k: usize) {
        let k = k.max(1);
        for c in self.heaps.iter_mut().take(n) {
            c.get_mut().reset(k);
        }
        if self.heaps.len() < n {
            let more = n - self.heaps.len();
            self.heaps
                .extend((0..more).map(|_| UnsafeCell::new(BoundedHeap::new(k))));
        }
    }

    /// Exclusive access to one position's heap - the master's resolve
    /// path, where `&mut self` proves no filter worker is live.
    fn heap_mut(&mut self, i: usize) -> &mut BoundedHeap {
        self.heaps[i].get_mut()
    }
}

/// Device output of one candidate chunk of one query tile.
enum Payload {
    /// full distance tile: rows follow the tile's positions, cols follow
    /// `cand_ids`, stride `ct`
    Dist { d2: Vec<f32>, ct: usize },
    /// top-k tile: `vals`/`idx` are qt x k, idx indexes into `cand_ids`
    TopK { vals: Vec<f32>, idx: Vec<i32>, k: usize },
}

struct ChunkOut {
    cand_ids: Vec<u32>,
    payload: Payload,
}

/// All candidate-chunk outputs of one query tile: the filter work unit.
/// `pos` indexes the batch's flat query list; tiles partition it, which
/// is what makes arena access race-free.
struct TileOut {
    pos: std::ops::Range<usize>,
    chunks: Vec<ChunkOut>,
    /// brute-tier tile: no ε gate, `pairs` counts heap insertions
    brute: bool,
}

/// A device output literal that may be moved to the transfer stage.
///
/// SAFETY: `exec_lits` already materialised the literal on the host
/// (`to_literal_sync`), so it is a plain host-memory buffer detached
/// from the device; it is *moved* - never shared - to exactly one
/// consumer thread, which converts it and drops it. xla-rs leaves the
/// wrapper `!Send` only because it holds a raw pointer; single-owner
/// hand-off of a host buffer is sound.
struct SendLit(xla::Literal);

unsafe impl Send for SendLit {}

/// Raw (unconverted) device output of one candidate chunk: the literals
/// as PJRT returned them, before the device-to-host `to_f32`/`to_i32`
/// copy-out. What the exec stage emits and the transfer stage consumes.
enum RawPayload {
    /// full distance tile output, stride `ct` after conversion
    Dist { lit: SendLit, ct: usize },
    /// top-k tile outputs: values and candidate indices, row width `k`
    TopK { vals: SendLit, idx: SendLit, k: usize },
}

/// Raw form of [`ChunkOut`] (literal payload instead of host vectors).
struct RawChunk {
    cand_ids: Vec<u32>,
    payload: RawPayload,
}

/// Raw form of [`TileOut`]: same position contract, literal payloads.
struct RawTile {
    pos: std::ops::Range<usize>,
    chunks: Vec<RawChunk>,
    /// brute-tier tile (carried through to [`TileOut`])
    brute: bool,
}

/// The device-to-host transfer of ONE query tile: convert its literals
/// into the flat host buffers the filter stage scans. The pipelined
/// drains hand each converted tile to the filter pool individually (the
/// per-tile hand-off over the claim's sublanes); the synchronous paths
/// batch whole rounds through [`convert_tiles`].
fn convert_tile(t: RawTile) -> Result<TileOut> {
    Ok(TileOut {
        pos: t.pos,
        brute: t.brute,
        chunks: t
            .chunks
            .into_iter()
            .map(|c| {
                Ok(ChunkOut {
                    cand_ids: c.cand_ids,
                    payload: match c.payload {
                        RawPayload::Dist { lit, ct } => Payload::Dist {
                            d2: Engine::to_f32(&lit.0)?,
                            ct,
                        },
                        RawPayload::TopK { vals, idx, k } => Payload::TopK {
                            vals: Engine::to_f32(&vals.0)?,
                            idx: Engine::to_i32(&idx.0)?,
                            k,
                        },
                    },
                })
            })
            .collect::<Result<Vec<_>>>()?,
    })
}

/// The device-to-host transfer: convert a flush round's literals into
/// the flat host buffers the filter stage scans. This is the copy that
/// used to hide inside `exec_secs` on the master thread; the pipelined
/// drains instead convert tile by tile ([`convert_tile`]) off the
/// master or on the dedicated transfer worker.
fn convert_tiles(raw: Vec<RawTile>) -> Result<Vec<TileOut>> {
    raw.into_iter().map(convert_tile).collect()
}

/// Filter a buffered set of tiles into the arena on `workers` threads via
/// the dynamic chunk scheduler (one tile per claim). Returns the in-ε
/// pair count.
fn filter_tiles(
    tiles_out: &[TileOut],
    batch_queries: &[u32],
    arena: &HeapArena,
    eps2: f64,
    exclude_self: bool,
    workers: usize,
) -> u64 {
    if tiles_out.is_empty() {
        return 0;
    }
    let per_worker = pool::parallel_chunks_stateful(
        tiles_out.len(),
        workers.max(1),
        1,
        |_w| 0u64,
        |pairs, range| {
            for ti in range {
                apply_tile(&tiles_out[ti], batch_queries, arena, eps2, exclude_self, pairs);
            }
        },
        |pairs| pairs,
    );
    per_worker.iter().sum()
}

/// Merge one tile's device output into the arena heaps (the paper's
/// host-side stream filter).
///
/// Brute-tier tiles scan the whole corpus with no ε semantics: the ε
/// gate is vacuous (infinite - every candidate is heap-eligible), and
/// `pairs` counts actual heap *insertions* instead of in-ε candidates -
/// the per-candidate count would inflate quadratically (|Q| x |D|) and
/// wreck the buffer-bound telemetry it feeds.
fn apply_tile(
    t: &TileOut,
    batch_queries: &[u32],
    arena: &HeapArena,
    eps2: f64,
    exclude_self: bool,
    pairs: &mut u64,
) {
    let (eps_gate, count_in_eps) =
        if t.brute { (f64::INFINITY, false) } else { (eps2, true) };
    for chunk in &t.chunks {
        match &chunk.payload {
            Payload::Dist { d2, ct } => {
                for (r, pos) in t.pos.clone().enumerate() {
                    let q = batch_queries[pos];
                    // SAFETY: this tile is the sole owner of `pos` and is
                    // processed by exactly one worker (see HeapArena).
                    let heap = unsafe { arena.heap(pos) };
                    let row = &d2[r * ct..r * ct + chunk.cand_ids.len()];
                    // Fast path: once the heap is full, only candidates
                    // below the current k-th best can matter - track that
                    // bound as an f32 so the hot compare stays branchy-
                    // cheap and pushes become rare (EXPERIMENTS.md Perf#1).
                    // next_up: f64->f32 rounding must never exclude a
                    // candidate exactly at the bound (next_up of INF is
                    // INF, so the brute gate stays vacuous until the
                    // heap fills)
                    let mut gate =
                        ((heap.bound().min(eps_gate)) as f32).next_up();
                    for (c, &dd) in row.iter().enumerate() {
                        if count_in_eps && dd as f64 <= eps2 {
                            *pairs += 1;
                        }
                        if dd <= gate {
                            let id = chunk.cand_ids[c];
                            if !(exclude_self && id == q) {
                                let dist2 = (dd as f64).max(0.0);
                                // brute: count exactly the insertions
                                // (bound is INF while filling, then the
                                // heap's strict replace-below-bound test)
                                if !count_in_eps && dist2 < heap.bound() {
                                    *pairs += 1;
                                }
                                heap.push(Neighbor { id, dist2 });
                                gate = ((heap.bound().min(eps_gate)) as f32)
                                    .next_up();
                            }
                        }
                    }
                }
            }
            Payload::TopK { vals, idx, k } => {
                for (r, pos) in t.pos.clone().enumerate() {
                    let q = batch_queries[pos];
                    // SAFETY: as above.
                    let heap = unsafe { arena.heap(pos) };
                    for s in 0..*k {
                        let dd = vals[r * k + s] as f64;
                        if dd > eps_gate {
                            break; // ascending: rest of the row is farther
                        }
                        let ci = idx[r * k + s] as usize;
                        if ci >= chunk.cand_ids.len() {
                            continue; // padded candidate row
                        }
                        let id = chunk.cand_ids[ci];
                        if !(exclude_self && id == q) {
                            let dist2 = dd.max(0.0);
                            if count_in_eps || dist2 < heap.bound() {
                                *pairs += 1;
                            }
                            heap.push(Neighbor { id, dist2 });
                        }
                    }
                }
            }
        }
    }
}

/// Execute the tile program over a set of cells on this thread (the PJRT
/// client is !Send, the paper's single GPU-master rank), buffering *raw*
/// device chunk outputs (literals - see [`RawTile`]; the device-to-host
/// conversion is the consumer's job, so it can run off this thread) and
/// handing them to `emit` in flush *rounds* of at most `round_cap`
/// chunks (each <= qt x ct x 4B) — the unit the former stream channels
/// bounded. Positions index the batch's flat query list, cell by cell. A
/// query tile whose candidate list spans more chunks than the cap is
/// split across rounds — the same position range re-appears in the next
/// round — so a round's consumer must process the rounds of one batch
/// *strictly sequentially* for the within-round position-disjointness
/// that makes a heap arena race-free to hold. All consumers do: the
/// synchronous path converts + filters each round inline before the next
/// device call, and the pooled paths submit rounds on a per-claim lane
/// whose ordering the stage pool enforces.
#[allow(clippy::too_many_arguments)]
fn exec_cells_into_rounds(
    engine: &Engine,
    (r_data, data): (&Dataset, &Dataset),
    (plan_large, plan_small): (&tiles::TilePlan, &tiles::TilePlan),
    use_topk: bool,
    cells: &[WorkCell],
    params: &GpuJoinParams,
    round_cap: usize,
    brute_cache: &mut BruteCache,
    kernel_time: &mut f64,
    brute_tiles: &mut u64,
    emit: &mut dyn FnMut(Vec<RawTile>) -> Result<()>,
) -> Result<()> {
    let round_cap = round_cap.max(1);
    let mut tiles_buf: Vec<RawTile> = Vec::new();
    let mut chunks_buffered = 0usize;
    let mut q_buf: Vec<f32> = Vec::new();
    let mut c_buf: Vec<f32> = Vec::new();
    let mut base = 0usize;
    for cell in cells {
        // One plan per cell: thin cells run on the small tile (less
        // padding); the small plan has no top-k variant, so it always
        // takes the dist path. Brute cells scan the whole corpus and
        // always saturate the large tile.
        let (plan, cell_topk) = if cell.brute {
            (plan_large, use_topk)
        } else if cell.queries.len() <= plan_small.qt {
            (plan_small, use_topk && plan_small.topk_name.is_some())
        } else {
            (plan_large, use_topk)
        };
        let (qt, ct, d_pad) = (plan.qt, plan.ct, plan.d);
        // Candidate tiles are shared by every query chunk of the cell:
        // pack + upload once (Perf#2). Brute cells go further - their
        // candidate set IS the corpus, so the packed tiles are shared
        // across every brute claim of the drain through the cache.
        let local_lits: Vec<(Vec<u32>, xla::Literal)>;
        let c_lits: &[(Vec<u32>, xla::Literal)] = if cell.brute {
            brute_cache.ensure(data, ct, d_pad)?
        } else {
            local_lits = cell
                .candidates
                .chunks(ct)
                .map(|c_chunk| {
                    tiles::pack_candidates(&mut c_buf, data, c_chunk, ct, d_pad);
                    Ok((
                        c_chunk.to_vec(),
                        Engine::literal(&c_buf, &[ct as i64, d_pad as i64])?,
                    ))
                })
                .collect::<Result<_>>()?;
            &local_lits
        };
        for q_chunk in cell.queries.chunks(qt) {
            tiles::pack(&mut q_buf, r_data, q_chunk, qt, d_pad, 0.0);
            let q_lit = Engine::literal(&q_buf, &[qt as i64, d_pad as i64])?;
            let mut chunks: Vec<RawChunk> = Vec::new();
            for (c_chunk, c_lit) in c_lits {
                let t0 = Instant::now();
                let payload = if cell_topk {
                    let out = engine.exec_lits(
                        plan.topk_name.as_deref().unwrap(),
                        &[&q_lit, c_lit],
                    )?;
                    *kernel_time += t0.elapsed().as_secs_f64();
                    let mut it = out.into_iter();
                    let vals = it.next().expect("topk artifact tuple arity");
                    let idx = it.next().expect("topk artifact tuple arity");
                    RawPayload::TopK {
                        vals: SendLit(vals),
                        idx: SendLit(idx),
                        k: plan.topk_k,
                    }
                } else {
                    let out = engine.exec_lits(&plan.dist_name, &[&q_lit, c_lit])?;
                    *kernel_time += t0.elapsed().as_secs_f64();
                    let lit =
                        out.into_iter().next().expect("dist artifact tuple arity");
                    RawPayload::Dist { lit: SendLit(lit), ct }
                };
                chunks.push(RawChunk { cand_ids: c_chunk.clone(), payload });
                chunks_buffered += 1;
                if cell.brute {
                    *brute_tiles += 1;
                }
                if chunks_buffered >= round_cap {
                    // emit the tile's chunks so far and close the round;
                    // the next round may revisit this tile's positions
                    tiles_buf.push(RawTile {
                        pos: base..base + q_chunk.len(),
                        chunks: std::mem::take(&mut chunks),
                        brute: cell.brute,
                    });
                    emit(std::mem::take(&mut tiles_buf))?;
                    chunks_buffered = 0;
                }
            }
            if !chunks.is_empty() {
                tiles_buf.push(RawTile {
                    pos: base..base + q_chunk.len(),
                    chunks,
                    brute: cell.brute,
                });
            }
            base += q_chunk.len();
        }
    }
    if !tiles_buf.is_empty() {
        emit(std::mem::take(&mut tiles_buf))?;
    }
    Ok(())
}

/// Execute + filter a set of cells *synchronously*: each flush round is
/// converted (device-to-host transfer, timed separately) and filtered
/// inline on `streams` workers before the next device call, so all three
/// stages alternate within the batch. This is the synchronous queue
/// drain's path - the ablation baseline of the pipelined drains, which
/// instead overlap the stages across claims (`drain_pipelined` /
/// DESIGN.md §5) - and the retry path of claim recovery (every retry is
/// synchronous, whatever drain mode failed). Returns the batch's flat
/// query list (cell by cell), one heap per position, the in-ε pair
/// count, and the transfer / filter wall seconds (the
/// exec/transfer/filter telemetry split).
///
/// `claim` scopes the fault hooks: all three stage hooks fire here per
/// flush round, on the master thread (the sync drain has no worker to
/// panic, so an injected filter panic unwinds the master - the sync
/// attempt runs under `catch_unwind` in [`sync_cells_attempt`]). The
/// watchdog deadline is checked at round boundaries only - `exec_lits`
/// is uninterruptible, so a stalled device is detected when its round
/// finally emits, never mid-kernel.
#[allow(clippy::too_many_arguments)]
fn exec_filter_cells(
    engine: &Engine,
    (r_data, data): (&Dataset, &Dataset),
    (plan_large, plan_small): (&tiles::TilePlan, &tiles::TilePlan),
    use_topk: bool,
    cells: &[WorkCell],
    params: &GpuJoinParams,
    brute_cache: &mut BruteCache,
    kernel_time: &mut f64,
    brute_tiles: &mut u64,
    claim: usize,
    deadline_secs: f64,
) -> Result<(Vec<u32>, Vec<BoundedHeap>, u64, f64, f64)> {
    let n_queries: usize = cells.iter().map(|c| c.queries.len()).sum();
    let batch_queries: Vec<u32> = cells
        .iter()
        .flat_map(|c| c.queries.iter().copied())
        .collect();
    let arena = HeapArena::new(n_queries, params.k.max(1));
    let eps2 = params.eps * params.eps;
    let n_workers = params.streams.max(1);
    // flush threshold in buffered device chunks: enough to keep every
    // filter worker busy, small enough that host memory stays bounded
    // regardless of any one cell's candidate count - the same unit the
    // former sync_channel depth (4/worker) bounded.
    let chunk_cap = n_workers * 8;

    let fault = &params.fault;
    let t_attempt = Instant::now();
    let mut round = 0usize;
    let mut pairs_total = 0u64;
    let mut transfer_secs = 0f64;
    let mut filter_secs = 0f64;
    exec_cells_into_rounds(
        engine,
        (r_data, data),
        (plan_large, plan_small),
        use_topk,
        cells,
        params,
        chunk_cap,
        brute_cache,
        kernel_time,
        brute_tiles,
        &mut |raw: Vec<RawTile>| {
            fault.exec_round(claim, round)?;
            if let Some(e) = fault.transfer_fault(claim, round) {
                return Err(e);
            }
            if fault.filter_panic(claim, round) {
                panic!("injected filter panic (claim {claim}, round {round})");
            }
            let t = Instant::now();
            let tiles = convert_tiles(raw)?;
            transfer_secs += t.elapsed().as_secs_f64();
            let t = Instant::now();
            pairs_total += filter_tiles(
                &tiles,
                &batch_queries,
                &arena,
                eps2,
                params.exclude_self,
                n_workers,
            );
            filter_secs += t.elapsed().as_secs_f64();
            round += 1;
            let elapsed = t_attempt.elapsed().as_secs_f64();
            if elapsed > deadline_secs {
                return Err(WatchdogTimeout {
                    claim,
                    elapsed,
                    deadline: deadline_secs,
                }
                .into());
            }
            Ok(())
        },
    )?;

    Ok((batch_queries, arena.into_heaps(), pairs_total, transfer_secs, filter_secs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::sqdist;
    use crate::data::synthetic::{chist_like, susy_like};
    use crate::index::KdTree;

    fn setup(n: usize) -> (Engine, Dataset) {
        (Engine::load_default().unwrap(), susy_like(n).generate(21))
    }

    fn exact_ref(data: &Dataset, q: u32, k: usize) -> Vec<Neighbor> {
        let t = KdTree::build(data);
        t.knn(data, data.point(q as usize), k, q)
    }

    #[test]
    fn solved_queries_are_exact_knn() {
        let (engine, data) = setup(1200);
        let grid = GridIndex::build(&data, 6, 3.0);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let params = GpuJoinParams::new(4, 3.0);
        let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
        assert!(out.solved > 0, "nothing solved - eps too small for test");
        let mut checked = 0;
        for q in (0..data.len() as u32).step_by(97) {
            let got = out.result.get(q as usize);
            if got.len() < params.k {
                continue; // failed query - CPU's job
            }
            let want = exact_ref(&data, q, params.k);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g.dist2 - w.dist2).abs() < 1e-3 * (1.0 + w.dist2),
                    "q={q} got={g:?} want={w:?}"
                );
            }
            checked += 1;
        }
        assert!(checked > 0);
    }

    #[test]
    fn failed_queries_have_too_few_in_eps_neighbors() {
        let (engine, data) = setup(900);
        let eps = 1.0; // small: guarantees some failures
        let grid = GridIndex::build(&data, 6, eps);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let params = GpuJoinParams::new(8, eps);
        let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
        assert_eq!(out.solved + out.failed.len(), queries.len());
        // verify failure ground truth on a sample
        for &q in out.failed.iter().step_by(53) {
            let within = (0..data.len())
                .filter(|&i| i != q as usize)
                .filter(|&i| sqdist(data.point(q as usize), data.point(i)) <= eps * eps)
                .count();
            assert!(
                within < params.k,
                "query {q} has {within} >= k in-eps neighbors but was failed"
            );
        }
    }

    #[test]
    fn dist_and_topk_paths_agree() {
        let (engine, data) = setup(700);
        let grid = GridIndex::build(&data, 6, 2.5);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let mut p_topk = GpuJoinParams::new(5, 2.5);
        p_topk.use_topk = true;
        let mut p_dist = p_topk.clone();
        p_dist.use_topk = false;
        let a = gpu_join(&engine, &data, &grid, &queries, &p_topk).unwrap();
        let b = gpu_join(&engine, &data, &grid, &queries, &p_dist).unwrap();
        assert_eq!(a.solved, b.solved);
        assert_eq!(a.failed, b.failed);
        for q in (0..data.len()).step_by(31) {
            let (ga, gb) = (a.result.get(q), b.result.get(q));
            assert_eq!(ga.len(), gb.len());
            for (x, y) in ga.iter().zip(gb) {
                assert!((x.dist2 - y.dist2).abs() < 1e-4 * (1.0 + y.dist2));
            }
        }
    }

    #[test]
    fn batching_respects_buffer_and_minimum() {
        let (engine, data) = setup(1500);
        let grid = GridIndex::build(&data, 6, 3.0);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let mut params = GpuJoinParams::new(4, 3.0);
        params.buffer_pairs = 2_000; // force many batches
        let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
        assert!(out.batches >= 3, "minimum 3 batches (stream overlap)");
        // byte-accurate envelope: realised pairs never exceed the
        // scheduled per-batch capacity ...
        assert!(
            out.max_batch_pairs <= out.batch_envelope_pairs,
            "realised {} exceeds scheduled envelope {}",
            out.max_batch_pairs,
            out.batch_envelope_pairs
        );
        // ... and the scheduled capacity stays within buffer_pairs
        // unless a single indivisible cell exceeds it (recompute the
        // largest cell's |queries| x |candidates| straight off the grid)
        let mut by_cell: std::collections::HashMap<u64, (u64, u32)> =
            std::collections::HashMap::new();
        for q in 0..data.len() as u32 {
            by_cell
                .entry(grid.query_cell_id(true, &data, q))
                .or_insert((0, q))
                .0 += 1;
        }
        let mut cands = Vec::new();
        let max_cell_capacity = by_cell
            .values()
            .map(|&(nq, rep)| {
                cands.clear();
                grid.query_candidates_into(true, &data, rep, &mut cands);
                nq * cands.len() as u64
            })
            .max()
            .unwrap_or(0);
        assert!(
            out.batch_envelope_pairs
                <= params.buffer_pairs.max(max_cell_capacity),
            "envelope {} exceeds buffer {} (largest cell {})",
            out.batch_envelope_pairs,
            params.buffer_pairs,
            max_cell_capacity
        );
        assert!(out.estimated_pairs > 0);
    }

    #[test]
    fn filter_pool_capacity_adapts_to_micro_batches() {
        // steady state: the historical rounds-counted envelope survives
        assert_eq!(filter_pool_capacity(3, 6, 2, 10_000, 128), 12);
        assert_eq!(filter_pool_capacity(3, 12, 1, 10_000, 128), 12);
        // micro-batch regime: a head smaller than one tile row per
        // worker widens the cap to the sync envelope (n_workers * 8)
        assert_eq!(filter_pool_capacity(3, 6, 2, 64, 128), 24);
        assert_eq!(filter_pool_capacity(1, 4, 1, 1, 32), 8);
        // widening never shrinks an already-larger steady envelope
        assert_eq!(filter_pool_capacity(4, 32, 2, 2, 128), 64);
        // degenerate inputs still yield a usable capacity
        assert!(filter_pool_capacity(1, 0, 0, 0, 0) >= 1);
    }

    #[test]
    fn subset_queries_only() {
        let (engine, data) = setup(600);
        let grid = GridIndex::build(&data, 6, 3.0);
        let queries: Vec<u32> = (0..200).collect();
        let params = GpuJoinParams::new(3, 3.0);
        let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
        assert_eq!(out.solved + out.failed.len(), 200);
        // queries outside the set must remain empty
        for q in 200..data.len() {
            assert!(out.result.get(q).is_empty());
        }
    }

    #[test]
    fn high_dim_chist_route() {
        // 32-D surrogate exercises the d=32 artifact family
        let engine = Engine::load_default().unwrap();
        let data = chist_like(500).generate(8);
        let sel = crate::epsilon::EpsilonSelector::default().select_host(&data, 3, 0.2);
        let grid = GridIndex::build(&data, 6, sel.eps);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let params = GpuJoinParams::new(3, sel.eps);
        let out = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();
        assert!(out.solved + out.failed.len() == queries.len());
        assert!(out.kernel_time > 0.0);
        assert!(out.device_model.threads > 0);
    }

    /// Test replica of `exec_cells_into_rounds`' buffering arithmetic:
    /// given cell shapes (queries, candidates) and tile dims, produce the
    /// flush rounds as (position range, chunk count) tiles, exactly as
    /// the exec loop would emit them.
    fn plan_rounds(
        shapes: &[(usize, usize)],
        qt: usize,
        ct: usize,
        cap: usize,
    ) -> Vec<Vec<(std::ops::Range<usize>, usize)>> {
        let cap = cap.max(1);
        let mut rounds = Vec::new();
        let mut buf: Vec<(std::ops::Range<usize>, usize)> = Vec::new();
        let mut buffered = 0usize;
        let mut base = 0usize;
        for &(nq, nc) in shapes {
            let n_cchunks = nc.div_ceil(ct);
            let mut q0 = 0usize;
            while q0 < nq {
                let qlen = qt.min(nq - q0);
                let mut chunks_here = 0usize;
                for _ in 0..n_cchunks {
                    chunks_here += 1;
                    buffered += 1;
                    if buffered >= cap {
                        buf.push((base..base + qlen, chunks_here));
                        chunks_here = 0;
                        rounds.push(std::mem::take(&mut buf));
                        buffered = 0;
                    }
                }
                if chunks_here > 0 {
                    buf.push((base..base + qlen, chunks_here));
                }
                base += qlen;
                q0 += qlen;
            }
        }
        if !buf.is_empty() {
            rounds.push(buf);
        }
        rounds
    }

    #[test]
    fn flush_rounds_position_disjoint_across_staging_sets() {
        // The staging-set soundness property: for random cell/chunk
        // shapes, (a) no queue position is aliased within a flush round,
        // (b) no round exceeds the chunk cap (the bounded hand-off), (c)
        // every (position, candidate-chunk) pair is covered exactly once
        // across rounds - tiles split across rounds included - and (d)
        // the staging sets' claims occupy pairwise-disjoint queue
        // intervals, so concurrently-live arenas can never alias a queue
        // position - the invariant that lets the stage pool retire
        // rounds of different claims out of order.
        use crate::util::prop;
        prop::cases(60, 0x0D15C0, |rng| {
            let qt = 1 + rng.below(8);
            let ct = 1 + rng.below(8);
            let cap = 1 + rng.below(6);
            // three consecutive claims = the three-stage drain's rotating
            // staging sets (exec / transfer / filter); each claim's queue
            // positions start where the previous claim's end
            let claims: Vec<Vec<(usize, usize)>> = (0..3)
                .map(|_| {
                    (0..1 + rng.below(6))
                        .map(|_| (1 + rng.below(20), rng.below(40)))
                        .collect()
                })
                .collect();
            let mut offset = 0usize;
            let mut intervals = Vec::new();
            for shapes in &claims {
                let n: usize = shapes.iter().map(|s| s.0).sum();
                // expected chunk coverage per claim-local position
                let mut expect = vec![0usize; n];
                let mut p = 0usize;
                for &(nq, nc) in shapes {
                    for _ in 0..nq {
                        expect[p] = nc.div_ceil(ct);
                        p += 1;
                    }
                }
                let rounds = plan_rounds(shapes, qt, ct, cap);
                let mut got = vec![0usize; n];
                for round in &rounds {
                    // (b) bounded hand-off: a round never buffers more
                    // than `cap` device chunks
                    let chunks: usize = round.iter().map(|t| t.1).sum();
                    assert!(chunks <= cap, "round of {chunks} chunks > cap {cap}");
                    // (a) within-round position disjointness
                    let mut in_round = vec![false; n];
                    for (pos, nchunks) in round {
                        assert!(pos.end <= n, "tile escapes the claim arena");
                        assert!(!pos.is_empty(), "empty tile emitted");
                        for i in pos.clone() {
                            assert!(
                                !in_round[i],
                                "position {i} aliased within one round"
                            );
                            in_round[i] = true;
                            got[i] += nchunks;
                        }
                    }
                }
                // (c) exact coverage, split tiles included
                assert_eq!(got, expect, "per-position chunk coverage");
                intervals.push(offset..offset + n);
                offset += n;
            }
            // (d) the staging sets' queue intervals are pairwise
            // disjoint, so no two live arenas map to one queue position
            for w in intervals.windows(2) {
                assert!(
                    w[0].end <= w[1].start,
                    "staging-set claims overlap in queue space"
                );
            }
        });
    }

    #[test]
    fn drain_equals_list_form_and_recirculates_failures() {
        // the queue-driven GPU master must solve exactly the queries the
        // list form solves (same cells, same candidates) and push every
        // failure into the recirculation buffer
        use crate::sched::build_queue;

        let (engine, data) = setup(800);
        let eps = 2.0;
        let grid = GridIndex::build(&data, 6, eps);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let params = GpuJoinParams::new(6, eps);

        let list = gpu_join(&engine, &data, &grid, &queries, &params).unwrap();

        let queue = build_queue(&data, &grid, &queries, params.k, 0.0, 0.0, true);
        let mut result = KnnResult::new(data.len(), params.k);
        let slots = result.slots();
        let out = gpu_join_drain(
            &engine, &data, &data, &grid, &queue, &params, &slots,
            queue.len(),
        )
        .unwrap();
        drop(slots);

        assert_eq!(out.solved + out.failed.len(), queries.len());
        assert_eq!(out.solved, list.solved);
        assert_eq!(out.failed, list.failed);
        assert_eq!(queue.claimed_head(), queries.len());
        assert_eq!(queue.recirc_pushed(), out.failed.len());
        assert!(!out.claims.is_empty());
        assert!(out.claims.iter().all(|c| matches!(c.arch, Arch::Gpu)));
        let claimed: usize = out.claims.iter().map(|c| c.queries).sum();
        assert_eq!(claimed, queries.len());
        for q in (0..data.len()).step_by(61) {
            let (a, b) = (result.get(q), list.result.get(q));
            assert_eq!(a.len(), b.len(), "q={q}");
            for (x, y) in a.iter().zip(b) {
                assert!((x.dist2 - y.dist2).abs() < 1e-4 * (1.0 + y.dist2));
            }
        }
    }
}
