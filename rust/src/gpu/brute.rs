//! Brute-force GPU tier: the O(|D|^2) all-scan join of paper Sec. VI-D,
//! in two forms.
//!
//! [`brute_join_linear`] is the standalone measurement loop - every query
//! scans every point, no index, kernel work independent of ε (Fig. 7's
//! flat curve, the lower bound of Fig. 11).
//!
//! [`BruteCache`] + [`brute_join_tiled`] are the *production* form: the
//! drain in [`super::join`] routes whole claims onto the brute tier (high
//! m / high k, where grid candidate lists approach the corpus anyway -
//! DESIGN.md §10), and those claims execute through the same tiled,
//! pipelined three-stage machinery as grid claims. The cache packs the
//! corpus into candidate tiles once per drain and shares the uploaded
//! literals across every brute claim, so the tier's per-claim cost is
//! query packing + kernels only.

use std::time::Instant;

use anyhow::Result;

use crate::core::{BoundedHeap, Dataset, KnnResult, Neighbor};
use crate::index::GridIndex;
use crate::runtime::{tiles, tiles::TileClass, Engine};
use crate::sched::{self, BackendMode};

/// Lazily packed, device-resident candidate tiles covering the whole
/// corpus, shared by every brute-routed claim of one drain.
///
/// The corpus never changes within a drain, so the tiles are built once
/// (on the first brute claim - grid-only drains pay nothing) and the
/// uploaded literals are reused by every subsequent brute tile. Without
/// churn, chunk ids are the contiguous ranges `start..start+len`,
/// packed via [`tiles::pack_candidate_range`] without materialising an
/// id list; under churn ([`Self::set_live`]) only the live subset is
/// packed, in ascending id order, via [`tiles::pack_candidates`] - a
/// removed point must never reappear as a brute-tier neighbor. The
/// resident drain state invalidates the cache whenever the index epoch
/// (queue generation stamp) moves, so cross-flush reuse always reads a
/// consistent snapshot.
pub(crate) struct BruteCache {
    ct: usize,
    d_pad: usize,
    chunks: Vec<(Vec<u32>, xla::Literal)>,
    built: bool,
    /// live-id subset to pack (ascending); None = whole corpus
    live: Option<Vec<u32>>,
}

impl BruteCache {
    /// Empty cache; nothing is packed until [`Self::ensure`].
    pub(crate) fn new() -> Self {
        BruteCache {
            ct: 0,
            d_pad: 0,
            chunks: Vec::new(),
            built: false,
            live: None,
        }
    }

    /// Drop the packed tiles; the next [`Self::ensure`] repacks. Called
    /// on every index-epoch change.
    pub(crate) fn invalidate(&mut self) {
        self.chunks.clear();
        self.built = false;
    }

    /// Restrict packing to a live-id subset (ascending; `None` restores
    /// whole-corpus packing). Invalidates the packed tiles when the set
    /// actually changes.
    pub(crate) fn set_live(&mut self, live: Option<Vec<u32>>) {
        if self.live != live {
            self.invalidate();
            self.live = live;
        }
    }

    /// Return the corpus candidate tiles for tile shape `(ct, d_pad)`,
    /// packing and uploading them on first use. The tile plan is a
    /// function of the dataset dimensionality alone, so one drain only
    /// ever asks for one shape (debug-asserted).
    pub(crate) fn ensure(
        &mut self,
        data: &Dataset,
        ct: usize,
        d_pad: usize,
    ) -> Result<&[(Vec<u32>, xla::Literal)]> {
        if self.built {
            debug_assert_eq!(
                (self.ct, self.d_pad),
                (ct, d_pad),
                "tile plan changed mid-drain"
            );
            return Ok(&self.chunks);
        }
        let mut buf: Vec<f32> = Vec::new();
        match &self.live {
            Some(live) => {
                for chunk in live.chunks(ct.max(1)) {
                    tiles::pack_candidates(&mut buf, data, chunk, ct, d_pad);
                    let lit = Engine::literal(&buf, &[ct as i64, d_pad as i64])?;
                    self.chunks.push((chunk.to_vec(), lit));
                }
            }
            None => {
                let n = data.len();
                let mut start = 0usize;
                while start < n {
                    let len = ct.min(n - start);
                    tiles::pack_candidate_range(&mut buf, data, start as u32, len, ct, d_pad);
                    let lit = Engine::literal(&buf, &[ct as i64, d_pad as i64])?;
                    let ids: Vec<u32> = (start as u32..(start + len) as u32).collect();
                    self.chunks.push((ids, lit));
                    start += len;
                }
            }
        }
        self.ct = ct;
        self.d_pad = d_pad;
        self.built = true;
        Ok(&self.chunks)
    }
}

/// Exact k-NN over `queries` on the tiled, pipelined brute tier: builds a
/// degenerate single-cell grid (the drain needs an index for claim
/// bookkeeping, not for pruning) and runs the queue drain with the
/// backend forced to [`BackendMode::Brute`], so every claim takes the
/// corpus-scan path through the cache. This is the standalone entry the
/// backend benches and equivalence tests drive; the hybrid engine reaches
/// the same code through per-claim routing instead.
pub fn brute_join_tiled(
    engine: &Engine,
    data: &Dataset,
    queries: &[u32],
    params: &super::join::GpuJoinParams,
) -> Result<(KnnResult, super::join::GpuJoinStats)> {
    // One cell spanning everything: side length >= the data extent makes
    // every point land in cell (0,..,0) of an m=1 grid.
    let grid = GridIndex::build(data, 1, f64::MAX / 4.0);
    let queue = sched::build_queue(data, &grid, queries, params.k, 0.0, 0.0, true);
    let mut forced = params.clone();
    forced.backend = BackendMode::Brute;
    let mut result = KnnResult::new(data.len(), params.k.max(1));
    let slots = result.slots();
    let stats = super::join::gpu_join_drain(
        engine,
        data,
        data,
        &grid,
        &queue,
        &forced,
        &slots,
        queue.len(),
    )?;
    drop(slots);
    Ok((result, stats))
}

/// Outcome of the brute-force pass.
#[derive(Debug)]
pub struct BruteOutcome {
    /// kernel-only wall time (the paper's lower-bound metric excludes
    /// host-side filtering and result returns)
    pub kernel_time: f64,
    /// wall time of the whole pass
    pub total_time: f64,
    /// tiles executed
    pub tiles: usize,
    /// exact KNN result when `collect` was requested
    pub result: Option<KnnResult>,
}

/// Run the linear self-join over `queries` (all of D in the paper).
/// `eps` only gates result collection - kernel work is independent of it,
/// which is exactly what Fig. 7 demonstrates. With `collect_k = Some(k)`
/// the host additionally merges exact top-k (ignoring ε like the paper's
/// in-principle use).
pub fn brute_join_linear(
    engine: &Engine,
    data: &Dataset,
    queries: &[u32],
    eps: f64,
    collect_k: Option<usize>,
) -> Result<BruteOutcome> {
    let t_start = Instant::now();
    let plan = tiles::plan_for(engine, data.dims(), TileClass::Large)?;
    let (qt, ct, d_pad) = (plan.qt, plan.ct, plan.d);
    let _ = eps; // kernel work independent of eps (Fig. 7)

    let mut kernel_time = 0f64;
    let mut n_tiles = 0usize;
    let mut heaps: Vec<BoundedHeap> = match collect_k {
        Some(k) => queries.iter().map(|_| BoundedHeap::new(k)).collect(),
        None => Vec::new(),
    };

    let all_ids: Vec<u32> = (0..data.len() as u32).collect();
    let mut q_buf: Vec<f32> = Vec::new();
    let mut c_buf: Vec<f32> = Vec::new();
    for (qi, q_chunk) in queries.chunks(qt).enumerate() {
        tiles::pack(&mut q_buf, data, q_chunk, qt, d_pad, 0.0);
        for c_chunk in all_ids.chunks(ct) {
            tiles::pack_candidates(&mut c_buf, data, c_chunk, ct, d_pad);
            let t0 = Instant::now();
            let out = engine.exec(
                &plan.dist_name,
                &[
                    (&q_buf, &[qt as i64, d_pad as i64]),
                    (&c_buf, &[ct as i64, d_pad as i64]),
                ],
            )?;
            kernel_time += t0.elapsed().as_secs_f64();
            n_tiles += 1;
            if collect_k.is_some() {
                let d2 = Engine::to_f32(&out[0])?;
                for (r, &q) in q_chunk.iter().enumerate() {
                    let heap = &mut heaps[qi * qt + r];
                    let row = &d2[r * ct..r * ct + c_chunk.len()];
                    for (c, &dd) in row.iter().enumerate() {
                        let id = c_chunk[c];
                        if id != q {
                            heap.push(Neighbor { id, dist2: (dd as f64).max(0.0) });
                        }
                    }
                }
            }
        }
    }

    let result = collect_k.map(|k| {
        let mut res = KnnResult::new(data.len(), k);
        for (i, &q) in queries.iter().enumerate() {
            res.write_heap(q as usize, &mut heaps[i]);
        }
        res
    });

    Ok(BruteOutcome {
        kernel_time,
        total_time: t_start.elapsed().as_secs_f64(),
        tiles: n_tiles,
        result,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::susy_like;
    use crate::index::KdTree;

    #[test]
    fn brute_collect_matches_kdtree() {
        let engine = Engine::load_default().unwrap();
        let data = susy_like(600).generate(31);
        let queries: Vec<u32> = (0..100).collect();
        let out =
            brute_join_linear(&engine, &data, &queries, 1.0, Some(5)).unwrap();
        let res = out.result.unwrap();
        let tree = KdTree::build(&data);
        for &q in queries.iter().step_by(17) {
            let got = res.get(q as usize);
            let want = tree.knn(&data, data.point(q as usize), 5, q);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g.dist2 - w.dist2).abs() < 1e-3 * (1.0 + w.dist2),
                    "q={q}: {g:?} vs {w:?}"
                );
            }
        }
    }

    #[test]
    fn kernel_work_independent_of_eps() {
        // Fig. 7 invariant: tiles executed do not depend on eps
        let engine = Engine::load_default().unwrap();
        let data = susy_like(400).generate(32);
        let queries: Vec<u32> = (0..128).collect();
        let a = brute_join_linear(&engine, &data, &queries, 0.1, None).unwrap();
        let b = brute_join_linear(&engine, &data, &queries, 10.0, None).unwrap();
        assert_eq!(a.tiles, b.tiles);
        assert!(a.result.is_none());
    }

    #[test]
    fn tile_count_is_quadratic_grid() {
        let engine = Engine::load_default().unwrap();
        let data = susy_like(1100).generate(33);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let out = brute_join_linear(&engine, &data, &queries, 1.0, None).unwrap();
        // ceil(1100/128) * ceil(1100/512) = 9 * 3
        assert_eq!(out.tiles, 27);
    }
}
