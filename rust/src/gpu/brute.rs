//! GPU-JOINLINEAR (paper Sec. VI-D): the brute-force O(|D|^2) self-join
//! lower bound. Every query scans every point; no index. Used to show
//! where index pruning wins (Fig. 7 - flat in ε - and Fig. 11).

use std::time::Instant;

use anyhow::Result;

use crate::core::{BoundedHeap, Dataset, KnnResult, Neighbor};
use crate::runtime::{tiles, tiles::TileClass, Engine};

/// Outcome of the brute-force pass.
#[derive(Debug)]
pub struct BruteOutcome {
    /// kernel-only wall time (the paper's lower-bound metric excludes
    /// host-side filtering and result returns)
    pub kernel_time: f64,
    /// wall time of the whole pass
    pub total_time: f64,
    /// tiles executed
    pub tiles: usize,
    /// exact KNN result when `collect` was requested
    pub result: Option<KnnResult>,
}

/// Run the linear self-join over `queries` (all of D in the paper).
/// `eps` only gates result collection - kernel work is independent of it,
/// which is exactly what Fig. 7 demonstrates. With `collect_k = Some(k)`
/// the host additionally merges exact top-k (ignoring ε like the paper's
/// in-principle use).
pub fn brute_join_linear(
    engine: &Engine,
    data: &Dataset,
    queries: &[u32],
    eps: f64,
    collect_k: Option<usize>,
) -> Result<BruteOutcome> {
    let t_start = Instant::now();
    let plan = tiles::plan_for(engine, data.dims(), TileClass::Large)?;
    let (qt, ct, d_pad) = (plan.qt, plan.ct, plan.d);
    let _ = eps; // kernel work independent of eps (Fig. 7)

    let mut kernel_time = 0f64;
    let mut n_tiles = 0usize;
    let mut heaps: Vec<BoundedHeap> = match collect_k {
        Some(k) => queries.iter().map(|_| BoundedHeap::new(k)).collect(),
        None => Vec::new(),
    };

    let all_ids: Vec<u32> = (0..data.len() as u32).collect();
    let mut q_buf: Vec<f32> = Vec::new();
    let mut c_buf: Vec<f32> = Vec::new();
    for (qi, q_chunk) in queries.chunks(qt).enumerate() {
        tiles::pack(&mut q_buf, data, q_chunk, qt, d_pad, 0.0);
        for c_chunk in all_ids.chunks(ct) {
            tiles::pack_candidates(&mut c_buf, data, c_chunk, ct, d_pad);
            let t0 = Instant::now();
            let out = engine.exec(
                &plan.dist_name,
                &[
                    (&q_buf, &[qt as i64, d_pad as i64]),
                    (&c_buf, &[ct as i64, d_pad as i64]),
                ],
            )?;
            kernel_time += t0.elapsed().as_secs_f64();
            n_tiles += 1;
            if collect_k.is_some() {
                let d2 = Engine::to_f32(&out[0])?;
                for (r, &q) in q_chunk.iter().enumerate() {
                    let heap = &mut heaps[qi * qt + r];
                    let row = &d2[r * ct..r * ct + c_chunk.len()];
                    for (c, &dd) in row.iter().enumerate() {
                        let id = c_chunk[c];
                        if id != q {
                            heap.push(Neighbor { id, dist2: (dd as f64).max(0.0) });
                        }
                    }
                }
            }
        }
    }

    let result = collect_k.map(|k| {
        let mut res = KnnResult::new(data.len(), k);
        for (i, &q) in queries.iter().enumerate() {
            res.write_heap(q as usize, &mut heaps[i]);
        }
        res
    });

    Ok(BruteOutcome {
        kernel_time,
        total_time: t_start.elapsed().as_secs_f64(),
        tiles: n_tiles,
        result,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::susy_like;
    use crate::index::KdTree;

    #[test]
    fn brute_collect_matches_kdtree() {
        let engine = Engine::load_default().unwrap();
        let data = susy_like(600).generate(31);
        let queries: Vec<u32> = (0..100).collect();
        let out =
            brute_join_linear(&engine, &data, &queries, 1.0, Some(5)).unwrap();
        let res = out.result.unwrap();
        let tree = KdTree::build(&data);
        for &q in queries.iter().step_by(17) {
            let got = res.get(q as usize);
            let want = tree.knn(&data, data.point(q as usize), 5, q);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g.dist2 - w.dist2).abs() < 1e-3 * (1.0 + w.dist2),
                    "q={q}: {g:?} vs {w:?}"
                );
            }
        }
    }

    #[test]
    fn kernel_work_independent_of_eps() {
        // Fig. 7 invariant: tiles executed do not depend on eps
        let engine = Engine::load_default().unwrap();
        let data = susy_like(400).generate(32);
        let queries: Vec<u32> = (0..128).collect();
        let a = brute_join_linear(&engine, &data, &queries, 0.1, None).unwrap();
        let b = brute_join_linear(&engine, &data, &queries, 10.0, None).unwrap();
        assert_eq!(a.tiles, b.tiles);
        assert!(a.result.is_none());
    }

    #[test]
    fn tile_count_is_quadratic_grid() {
        let engine = Engine::load_default().unwrap();
        let data = susy_like(1100).generate(33);
        let queries: Vec<u32> = (0..data.len() as u32).collect();
        let out = brute_join_linear(&engine, &data, &queries, 1.0, None).unwrap();
        // ceil(1100/128) * ceil(1100/512) = 9 * 3
        assert_eq!(out.tiles, 27);
    }
}
