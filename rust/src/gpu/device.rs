//! Analytic GPU occupancy/divergence model for the kernel task-granularity
//! study (paper Sec. V-G, Table III).
//!
//! This testbed has no CUDA device; the PJRT CPU client executes the same
//! arithmetic, but warp effects - the subject of Table III - do not exist
//! on it. This model reproduces them from first principles, driven by the
//! *real* per-query candidate counts produced by the grid walk:
//!
//! * lanes are grouped into 32-wide warps in assignment order;
//! * a warp's time is its max lane time (SIMT lockstep), inflated by a
//!   divergence penalty when the warp serves queries whose thread groups
//!   straddle the warp boundary (the TDYNAMIC failure mode the paper
//!   describes);
//! * the device is simultaneously throughput-bound (total warp cycles
//!   spread over `concurrent_warps` resident slots) and critical-path
//!   bound (no kernel finishes before its longest warp): time =
//!   max(sum/width, max) - few long warps mean under-saturation, exactly
//!   the small-|Q^GPU| regime of Sec. V-G;
//! * each launched thread pays a fixed scheduling overhead - many threads
//!   per point stop paying off once lane work shrinks below it.
//!
//! Constants approximate the paper's GP100 (56 SMs, 1.48 GHz); they set
//! the *scale*, while the shape of Table III comes from the workload.

/// Thread-to-point assignment strategies of Sec. V-G.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ThreadAssign {
    /// TSTATIC: a fixed number of threads per query point.
    Static(u32),
    /// TDYNAMIC: a minimum total thread count per kernel invocation,
    /// divided evenly over the query points.
    Dynamic(u64),
}

/// Model constants (GP100-flavoured defaults).
#[derive(Debug, Clone)]
pub struct DeviceModel {
    /// SIMT width (32 on NVIDIA)
    pub lanes_per_warp: usize,
    /// SMs x resident warps each that can hide latency concurrently
    pub concurrent_warps: usize,
    /// cycles to schedule/launch one thread
    pub launch_cycles: f64,
    /// cycles per candidate distance per lane (includes the filter)
    pub cycles_per_candidate: f64,
    /// fractional penalty per extra distinct query sharing a warp *when
    /// the sharing is misaligned* (group straddles the warp boundary)
    pub divergence_penalty: f64,
    /// device clock in Hz (cycles -> seconds)
    pub clock_hz: f64,
}

impl Default for DeviceModel {
    fn default() -> Self {
        DeviceModel {
            lanes_per_warp: 32,
            concurrent_warps: 56 * 8,
            launch_cycles: 20.0,
            cycles_per_candidate: 8.0,
            divergence_penalty: 0.15,
            clock_hz: 1.48e9,
        }
    }
}

/// Result of a model evaluation.
#[derive(Debug, Clone, Default)]
pub struct DeviceEstimate {
    /// total threads launched
    pub threads: u64,
    /// 32-lane warps formed
    pub warps: u64,
    /// full waves over the concurrent-warp width
    pub waves: u64,
    /// modeled device cycles
    pub cycles: f64,
    /// modeled kernel seconds (cycles / clock)
    pub seconds: f64,
    /// fraction of lane slots doing useful work in the mean warp
    pub lane_utilisation: f64,
}

impl DeviceModel {
    /// Estimate kernel time for per-query candidate workloads `work`
    /// (candidate count for each query in the batch).
    pub fn estimate(&self, work: &[u64], assign: ThreadAssign) -> DeviceEstimate {
        if work.is_empty() {
            return DeviceEstimate::default();
        }
        let nq = work.len() as u64;
        // threads per query
        let per_q: Vec<u64> = match assign {
            ThreadAssign::Static(t) => vec![t.max(1) as u64; work.len()],
            ThreadAssign::Dynamic(min_total) => {
                let total = min_total.max(nq);
                let base = total / nq;
                let rem = (total % nq) as usize;
                (0..work.len())
                    .map(|i| base + if i < rem { 1 } else { 0 })
                    .collect()
            }
        };

        // lane stream: (query index, lane work) in assignment order
        let lanes_per_warp = self.lanes_per_warp as u64;
        let total_threads: u64 = per_q.iter().sum();
        let warps = total_threads.div_ceil(lanes_per_warp);

        let mut warp_times: Vec<f64> = Vec::with_capacity(warps as usize);
        let mut cur_max = 0f64;
        let mut cur_lanes = 0u64;
        let mut cur_first_query: Option<usize> = None;
        let mut cur_distinct = 0usize;
        let mut cur_straddle = false;
        let mut useful_lane_cycles = 0f64;

        let flush =
            |max: f64, distinct: usize, straddle: bool, times: &mut Vec<f64>| {
                let div = if straddle && distinct > 1 {
                    1.0 + self.divergence_penalty * (distinct - 1) as f64
                } else {
                    1.0
                };
                times.push(max * div);
            };

        for (qi, (&w, &t)) in work.iter().zip(&per_q).enumerate() {
            let lane_work =
                (w as f64 / t as f64).ceil() * self.cycles_per_candidate;
            useful_lane_cycles += w as f64 * self.cycles_per_candidate;
            let mut remaining = t;
            while remaining > 0 {
                if cur_lanes == lanes_per_warp {
                    flush(cur_max, cur_distinct, cur_straddle, &mut warp_times);
                    cur_max = 0.0;
                    cur_lanes = 0;
                    cur_first_query = None;
                    cur_distinct = 0;
                    cur_straddle = false;
                }
                let space = lanes_per_warp - cur_lanes;
                let take = remaining.min(space);
                if cur_first_query != Some(qi) {
                    cur_distinct += 1;
                    cur_first_query = Some(qi);
                }
                // a query group straddles if it doesn't finish in this warp
                // or didn't start at a warp-aligned group boundary with an
                // even divisor of the warp width
                if take < remaining || (cur_lanes % t.min(lanes_per_warp)) != 0 {
                    cur_straddle = true;
                }
                if lane_work > cur_max {
                    cur_max = lane_work;
                }
                cur_lanes += take;
                remaining -= take;
            }
        }
        if cur_lanes > 0 {
            flush(cur_max, cur_distinct, cur_straddle, &mut warp_times);
        }

        // throughput bound vs critical path, plus per-thread launch cost
        // amortised over the concurrent width
        let sum_warp: f64 = warp_times.iter().sum();
        let max_warp: f64 = warp_times.iter().cloned().fold(0.0, f64::max);
        let cycles = (sum_warp / self.concurrent_warps as f64).max(max_warp)
            + self.launch_cycles * total_threads as f64
                / self.concurrent_warps as f64;

        let total_lane_cycles: f64 = warp_times.iter().sum::<f64>()
            * self.lanes_per_warp as f64;
        let lane_utilisation = if total_lane_cycles > 0.0 {
            (useful_lane_cycles / total_lane_cycles).min(1.0)
        } else {
            0.0
        };

        DeviceEstimate {
            threads: total_threads,
            warps,
            waves: warps.div_ceil(self.concurrent_warps as u64),
            cycles,
            seconds: cycles / self.clock_hz,
            lane_utilisation,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// skewed workload resembling clustered data: most queries small,
    /// a tail of dense ones
    fn skewed_work(rng: &mut Rng, n: usize) -> Vec<u64> {
        (0..n)
            .map(|_| {
                if rng.f64() < 0.1 {
                    2000 + rng.below(4000) as u64
                } else {
                    50 + rng.below(300) as u64
                }
            })
            .collect()
    }

    #[test]
    fn empty_workload() {
        let m = DeviceModel::default();
        let e = m.estimate(&[], ThreadAssign::Static(8));
        assert_eq!(e.threads, 0);
        assert_eq!(e.cycles, 0.0);
    }

    #[test]
    fn thread_counts() {
        let m = DeviceModel::default();
        let w = vec![100u64; 10];
        assert_eq!(m.estimate(&w, ThreadAssign::Static(8)).threads, 80);
        // dynamic: max(min_total, |Q|) distributed evenly
        assert_eq!(m.estimate(&w, ThreadAssign::Dynamic(64)).threads, 64);
        assert_eq!(m.estimate(&w, ThreadAssign::Dynamic(5)).threads, 10);
    }

    #[test]
    fn eight_threads_beats_one_on_skewed_large_batch() {
        // Table III regime: skew within warps hurts 1 thread/pt
        let mut rng = Rng::new(1);
        let w = skewed_work(&mut rng, 20_000);
        let m = DeviceModel::default();
        let t1 = m.estimate(&w, ThreadAssign::Static(1)).seconds;
        let t8 = m.estimate(&w, ThreadAssign::Static(8)).seconds;
        assert!(t8 < t1, "t1={t1} t8={t8}");
    }

    #[test]
    fn too_many_threads_pays_launch_overhead() {
        // tiny per-query work: 32 threads/pt mostly idle + launch cost
        let w = vec![8u64; 50_000];
        let m = DeviceModel::default();
        let t8 = m.estimate(&w, ThreadAssign::Static(8)).seconds;
        let t32 = m.estimate(&w, ThreadAssign::Static(32)).seconds;
        assert!(t32 > t8, "t8={t8} t32={t32}");
    }

    #[test]
    fn undersaturation_hurts_single_thread_small_batch() {
        // few queries, heavy work: 1 thread/pt cannot fill the device
        let w = vec![100_000u64; 64];
        let m = DeviceModel::default();
        let t1 = m.estimate(&w, ThreadAssign::Static(1));
        let t32 = m.estimate(&w, ThreadAssign::Static(32));
        assert!(t32.seconds < t1.seconds);
        assert!(t1.warps < m.concurrent_warps as u64);
    }

    #[test]
    fn dynamic_straddling_penalised_vs_aligned_static() {
        // same thread budget; dynamic assignment lands 5 threads/query
        // (misaligned within 32-lane warps), static 8 is aligned
        let mut rng = Rng::new(2);
        let w = skewed_work(&mut rng, 10_000);
        let m = DeviceModel::default();
        let stat = m.estimate(&w, ThreadAssign::Static(8)).seconds;
        let dyn5 = m
            .estimate(&w, ThreadAssign::Dynamic(5 * w.len() as u64))
            .seconds;
        assert!(stat <= dyn5, "static8={stat} dynamic5x={dyn5}");
    }

    #[test]
    fn monotone_in_work() {
        let m = DeviceModel::default();
        let small = vec![100u64; 1000];
        let large = vec![1000u64; 1000];
        // (Dynamic with threads/query >> work is legitimately flat - each
        // lane does ceil(w/t)=1 candidate either way - so use a budget
        // below the per-query work.)
        for a in [ThreadAssign::Static(8), ThreadAssign::Dynamic(10_000)] {
            assert!(m.estimate(&small, a).seconds < m.estimate(&large, a).seconds);
        }
    }

    #[test]
    fn utilisation_bounded() {
        let mut rng = Rng::new(3);
        let w = skewed_work(&mut rng, 5000);
        let m = DeviceModel::default();
        for a in [
            ThreadAssign::Static(1),
            ThreadAssign::Static(8),
            ThreadAssign::Dynamic(100_000),
        ] {
            let e = m.estimate(&w, a);
            assert!(e.lane_utilisation > 0.0 && e.lane_utilisation <= 1.0);
        }
    }
}
