//! The GPU component of HYBRIDKNN-JOIN: the grid range-query join
//! (join), the brute-force tier (brute - both the standalone lower
//! bound and the tiled production path the claim router targets), and
//! the warp-level device model for the task-granularity study (device).

/// The brute-force tier: GPU-JOINLINEAR (Sec. VI-D) and the tiled,
/// pipelined corpus-scan path behind per-claim backend routing.
pub mod brute;
/// Analytic warp model for the thread-granularity study (Sec. V-G).
pub mod device;
/// GPU-JOIN over the ε-grid, with the pipelined queue drains.
pub mod join;

pub use brute::{brute_join_linear, brute_join_tiled, BruteOutcome};
pub use device::{DeviceEstimate, DeviceModel, ThreadAssign};
pub use join::{
    gpu_join, gpu_join_drain, gpu_join_rs, gpu_join_rs_into, DrainMode,
    GpuJoinOutcome, GpuJoinParams, GpuJoinStats,
};
