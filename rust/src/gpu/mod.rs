//! The GPU component of HYBRIDKNN-JOIN: the grid range-query join
//! (join), the brute-force lower bound (brute), and the warp-level
//! device model for the task-granularity study (device).

/// GPU-JOINLINEAR: the brute-force lower bound (Sec. VI-D).
pub mod brute;
/// Analytic warp model for the thread-granularity study (Sec. V-G).
pub mod device;
/// GPU-JOIN over the ε-grid, with the pipelined queue drains.
pub mod join;

pub use brute::{brute_join_linear, BruteOutcome};
pub use device::{DeviceEstimate, DeviceModel, ThreadAssign};
pub use join::{
    gpu_join, gpu_join_drain, gpu_join_rs, gpu_join_rs_into, DrainMode,
    GpuJoinOutcome, GpuJoinParams, GpuJoinStats,
};
