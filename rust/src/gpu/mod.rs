//! The GPU component of HYBRIDKNN-JOIN: the grid range-query join
//! (join), the brute-force lower bound (brute), and the warp-level
//! device model for the task-granularity study (device).

pub mod brute;
pub mod device;
pub mod join;

pub use brute::{brute_join_linear, BruteOutcome};
pub use device::{DeviceEstimate, DeviceModel, ThreadAssign};
pub use join::{
    gpu_join, gpu_join_drain, gpu_join_rs, gpu_join_rs_into, GpuJoinOutcome,
    GpuJoinParams, GpuJoinStats,
};
