//! The γ/ρ work-division *predicates* (paper Sec. V-D, V-F) and the
//! ρ^Model load-balancing estimate (Sec. VI-E2, Eq. 6).
//!
//! Since the density-ordered work queue landed (`sched`), these formulas
//! play a seeding role rather than a partitioning one: `n_thresh` marks
//! the queue's dense prefix (the GPU's first-batch seed and its
//! single-core cap), the ρ floor becomes the queue's tail reservation,
//! and `rho_model` runs *live* inside the GPU batch loop
//! (`sched::next_batch_work`) instead of only as post-hoc diagnosis.
//! `split_work` itself - the one-shot partition - survives as the
//! `Scheduler::StaticSplit` ablation baseline and as the reference
//! the queue's γ seeding is property-tested against.

use crate::core::Dataset;
use crate::index::GridIndex;
use crate::util::math::unit_ball_volume;

/// Eq. 1: lower bound on the cell population needed so that a point at the
/// cell centre probabilistically finds >= K neighbors within ε^β.
/// n^min = ((2ε^β)^m · K) / (V_ball(m, ε^β)); the ε^β factors cancel,
/// leaving K · 2^m / V_unit_ball(m). `m` is the *indexed* dimensionality
/// (the paper substitutes m for n when m < n dims are indexed).
pub fn n_min(k: usize, m: usize) -> f64 {
    let m = m.max(1);
    k as f64 * 2f64.powi(m as i32) / unit_ball_volume(m)
}

/// n^thresh = n^min + (10·n^min − n^min)·γ = n^min (1 + 9γ).
pub fn n_thresh(k: usize, m: usize, gamma: f64) -> f64 {
    n_min(k, m) * (1.0 + 9.0 * gamma)
}

/// The split of query points between architectures.
#[derive(Debug, Clone, Default)]
pub struct WorkSplit {
    /// Q^GPU - queries in cells meeting the γ threshold
    pub q_gpu: Vec<u32>,
    /// Q^CPU - everything else, plus the ρ floor's transfers
    pub q_cpu: Vec<u32>,
    /// the threshold used (diagnostics)
    pub threshold: f64,
    /// queries moved GPU->CPU by the ρ floor (diagnostics)
    pub rho_moved: usize,
}

/// Assign every point to GPU iff its grid cell holds >= n^thresh points
/// (Sec. V-D), then enforce the ρ floor |Q^CPU| >= ρ|D| by draining the
/// *sparsest* GPU cells first (Sec. V-F).
///
/// `native_ids` marks the self-join case where the points of `d` are the
/// points the grid indexes: the per-point density probe is then an O(1)
/// read off the grid's point→cell-rank map. Bipartite callers (R queries
/// against the S grid) pass `false` and pay one coordinate linearisation
/// plus one binary search per point - still allocation-free.
pub fn split_work(
    d: &Dataset,
    grid: &GridIndex,
    k: usize,
    gamma: f64,
    rho: f64,
    native_ids: bool,
) -> WorkSplit {
    let thresh = n_thresh(k, grid.m, gamma);
    let mut q_gpu = Vec::new();
    let mut q_cpu = Vec::new();
    // cell population per point via the grid (already built for the join)
    for i in 0..d.len() {
        let pop = if native_ids {
            grid.cell_population_of_id(i as u32) as f64
        } else {
            grid.cell_population(d.point(i)) as f64
        };
        if pop >= thresh {
            q_gpu.push(i as u32);
        } else {
            q_cpu.push(i as u32);
        }
    }

    // ρ floor: move whole cells, sparsest first (their queries have the
    // least GPU-side work, so they are the cheapest to reassign).
    let floor = (rho * d.len() as f64).ceil() as usize;
    let mut moved = 0usize;
    if q_cpu.len() < floor && !q_gpu.is_empty() {
        // group GPU queries by cell
        let mut by_cell: std::collections::HashMap<u64, Vec<u32>> =
            std::collections::HashMap::new();
        for &q in &q_gpu {
            by_cell
                .entry(grid.query_cell_id(native_ids, d, q))
                .or_default()
                .push(q);
        }
        let mut cells: Vec<(usize, u64)> = by_cell
            .iter()
            .map(|(&id, v)| (v.len(), id))
            .collect();
        cells.sort_unstable();
        // drain per query, sparsest cells first, stopping exactly at the
        // floor (a dense cell may be drained partially - the paper moves
        // "those found within cells with the least number of points", not
        // whole cells)
        let mut need = floor - q_cpu.len();
        let mut demote: std::collections::HashSet<u32> =
            std::collections::HashSet::new();
        'outer: for (_, id) in cells {
            for &q in by_cell[&id].iter() {
                if need == 0 {
                    break 'outer;
                }
                demote.insert(q);
                need -= 1;
            }
        }
        if !demote.is_empty() {
            let (stay, go): (Vec<u32>, Vec<u32>) =
                q_gpu.into_iter().partition(|q| !demote.contains(q));
            moved = go.len();
            q_cpu.extend(go);
            q_cpu.sort_unstable();
            q_gpu = stay;
        }
    }

    WorkSplit { q_gpu, q_cpu, threshold: thresh, rho_moved: moved }
}

/// Eq. 6: ρ^Model = T2 / (T1 + T2), where T1/T2 are the measured average
/// per-query times of EXACT-ANN and GPU-JOIN under an arbitrary split.
pub fn rho_model(t1: f64, t2: f64) -> f64 {
    if t1 + t2 <= 0.0 {
        return 0.5;
    }
    t2 / (t1 + t2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::susy_like;
    use crate::util::prop;

    #[test]
    fn n_min_known_values() {
        // m=2: K * 4 / π
        assert!((n_min(1, 2) - 4.0 / std::f64::consts::PI).abs() < 1e-12);
        // m=3: K * 8 / (4π/3) = 6K/π
        assert!((n_min(5, 3) - 5.0 * 6.0 / std::f64::consts::PI).abs() < 1e-9);
        // cube/sphere ratio grows rapidly with m
        assert!(n_min(1, 6) > n_min(1, 3));
        assert!(n_min(1, 10) > 100.0);
    }

    #[test]
    fn n_thresh_interpolates_to_10x() {
        let k = 4;
        let m = 3;
        assert!((n_thresh(k, m, 0.0) - n_min(k, m)).abs() < 1e-12);
        assert!((n_thresh(k, m, 1.0) - 10.0 * n_min(k, m)).abs() < 1e-9);
    }

    #[test]
    fn split_partitions_dataset() {
        let d = susy_like(2000).generate(1);
        let grid = GridIndex::build(&d, 6, 2.0);
        let s = split_work(&d, &grid, 5, 0.0, 0.0, true);
        assert_eq!(s.q_gpu.len() + s.q_cpu.len(), d.len());
        let mut all: Vec<u32> = s.q_gpu.iter().chain(&s.q_cpu).cloned().collect();
        all.sort_unstable();
        assert_eq!(all, (0..d.len() as u32).collect::<Vec<_>>());
    }

    #[test]
    fn gamma_monotone_shrinks_gpu_side() {
        let d = susy_like(3000).generate(2);
        let grid = GridIndex::build(&d, 6, 2.5);
        let mut last = usize::MAX;
        for gamma in [0.0, 0.4, 0.8, 1.0] {
            let s = split_work(&d, &grid, 5, gamma, 0.0, true);
            assert!(s.q_gpu.len() <= last, "gamma must shrink |Q_gpu|");
            last = s.q_gpu.len();
        }
    }

    #[test]
    fn gpu_cells_denser_than_cpu_cells() {
        let d = susy_like(3000).generate(3);
        let grid = GridIndex::build(&d, 6, 2.5);
        let s = split_work(&d, &grid, 5, 0.2, 0.0, true);
        if s.q_gpu.is_empty() || s.q_cpu.is_empty() {
            return; // degenerate split - nothing to compare
        }
        let mean_pop = |qs: &[u32]| -> f64 {
            qs.iter()
                .map(|&q| grid.cell_population(d.point(q as usize)) as f64)
                .sum::<f64>()
                / qs.len() as f64
        };
        assert!(mean_pop(&s.q_gpu) > mean_pop(&s.q_cpu));
        // threshold is respected exactly
        for &q in &s.q_gpu {
            assert!(grid.cell_population(d.point(q as usize)) as f64 >= s.threshold);
        }
    }

    #[test]
    fn rho_floor_enforced_with_sparsest_cells_first() {
        prop::cases(10, 0x5137, |rng| {
            let n = 1000 + rng.below(2000);
            let d = susy_like(n).generate(rng.next_u64());
            let grid = GridIndex::build(&d, 6, 2.0 + rng.f64() * 2.0);
            let rho = rng.f64();
            let s = split_work(&d, &grid, 5, 0.0, rho, true);
            let floor = (rho * d.len() as f64).ceil() as usize;
            // floor met unless the GPU side was exhausted entirely
            assert!(
                s.q_cpu.len() >= floor || s.q_gpu.is_empty(),
                "cpu={} floor={floor} gpu={}",
                s.q_cpu.len(),
                s.q_gpu.len()
            );
            // remaining GPU cells are at least as dense as any demoted cell
            if s.rho_moved > 0 && !s.q_gpu.is_empty() {
                let min_gpu_pop = s
                    .q_gpu
                    .iter()
                    .map(|&q| grid.cell_population(d.point(q as usize)))
                    .min()
                    .unwrap();
                // every remaining GPU query sits in a cell >= threshold
                assert!(min_gpu_pop as f64 >= s.threshold);
            }
        });
    }

    #[test]
    fn rho_one_forces_pure_cpu() {
        let d = susy_like(800).generate(5);
        let grid = GridIndex::build(&d, 6, 2.0);
        let s = split_work(&d, &grid, 5, 0.0, 1.0, true);
        assert!(s.q_gpu.is_empty());
        assert_eq!(s.q_cpu.len(), d.len());
    }

    #[test]
    fn native_and_coordinate_keyed_splits_agree() {
        // self-join: the O(1) id-keyed density probe must reproduce the
        // coordinate-keyed split exactly, ρ drain included
        prop::cases(8, 0x5A11, |rng| {
            let d = susy_like(800 + rng.below(1200)).generate(rng.next_u64());
            let grid = GridIndex::build(&d, 6, 1.5 + rng.f64() * 2.0);
            let (gamma, rho) = (rng.f64(), rng.f64() * 0.8);
            let a = split_work(&d, &grid, 5, gamma, rho, true);
            let b = split_work(&d, &grid, 5, gamma, rho, false);
            assert_eq!(a.q_gpu, b.q_gpu);
            assert_eq!(a.q_cpu, b.q_cpu);
            assert_eq!(a.rho_moved, b.rho_moved);
        });
    }

    #[test]
    fn rho_model_eq6() {
        assert!((rho_model(1.0, 1.0) - 0.5).abs() < 1e-12);
        assert!((rho_model(1.0, 3.0) - 0.75).abs() < 1e-12);
        // slower GPU per query -> larger CPU share
        assert!(rho_model(1e-5, 5e-5) > rho_model(1e-5, 1e-5));
        assert_eq!(rho_model(0.0, 0.0), 0.5);
    }
}
