//! # hybrid-knn-join
//!
//! Production-quality reproduction of Gowanlock (2018), "KNN Joins Using a
//! Hybrid Approach: Exploiting CPU/GPU Workload Characteristics", as a
//! three-layer rust + JAX/Pallas stack (see DESIGN.md):
//!
//! * L3 (this crate): the paper's coordination contribution - empirical
//!   ε selection, the β/γ/ρ work splitter, the grid-join "GPU" engine with
//!   batching + streams, the EXACT-ANN kd-tree CPU ranks, Q^Fail
//!   reassignment and ρ^Model load balancing.
//! * L2/L1 (python/compile): JAX graphs + Pallas kernels AOT-lowered to
//!   HLO text artifacts, executed at runtime through PJRT (runtime::Engine).

// Docs are part of the public surface: every public item must say what
// it is. CI builds `cargo doc --no-deps` with RUSTDOCFLAGS="-D warnings",
// which promotes this lint (and broken intra-doc links) to errors.
#![warn(missing_docs)]

/// Downstream applications of the join (DBSCAN, k-dist, KNN graphs).
pub mod apps;
/// Paper-artifact experiment runners (one per table / figure).
pub mod bench;
/// Core data types: datasets, the SoA result table, bounded heaps.
pub mod core;
/// EXACT-ANN: rank-parallel exact KNN over the kd-tree (Sec. V-B).
pub mod cpu;
/// Dataset surrogates, I/O and the variance reorder (Sec. IV-D).
pub mod data;
/// Empirical ε selection on the device (Sec. V-C).
pub mod epsilon;
/// Deterministic fault injection and the GPU master's recovery policy.
pub mod fault;
/// The GPU component: grid join, brute-force bound, device model.
pub mod gpu;
/// HYBRIDKNN-JOIN - Algorithm 1 end to end.
pub mod hybrid;
/// Spatial indexes: the ε-grid and the kd-tree.
pub mod index;
/// PJRT runtime executing the AOT-compiled HLO artifacts.
pub mod runtime;
/// The density-ordered shared work queue and its claim policies.
pub mod sched;
/// γ/ρ split predicates and the Eq. 6 ρ^Model (static split).
pub mod split;
/// Shared utilities: thread pools, RNG, JSON, timers, CLI, property tests.
pub mod util;

/// Convenience re-exports for examples and benches.
pub mod prelude {
    pub use crate::core::{Dataset, KnnResult, Neighbor, Neighbors, SoaSlots};
    pub use crate::cpu::{
        exact_ann, exact_ann_rs, exact_ann_rs_into, ref_impl, CpuKnnOutcome,
        CpuKnnStats,
    };
    pub use crate::data::synthetic::{
        by_name, chist_like, fma_like, songs_like, susy_like, DatasetSpec,
    };
    pub use crate::epsilon::{EpsilonSelection, EpsilonSelector};
    pub use crate::fault::{
        FaultAction, FaultEvent, FaultKind, FaultLog, FaultPlan, FaultSpec,
        InjectedFault, RecoveryPolicy, WatchdogTimeout,
    };
    pub use crate::gpu::{
        brute_join_linear, brute_join_tiled, gpu_join, join::gpu_join_rs,
        DrainMode, GpuJoinParams, ThreadAssign,
    };
    pub use crate::hybrid::admission::{
        AdmissionPolicy, AdmissionStats, CapacityController, ClientQuota,
        Rejected, ShedPolicy, TokenBucket,
    };
    pub use crate::hybrid::service::{
        percentile, BatchReply, Client, FlushReport, Ingress, KnnEngine,
        QueryResult, ServiceReport,
    };
    pub use crate::hybrid::{HybridKnnJoin, HybridParams, HybridReport, Scheduler};
    pub use crate::index::{GridIndex, KdTree, KnnScratch};
    pub use crate::runtime::{tiles::TileClass, Engine};
    pub use crate::sched::{build_queue, Arch, BackendMode, ClaimRecord, WorkQueue};
    pub use crate::split::{rho_model, split_work};
}
