//! # hybrid-knn-join
//!
//! Production-quality reproduction of Gowanlock (2018), "KNN Joins Using a
//! Hybrid Approach: Exploiting CPU/GPU Workload Characteristics", as a
//! three-layer rust + JAX/Pallas stack (see DESIGN.md):
//!
//! * L3 (this crate): the paper's coordination contribution - empirical
//!   ε selection, the β/γ/ρ work splitter, the grid-join "GPU" engine with
//!   batching + streams, the EXACT-ANN kd-tree CPU ranks, Q^Fail
//!   reassignment and ρ^Model load balancing.
//! * L2/L1 (python/compile): JAX graphs + Pallas kernels AOT-lowered to
//!   HLO text artifacts, executed at runtime through PJRT (runtime::Engine).

pub mod apps;
pub mod bench;
pub mod core;
pub mod cpu;
pub mod data;
pub mod epsilon;
pub mod gpu;
pub mod hybrid;
pub mod index;
pub mod runtime;
pub mod sched;
pub mod split;
pub mod util;

/// Convenience re-exports for examples and benches.
pub mod prelude {
    pub use crate::core::{Dataset, KnnResult, Neighbor, Neighbors, SoaSlots};
    pub use crate::cpu::{
        exact_ann, exact_ann_rs, exact_ann_rs_into, ref_impl, CpuKnnOutcome,
        CpuKnnStats,
    };
    pub use crate::data::synthetic::{
        by_name, chist_like, fma_like, songs_like, susy_like, DatasetSpec,
    };
    pub use crate::epsilon::{EpsilonSelection, EpsilonSelector};
    pub use crate::gpu::{
        brute_join_linear, gpu_join, join::gpu_join_rs, GpuJoinParams, ThreadAssign,
    };
    pub use crate::hybrid::{HybridKnnJoin, HybridParams, HybridReport, Scheduler};
    pub use crate::index::{GridIndex, KdTree, KnnScratch};
    pub use crate::runtime::{tiles::TileClass, Engine};
    pub use crate::sched::{build_queue, Arch, ClaimRecord, WorkQueue};
    pub use crate::split::{rho_model, split_work};
}
