//! Minimal CLI argument parser (the vendor set has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Typed getters parse on demand and report friendly errors.

use std::collections::HashMap;

/// Parsed command line: flags/options by name plus positionals in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: HashMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Is the boolean flag `--name` present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw value of `--name=value` / `--name value`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// String option with a default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// `usize` option with a default (panics on malformed input).
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.parse_or(name, default)
    }

    /// `u64` option with a default (panics on malformed input).
    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.parse_or(name, default)
    }

    /// `f64` option with a default (panics on malformed input).
    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.parse_or(name, default)
    }

    fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            None => default,
            Some(s) => s.parse().unwrap_or_else(|_| {
                panic!("--{name}: cannot parse {s:?}");
            }),
        }
    }

    /// All positional (non-flag) arguments, in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional (subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse("run --k 5 --beta=0.5 --dataset susy");
        assert_eq!(a.subcommand(), Some("run"));
        assert_eq!(a.usize_or("k", 0), 5);
        assert_eq!(a.f64_or("beta", 0.0), 0.5);
        assert_eq!(a.str_or("dataset", ""), "susy");
    }

    #[test]
    fn flags_and_defaults() {
        let a = parse("--verbose --k 3");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn trailing_flag_not_eating_next_option() {
        let a = parse("--verbose --k 3");
        assert_eq!(a.usize_or("k", 0), 3);
    }

    #[test]
    fn positionals_preserved() {
        let a = parse("bench fig11 --trials 3");
        assert_eq!(a.positional(), &["bench".to_string(), "fig11".to_string()]);
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn bad_parse_panics() {
        let a = parse("--k notanumber");
        a.usize_or("k", 0);
    }
}
