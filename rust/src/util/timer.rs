//! Phase timing + the micro-bench loop used by `benches/` (no criterion
//! offline). Reports min/median/mean over trials after warmup.

use std::time::Instant;

/// Accumulates named phase durations (seconds).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    phases: Vec<(String, f64)>,
}

impl PhaseTimer {
    /// New empty timer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and record it under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.phases.push((name.to_string(), t0.elapsed().as_secs_f64()));
        out
    }

    /// Record an externally measured duration under `name`.
    pub fn add(&mut self, name: &str, secs: f64) {
        self.phases.push((name.to_string(), secs));
    }

    /// Total seconds recorded under `name` (0.0 if absent).
    pub fn get(&self, name: &str) -> f64 {
        self.phases
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, s)| s)
            .sum()
    }

    /// Sum over all recorded phases.
    pub fn total(&self) -> f64 {
        self.phases.iter().map(|(_, s)| s).sum()
    }

    /// The recorded (name, seconds) pairs, in recording order.
    pub fn phases(&self) -> &[(String, f64)] {
        &self.phases
    }

    /// Aligned text report of all phases plus the total.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for (n, secs) in &self.phases {
            s.push_str(&format!("  {n:<28} {secs:>10.4}s\n"));
        }
        s.push_str(&format!("  {:<28} {:>10.4}s\n", "TOTAL", self.total()));
        s
    }
}

/// Result of a micro-bench run.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// per-trial wall seconds (warmup excluded)
    pub trials: Vec<f64>,
}

impl BenchStats {
    /// Fastest trial.
    pub fn min(&self) -> f64 {
        self.trials.iter().cloned().fold(f64::INFINITY, f64::min)
    }
    /// Mean trial time.
    pub fn mean(&self) -> f64 {
        crate::util::math::mean(&self.trials)
    }
    /// Median trial time.
    pub fn median(&self) -> f64 {
        crate::util::math::median(&self.trials)
    }
}

/// Run `f` `warmup + trials` times, timing the trials.
pub fn bench<T>(warmup: usize, trials: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut out = Vec::with_capacity(trials);
    for _ in 0..trials {
        let t0 = Instant::now();
        std::hint::black_box(f());
        out.push(t0.elapsed().as_secs_f64());
    }
    BenchStats { trials: out }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_timer_accumulates() {
        let mut t = PhaseTimer::new();
        let x = t.time("a", || 41 + 1);
        assert_eq!(x, 42);
        t.add("a", 1.0);
        t.add("b", 0.5);
        assert!(t.get("a") >= 1.0);
        assert!((t.total() - t.get("a") - t.get("b")).abs() < 1e-9);
        assert!(t.report().contains("TOTAL"));
    }

    #[test]
    fn bench_counts_trials() {
        let stats = bench(1, 5, || 1 + 1);
        assert_eq!(stats.trials.len(), 5);
        assert!(stats.min() <= stats.median());
        assert!(stats.mean() >= 0.0);
    }
}
