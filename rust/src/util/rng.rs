//! Deterministic PRNG + distributions.
//!
//! The offline vendor set has no `rand` crate, so we carry our own
//! xoshiro256++ (Blackman & Vigna) with SplitMix64 seeding, plus the
//! distribution helpers the synthetic data generators need (uniform ranges,
//! Box–Muller gaussians, index sampling, Fisher–Yates shuffles).

/// xoshiro256++ PRNG. Deterministic for a given seed; not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second gaussian from Box–Muller
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed all 256 bits of state through SplitMix64 (never all-zero).
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        let s = [
            splitmix64(&mut x),
            splitmix64(&mut x),
            splitmix64(&mut x),
            splitmix64(&mut x),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for per-thread / per-cluster use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// Next raw 64-bit value (xoshiro256++ step).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean / standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.gaussian()
    }

    /// Exponential with rate lambda.
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.below(n - i);
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        } else {
            // sparse rejection sampling
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.below(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut hit = [false; 7];
        for _ in 0..1000 {
            hit[r.below(7)] = true;
        }
        assert!(hit.iter().all(|&h| h));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(8);
        for (n, k) in [(10, 10), (100, 5), (1000, 999), (1, 1), (50, 0)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k.min(n));
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), s.len(), "distinct");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..64).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(10);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
