//! Infrastructure substrates built in-repo (the offline vendor set lacks
//! rand/clap/serde_json/rayon/proptest/criterion): PRNG + distributions,
//! numeric helpers, CLI parsing, JSON, rank-parallel helpers, a property
//! test harness, and phase/bench timers.

pub mod cli;
pub mod json;
pub mod math;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod timer;
