//! Infrastructure substrates built in-repo (the offline vendor set lacks
//! rand/clap/serde_json/rayon/proptest/criterion): PRNG + distributions,
//! numeric helpers, CLI parsing, JSON, rank-parallel helpers, a property
//! test harness, and phase/bench timers.

/// Dependency-free CLI argument parsing.
pub mod cli;
/// Tiny JSON reader/writer (no serde in the vendor set).
pub mod json;
/// Small numeric helpers.
pub mod math;
/// Thread pools, chunk cursors, the two-ended claim cursor, and the
/// lane-ordered stage pool behind the GPU pipelines.
pub mod pool;
/// Seeded property-test harness.
pub mod prop;
/// Deterministic xorshift RNG.
pub mod rng;
/// Phase timers and trial statistics.
pub mod timer;
