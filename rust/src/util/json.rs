//! Tiny JSON: a writer for report emission and a reader sufficient for the
//! artifact manifest (the vendor set has no `serde_json`).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. BTreeMap keeps key order deterministic in output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any number (stored as f64)
    Num(f64),
    /// a string
    Str(String),
    /// an array
    Arr(Vec<Json>),
    /// an object (sorted keys)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Object member lookup (None on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (recursive descent; enough for manifest.json).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(v)
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && (b[*pos] as char).is_ascii_whitespace() {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected {:?} at byte {}", c as char, pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end".into());
    }
    match b[*pos] {
        b'{' => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let k = match parse_value(b, pos)? {
                    Json::Str(s) => s,
                    _ => return Err("object key must be string".into()),
                };
                skip_ws(b, pos);
                expect(b, pos, b':')?;
                let v = parse_value(b, pos)?;
                m.insert(k, v);
                skip_ws(b, pos);
                if *pos < b.len() && b[*pos] == b',' {
                    *pos += 1;
                } else {
                    expect(b, pos, b'}')?;
                    return Ok(Json::Obj(m));
                }
            }
        }
        b'[' => {
            *pos += 1;
            let mut v = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(v));
            }
            loop {
                v.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                if *pos < b.len() && b[*pos] == b',' {
                    *pos += 1;
                } else {
                    expect(b, pos, b']')?;
                    return Ok(Json::Arr(v));
                }
            }
        }
        b'"' => {
            *pos += 1;
            let mut s = String::new();
            while *pos < b.len() {
                match b[*pos] {
                    b'"' => {
                        *pos += 1;
                        return Ok(Json::Str(s));
                    }
                    b'\\' => {
                        *pos += 1;
                        if *pos >= b.len() {
                            return Err("bad escape".into());
                        }
                        match b[*pos] {
                            b'"' => s.push('"'),
                            b'\\' => s.push('\\'),
                            b'/' => s.push('/'),
                            b'n' => s.push('\n'),
                            b't' => s.push('\t'),
                            b'r' => s.push('\r'),
                            b'b' => s.push('\u{8}'),
                            b'f' => s.push('\u{c}'),
                            b'u' => {
                                if *pos + 4 >= b.len() {
                                    return Err("bad \\u".into());
                                }
                                let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                                    .map_err(|_| "bad \\u")?;
                                let cp =
                                    u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                                s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                                *pos += 4;
                            }
                            c => return Err(format!("bad escape \\{}", c as char)),
                        }
                        *pos += 1;
                    }
                    _ => {
                        // consume one UTF-8 char
                        let rest = std::str::from_utf8(&b[*pos..])
                            .map_err(|_| "invalid utf8".to_string())?;
                        let c = rest.chars().next().unwrap();
                        s.push(c);
                        *pos += c.len_utf8();
                    }
                }
            }
            Err("unterminated string".into())
        }
        b't' => {
            if b[*pos..].starts_with(b"true") {
                *pos += 4;
                Ok(Json::Bool(true))
            } else {
                Err("bad literal".into())
            }
        }
        b'f' => {
            if b[*pos..].starts_with(b"false") {
                *pos += 5;
                Ok(Json::Bool(false))
            } else {
                Err("bad literal".into())
            }
        }
        b'n' => {
            if b[*pos..].starts_with(b"null") {
                *pos += 4;
                Ok(Json::Null)
            } else {
                Err("bad literal".into())
            }
        }
        _ => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let s = std::str::from_utf8(&b[start..*pos]).map_err(|_| "bad num")?;
            s.parse::<f64>()
                .map(Json::Num)
                .map_err(|_| format!("bad number {s:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let j = Json::obj(vec![
            ("a", Json::Num(1.0)),
            ("b", Json::Arr(vec![Json::Num(2.5), Json::Str("x\"y".into())])),
            ("c", Json::Bool(true)),
            ("d", Json::Null),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
          "format": "hlo-text",
          "artifacts": [
            {"name": "dist_q128_c512_d24", "file": "dist_q128_c512_d24.hlo.txt",
             "kind": "dist", "params": {"qt": 128, "ct": 512, "d": 24},
             "out_shapes": [[128, 512]]}
          ]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("format").unwrap().as_str(), Some("hlo-text"));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(
            arts[0].get("params").unwrap().get("qt").unwrap().as_usize(),
            Some(128)
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}x").is_err());
    }

    #[test]
    fn numbers_int_vs_float_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""aéb""#).unwrap();
        assert_eq!(j.as_str(), Some("aéb"));
    }
}
