//! Small numeric helpers: ln-gamma (Lanczos) for the Eq. 1 n-sphere volume
//! ratio, and summary statistics used by the bench harness.

/// ln Γ(x) for x > 0 via the Lanczos approximation (g = 7, n = 9).
/// Max relative error ~1e-13 over the domain we use (x in [1, 300]).
pub fn ln_gamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection: Γ(x)Γ(1-x) = π / sin(πx)
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + G + 0.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Volume of the unit n-ball: π^{n/2} / Γ(n/2 + 1).
pub fn unit_ball_volume(n: usize) -> f64 {
    let half_n = n as f64 / 2.0;
    (half_n * std::f64::consts::PI.ln() - ln_gamma(half_n + 1.0)).exp()
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_integers_match_factorials() {
        // Γ(n) = (n-1)!
        let mut fact = 1.0f64;
        for n in 1..15u32 {
            if n > 1 {
                fact *= (n - 1) as f64;
            }
            let got = ln_gamma(n as f64);
            assert!(
                (got - fact.ln()).abs() < 1e-10,
                "n={n} got={got} want={}",
                fact.ln()
            );
        }
    }

    #[test]
    fn ln_gamma_half() {
        // Γ(1/2) = sqrt(π)
        let want = std::f64::consts::PI.sqrt().ln();
        assert!((ln_gamma(0.5) - want).abs() < 1e-12);
    }

    #[test]
    fn unit_ball_volumes_known() {
        // V1=2, V2=π, V3=4π/3
        assert!((unit_ball_volume(1) - 2.0).abs() < 1e-12);
        assert!((unit_ball_volume(2) - std::f64::consts::PI).abs() < 1e-12);
        assert!((unit_ball_volume(3) - 4.0 * std::f64::consts::PI / 3.0).abs() < 1e-12);
        // high-d volume collapses toward 0
        assert!(unit_ball_volume(100) < 1e-39);
    }

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
    }
}
