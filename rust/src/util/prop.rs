//! Miniature property-testing harness (the vendor set has no `proptest`).
//!
//! `cases(n, seed, |rng| ...)` runs a closure over `n` independently seeded
//! RNG streams; on failure it reports the failing case seed so the case can
//! be replayed deterministically with `replay(seed, ...)`. No shrinking -
//! generators in this repo draw small sizes so raw failures stay readable.

use super::rng::Rng;

/// Run `n` property cases. The closure receives a fresh RNG per case and
/// should panic (assert) on violation. Prints the case seed on panic.
pub fn cases<F: Fn(&mut Rng)>(n: usize, seed: u64, f: F) {
    for case in 0..n {
        let case_seed = seed
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!("property failed at case {case} (replay seed {case_seed})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Replay one failing case by its reported seed.
pub fn replay<F: Fn(&mut Rng)>(case_seed: u64, f: F) {
    let mut rng = Rng::new(case_seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_runs_all_cases() {
        let mut count = 0;
        // deliberately use interior mutability via Cell - closure is Fn
        let counter = std::cell::Cell::new(0);
        cases(25, 42, |_rng| {
            counter.set(counter.get() + 1);
        });
        count += counter.get();
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic]
    fn failure_propagates() {
        cases(10, 7, |rng| {
            assert!(rng.f64() < 0.9, "eventually draws above 0.9");
        });
    }

    #[test]
    fn case_rngs_differ() {
        let seen = std::cell::RefCell::new(std::collections::HashSet::new());
        cases(10, 9, |rng| {
            assert!(seen.borrow_mut().insert(rng.next_u64()));
        });
    }
}
