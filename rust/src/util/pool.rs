//! Rank-parallel execution helpers (no rayon/tokio in the vendor set).
//!
//! The paper's host-side parallelism is MPI shared-nothing ranks with
//! round-robin query assignment; here a "rank" is an OS thread. `run_ranks`
//! spawns |p| scoped threads and returns each rank's result, which is all
//! EXACT-ANN / REFIMPL need.

/// Run `ranks` workers; worker `k` receives its rank id. Results are
/// returned in rank order. Panics propagate.
pub fn run_ranks<T, F>(ranks: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(ranks > 0);
    if ranks == 1 {
        return vec![f(0)];
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..ranks)
            .map(|k| {
                let f = &f;
                scope.spawn(move || f(k))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("rank panicked"))
            .collect()
    })
}

/// Chunked parallel map over indices [0, n): each worker pulls the next
/// chunk from a shared atomic cursor (simple work stealing).
pub fn parallel_chunks<F>(n: usize, workers: usize, chunk: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};
    let cursor = AtomicUsize::new(0);
    let workers = workers.max(1);
    let chunk = chunk.max(1);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let cursor = &cursor;
            let f = &f;
            scope.spawn(move || loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                f(start..(start + chunk).min(n));
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn ranks_return_in_order() {
        let out = run_ranks(8, |k| k * 10);
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
    }

    #[test]
    fn single_rank_shortcut() {
        assert_eq!(run_ranks(1, |k| k + 1), vec![1]);
    }

    #[test]
    fn chunks_cover_everything_once() {
        let n = 10_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(n, 4, 97, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn chunks_empty_input() {
        parallel_chunks(0, 4, 8, |_| panic!("must not be called"));
    }
}
