//! Rank-parallel execution helpers (no rayon/tokio in the vendor set).
//!
//! The paper's host-side parallelism is MPI shared-nothing ranks; here a
//! "rank" is an OS thread. `run_ranks` spawns |p| scoped threads and
//! returns each rank's result. `parallel_chunks_stateful` is the dynamic
//! scheduler of the CPU query engine: workers pull fixed-size index
//! chunks off a shared atomic cursor (self-balancing under density skew,
//! unlike static round-robin) while carrying a per-worker state - the
//! reusable `KnnScratch` of EXACT-ANN lives there.
//!
//! `TwoEndedCursor` generalises the single cursor to *two ends* of one
//! index range: one claimant eats from the front, many eat from the back,
//! and the two fronts meet in the middle. This is the claim machinery of
//! the density-ordered work queue (`sched`): the GPU master claims large
//! batches off the dense head while CPU ranks chunk through the sparse
//! tail, so the CPU/GPU split is discovered at run time instead of
//! predicted up front.

use std::ops::Range;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Run `ranks` workers; worker `k` receives its rank id. Results are
/// returned in rank order. Panics propagate.
pub fn run_ranks<T, F>(ranks: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(ranks > 0);
    if ranks == 1 {
        return vec![f(0)];
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..ranks)
            .map(|k| {
                let f = &f;
                scope.spawn(move || f(k))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("rank panicked"))
            .collect()
    })
}

/// Dynamically scheduled chunked map over indices [0, n) with per-worker
/// state: worker `w` builds its state with `init(w)`, then repeatedly
/// claims the next `chunk`-sized index range from a shared atomic cursor
/// and runs `f(&mut state, range)` until the range space is exhausted;
/// `fini(state)` converts the state into the worker's result (e.g. its
/// busy time). Results are returned in worker-id order, one per worker,
/// even for workers that claimed no chunk.
///
/// State stays on its worker thread (no `Send` bound), which is what lets
/// scratch buffers be reused across chunks without synchronisation.
pub fn parallel_chunks_stateful<S, T, I, F, G>(
    n: usize,
    workers: usize,
    chunk: usize,
    init: I,
    f: F,
    fini: G,
) -> Vec<T>
where
    T: Send,
    I: Fn(usize) -> S + Sync,
    F: Fn(&mut S, Range<usize>) + Sync,
    G: Fn(S) -> T + Sync,
{
    let workers = workers.max(1);
    let chunk = chunk.max(1);
    if workers == 1 {
        let mut state = init(0);
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            f(&mut state, start..end);
            start = end;
        }
        return vec![fini(state)];
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let (cursor, init, f, fini) = (&cursor, &init, &f, &fini);
                scope.spawn(move || {
                    let mut state = init(w);
                    loop {
                        let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        f(&mut state, start..(start + chunk).min(n));
                    }
                    fini(state)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    })
}

/// Chunked parallel map over indices [0, n): each worker pulls the next
/// chunk from a shared atomic cursor (simple work stealing). Stateless
/// form of `parallel_chunks_stateful`.
pub fn parallel_chunks<F>(n: usize, workers: usize, chunk: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    parallel_chunks_stateful(n, workers, chunk, |_| (), |(), r| f(r), |()| ());
}

/// Lock-free two-ended claim cursor over indices [0, n): front claims
/// grow a `head` cursor, back claims grow a `taken_back` count, and a
/// claim succeeds only when the two regions would stay disjoint - both
/// cursors live in one packed `AtomicU64`, so a single CAS decides every
/// claim and no interleaving can hand out an index twice. An optional
/// *back reserve* keeps the front out of the last `reserve` indices (the
/// ρ floor of the hybrid join: that tail belongs to the CPU no matter
/// what), while back claims may freely eat into front territory - that is
/// exactly how a mispredicted split self-corrects.
///
/// Indices must fit in u32 (the query-id width of the whole repo).
#[derive(Debug)]
pub struct TwoEndedCursor {
    /// (head << 32) | taken_back
    state: AtomicU64,
    n: usize,
    /// front claims never reach at or beyond this index
    front_limit: usize,
}

impl TwoEndedCursor {
    /// Cursor over [0, n) with the last `back_reserve` indices claimable
    /// only from the back.
    pub fn new(n: usize, back_reserve: usize) -> Self {
        assert!(n <= u32::MAX as usize, "range {n} exceeds u32 index space");
        TwoEndedCursor {
            state: AtomicU64::new(0),
            n,
            front_limit: n - back_reserve.min(n),
        }
    }

    #[inline]
    fn unpack(s: u64) -> (usize, usize) {
        ((s >> 32) as usize, (s & u32::MAX as u64) as usize)
    }

    #[inline]
    fn pack(head: usize, back: usize) -> u64 {
        ((head as u64) << 32) | back as u64
    }

    /// Claim from the front with a caller-chosen size: `f` receives the
    /// current head position and the indices available to the front
    /// (respecting the back reserve, `pos_cap`, and the advancing back
    /// region) and returns how many to take (clamped; 0 gives up).
    /// The closure may run several times under CAS contention.
    pub fn claim_front_with(
        &self,
        pos_cap: usize,
        f: impl Fn(usize, usize) -> usize,
    ) -> Option<Range<usize>> {
        loop {
            let s = self.state.load(Ordering::Acquire);
            let (head, back) = Self::unpack(s);
            let limit = self.front_limit.min(pos_cap).min(self.n - back);
            if head >= limit {
                return None;
            }
            let take = f(head, limit - head).min(limit - head);
            if take == 0 {
                return None;
            }
            let ns = Self::pack(head + take, back);
            if self
                .state
                .compare_exchange_weak(s, ns, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return Some(head..head + take);
            }
        }
    }

    /// Claim up to `max` indices from the front.
    pub fn claim_front(&self, max: usize) -> Option<Range<usize>> {
        self.claim_front_with(self.n, |_, avail| avail.min(max.max(1)))
    }

    /// Claim up to `chunk` indices from the back (the range closest to the
    /// end that is still unclaimed).
    pub fn claim_back(&self, chunk: usize) -> Option<Range<usize>> {
        let chunk = chunk.max(1);
        loop {
            let s = self.state.load(Ordering::Acquire);
            let (head, back) = Self::unpack(s);
            let avail = self.n - back - head;
            if avail == 0 {
                return None;
            }
            let take = chunk.min(avail);
            let ns = Self::pack(head, back + take);
            if self
                .state
                .compare_exchange_weak(s, ns, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                let end = self.n - back;
                return Some(end - take..end);
            }
        }
    }

    /// Total indices handed out from the front so far.
    pub fn claimed_front(&self) -> usize {
        Self::unpack(self.state.load(Ordering::Acquire)).0
    }

    /// Total indices handed out from the back so far.
    pub fn claimed_back(&self) -> usize {
        Self::unpack(self.state.load(Ordering::Acquire)).1
    }

    /// Unclaimed indices between the two fronts.
    pub fn remaining(&self) -> usize {
        let (head, back) = Self::unpack(self.state.load(Ordering::Acquire));
        self.n - back - head
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// First index the front may never reach (n - back reserve).
    pub fn front_limit(&self) -> usize {
        self.front_limit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_return_in_order() {
        let out = run_ranks(8, |k| k * 10);
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
    }

    #[test]
    fn single_rank_shortcut() {
        assert_eq!(run_ranks(1, |k| k + 1), vec![1]);
    }

    #[test]
    fn chunks_cover_everything_once() {
        let n = 10_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(n, 4, 97, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn chunks_empty_input() {
        parallel_chunks(0, 4, 8, |_| panic!("must not be called"));
    }

    #[test]
    fn stateful_states_partition_the_range() {
        let n = 5_000;
        let per_worker = parallel_chunks_stateful(
            n,
            4,
            64,
            |w| (w, 0usize),
            |state, range| state.1 += range.len(),
            |state| state,
        );
        assert_eq!(per_worker.len(), 4);
        assert_eq!(per_worker.iter().map(|s| s.0).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(per_worker.iter().map(|s| s.1).sum::<usize>(), n);
    }

    #[test]
    fn two_ended_claims_are_disjoint_and_exhaustive() {
        let c = TwoEndedCursor::new(100, 0);
        let f = c.claim_front(30).unwrap();
        assert_eq!(f, 0..30);
        let b = c.claim_back(25).unwrap();
        assert_eq!(b, 75..100);
        let f2 = c.claim_front(100).unwrap();
        assert_eq!(f2, 30..75, "front stops where the back begins");
        assert!(c.claim_front(1).is_none());
        assert!(c.claim_back(1).is_none());
        assert_eq!(c.claimed_front() + c.claimed_back(), 100);
        assert_eq!(c.remaining(), 0);
    }

    #[test]
    fn back_reserve_blocks_front_not_back() {
        let c = TwoEndedCursor::new(10, 4);
        assert_eq!(c.front_limit(), 6);
        let f = c.claim_front(100).unwrap();
        assert_eq!(f, 0..6, "front capped by the reserve");
        assert!(c.claim_front(1).is_none());
        // the back drains the reserve and nothing is lost
        let mut got = 0;
        while let Some(r) = c.claim_back(3) {
            got += r.len();
        }
        assert_eq!(got, 4);
        // full reserve: front gets nothing at all
        let c = TwoEndedCursor::new(5, 5);
        assert!(c.claim_front(1).is_none());
        assert_eq!(c.claim_back(10).unwrap(), 0..5);
    }

    #[test]
    fn front_with_sees_live_position_and_may_decline() {
        let c = TwoEndedCursor::new(50, 0);
        let r = c
            .claim_front_with(50, |head, avail| {
                assert_eq!(head, 0);
                assert_eq!(avail, 50);
                7
            })
            .unwrap();
        assert_eq!(r, 0..7);
        assert!(c.claim_front_with(50, |_, _| 0).is_none());
        // pos_cap bounds the front like a temporary limit
        let r = c.claim_front_with(10, |head, avail| {
            assert_eq!(head, 7);
            assert_eq!(avail, 3);
            avail
        });
        assert_eq!(r.unwrap(), 7..10);
        assert!(c.claim_front_with(10, |_, a| a).is_none());
    }

    #[test]
    fn two_ended_concurrent_partition_exactly_once() {
        let n = 20_000;
        let c = TwoEndedCursor::new(n, 1000);
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        std::thread::scope(|scope| {
            // one front claimant (the GPU-master pattern), variable sizes
            scope.spawn(|| {
                let mut sz = 1usize;
                while let Some(r) = c.claim_front(sz) {
                    for i in r {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                    sz = (sz * 2 + 1) % 700;
                }
            });
            // several back claimants (the CPU-rank pattern)
            for w in 0..4 {
                let (c, hits) = (&c, &hits);
                scope.spawn(move || {
                    while let Some(r) = c.claim_back(17 + w) {
                        for i in r {
                            hits[i].fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(c.claimed_front() + c.claimed_back(), n);
        assert!(c.claimed_back() >= 1000, "reserve honoured");
    }

    #[test]
    fn cursor_empty_range() {
        let c = TwoEndedCursor::new(0, 0);
        assert!(c.claim_front(4).is_none());
        assert!(c.claim_back(4).is_none());
        assert!(c.is_empty());
    }

    #[test]
    fn stateful_single_worker_and_tiny_inputs() {
        let out = parallel_chunks_stateful(
            3,
            1,
            100,
            |_| Vec::new(),
            |acc: &mut Vec<usize>, r| acc.extend(r),
            |acc| acc,
        );
        assert_eq!(out, vec![vec![0, 1, 2]]);
        // more workers than items: idle workers still report
        let out = parallel_chunks_stateful(
            2,
            6,
            1,
            |_| 0usize,
            |acc, r| *acc += r.len(),
            |acc| acc,
        );
        assert_eq!(out.len(), 6);
        assert_eq!(out.iter().sum::<usize>(), 2);
    }
}
