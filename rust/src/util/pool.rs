//! Rank-parallel execution helpers (no rayon/tokio in the vendor set).
//!
//! The paper's host-side parallelism is MPI shared-nothing ranks; here a
//! "rank" is an OS thread. `run_ranks` spawns |p| scoped threads and
//! returns each rank's result. `parallel_chunks_stateful` is the dynamic
//! scheduler of the CPU query engine: workers pull fixed-size index
//! chunks off a shared atomic cursor (self-balancing under density skew,
//! unlike static round-robin) while carrying a per-worker state - the
//! reusable `KnnScratch` of EXACT-ANN lives there.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Run `ranks` workers; worker `k` receives its rank id. Results are
/// returned in rank order. Panics propagate.
pub fn run_ranks<T, F>(ranks: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(ranks > 0);
    if ranks == 1 {
        return vec![f(0)];
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..ranks)
            .map(|k| {
                let f = &f;
                scope.spawn(move || f(k))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("rank panicked"))
            .collect()
    })
}

/// Dynamically scheduled chunked map over indices [0, n) with per-worker
/// state: worker `w` builds its state with `init(w)`, then repeatedly
/// claims the next `chunk`-sized index range from a shared atomic cursor
/// and runs `f(&mut state, range)` until the range space is exhausted;
/// `fini(state)` converts the state into the worker's result (e.g. its
/// busy time). Results are returned in worker-id order, one per worker,
/// even for workers that claimed no chunk.
///
/// State stays on its worker thread (no `Send` bound), which is what lets
/// scratch buffers be reused across chunks without synchronisation.
pub fn parallel_chunks_stateful<S, T, I, F, G>(
    n: usize,
    workers: usize,
    chunk: usize,
    init: I,
    f: F,
    fini: G,
) -> Vec<T>
where
    T: Send,
    I: Fn(usize) -> S + Sync,
    F: Fn(&mut S, Range<usize>) + Sync,
    G: Fn(S) -> T + Sync,
{
    let workers = workers.max(1);
    let chunk = chunk.max(1);
    if workers == 1 {
        let mut state = init(0);
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            f(&mut state, start..end);
            start = end;
        }
        return vec![fini(state)];
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let (cursor, init, f, fini) = (&cursor, &init, &f, &fini);
                scope.spawn(move || {
                    let mut state = init(w);
                    loop {
                        let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        f(&mut state, start..(start + chunk).min(n));
                    }
                    fini(state)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    })
}

/// Chunked parallel map over indices [0, n): each worker pulls the next
/// chunk from a shared atomic cursor (simple work stealing). Stateless
/// form of `parallel_chunks_stateful`.
pub fn parallel_chunks<F>(n: usize, workers: usize, chunk: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    parallel_chunks_stateful(n, workers, chunk, |_| (), |(), r| f(r), |()| ());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_return_in_order() {
        let out = run_ranks(8, |k| k * 10);
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
    }

    #[test]
    fn single_rank_shortcut() {
        assert_eq!(run_ranks(1, |k| k + 1), vec![1]);
    }

    #[test]
    fn chunks_cover_everything_once() {
        let n = 10_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(n, 4, 97, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn chunks_empty_input() {
        parallel_chunks(0, 4, 8, |_| panic!("must not be called"));
    }

    #[test]
    fn stateful_states_partition_the_range() {
        let n = 5_000;
        let per_worker = parallel_chunks_stateful(
            n,
            4,
            64,
            |w| (w, 0usize),
            |state, range| state.1 += range.len(),
            |state| state,
        );
        assert_eq!(per_worker.len(), 4);
        assert_eq!(per_worker.iter().map(|s| s.0).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(per_worker.iter().map(|s| s.1).sum::<usize>(), n);
    }

    #[test]
    fn stateful_single_worker_and_tiny_inputs() {
        let out = parallel_chunks_stateful(
            3,
            1,
            100,
            |_| Vec::new(),
            |acc: &mut Vec<usize>, r| acc.extend(r),
            |acc| acc,
        );
        assert_eq!(out, vec![vec![0, 1, 2]]);
        // more workers than items: idle workers still report
        let out = parallel_chunks_stateful(
            2,
            6,
            1,
            |_| 0usize,
            |acc, r| *acc += r.len(),
            |acc| acc,
        );
        assert_eq!(out.len(), 6);
        assert_eq!(out.iter().sum::<usize>(), 2);
    }
}
