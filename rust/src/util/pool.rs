//! Rank-parallel execution helpers (no rayon/tokio in the vendor set).
//!
//! The paper's host-side parallelism is MPI shared-nothing ranks; here a
//! "rank" is an OS thread. `run_ranks` spawns |p| scoped threads and
//! returns each rank's result. `parallel_chunks_stateful` is the dynamic
//! scheduler of the CPU query engine: workers pull fixed-size index
//! chunks off a shared atomic cursor (self-balancing under density skew,
//! unlike static round-robin) while carrying a per-worker state - the
//! reusable `KnnScratch` of EXACT-ANN lives there.
//!
//! `TwoEndedCursor` generalises the single cursor to *two ends* of one
//! index range: one claimant eats from the front, many eat from the back,
//! and the two fronts meet in the middle. This is the claim machinery of
//! the density-ordered work queue (`sched`): the GPU master claims large
//! batches off the dense head while CPU ranks chunk through the sparse
//! tail, so the CPU/GPU split is discovered at run time instead of
//! predicted up front.
//!
//! `stage_scope` is the pipeline variant of `parallel_chunks_stateful`:
//! instead of spawning workers per call, it keeps a *persistent* pool of
//! stateful workers alive next to a producing master thread. The master
//! submits bounded *rounds* of work (the staged flush sets of the
//! pipelined GPU drains) on **lanes**: rounds of one lane run strictly
//! in submission order, rounds of different lanes may overlap and retire
//! out of order. The GPU drains key lanes by claim - within a claim the
//! flush rounds stay ordered (split tiles revisit arena positions), while
//! rounds of different claims target disjoint staging arenas and are free
//! to interleave - the hand-off that lets device execution of claim i+1
//! overlap the device-to-host transfer of claim i and the host filtering
//! of claim i-1.

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Lock a mutex, recovering the guard from a poisoned lock. Poisoning
/// means some thread panicked while holding the guard - on the paths
/// that use this, the panic is *already* being surfaced through its own
/// channel (the pool's fail/recover machinery, a stage's error slot), so
/// propagating the poison would only bury the first failure under a
/// second opaque panic. Callers must tolerate the protected value being
/// mid-update; the drains only guard Option slots, which are always
/// structurally whole.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// [`Condvar::wait`] that recovers the guard from a poisoned lock, the
/// condvar companion of [`lock_unpoisoned`]: a resident engine parks in
/// these waits between flushes, and a panic elsewhere must surface
/// through the pool's failed flag / panic notes, not as a second opaque
/// poison panic out of a wait.
fn wait_unpoisoned<'a, T>(
    cv: &Condvar,
    g: std::sync::MutexGuard<'a, T>,
) -> std::sync::MutexGuard<'a, T> {
    match cv.wait(g) {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Run `ranks` workers; worker `k` receives its rank id. Results are
/// returned in rank order. Panics propagate.
pub fn run_ranks<T, F>(ranks: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(ranks > 0);
    if ranks == 1 {
        return vec![f(0)];
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..ranks)
            .map(|k| {
                let f = &f;
                scope.spawn(move || f(k))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("rank panicked"))
            .collect()
    })
}

/// Dynamically scheduled chunked map over indices [0, n) with per-worker
/// state: worker `w` builds its state with `init(w)`, then repeatedly
/// claims the next `chunk`-sized index range from a shared atomic cursor
/// and runs `f(&mut state, range)` until the range space is exhausted;
/// `fini(state)` converts the state into the worker's result (e.g. its
/// busy time). Results are returned in worker-id order, one per worker,
/// even for workers that claimed no chunk.
///
/// State stays on its worker thread (no `Send` bound), which is what lets
/// scratch buffers be reused across chunks without synchronisation.
pub fn parallel_chunks_stateful<S, T, I, F, G>(
    n: usize,
    workers: usize,
    chunk: usize,
    init: I,
    f: F,
    fini: G,
) -> Vec<T>
where
    T: Send,
    I: Fn(usize) -> S + Sync,
    F: Fn(&mut S, Range<usize>) + Sync,
    G: Fn(S) -> T + Sync,
{
    let workers = workers.max(1);
    let chunk = chunk.max(1);
    if workers == 1 {
        let mut state = init(0);
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            f(&mut state, start..end);
            start = end;
        }
        return vec![fini(state)];
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let (cursor, init, f, fini) = (&cursor, &init, &f, &fini);
                scope.spawn(move || {
                    let mut state = init(w);
                    loop {
                        let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        f(&mut state, start..(start + chunk).min(n));
                    }
                    fini(state)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    })
}

/// Chunked parallel map over indices [0, n): each worker pulls the next
/// chunk from a shared atomic cursor (simple work stealing). Stateless
/// form of `parallel_chunks_stateful`.
pub fn parallel_chunks<F>(n: usize, workers: usize, chunk: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    parallel_chunks_stateful(n, workers, chunk, |_| (), |(), r| f(r), |()| ());
}

/// One submitted round of a [`stage_scope`] pipeline: a job plus its item
/// count and claim bookkeeping. The job is boxed so its heap address
/// stays stable while the `VecDeque` grows and rounds move - workers hold
/// raw pointers into it between `take` and `finish`.
struct Round<J> {
    /// 1-based global submission ordinal (monotone across lanes)
    uid: usize,
    /// ordering lane: rounds of one lane run strictly in submission
    /// order; rounds of different lanes are mutually unordered
    lane: u64,
    job: Box<J>,
    len: usize,
    /// next item to hand out
    next: usize,
    /// items handed out but not yet finished
    active: usize,
    /// set when the first item is taken (round wall-time start)
    started: Option<Instant>,
}

struct StageQueue<J> {
    /// in submission (uid) order; retirement may remove from the middle,
    /// so the order is preserved but not contiguous
    rounds: VecDeque<Round<J>>,
    /// rounds submitted so far (== the last uid issued)
    submitted: usize,
    /// rounds fully processed so far (a count: with lanes, retirement is
    /// not a prefix of the submission order)
    retired: usize,
    closed: bool,
    /// a worker panicked: some round may never complete, so the blocking
    /// master entry points panic instead of waiting forever
    failed: bool,
    /// recoverable mode only ([`stage_scope_recoverable`]): panics caught
    /// in `process`, recorded as (lane, message) for the master to drain
    /// via [`StageHandle::take_lane_panic`] at its per-lane resolve point
    panics: Vec<(u64, String)>,
}

/// Hand-off between the master thread and the stage workers of a
/// [`stage_scope`] pipeline. The master `submit`s rounds on *lanes*
/// (blocking while `capacity` rounds are already in flight - the bounded
/// hand-off that keeps host memory inside the staging envelope) and waits
/// for their completion per lane (`wait_lane`) or globally (`wait`,
/// `drain`).
///
/// Ordering contract: rounds of **one lane** are processed strictly in
/// submission order - no item of a lane's round is handed out before the
/// lane's previous round retired - which is what lets a tile split across
/// rounds revisit the same arena positions safely. Rounds of **different
/// lanes** are mutually unordered: they may be processed concurrently and
/// retire out of submission order. The pipelined GPU drains key lanes by
/// claim, whose staging arenas are disjoint objects, so cross-lane
/// concurrency can never alias a filter-arena slot.
pub struct StageHandle<J> {
    shared: Mutex<StageQueue<J>>,
    /// master waits here (retirements free capacity / advance the waits)
    cv_space: Condvar,
    /// workers wait here (new rounds / a retirement unblocking a lane)
    cv_work: Condvar,
    capacity: usize,
}

impl<J: Send> StageHandle<J> {
    fn new(capacity: usize) -> Self {
        StageHandle {
            shared: Mutex::new(StageQueue {
                rounds: VecDeque::new(),
                submitted: 0,
                retired: 0,
                closed: false,
                failed: false,
                panics: Vec::new(),
            }),
            cv_space: Condvar::new(),
            cv_work: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Submit a round of `len` items on `lane`; blocks while `capacity`
    /// rounds are in flight (queued or processing, across all lanes).
    /// Returns the round's uid (1-based, monotone across lanes).
    pub fn submit(&self, job: J, len: usize, lane: u64) -> usize {
        let mut g = self.lock_recover();
        while g.rounds.len() >= self.capacity && !g.failed {
            g = wait_unpoisoned(&self.cv_space, g);
        }
        assert!(!g.failed, "stage pool failed: a worker panicked");
        g.submitted += 1;
        let uid = g.submitted;
        g.rounds.push_back(Round {
            uid,
            lane,
            job: Box::new(job),
            len,
            next: 0,
            active: 0,
            started: None,
        });
        drop(g);
        self.cv_work.notify_all();
        uid
    }

    /// Block until every round with a uid up to and including `uid` has
    /// retired (a global barrier over the submission prefix, regardless
    /// of lane).
    pub fn wait(&self, uid: usize) {
        let mut g = self.lock_recover();
        // the queue is in uid order, so "no round with uid <= target
        // remains" is exactly "the oldest remaining round is younger"
        while g.rounds.front().is_some_and(|r| r.uid <= uid) && !g.failed {
            g = wait_unpoisoned(&self.cv_space, g);
        }
        assert!(!g.failed, "stage pool failed: a worker panicked");
    }

    /// Block until `lane` has no submitted-but-unretired rounds. With
    /// per-lane FIFO processing this means everything submitted on the
    /// lane so far is fully done - the per-claim resolve barrier of the
    /// pipelined GPU drains.
    pub fn wait_lane(&self, lane: u64) {
        let mut g = self.lock_recover();
        while g.rounds.iter().any(|r| r.lane == lane) && !g.failed {
            g = wait_unpoisoned(&self.cv_space, g);
        }
        assert!(!g.failed, "stage pool failed: a worker panicked");
    }

    /// Block until every round submitted so far has retired.
    pub fn drain(&self) {
        let mut g = self.lock_recover();
        let target = g.submitted;
        while g.rounds.front().is_some_and(|r| r.uid <= target) && !g.failed {
            g = wait_unpoisoned(&self.cv_space, g);
        }
        assert!(!g.failed, "stage pool failed: a worker panicked");
    }

    /// Rounds submitted so far.
    pub fn submitted(&self) -> usize {
        self.lock_recover().submitted
    }

    /// Rounds fully processed so far.
    pub fn retired(&self) -> usize {
        self.lock_recover().retired
    }

    /// Lock, recovering from poisoning. Every entry point of the handle
    /// locks through here: a long-lived engine keeps this pool's state
    /// across many flushes, and one thread panicking while it holds the
    /// guard (a failed assert in a master wait, an unwinding worker in
    /// close/finish) must not turn every later lock into an opaque
    /// poison panic - the pool's `failed` flag and panic notes are the
    /// error channel, not the mutex.
    fn lock_recover(&self) -> std::sync::MutexGuard<'_, StageQueue<J>> {
        lock_unpoisoned(&self.shared)
    }

    /// Recoverable mode: record a panic caught while processing an item
    /// of round `uid`, keyed by the round's lane so the master can map it
    /// back to a claim. Called while the item hold is still live, so the
    /// round is guaranteed to still be queued.
    fn note_panic(&self, uid: usize, msg: String) {
        let mut g = self.lock_recover();
        let lane = g
            .rounds
            .iter()
            .find(|r| r.uid == uid)
            .map(|r| r.lane)
            .expect("note_panic: round already retired");
        g.panics.push((lane, msg));
    }

    /// Recoverable mode: drain the first recorded panic of `lane`, if
    /// any. The master calls this at its per-lane resolve point (after
    /// [`wait_lane`](Self::wait_lane), so every round of the lane has
    /// retired and any panic it suffered is visible) and turns a `Some`
    /// into that lane's claim failure.
    pub fn take_lane_panic(&self, lane: u64) -> Option<String> {
        let mut g = self.lock_recover();
        let i = g.panics.iter().position(|(l, _)| *l == lane)?;
        Some(g.panics.remove(i).1)
    }

    /// Mark the pool closed and wake every worker; workers exit once the
    /// queued rounds are drained.
    fn close(&self) {
        let mut g = self.lock_recover();
        g.closed = true;
        drop(g);
        self.cv_work.notify_all();
    }

    /// Mark the pool failed (a worker is unwinding: its round may never
    /// complete) and wake everyone - the master's blocking entry points
    /// panic instead of waiting on a round that cannot retire, and idle
    /// workers exit.
    fn fail(&self) {
        let mut g = self.lock_recover();
        g.failed = true;
        drop(g);
        self.cv_space.notify_all();
        self.cv_work.notify_all();
    }

    /// Remove round `i` from the queue and run the retire callback. The
    /// callback and the job's destruction run under the lock, BEFORE the
    /// removal becomes observable through the blocking entry points: a
    /// master woken by `wait`/`wait_lane` may immediately assert
    /// uniqueness of state the job still references (the drains'
    /// Arc::get_mut resolve), so the job must be gone by then. Keep
    /// callbacks light (one atomic add). Callers notify both condvars
    /// after dropping the lock.
    fn retire_at(
        g: &mut std::sync::MutexGuard<'_, StageQueue<J>>,
        i: usize,
        retire: &(impl Fn(&J, f64) + Sync),
    ) {
        let r = g.rounds.remove(i).expect("retire with no round");
        let wall = r.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        retire(&r.job, wall);
        drop(r);
        g.retired += 1;
    }

    /// Take one item off the oldest *eligible* round - a round is
    /// eligible when it is its lane's front (per-lane FIFO) - retiring
    /// exhausted eligible rounds along the way. Returns a raw pointer to
    /// the round's job, the item index, and the round's uid; or `None`
    /// once the pool is closed and drained.
    ///
    /// The pointer stays valid until the matching [`finish`]: the job is
    /// boxed (heap address stable while the queue mutates) and a round is
    /// only removed once `active == 0`, i.e. when no item pointer is
    /// live.
    fn take(
        &self,
        retire: &(impl Fn(&J, f64) + Sync),
    ) -> Option<(*const J, usize, usize)> {
        let mut g = self.lock_recover();
        loop {
            if g.failed {
                // a sibling worker is unwinding: results are no longer
                // trustworthy, stop drawing work
                return None;
            }
            // scan in uid order for the first lane-front round with an
            // item to hand out, or with nothing left at all (retire it
            // and rescan); a lane whose front round is exhausted but
            // still processing is blocked, later lanes may proceed. The
            // lane-front test rescans the prefix instead of keeping a
            // seen-set: the queue is capacity-bounded (a handful of
            // rounds), so O(n²) beats allocating under the hot mutex.
            let mut take_idx = None;
            let mut retire_idx = None;
            'scan: for (i, r) in g.rounds.iter().enumerate() {
                for earlier in g.rounds.iter().take(i) {
                    if earlier.lane == r.lane {
                        continue 'scan; // not the lane's front round
                    }
                }
                if r.next < r.len {
                    take_idx = Some(i);
                    break;
                }
                if r.active == 0 {
                    retire_idx = Some(i);
                    break;
                }
            }
            if let Some(i) = take_idx {
                let r = &mut g.rounds[i];
                if r.started.is_none() {
                    r.started = Some(Instant::now());
                }
                let item = r.next;
                r.next += 1;
                r.active += 1;
                return Some((&*r.job as *const J, item, r.uid));
            }
            if let Some(i) = retire_idx {
                Self::retire_at(&mut g, i, retire);
                drop(g);
                self.cv_space.notify_all();
                self.cv_work.notify_all();
                g = self.lock_recover();
                continue;
            }
            if g.closed && g.rounds.is_empty() {
                return None;
            }
            g = wait_unpoisoned(&self.cv_work, g);
        }
    }

    /// Release one item hold on round `uid` (still queued: a round is
    /// only removed once no item is live). When this was the round's last
    /// item, retire it HERE rather than in the next `take`: this may be
    /// the last live worker (the others exited - or this one is unwinding
    /// and will never take again), and a round nobody retires would
    /// deadlock the master.
    fn finish(&self, uid: usize, retire: &(impl Fn(&J, f64) + Sync)) {
        let mut g = self.lock_recover();
        let i = g
            .rounds
            .iter()
            .position(|r| r.uid == uid)
            .expect("finish: round already retired");
        let r = &mut g.rounds[i];
        debug_assert!(r.active > 0, "finish without a taken item");
        r.active -= 1;
        if r.active == 0 && r.next >= r.len {
            Self::retire_at(&mut g, i, retire);
            drop(g);
            self.cv_space.notify_all();
            self.cv_work.notify_all();
        }
    }
}

/// Run a producing master thread next to a persistent pool of `workers`
/// stateful stage workers (see [`StageHandle`]).
///
/// * `init(w)` builds worker `w`'s thread-local state;
/// * `process(&mut state, &job, item)` handles one item of a round -
///   items of one round fan out across workers, rounds of one *lane* run
///   strictly in submission order, rounds of different lanes may overlap
///   and retire out of order;
/// * `retire(&job, wall_secs)` runs once per round when its last item
///   completes, with the round's processing wall time (first take to
///   retirement) - the filter-time telemetry hook;
/// * `fini(state)` converts each worker's state into its result;
/// * `master(&handle)` runs on the calling thread and drives the pool.
///
/// Returns the master's result and the worker results in worker order.
/// Rounds still queued when the master returns are drained before the
/// workers exit.
pub fn stage_scope<J, S, W, T, I, P, R, G, M>(
    workers: usize,
    capacity: usize,
    init: I,
    process: P,
    retire: R,
    fini: G,
    master: M,
) -> (T, Vec<W>)
where
    J: Send + Sync,
    W: Send,
    I: Fn(usize) -> S + Sync,
    P: Fn(&mut S, &J, usize) + Sync,
    R: Fn(&J, f64) + Sync,
    G: Fn(S) -> W + Sync,
    M: FnOnce(&StageHandle<J>) -> T,
{
    stage_scope_impl(workers, capacity, false, init, process, retire, fini, master)
}

/// [`stage_scope`] in *recoverable* mode: a panic inside `process` is
/// caught (`catch_unwind`) instead of failing the pool. The item still
/// counts as finished (the round retires normally, nothing deadlocks),
/// the worker keeps drawing work, and the panic is recorded against the
/// round's lane for the master to drain with
/// [`StageHandle::take_lane_panic`] at its per-lane resolve point - the
/// GPU drains turn it into that lane's claim failure instead of an
/// aborted run. Any state the panicking item half-wrote (worker-local or
/// in the round's job) is only reachable through the lane's claim, which
/// the caller must discard once it sees the panic.
///
/// Panics in `init`, `retire`, `fini` and the master are NOT caught -
/// those are harness bugs, not claim-scoped work.
#[allow(clippy::too_many_arguments)]
pub fn stage_scope_recoverable<J, S, W, T, I, P, R, G, M>(
    workers: usize,
    capacity: usize,
    init: I,
    process: P,
    retire: R,
    fini: G,
    master: M,
) -> (T, Vec<W>)
where
    J: Send + Sync,
    W: Send,
    I: Fn(usize) -> S + Sync,
    P: Fn(&mut S, &J, usize) + Sync,
    R: Fn(&J, f64) + Sync,
    G: Fn(S) -> W + Sync,
    M: FnOnce(&StageHandle<J>) -> T,
{
    stage_scope_impl(workers, capacity, true, init, process, retire, fini, master)
}

#[allow(clippy::too_many_arguments)]
fn stage_scope_impl<J, S, W, T, I, P, R, G, M>(
    workers: usize,
    capacity: usize,
    recover: bool,
    init: I,
    process: P,
    retire: R,
    fini: G,
    master: M,
) -> (T, Vec<W>)
where
    // Sync because items of one round fan out across workers: several
    // threads hold `&J` at once (through the pool's raw pointer).
    J: Send + Sync,
    W: Send,
    I: Fn(usize) -> S + Sync,
    P: Fn(&mut S, &J, usize) + Sync,
    R: Fn(&J, f64) + Sync,
    G: Fn(S) -> W + Sync,
    M: FnOnce(&StageHandle<J>) -> T,
{
    let workers = workers.max(1);
    let handle = StageHandle::new(capacity);
    std::thread::scope(|scope| {
        let joins: Vec<_> = (0..workers)
            .map(|w| {
                let (handle, init, process, retire, fini) =
                    (&handle, &init, &process, &retire, &fini);
                scope.spawn(move || {
                    /// Drops the item hold (and retires the round when it
                    /// was the last item) even when `process` unwinds; an
                    /// unwinding worker additionally fails the pool, so a
                    /// round it leaves incomplete cannot strand the master
                    /// - the panic propagates instead of deadlocking.
                    struct FinishGuard<'a, J: Send, R: Fn(&J, f64) + Sync>(
                        &'a StageHandle<J>,
                        &'a R,
                        usize,
                    );
                    impl<J: Send, R: Fn(&J, f64) + Sync> Drop
                        for FinishGuard<'_, J, R>
                    {
                        fn drop(&mut self) {
                            self.0.finish(self.2, self.1);
                            if std::thread::panicking() {
                                self.0.fail();
                            }
                        }
                    }
                    let mut state = init(w);
                    while let Some((job, item, uid)) = handle.take(retire) {
                        let _fin = FinishGuard(handle, retire, uid);
                        // SAFETY: `take` hands out a pointer that stays
                        // valid until the matching `finish` (see `take`).
                        if recover {
                            // note_panic runs while `_fin` still holds the
                            // item, so the round (and its lane) is still
                            // queued; `_fin` then finishes the item on a
                            // non-panicking thread - the pool stays alive.
                            let r = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| {
                                    process(&mut state, unsafe { &*job }, item)
                                }),
                            );
                            if let Err(e) = r {
                                handle.note_panic(
                                    uid,
                                    crate::fault::panic_message(e.as_ref()),
                                );
                            }
                        } else {
                            process(&mut state, unsafe { &*job }, item);
                        }
                    }
                    fini(state)
                })
            })
            .collect();
        let out = {
            /// Closes the pool even when `master` unwinds, so the workers
            /// drain and exit and the scope can propagate the panic
            /// instead of deadlocking on the join.
            struct CloseGuard<'a, J: Send>(&'a StageHandle<J>);
            impl<J: Send> Drop for CloseGuard<'_, J> {
                fn drop(&mut self) {
                    self.0.close();
                }
            }
            let _close = CloseGuard(&handle);
            master(&handle)
        };
        let worker_out = joins
            .into_iter()
            .map(|h| h.join().expect("stage worker panicked"))
            .collect();
        (out, worker_out)
    })
}

/// Lock-free two-ended claim cursor over indices [0, n): front claims
/// grow a `head` cursor, back claims grow a `taken_back` count, and a
/// claim succeeds only when the two regions would stay disjoint - both
/// cursors live in one packed `AtomicU64`, so a single CAS decides every
/// claim and no interleaving can hand out an index twice. An optional
/// *back reserve* keeps the front out of the last `reserve` indices (the
/// ρ floor of the hybrid join: that tail belongs to the CPU no matter
/// what), while back claims may freely eat into front territory - that is
/// exactly how a mispredicted split self-corrects.
///
/// Indices must fit in u32 (the query-id width of the whole repo).
#[derive(Debug)]
pub struct TwoEndedCursor {
    /// (head << 32) | taken_back
    state: AtomicU64,
    n: usize,
    /// front claims never reach at or beyond this index
    front_limit: usize,
}

impl TwoEndedCursor {
    /// Cursor over [0, n) with the last `back_reserve` indices claimable
    /// only from the back.
    pub fn new(n: usize, back_reserve: usize) -> Self {
        assert!(n <= u32::MAX as usize, "range {n} exceeds u32 index space");
        TwoEndedCursor {
            state: AtomicU64::new(0),
            n,
            front_limit: n - back_reserve.min(n),
        }
    }

    #[inline]
    fn unpack(s: u64) -> (usize, usize) {
        ((s >> 32) as usize, (s & u32::MAX as u64) as usize)
    }

    #[inline]
    fn pack(head: usize, back: usize) -> u64 {
        ((head as u64) << 32) | back as u64
    }

    /// Claim from the front with a caller-chosen size: `f` receives the
    /// current head position and the indices available to the front
    /// (respecting the back reserve, `pos_cap`, and the advancing back
    /// region) and returns how many to take (clamped; 0 gives up).
    /// The closure may run several times under CAS contention.
    pub fn claim_front_with(
        &self,
        pos_cap: usize,
        f: impl Fn(usize, usize) -> usize,
    ) -> Option<Range<usize>> {
        loop {
            let s = self.state.load(Ordering::Acquire);
            let (head, back) = Self::unpack(s);
            let limit = self.front_limit.min(pos_cap).min(self.n - back);
            if head >= limit {
                return None;
            }
            let take = f(head, limit - head).min(limit - head);
            if take == 0 {
                return None;
            }
            let ns = Self::pack(head + take, back);
            if self
                .state
                .compare_exchange_weak(s, ns, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return Some(head..head + take);
            }
        }
    }

    /// Claim up to `max` indices from the front.
    pub fn claim_front(&self, max: usize) -> Option<Range<usize>> {
        self.claim_front_with(self.n, |_, avail| avail.min(max.max(1)))
    }

    /// Claim up to `chunk` indices from the back (the range closest to the
    /// end that is still unclaimed).
    pub fn claim_back(&self, chunk: usize) -> Option<Range<usize>> {
        let chunk = chunk.max(1);
        loop {
            let s = self.state.load(Ordering::Acquire);
            let (head, back) = Self::unpack(s);
            let avail = self.n - back - head;
            if avail == 0 {
                return None;
            }
            let take = chunk.min(avail);
            let ns = Self::pack(head, back + take);
            if self
                .state
                .compare_exchange_weak(s, ns, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                let end = self.n - back;
                return Some(end - take..end);
            }
        }
    }

    /// Total indices handed out from the front so far.
    pub fn claimed_front(&self) -> usize {
        Self::unpack(self.state.load(Ordering::Acquire)).0
    }

    /// Total indices handed out from the back so far.
    pub fn claimed_back(&self) -> usize {
        Self::unpack(self.state.load(Ordering::Acquire)).1
    }

    /// Unclaimed indices between the two fronts.
    pub fn remaining(&self) -> usize {
        let (head, back) = Self::unpack(self.state.load(Ordering::Acquire));
        self.n - back - head
    }

    /// Size of the index range the cursor covers.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when the cursor covers an empty range.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// First index the front may never reach (n - back reserve).
    pub fn front_limit(&self) -> usize {
        self.front_limit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_return_in_order() {
        let out = run_ranks(8, |k| k * 10);
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
    }

    #[test]
    fn single_rank_shortcut() {
        assert_eq!(run_ranks(1, |k| k + 1), vec![1]);
    }

    #[test]
    fn chunks_cover_everything_once() {
        let n = 10_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(n, 4, 97, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn chunks_empty_input() {
        parallel_chunks(0, 4, 8, |_| panic!("must not be called"));
    }

    #[test]
    fn stateful_states_partition_the_range() {
        let n = 5_000;
        let per_worker = parallel_chunks_stateful(
            n,
            4,
            64,
            |w| (w, 0usize),
            |state, range| state.1 += range.len(),
            |state| state,
        );
        assert_eq!(per_worker.len(), 4);
        assert_eq!(per_worker.iter().map(|s| s.0).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(per_worker.iter().map(|s| s.1).sum::<usize>(), n);
    }

    #[test]
    fn two_ended_claims_are_disjoint_and_exhaustive() {
        let c = TwoEndedCursor::new(100, 0);
        let f = c.claim_front(30).unwrap();
        assert_eq!(f, 0..30);
        let b = c.claim_back(25).unwrap();
        assert_eq!(b, 75..100);
        let f2 = c.claim_front(100).unwrap();
        assert_eq!(f2, 30..75, "front stops where the back begins");
        assert!(c.claim_front(1).is_none());
        assert!(c.claim_back(1).is_none());
        assert_eq!(c.claimed_front() + c.claimed_back(), 100);
        assert_eq!(c.remaining(), 0);
    }

    #[test]
    fn back_reserve_blocks_front_not_back() {
        let c = TwoEndedCursor::new(10, 4);
        assert_eq!(c.front_limit(), 6);
        let f = c.claim_front(100).unwrap();
        assert_eq!(f, 0..6, "front capped by the reserve");
        assert!(c.claim_front(1).is_none());
        // the back drains the reserve and nothing is lost
        let mut got = 0;
        while let Some(r) = c.claim_back(3) {
            got += r.len();
        }
        assert_eq!(got, 4);
        // full reserve: front gets nothing at all
        let c = TwoEndedCursor::new(5, 5);
        assert!(c.claim_front(1).is_none());
        assert_eq!(c.claim_back(10).unwrap(), 0..5);
    }

    #[test]
    fn front_with_sees_live_position_and_may_decline() {
        let c = TwoEndedCursor::new(50, 0);
        let r = c
            .claim_front_with(50, |head, avail| {
                assert_eq!(head, 0);
                assert_eq!(avail, 50);
                7
            })
            .unwrap();
        assert_eq!(r, 0..7);
        assert!(c.claim_front_with(50, |_, _| 0).is_none());
        // pos_cap bounds the front like a temporary limit
        let r = c.claim_front_with(10, |head, avail| {
            assert_eq!(head, 7);
            assert_eq!(avail, 3);
            avail
        });
        assert_eq!(r.unwrap(), 7..10);
        assert!(c.claim_front_with(10, |_, a| a).is_none());
    }

    #[test]
    fn two_ended_concurrent_partition_exactly_once() {
        let n = 20_000;
        let c = TwoEndedCursor::new(n, 1000);
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        std::thread::scope(|scope| {
            // one front claimant (the GPU-master pattern), variable sizes
            scope.spawn(|| {
                let mut sz = 1usize;
                while let Some(r) = c.claim_front(sz) {
                    for i in r {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                    sz = (sz * 2 + 1) % 700;
                }
            });
            // several back claimants (the CPU-rank pattern)
            for w in 0..4 {
                let (c, hits) = (&c, &hits);
                scope.spawn(move || {
                    while let Some(r) = c.claim_back(17 + w) {
                        for i in r {
                            hits[i].fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(c.claimed_front() + c.claimed_back(), n);
        assert!(c.claimed_back() >= 1000, "reserve honoured");
    }

    #[test]
    fn cursor_empty_range() {
        let c = TwoEndedCursor::new(0, 0);
        assert!(c.claim_front(4).is_none());
        assert!(c.claim_back(4).is_none());
        assert!(c.is_empty());
    }

    #[test]
    fn stage_pool_rounds_run_in_order_exactly_once() {
        // Rounds must be processed strictly in submission order (no item
        // of round r runs before round r-1 retired), every item exactly
        // once, with worker state carried across rounds.
        let (n_rounds, items) = (20usize, 37usize);
        let hits: Vec<AtomicUsize> =
            (0..n_rounds * items).map(|_| AtomicUsize::new(0)).collect();
        let done: Vec<AtomicUsize> =
            (0..n_rounds).map(|_| AtomicUsize::new(0)).collect();
        let ((), states) = stage_scope(
            3,
            2,
            |_w| 0usize,
            |count: &mut usize, job: &(usize, usize), i| {
                let (round, base) = *job;
                if round > 0 {
                    // strict sequencing: the previous round fully retired
                    // before any item of this round was handed out
                    assert_eq!(
                        done[round - 1].load(Ordering::SeqCst),
                        items,
                        "round {round} started before round {} finished",
                        round - 1
                    );
                }
                hits[base + i].fetch_add(1, Ordering::Relaxed);
                *count += 1;
                done[round].fetch_add(1, Ordering::SeqCst);
            },
            |_job, _wall| {},
            |count| count,
            |h| {
                for r in 0..n_rounds {
                    h.submit((r, r * items), items, 0);
                }
                h.drain();
                assert_eq!(h.retired(), n_rounds);
            },
        );
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(states.len(), 3);
        assert_eq!(states.iter().sum::<usize>(), n_rounds * items);
    }

    #[test]
    fn stage_pool_bounded_handoff_blocks_until_retirement() {
        // capacity 1: the second submit must block until the first round
        // has fully retired - the memory bound of the pipelined drain.
        let retired = std::sync::Mutex::new(Vec::new());
        let ((), _) = stage_scope(
            2,
            1,
            |_w| (),
            |_s, _job: &usize, _i| {
                std::thread::sleep(std::time::Duration::from_millis(1));
            },
            |job, wall| {
                assert!(wall >= 0.0);
                retired.lock().unwrap().push(*job);
            },
            |_s| (),
            |h| {
                let e1 = h.submit(1, 3, 0);
                assert_eq!(e1, 1);
                let e2 = h.submit(2, 3, 0);
                assert_eq!(e2, 2);
                // capacity 1: submit(2) waited for round 1 to retire
                assert_eq!(retired.lock().unwrap().as_slice(), &[1]);
                h.wait(e2);
                assert_eq!(retired.lock().unwrap().as_slice(), &[1, 2]);
            },
        );
        assert_eq!(retired.lock().unwrap().as_slice(), &[1, 2]);
    }

    #[test]
    fn stage_pool_worker_panic_fails_fast_instead_of_hanging() {
        // A worker panicking mid-round (untaken items left, no surviving
        // worker) must propagate a panic through the blocked master - a
        // hang here would freeze the whole hybrid join.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            stage_scope(
                1,
                1,
                |_w| (),
                |_s, _job: &(), i| {
                    if i == 0 {
                        panic!("injected filter panic");
                    }
                },
                |_job, _wall| {},
                |_s| (),
                |h| {
                    let e = h.submit((), 3, 0);
                    h.wait(e); // must panic, not hang
                },
            );
        }));
        assert!(result.is_err(), "worker panic must propagate to the caller");
    }

    #[test]
    fn recoverable_stage_pool_surfaces_panics_per_lane() {
        // A worker panic in recoverable mode must NOT abort: the round
        // retires, later rounds still run, and the panic is drained by
        // lane at the master's resolve point.
        let seen = AtomicUsize::new(0);
        let ((), _) = stage_scope_recoverable(
            2,
            4,
            |_w| (),
            |_s, job: &u64, i| {
                if *job == 1 && i == 1 {
                    panic!("injected filter panic (lane {job})");
                }
                seen.fetch_add(1, Ordering::Relaxed);
            },
            |_job, _wall| {},
            |_s| (),
            |h| {
                h.submit(0u64, 3, 0);
                h.submit(1u64, 3, 1);
                h.submit(1u64, 2, 1); // lane 1 keeps running after the panic
                h.wait_lane(0);
                h.wait_lane(1);
                assert!(h.take_lane_panic(0).is_none(), "lane 0 was clean");
                let msg = h.take_lane_panic(1).expect("lane 1 panic recorded");
                assert!(msg.contains("injected filter panic"), "{msg}");
                assert!(h.take_lane_panic(1).is_none(), "drained exactly once");
            },
        );
        // 3 + 3 + 2 items, one of which panicked instead of counting
        assert_eq!(seen.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn recoverable_stage_pool_completes_all_rounds() {
        // every round retires even when several items panic across lanes
        let ((), _) = stage_scope_recoverable(
            3,
            2,
            |_w| (),
            |_s, job: &u64, _i| {
                if *job % 2 == 0 {
                    panic!("boom");
                }
            },
            |_job, _wall| {},
            |_s| (),
            |h| {
                for lane in 0..6u64 {
                    h.submit(lane, 2, lane);
                }
                h.drain();
                assert_eq!(h.retired(), 6);
                for lane in [0u64, 2, 4] {
                    // two panicking items per even lane, drained in order
                    assert!(h.take_lane_panic(lane).is_some());
                    assert!(h.take_lane_panic(lane).is_some());
                    assert!(h.take_lane_panic(lane).is_none());
                }
                for lane in [1u64, 3, 5] {
                    assert!(h.take_lane_panic(lane).is_none());
                }
            },
        );
    }

    #[test]
    fn lock_unpoisoned_recovers_the_value() {
        let m = std::sync::Mutex::new(41);
        let m = &m;
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison it");
        }));
        assert!(m.lock().is_err(), "lock must actually be poisoned");
        *lock_unpoisoned(m) += 1;
        assert_eq!(*lock_unpoisoned(m), 42);
    }

    #[test]
    fn stage_pool_empty_rounds_and_undrained_exit() {
        // empty rounds retire; rounds still queued when the master
        // returns are drained before the workers exit
        let seen = AtomicUsize::new(0);
        let ((), _) = stage_scope(
            2,
            4,
            |_w| (),
            |_s, _job: &(), _i| {
                seen.fetch_add(1, Ordering::Relaxed);
            },
            |_job, _wall| {},
            |_s| (),
            |h| {
                let e = h.submit((), 0, 0); // empty round must still retire
                h.wait(e);
                h.submit((), 5, 0); // master exits without draining
                assert_eq!(h.submitted(), 2);
            },
        );
        assert_eq!(seen.load(Ordering::Relaxed), 5, "undrained round completed");
    }

    #[test]
    fn stage_pool_lanes_retire_out_of_order() {
        // A short round on lane 1 must be able to start, finish and
        // retire while lane 0's older round is still processing - the
        // cross-claim filter parallelism of the three-stage drain - and
        // wait_lane(1) must return while lane 0 is still live.
        use std::sync::atomic::AtomicBool;
        let release = AtomicBool::new(false);
        let lane1_done = AtomicBool::new(false);
        let ((), _) = stage_scope(
            2,
            4,
            |_w| (),
            |_s, job: &u64, _i| match *job {
                0 => {
                    // lane 0: block until the master observed lane 1 retire
                    while !release.load(Ordering::Acquire) {
                        std::thread::yield_now();
                    }
                }
                _ => lane1_done.store(true, Ordering::Release),
            },
            |_job, _wall| {},
            |_s| (),
            |h| {
                h.submit(0u64, 1, 0);
                h.submit(1u64, 1, 1);
                // lane 1 retires although the older lane-0 round is blocked
                h.wait_lane(1);
                assert!(lane1_done.load(Ordering::Acquire));
                assert_eq!(h.retired(), 1);
                // a lane with no rounds is a no-op wait
                h.wait_lane(99);
                release.store(true, Ordering::Release);
                h.wait_lane(0);
                assert_eq!(h.retired(), 2);
            },
        );
    }

    #[test]
    fn stage_pool_per_lane_fifo_with_interleaved_lanes() {
        // Rounds of one lane never start before the lane's previous round
        // fully retired, even with rounds of other lanes interleaved
        // between them; every item runs exactly once.
        let (lanes, per_lane, items) = (3usize, 8usize, 11usize);
        let hits: Vec<AtomicUsize> =
            (0..lanes * per_lane * items).map(|_| AtomicUsize::new(0)).collect();
        let done: Vec<AtomicUsize> =
            (0..lanes * per_lane).map(|_| AtomicUsize::new(0)).collect();
        let ((), _) = stage_scope(
            4,
            6,
            |_w| (),
            |_s, job: &(usize, usize, usize), i| {
                let (lane, seq, base) = *job;
                if seq > 0 {
                    // per-lane strict sequencing: the lane's previous
                    // round fully retired before this round's first item
                    assert_eq!(
                        done[lane * per_lane + seq - 1].load(Ordering::SeqCst),
                        items,
                        "lane {lane} round {seq} started before round {} retired",
                        seq - 1
                    );
                }
                hits[base + i].fetch_add(1, Ordering::Relaxed);
                done[lane * per_lane + seq].fetch_add(1, Ordering::SeqCst);
            },
            |_job, _wall| {},
            |_s| (),
            |h| {
                let mut base = 0usize;
                for seq in 0..per_lane {
                    for lane in 0..lanes {
                        h.submit((lane, seq, base), items, lane as u64);
                        base += items;
                    }
                }
                h.drain();
                assert_eq!(h.retired(), lanes * per_lane);
            },
        );
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn stateful_single_worker_and_tiny_inputs() {
        let out = parallel_chunks_stateful(
            3,
            1,
            100,
            |_| Vec::new(),
            |acc: &mut Vec<usize>, r| acc.extend(r),
            |acc| acc,
        );
        assert_eq!(out, vec![vec![0, 1, 2]]);
        // more workers than items: idle workers still report
        let out = parallel_chunks_stateful(
            2,
            6,
            1,
            |_| 0usize,
            |acc, r| *acc += r.len(),
            |acc| acc,
        );
        assert_eq!(out.len(), 6);
        assert_eq!(out.iter().sum::<usize>(), 2);
    }
}
