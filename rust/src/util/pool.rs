//! Rank-parallel execution helpers (no rayon/tokio in the vendor set).
//!
//! The paper's host-side parallelism is MPI shared-nothing ranks; here a
//! "rank" is an OS thread. `run_ranks` spawns |p| scoped threads and
//! returns each rank's result. `parallel_chunks_stateful` is the dynamic
//! scheduler of the CPU query engine: workers pull fixed-size index
//! chunks off a shared atomic cursor (self-balancing under density skew,
//! unlike static round-robin) while carrying a per-worker state - the
//! reusable `KnnScratch` of EXACT-ANN lives there.
//!
//! `TwoEndedCursor` generalises the single cursor to *two ends* of one
//! index range: one claimant eats from the front, many eat from the back,
//! and the two fronts meet in the middle. This is the claim machinery of
//! the density-ordered work queue (`sched`): the GPU master claims large
//! batches off the dense head while CPU ranks chunk through the sparse
//! tail, so the CPU/GPU split is discovered at run time instead of
//! predicted up front.
//!
//! `stage_scope` is the pipeline variant of `parallel_chunks_stateful`:
//! instead of spawning workers per call, it keeps a *persistent* pool of
//! stateful workers alive next to a producing master thread. The master
//! submits bounded *rounds* of work (the staged flush sets of the
//! pipelined GPU drain) and keeps producing while the workers chew
//! through them strictly in submission order - the hand-off that lets
//! device execution of claim i+1 overlap host filtering of claim i.

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Run `ranks` workers; worker `k` receives its rank id. Results are
/// returned in rank order. Panics propagate.
pub fn run_ranks<T, F>(ranks: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(ranks > 0);
    if ranks == 1 {
        return vec![f(0)];
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..ranks)
            .map(|k| {
                let f = &f;
                scope.spawn(move || f(k))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("rank panicked"))
            .collect()
    })
}

/// Dynamically scheduled chunked map over indices [0, n) with per-worker
/// state: worker `w` builds its state with `init(w)`, then repeatedly
/// claims the next `chunk`-sized index range from a shared atomic cursor
/// and runs `f(&mut state, range)` until the range space is exhausted;
/// `fini(state)` converts the state into the worker's result (e.g. its
/// busy time). Results are returned in worker-id order, one per worker,
/// even for workers that claimed no chunk.
///
/// State stays on its worker thread (no `Send` bound), which is what lets
/// scratch buffers be reused across chunks without synchronisation.
pub fn parallel_chunks_stateful<S, T, I, F, G>(
    n: usize,
    workers: usize,
    chunk: usize,
    init: I,
    f: F,
    fini: G,
) -> Vec<T>
where
    T: Send,
    I: Fn(usize) -> S + Sync,
    F: Fn(&mut S, Range<usize>) + Sync,
    G: Fn(S) -> T + Sync,
{
    let workers = workers.max(1);
    let chunk = chunk.max(1);
    if workers == 1 {
        let mut state = init(0);
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            f(&mut state, start..end);
            start = end;
        }
        return vec![fini(state)];
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let (cursor, init, f, fini) = (&cursor, &init, &f, &fini);
                scope.spawn(move || {
                    let mut state = init(w);
                    loop {
                        let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        f(&mut state, start..(start + chunk).min(n));
                    }
                    fini(state)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    })
}

/// Chunked parallel map over indices [0, n): each worker pulls the next
/// chunk from a shared atomic cursor (simple work stealing). Stateless
/// form of `parallel_chunks_stateful`.
pub fn parallel_chunks<F>(n: usize, workers: usize, chunk: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    parallel_chunks_stateful(n, workers, chunk, |_| (), |(), r| f(r), |()| ());
}

/// One submitted round of a [`stage_scope`] pipeline: a job plus its item
/// count and claim bookkeeping. The job is boxed so its heap address
/// stays stable while the `VecDeque` grows and rounds move - workers hold
/// raw pointers into it between `take` and `finish`.
struct Round<J> {
    /// 1-based submission index; `completed` reports these in order
    epoch: usize,
    job: Box<J>,
    len: usize,
    /// next item to hand out
    next: usize,
    /// items handed out but not yet finished
    active: usize,
    /// set when the first item is taken (round wall-time start)
    started: Option<Instant>,
}

struct StageQueue<J> {
    rounds: VecDeque<Round<J>>,
    /// rounds submitted so far (== the last epoch issued)
    submitted: usize,
    /// highest epoch fully processed; rounds retire strictly in order
    completed: usize,
    closed: bool,
    /// a worker panicked: the front round may never complete, so the
    /// blocking master entry points panic instead of waiting forever
    failed: bool,
}

/// Hand-off between the master thread and the stage workers of a
/// [`stage_scope`] pipeline. The master `submit`s rounds (blocking while
/// `capacity` rounds are already in flight - the bounded hand-off that
/// keeps host memory inside the staging envelope) and `wait`s for their
/// completion; workers drain rounds *strictly in submission order*, so
/// two rounds never run concurrently - the within-round disjointness
/// that makes the filter arena race-free extends across rounds for free.
pub struct StageHandle<J> {
    shared: Mutex<StageQueue<J>>,
    /// master waits here (completions free capacity / advance `wait`)
    cv_space: Condvar,
    /// workers wait here (new rounds / front-round retirement)
    cv_work: Condvar,
    capacity: usize,
}

impl<J: Send> StageHandle<J> {
    fn new(capacity: usize) -> Self {
        StageHandle {
            shared: Mutex::new(StageQueue {
                rounds: VecDeque::new(),
                submitted: 0,
                completed: 0,
                closed: false,
                failed: false,
            }),
            cv_space: Condvar::new(),
            cv_work: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Submit a round of `len` items; blocks while `capacity` rounds are
    /// in flight. Returns the round's epoch (1-based, monotone).
    pub fn submit(&self, job: J, len: usize) -> usize {
        let mut g = self.shared.lock().unwrap();
        while g.rounds.len() >= self.capacity && !g.failed {
            g = self.cv_space.wait(g).unwrap();
        }
        assert!(!g.failed, "stage pool failed: a worker panicked");
        g.submitted += 1;
        let epoch = g.submitted;
        g.rounds.push_back(Round {
            epoch,
            job: Box::new(job),
            len,
            next: 0,
            active: 0,
            started: None,
        });
        drop(g);
        self.cv_work.notify_all();
        epoch
    }

    /// Block until every round up to and including `epoch` has retired.
    pub fn wait(&self, epoch: usize) {
        let mut g = self.shared.lock().unwrap();
        while g.completed < epoch && !g.failed {
            g = self.cv_space.wait(g).unwrap();
        }
        assert!(!g.failed, "stage pool failed: a worker panicked");
    }

    /// Block until every round submitted so far has retired.
    pub fn drain(&self) {
        let mut g = self.shared.lock().unwrap();
        let target = g.submitted;
        while g.completed < target && !g.failed {
            g = self.cv_space.wait(g).unwrap();
        }
        assert!(!g.failed, "stage pool failed: a worker panicked");
    }

    /// Rounds submitted so far.
    pub fn submitted(&self) -> usize {
        self.shared.lock().unwrap().submitted
    }

    /// Rounds fully processed so far.
    pub fn completed(&self) -> usize {
        self.shared.lock().unwrap().completed
    }

    /// Lock, recovering from poisoning - used on the paths that must
    /// still run while another thread is unwinding (close, finish), so
    /// a panic stays a panic instead of becoming a deadlock or abort.
    fn lock_recover(&self) -> std::sync::MutexGuard<'_, StageQueue<J>> {
        match self.shared.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Mark the pool closed and wake every worker; workers exit once the
    /// queued rounds are drained.
    fn close(&self) {
        let mut g = self.lock_recover();
        g.closed = true;
        drop(g);
        self.cv_work.notify_all();
    }

    /// Mark the pool failed (a worker is unwinding: its round may never
    /// complete) and wake everyone - the master's blocking entry points
    /// panic instead of waiting on a round that cannot retire, and idle
    /// workers exit.
    fn fail(&self) {
        let mut g = self.lock_recover();
        g.failed = true;
        drop(g);
        self.cv_space.notify_all();
        self.cv_work.notify_all();
    }

    /// Take one item off the front round, retiring exhausted rounds along
    /// the way. Returns a raw pointer to the round's job plus the item
    /// index, or `None` once the pool is closed and drained.
    ///
    /// The pointer stays valid until the matching [`finish`]: the job is
    /// boxed (heap address stable under queue growth) and a round is only
    /// popped once `active == 0`, i.e. when no item pointer is live.
    fn take(&self, retire: &(impl Fn(&J, f64) + Sync)) -> Option<(*const J, usize)> {
        enum Action<J> {
            Take(*const J, usize),
            Retire,
            Wait,
            Exit,
        }
        let mut g = self.shared.lock().unwrap();
        loop {
            let act: Action<J> = if g.failed {
                // a sibling worker is unwinding: results are no longer
                // trustworthy, stop drawing work
                Action::Exit
            } else if let Some(front) = g.rounds.front_mut() {
                if front.next < front.len {
                    if front.started.is_none() {
                        front.started = Some(Instant::now());
                    }
                    let i = front.next;
                    front.next += 1;
                    front.active += 1;
                    Action::Take(&*front.job as *const J, i)
                } else if front.active == 0 {
                    // exhausted (or empty) round with no live items
                    Action::Retire
                } else {
                    // exhausted but other workers still processing: rounds
                    // run strictly in order, so wait for retirement
                    Action::Wait
                }
            } else if g.closed {
                Action::Exit
            } else {
                Action::Wait
            };
            match act {
                Action::Take(j, i) => return Some((j, i)),
                Action::Exit => return None,
                Action::Retire => {
                    let r = g.rounds.pop_front().expect("retire with no round");
                    let epoch = r.epoch;
                    let wall =
                        r.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
                    // retire + job destruction run under the lock, BEFORE
                    // `completed` is published: a master woken by `wait`
                    // may immediately assert uniqueness of state the job
                    // still references (the drain's Arc::get_mut resolve),
                    // so the job must be gone by the time the epoch is
                    // observable. Keep callbacks light (one atomic add).
                    retire(&r.job, wall);
                    drop(r);
                    g.completed = epoch;
                    drop(g);
                    self.cv_space.notify_all();
                    self.cv_work.notify_all();
                    g = self.shared.lock().unwrap();
                }
                Action::Wait => {
                    g = self.cv_work.wait(g).unwrap();
                }
            }
        }
    }

    /// Release one item hold on the front round (the worker's round is
    /// necessarily still the front: rounds retire in order and ours has a
    /// live item). When this was the round's last item, retire it HERE
    /// rather than in the next `take`: this may be the last live worker
    /// (the others exited - or this one is unwinding and will never take
    /// again), and a round nobody retires would deadlock the master.
    fn finish(&self, retire: &(impl Fn(&J, f64) + Sync)) {
        let mut g = self.lock_recover();
        let front = g.rounds.front_mut().expect("finish with no round");
        debug_assert!(front.active > 0, "finish without a taken item");
        front.active -= 1;
        if front.active == 0 && front.next >= front.len {
            let r = g.rounds.pop_front().expect("retire with no round");
            let epoch = r.epoch;
            let wall = r.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
            // as in `take`: callback + job destruction precede the epoch
            // publish, so a woken master can assert job uniqueness
            retire(&r.job, wall);
            drop(r);
            g.completed = epoch;
            drop(g);
            self.cv_space.notify_all();
            self.cv_work.notify_all();
        }
    }
}

/// Run a producing master thread next to a persistent pool of `workers`
/// stateful stage workers (see [`StageHandle`]).
///
/// * `init(w)` builds worker `w`'s thread-local state;
/// * `process(&mut state, &job, item)` handles one item of a round -
///   items of one round fan out across workers, rounds run strictly in
///   submission order;
/// * `retire(&job, wall_secs)` runs once per round when its last item
///   completes, with the round's processing wall time (first take to
///   retirement) - the filter-time telemetry hook;
/// * `fini(state)` converts each worker's state into its result;
/// * `master(&handle)` runs on the calling thread and drives the pool.
///
/// Returns the master's result and the worker results in worker order.
/// Rounds still queued when the master returns are drained before the
/// workers exit.
pub fn stage_scope<J, S, W, T, I, P, R, G, M>(
    workers: usize,
    capacity: usize,
    init: I,
    process: P,
    retire: R,
    fini: G,
    master: M,
) -> (T, Vec<W>)
where
    J: Send,
    W: Send,
    I: Fn(usize) -> S + Sync,
    P: Fn(&mut S, &J, usize) + Sync,
    R: Fn(&J, f64) + Sync,
    G: Fn(S) -> W + Sync,
    M: FnOnce(&StageHandle<J>) -> T,
{
    let workers = workers.max(1);
    let handle = StageHandle::new(capacity);
    std::thread::scope(|scope| {
        let joins: Vec<_> = (0..workers)
            .map(|w| {
                let (handle, init, process, retire, fini) =
                    (&handle, &init, &process, &retire, &fini);
                scope.spawn(move || {
                    /// Drops the item hold (and retires the round when it
                    /// was the last item) even when `process` unwinds; an
                    /// unwinding worker additionally fails the pool, so a
                    /// round it leaves incomplete cannot strand the master
                    /// - the panic propagates instead of deadlocking.
                    struct FinishGuard<'a, J: Send, R: Fn(&J, f64) + Sync>(
                        &'a StageHandle<J>,
                        &'a R,
                    );
                    impl<J: Send, R: Fn(&J, f64) + Sync> Drop
                        for FinishGuard<'_, J, R>
                    {
                        fn drop(&mut self) {
                            self.0.finish(self.1);
                            if std::thread::panicking() {
                                self.0.fail();
                            }
                        }
                    }
                    let mut state = init(w);
                    while let Some((job, item)) = handle.take(retire) {
                        let _fin = FinishGuard(handle, retire);
                        // SAFETY: `take` hands out a pointer that stays
                        // valid until the matching `finish` (see `take`).
                        process(&mut state, unsafe { &*job }, item);
                    }
                    fini(state)
                })
            })
            .collect();
        let out = {
            /// Closes the pool even when `master` unwinds, so the workers
            /// drain and exit and the scope can propagate the panic
            /// instead of deadlocking on the join.
            struct CloseGuard<'a, J: Send>(&'a StageHandle<J>);
            impl<J: Send> Drop for CloseGuard<'_, J> {
                fn drop(&mut self) {
                    self.0.close();
                }
            }
            let _close = CloseGuard(&handle);
            master(&handle)
        };
        let worker_out = joins
            .into_iter()
            .map(|h| h.join().expect("stage worker panicked"))
            .collect();
        (out, worker_out)
    })
}

/// Lock-free two-ended claim cursor over indices [0, n): front claims
/// grow a `head` cursor, back claims grow a `taken_back` count, and a
/// claim succeeds only when the two regions would stay disjoint - both
/// cursors live in one packed `AtomicU64`, so a single CAS decides every
/// claim and no interleaving can hand out an index twice. An optional
/// *back reserve* keeps the front out of the last `reserve` indices (the
/// ρ floor of the hybrid join: that tail belongs to the CPU no matter
/// what), while back claims may freely eat into front territory - that is
/// exactly how a mispredicted split self-corrects.
///
/// Indices must fit in u32 (the query-id width of the whole repo).
#[derive(Debug)]
pub struct TwoEndedCursor {
    /// (head << 32) | taken_back
    state: AtomicU64,
    n: usize,
    /// front claims never reach at or beyond this index
    front_limit: usize,
}

impl TwoEndedCursor {
    /// Cursor over [0, n) with the last `back_reserve` indices claimable
    /// only from the back.
    pub fn new(n: usize, back_reserve: usize) -> Self {
        assert!(n <= u32::MAX as usize, "range {n} exceeds u32 index space");
        TwoEndedCursor {
            state: AtomicU64::new(0),
            n,
            front_limit: n - back_reserve.min(n),
        }
    }

    #[inline]
    fn unpack(s: u64) -> (usize, usize) {
        ((s >> 32) as usize, (s & u32::MAX as u64) as usize)
    }

    #[inline]
    fn pack(head: usize, back: usize) -> u64 {
        ((head as u64) << 32) | back as u64
    }

    /// Claim from the front with a caller-chosen size: `f` receives the
    /// current head position and the indices available to the front
    /// (respecting the back reserve, `pos_cap`, and the advancing back
    /// region) and returns how many to take (clamped; 0 gives up).
    /// The closure may run several times under CAS contention.
    pub fn claim_front_with(
        &self,
        pos_cap: usize,
        f: impl Fn(usize, usize) -> usize,
    ) -> Option<Range<usize>> {
        loop {
            let s = self.state.load(Ordering::Acquire);
            let (head, back) = Self::unpack(s);
            let limit = self.front_limit.min(pos_cap).min(self.n - back);
            if head >= limit {
                return None;
            }
            let take = f(head, limit - head).min(limit - head);
            if take == 0 {
                return None;
            }
            let ns = Self::pack(head + take, back);
            if self
                .state
                .compare_exchange_weak(s, ns, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return Some(head..head + take);
            }
        }
    }

    /// Claim up to `max` indices from the front.
    pub fn claim_front(&self, max: usize) -> Option<Range<usize>> {
        self.claim_front_with(self.n, |_, avail| avail.min(max.max(1)))
    }

    /// Claim up to `chunk` indices from the back (the range closest to the
    /// end that is still unclaimed).
    pub fn claim_back(&self, chunk: usize) -> Option<Range<usize>> {
        let chunk = chunk.max(1);
        loop {
            let s = self.state.load(Ordering::Acquire);
            let (head, back) = Self::unpack(s);
            let avail = self.n - back - head;
            if avail == 0 {
                return None;
            }
            let take = chunk.min(avail);
            let ns = Self::pack(head, back + take);
            if self
                .state
                .compare_exchange_weak(s, ns, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                let end = self.n - back;
                return Some(end - take..end);
            }
        }
    }

    /// Total indices handed out from the front so far.
    pub fn claimed_front(&self) -> usize {
        Self::unpack(self.state.load(Ordering::Acquire)).0
    }

    /// Total indices handed out from the back so far.
    pub fn claimed_back(&self) -> usize {
        Self::unpack(self.state.load(Ordering::Acquire)).1
    }

    /// Unclaimed indices between the two fronts.
    pub fn remaining(&self) -> usize {
        let (head, back) = Self::unpack(self.state.load(Ordering::Acquire));
        self.n - back - head
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// First index the front may never reach (n - back reserve).
    pub fn front_limit(&self) -> usize {
        self.front_limit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_return_in_order() {
        let out = run_ranks(8, |k| k * 10);
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
    }

    #[test]
    fn single_rank_shortcut() {
        assert_eq!(run_ranks(1, |k| k + 1), vec![1]);
    }

    #[test]
    fn chunks_cover_everything_once() {
        let n = 10_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(n, 4, 97, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn chunks_empty_input() {
        parallel_chunks(0, 4, 8, |_| panic!("must not be called"));
    }

    #[test]
    fn stateful_states_partition_the_range() {
        let n = 5_000;
        let per_worker = parallel_chunks_stateful(
            n,
            4,
            64,
            |w| (w, 0usize),
            |state, range| state.1 += range.len(),
            |state| state,
        );
        assert_eq!(per_worker.len(), 4);
        assert_eq!(per_worker.iter().map(|s| s.0).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(per_worker.iter().map(|s| s.1).sum::<usize>(), n);
    }

    #[test]
    fn two_ended_claims_are_disjoint_and_exhaustive() {
        let c = TwoEndedCursor::new(100, 0);
        let f = c.claim_front(30).unwrap();
        assert_eq!(f, 0..30);
        let b = c.claim_back(25).unwrap();
        assert_eq!(b, 75..100);
        let f2 = c.claim_front(100).unwrap();
        assert_eq!(f2, 30..75, "front stops where the back begins");
        assert!(c.claim_front(1).is_none());
        assert!(c.claim_back(1).is_none());
        assert_eq!(c.claimed_front() + c.claimed_back(), 100);
        assert_eq!(c.remaining(), 0);
    }

    #[test]
    fn back_reserve_blocks_front_not_back() {
        let c = TwoEndedCursor::new(10, 4);
        assert_eq!(c.front_limit(), 6);
        let f = c.claim_front(100).unwrap();
        assert_eq!(f, 0..6, "front capped by the reserve");
        assert!(c.claim_front(1).is_none());
        // the back drains the reserve and nothing is lost
        let mut got = 0;
        while let Some(r) = c.claim_back(3) {
            got += r.len();
        }
        assert_eq!(got, 4);
        // full reserve: front gets nothing at all
        let c = TwoEndedCursor::new(5, 5);
        assert!(c.claim_front(1).is_none());
        assert_eq!(c.claim_back(10).unwrap(), 0..5);
    }

    #[test]
    fn front_with_sees_live_position_and_may_decline() {
        let c = TwoEndedCursor::new(50, 0);
        let r = c
            .claim_front_with(50, |head, avail| {
                assert_eq!(head, 0);
                assert_eq!(avail, 50);
                7
            })
            .unwrap();
        assert_eq!(r, 0..7);
        assert!(c.claim_front_with(50, |_, _| 0).is_none());
        // pos_cap bounds the front like a temporary limit
        let r = c.claim_front_with(10, |head, avail| {
            assert_eq!(head, 7);
            assert_eq!(avail, 3);
            avail
        });
        assert_eq!(r.unwrap(), 7..10);
        assert!(c.claim_front_with(10, |_, a| a).is_none());
    }

    #[test]
    fn two_ended_concurrent_partition_exactly_once() {
        let n = 20_000;
        let c = TwoEndedCursor::new(n, 1000);
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        std::thread::scope(|scope| {
            // one front claimant (the GPU-master pattern), variable sizes
            scope.spawn(|| {
                let mut sz = 1usize;
                while let Some(r) = c.claim_front(sz) {
                    for i in r {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                    sz = (sz * 2 + 1) % 700;
                }
            });
            // several back claimants (the CPU-rank pattern)
            for w in 0..4 {
                let (c, hits) = (&c, &hits);
                scope.spawn(move || {
                    while let Some(r) = c.claim_back(17 + w) {
                        for i in r {
                            hits[i].fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(c.claimed_front() + c.claimed_back(), n);
        assert!(c.claimed_back() >= 1000, "reserve honoured");
    }

    #[test]
    fn cursor_empty_range() {
        let c = TwoEndedCursor::new(0, 0);
        assert!(c.claim_front(4).is_none());
        assert!(c.claim_back(4).is_none());
        assert!(c.is_empty());
    }

    #[test]
    fn stage_pool_rounds_run_in_order_exactly_once() {
        // Rounds must be processed strictly in submission order (no item
        // of round r runs before round r-1 retired), every item exactly
        // once, with worker state carried across rounds.
        let (n_rounds, items) = (20usize, 37usize);
        let hits: Vec<AtomicUsize> =
            (0..n_rounds * items).map(|_| AtomicUsize::new(0)).collect();
        let done: Vec<AtomicUsize> =
            (0..n_rounds).map(|_| AtomicUsize::new(0)).collect();
        let ((), states) = stage_scope(
            3,
            2,
            |_w| 0usize,
            |count: &mut usize, job: &(usize, usize), i| {
                let (round, base) = *job;
                if round > 0 {
                    // strict sequencing: the previous round fully retired
                    // before any item of this round was handed out
                    assert_eq!(
                        done[round - 1].load(Ordering::SeqCst),
                        items,
                        "round {round} started before round {} finished",
                        round - 1
                    );
                }
                hits[base + i].fetch_add(1, Ordering::Relaxed);
                *count += 1;
                done[round].fetch_add(1, Ordering::SeqCst);
            },
            |_job, _wall| {},
            |count| count,
            |h| {
                for r in 0..n_rounds {
                    h.submit((r, r * items), items);
                }
                h.drain();
                assert_eq!(h.completed(), n_rounds);
            },
        );
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(states.len(), 3);
        assert_eq!(states.iter().sum::<usize>(), n_rounds * items);
    }

    #[test]
    fn stage_pool_bounded_handoff_blocks_until_retirement() {
        // capacity 1: the second submit must block until the first round
        // has fully retired - the memory bound of the pipelined drain.
        let retired = std::sync::Mutex::new(Vec::new());
        let ((), _) = stage_scope(
            2,
            1,
            |_w| (),
            |_s, _job: &usize, _i| {
                std::thread::sleep(std::time::Duration::from_millis(1));
            },
            |job, wall| {
                assert!(wall >= 0.0);
                retired.lock().unwrap().push(*job);
            },
            |_s| (),
            |h| {
                let e1 = h.submit(1, 3);
                assert_eq!(e1, 1);
                let e2 = h.submit(2, 3);
                assert_eq!(e2, 2);
                // capacity 1: submit(2) waited for round 1 to retire
                assert_eq!(retired.lock().unwrap().as_slice(), &[1]);
                h.wait(e2);
                assert_eq!(retired.lock().unwrap().as_slice(), &[1, 2]);
            },
        );
        assert_eq!(retired.lock().unwrap().as_slice(), &[1, 2]);
    }

    #[test]
    fn stage_pool_worker_panic_fails_fast_instead_of_hanging() {
        // A worker panicking mid-round (untaken items left, no surviving
        // worker) must propagate a panic through the blocked master - a
        // hang here would freeze the whole hybrid join.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            stage_scope(
                1,
                1,
                |_w| (),
                |_s, _job: &(), i| {
                    if i == 0 {
                        panic!("injected filter panic");
                    }
                },
                |_job, _wall| {},
                |_s| (),
                |h| {
                    let e = h.submit((), 3);
                    h.wait(e); // must panic, not hang
                },
            );
        }));
        assert!(result.is_err(), "worker panic must propagate to the caller");
    }

    #[test]
    fn stage_pool_empty_rounds_and_undrained_exit() {
        // empty rounds retire; rounds still queued when the master
        // returns are drained before the workers exit
        let seen = AtomicUsize::new(0);
        let ((), _) = stage_scope(
            2,
            4,
            |_w| (),
            |_s, _job: &(), _i| {
                seen.fetch_add(1, Ordering::Relaxed);
            },
            |_job, _wall| {},
            |_s| (),
            |h| {
                let e = h.submit((), 0); // empty round must still retire
                h.wait(e);
                h.submit((), 5); // master exits without draining
                assert_eq!(h.submitted(), 2);
            },
        );
        assert_eq!(seen.load(Ordering::Relaxed), 5, "undrained round completed");
    }

    #[test]
    fn stateful_single_worker_and_tiny_inputs() {
        let out = parallel_chunks_stateful(
            3,
            1,
            100,
            |_| Vec::new(),
            |acc: &mut Vec<usize>, r| acc.extend(r),
            |acc| acc,
        );
        assert_eq!(out, vec![vec![0, 1, 2]]);
        // more workers than items: idle workers still report
        let out = parallel_chunks_stateful(
            2,
            6,
            1,
            |_| 0usize,
            |acc, r| *acc += r.len(),
            |acc| acc,
        );
        assert_eq!(out.len(), 6);
        assert_eq!(out.iter().sum::<usize>(), 2);
    }
}
