//! Empirical ε selection (paper Sec. V-C2).
//!
//! 1. Sample pairs of points to estimate the mean pair distance ε^mean.
//! 2. Build n_bins cumulative distance bins of width ε^mean/n_bins and,
//!    for a sample of query points against dataset chunks, count pairs at
//!    or below each edge - executed on the "device" via the `hist`
//!    artifact (the paper's sampling GPU kernels), with a pure-host
//!    fallback used for cross-validation in tests.
//! 3. ε^default = bin centre where the *average cumulative neighbor count
//!    per query* crosses K; ε^β uses the inflated target
//!    K + (100K - K)·β; the final range-query / grid-cell length is
//!    ε = 2·ε^β (circumscribing the ε^β ball in a cell, Fig. 3).

use anyhow::Result;

use crate::core::{sqdist, Dataset};
use crate::runtime::{tiles, Engine};
use crate::util::rng::Rng;

/// Tuning knobs for the estimator (paper defaults are lightweight).
#[derive(Debug, Clone)]
pub struct EpsilonSelector {
    /// histogram bins between 0 and ε^mean
    pub n_bins: usize,
    /// points sampled for the ε^mean pair estimate
    pub mean_sample: usize,
    /// query points sampled for the histogram
    pub hist_queries: usize,
    /// dataset chunks (of artifact CT) scanned per histogram; caps cost on
    /// large datasets while scanning everything on small ones
    pub max_chunks: usize,
    /// sampling seed (selection is deterministic per seed)
    pub seed: u64,
}

impl Default for EpsilonSelector {
    fn default() -> Self {
        EpsilonSelector {
            n_bins: 64,
            mean_sample: 128,
            hist_queries: 128,
            max_chunks: 12,
            seed: 0xE55,
        }
    }
}

/// Outcome of the selection.
#[derive(Debug, Clone)]
pub struct EpsilonSelection {
    /// ε^mean - mean pairwise distance of the sample (Sec. V-C1)
    pub eps_mean: f64,
    /// ε^default - the K-th-neighbor histogram estimate (Sec. V-C1)
    pub eps_default: f64,
    /// ε^β - ε^default inflated toward ε^mean by β (Sec. V-C2)
    pub eps_beta: f64,
    /// final grid/search ε = 2 ε^β
    pub eps: f64,
    /// average cumulative neighbors per query at each bin edge
    pub cum_per_query: Vec<f64>,
    /// bin edges (true distance, ascending)
    pub edges: Vec<f64>,
}

impl EpsilonSelector {
    /// ε^mean from sampled point pairs (host-side: the sample is tiny).
    pub fn estimate_eps_mean(&self, d: &Dataset) -> f64 {
        let mut rng = Rng::new(self.seed ^ 0x3EA);
        let n = d.len();
        if n < 2 {
            return 1.0;
        }
        let ids = rng.sample_indices(n, self.mean_sample.min(n));
        let mut sum = 0f64;
        let mut cnt = 0usize;
        for (a, &i) in ids.iter().enumerate() {
            for &j in ids.iter().skip(a + 1) {
                sum += sqdist(d.point(i), d.point(j)).sqrt();
                cnt += 1;
            }
        }
        let m = if cnt == 0 { 0.0 } else { sum / cnt as f64 };
        // degenerate data (all points identical) has mean distance 0; any
        // positive eps is equivalent there - keep the grid well-formed
        if m > 0.0 {
            m
        } else {
            1.0
        }
    }

    /// Run the selection on the device (hist artifact).
    pub fn select(
        &self,
        engine: &Engine,
        d: &Dataset,
        k: usize,
        beta: f64,
    ) -> Result<EpsilonSelection> {
        self.select_rs(engine, d, d, k, beta)
    }

    /// Bipartite selection: sample queries from R, scan chunks of S
    /// (R = S gives the self-join estimator).
    pub fn select_rs(
        &self,
        engine: &Engine,
        r: &Dataset,
        s: &Dataset,
        k: usize,
        beta: f64,
    ) -> Result<EpsilonSelection> {
        let eps_mean = self.estimate_eps_mean_rs(r, s);
        let edges = self.make_edges(eps_mean);
        let counts = self.device_counts(engine, r, s, &edges)?;
        Ok(self.finish(eps_mean, edges, counts, k, beta))
    }

    /// Cross-relation mean pair distance (sampled).
    pub fn estimate_eps_mean_rs(&self, r: &Dataset, s: &Dataset) -> f64 {
        if std::ptr::eq(r, s) || (r.len() == s.len() && r.raw() == s.raw()) {
            return self.estimate_eps_mean(r);
        }
        let mut rng = Rng::new(self.seed ^ 0x3EA);
        let half = (self.mean_sample / 2).max(1);
        let ri = rng.sample_indices(r.len(), half.min(r.len()));
        let si = rng.sample_indices(s.len(), half.min(s.len()));
        let mut sum = 0f64;
        let mut cnt = 0usize;
        for &i in &ri {
            for &j in &si {
                sum += sqdist(r.point(i), s.point(j)).sqrt();
                cnt += 1;
            }
        }
        let m = if cnt == 0 { 0.0 } else { sum / cnt as f64 };
        if m > 0.0 {
            m
        } else {
            1.0
        }
    }

    /// Pure-host selection (no engine): same estimator, used for tests and
    /// as a reference for the device path.
    pub fn select_host(&self, d: &Dataset, k: usize, beta: f64) -> EpsilonSelection {
        let eps_mean = self.estimate_eps_mean(d);
        let edges = self.make_edges(eps_mean);
        let counts = self.host_counts(d, &edges);
        self.finish(eps_mean, edges, counts, k, beta)
    }

    fn make_edges(&self, eps_mean: f64) -> Vec<f64> {
        let w = eps_mean / self.n_bins as f64;
        (1..=self.n_bins).map(|b| b as f64 * w).collect()
    }

    /// Sampled query ids (shared by both paths).
    fn sample_queries(&self, n: usize) -> Vec<usize> {
        let mut rng = Rng::new(self.seed ^ 0x9015);
        rng.sample_indices(n, self.hist_queries.min(n))
    }

    fn host_counts(&self, d: &Dataset, edges: &[f64]) -> (Vec<f64>, f64) {
        let qs = self.sample_queries(d.len());
        let mut counts = vec![0f64; edges.len()];
        let mut n_q = 0f64;
        for &q in &qs {
            for i in 0..d.len() {
                if i == q {
                    continue;
                }
                let dist = sqdist(d.point(q), d.point(i)).sqrt();
                // cumulative bins: count in every edge >= dist
                for (b, &e) in edges.iter().enumerate() {
                    if dist <= e {
                        counts[b..].iter_mut().for_each(|c| *c += 1.0);
                        let _ = b;
                        break;
                    }
                }
            }
            n_q += 1.0;
        }
        (counts, n_q)
    }

    fn device_counts(
        &self,
        engine: &Engine,
        r: &Dataset,
        d: &Dataset,
        edges: &[f64],
    ) -> Result<(Vec<f64>, f64)> {
        // find the hist artifact for this dimensionality
        let dims = d.dims();
        let mut best: Option<(usize, String)> = None;
        for name in engine.artifact_names() {
            let info = engine.artifact(name).unwrap();
            if info.kind == "hist" {
                let ad = info.param("d");
                if ad >= dims && best.as_ref().map(|(b, _)| ad < *b).unwrap_or(true) {
                    best = Some((ad, name.to_string()));
                }
            }
        }
        let (d_pad, hist_name) = best
            .ok_or_else(|| anyhow::anyhow!("no hist artifact for dims={dims}"))?;
        let info = engine.artifact(&hist_name).unwrap();
        let s = info.param("s");
        let ct = info.param("ct");
        let bins = info.param("bins");
        assert_eq!(bins, edges.len(), "selector n_bins must match artifact");

        // the artifact's query tile caps the device-side sample size
        let mut qs = self.sample_queries(r.len());
        qs.truncate(s);
        let q_ids: Vec<u32> = qs.iter().map(|&i| i as u32).collect();
        let mut q_buf = Vec::new();
        // pad unused query rows with sentinel: their pair distances all
        // overflow the last edge so they contribute nothing.
        tiles::pack(&mut q_buf, r, &q_ids, s, d_pad, crate::runtime::PAD_SENTINEL);

        let edges2: Vec<f32> = edges.iter().map(|e| (e * e) as f32).collect();

        // scan chunks round-robin over the dataset (sampled, like the paper)
        let n_chunks_total = d.len().div_ceil(ct);
        let stride = (n_chunks_total.div_ceil(self.max_chunks)).max(1);
        let mut counts = vec![0f64; bins];
        let mut c_buf = Vec::new();
        let mut chunks_done = 0usize;
        let mut chunk_start = 0usize;
        while chunk_start < d.len() {
            let end = (chunk_start + ct).min(d.len());
            let c_ids: Vec<u32> = (chunk_start as u32..end as u32).collect();
            tiles::pack_candidates(&mut c_buf, d, &c_ids, ct, d_pad);
            let out = engine.exec(
                &hist_name,
                &[
                    (&q_buf, &[s as i64, d_pad as i64]),
                    (&c_buf, &[ct as i64, d_pad as i64]),
                    (&edges2, &[bins as i64]),
                ],
            )?;
            let c = Engine::to_f32(&out[0])?;
            for (acc, x) in counts.iter_mut().zip(c) {
                *acc += x as f64;
            }
            chunks_done += 1;
            chunk_start += ct * stride;
        }
        // scale counts up by the sampled fraction of chunks
        let scale = n_chunks_total as f64 / chunks_done as f64;
        counts.iter_mut().for_each(|c| *c *= scale);
        Ok((counts, qs.len() as f64))
    }

    fn finish(
        &self,
        eps_mean: f64,
        edges: Vec<f64>,
        (counts, n_q): (Vec<f64>, f64),
        k: usize,
        beta: f64,
    ) -> EpsilonSelection {
        let cum_per_query: Vec<f64> =
            counts.iter().map(|c| c / n_q.max(1.0)).collect();
        let w = edges[0];
        let centre = |b: usize| -> f64 {
            // (B_start + B_end)/2 of bin b
            let end = edges[b];
            end - 0.5 * w
        };
        let find = |target: f64| -> f64 {
            for (b, &c) in cum_per_query.iter().enumerate() {
                if c >= target {
                    return centre(b);
                }
            }
            // target beyond the last bin: clamp to the final edge (the
            // paper cuts the histogram off at eps_mean for the same reason)
            *edges.last().unwrap()
        };
        let eps_default = find(k as f64);
        let target_beta = k as f64 + (100.0 * k as f64 - k as f64) * beta;
        let eps_beta = find(target_beta);
        EpsilonSelection {
            eps_mean,
            eps_default,
            eps_beta,
            eps: 2.0 * eps_beta,
            cum_per_query,
            edges,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{chist_like, susy_like};

    #[test]
    fn eps_mean_scales_with_data() {
        let sel = EpsilonSelector::default();
        let d = susy_like(2000).generate(1);
        let m1 = sel.estimate_eps_mean(&d);
        // scale all coordinates 3x -> mean distance 3x
        let scaled = Dataset::new(d.raw().iter().map(|x| x * 3.0).collect(), d.dims());
        let m3 = sel.estimate_eps_mean(&scaled);
        assert!((m3 / m1 - 3.0).abs() < 0.05, "m1={m1} m3={m3}");
    }

    #[test]
    fn host_selection_monotone_in_beta_and_k() {
        let sel = EpsilonSelector::default();
        let d = susy_like(3000).generate(2);
        let s0 = sel.select_host(&d, 5, 0.0);
        let s1 = sel.select_host(&d, 5, 0.5);
        let s2 = sel.select_host(&d, 5, 1.0);
        assert!(s0.eps_beta <= s1.eps_beta + 1e-12);
        assert!(s1.eps_beta <= s2.eps_beta + 1e-12);
        assert!((s0.eps_beta - s0.eps_default).abs() < 1e-12, "beta=0 -> default");
        assert!((s0.eps - 2.0 * s0.eps_beta).abs() < 1e-12);
        let sk = sel.select_host(&d, 20, 0.0);
        assert!(sk.eps_default >= s0.eps_default);
    }

    #[test]
    fn cumulative_curve_monotone() {
        let sel = EpsilonSelector::default();
        let d = chist_like(1500).generate(3);
        let s = sel.select_host(&d, 5, 0.0);
        for w in s.cum_per_query.windows(2) {
            assert!(w[0] <= w[1] + 1e-9);
        }
        assert_eq!(s.cum_per_query.len(), sel.n_bins);
    }

    #[test]
    fn eps_default_finds_about_k_neighbors() {
        // sanity: a range query at eps_default should find >= K neighbors
        // for an "average" point (here: median over a sample)
        let sel = EpsilonSelector::default();
        let k = 8usize;
        let d = susy_like(3000).generate(4);
        let s = sel.select_host(&d, k, 0.0);
        let mut rng = crate::util::rng::Rng::new(7);
        let sample = rng.sample_indices(d.len(), 40);
        let mut counts: Vec<f64> = sample
            .iter()
            .map(|&q| {
                (0..d.len())
                    .filter(|&i| i != q)
                    .filter(|&i| sqdist(d.point(q), d.point(i)) <= s.eps_default * s.eps_default)
                    .count() as f64
            })
            .collect();
        counts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // NOTE: the estimator targets the *mean* cumulative count; on
        // clustered data the median point can still see 0 neighbors at
        // eps_default - that skew is exactly the paper's Fig. 2 failure
        // motivation, so only the mean is asserted here.
        let mean = crate::util::math::mean(&counts);
        assert!(
            mean >= k as f64 * 0.25 && mean <= k as f64 * 40.0,
            "mean neighbors {mean} far from K={k}"
        );
    }

    #[test]
    fn device_matches_host_counts() {
        let engine = Engine::load_default().unwrap();
        let sel = EpsilonSelector {
            max_chunks: usize::MAX, // scan everything: exact comparison
            // match the hist artifact's query-tile size so the host and
            // device paths sample the identical query set
            hist_queries: 64,
            ..EpsilonSelector::default()
        };
        let d = susy_like(2500).generate(5);
        let host = sel.select_host(&d, 5, 0.25);
        let dev = sel.select(&engine, &d, 5, 0.25).unwrap();
        assert!((host.eps_mean - dev.eps_mean).abs() < 1e-9);
        // Device excludes self-pairs only approximately (the matmul
        // formulation can give a tiny nonzero self-distance that lands in
        // bin 1), so every cumulative bin may differ by up to 1 per query;
        // on top of that, pairs exactly at a bin edge may flip bins.
        for (h, g) in host.cum_per_query.iter().zip(&dev.cum_per_query) {
            assert!(
                (h - g).abs() <= 1.0 + 0.05 * (1.0 + h.abs()),
                "host {h} vs device {g}"
            );
        }
        // eps agreement within a couple of bin widths
        let bin_w = host.edges[0];
        assert!(
            (host.eps - dev.eps).abs() <= (2.0 * bin_w).max(0.1 * host.eps),
            "host eps {} vs device eps {}",
            host.eps,
            dev.eps
        );
    }
}
